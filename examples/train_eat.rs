//! End-to-end training driver (deliverable (e2e)): trains the full EAT
//! agent — attention feature extraction + diffusion policy + double-critic
//! SAC, all executing as AOT-compiled HLO through the rust PJRT runtime —
//! on the 8-server environment, logging the learning curve (Fig 5), then
//! evaluates the trained policy against Greedy and Random on identical
//! workloads.
//!
//!     cargo run --release --example train_eat -- [--episodes 6] [--nodes 8]

use eat::config::{Algorithm, ExperimentConfig};
use eat::coordinator::evaluate;
use eat::policy::{GreedyPolicy, RandomPolicy, SacPolicy};
use eat::rl::SacDriver;
use eat::runtime::Runtime;
use eat::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let episodes = args.get_usize("episodes", 6);
    let nodes = args.get_usize("nodes", 8);
    let mut cfg = ExperimentConfig::preset(nodes);
    cfg.algorithm = Algorithm::Eat;
    cfg.seed = args.get_u64("seed", 42);

    let rt = Runtime::new(&cfg.artifacts_dir)?;
    println!(
        "training EAT (attention + diffusion SAC) on {nodes} nodes, {episodes} episodes, \
         batch {}, T {} denoise steps",
        cfg.train.batch_size, cfg.train.denoise_steps
    );
    let mut driver = SacDriver::new(&rt, &cfg)?;
    let t0 = std::time::Instant::now();
    let curve = driver.train_loop(&cfg, episodes, |p| {
        println!(
            "  ep {:>3}  reward {:>8.1}  len {:>4}  actor {:>8.3}  critic {:>7.3}",
            p.episode, p.reward, p.episode_len, p.actor_loss, p.critic_loss
        );
    })?;
    println!(
        "trained {} gradient steps in {:.1}s",
        driver.grad_steps(),
        t0.elapsed().as_secs_f64()
    );
    if curve.len() >= 2 {
        let first = curve.first().unwrap().reward;
        let last = curve.last().unwrap().reward;
        println!("reward: first episode {first:.1} -> last episode {last:.1}");
    }

    // Evaluate the trained policy vs baselines on identical workloads.
    println!("\nevaluating on 3 held-out episodes (common random numbers):");
    let mut eat_policy = SacPolicy::from_driver(driver, false);
    for (name, summary) in [
        ("EAT", evaluate(&cfg, &mut eat_policy, 3)),
        ("Greedy", evaluate(&cfg, &mut GreedyPolicy::new(cfg.env.clone()), 3)),
        ("Random", evaluate(&cfg, &mut RandomPolicy::new(cfg.env.clone(), cfg.seed), 3)),
    ] {
        println!(
            "  {name:<7} quality {:.3}  latency {:>6.1}s  reload {:.3}  efficiency {:.2e}",
            summary.avg_quality,
            summary.avg_response_latency,
            summary.reload_rate,
            summary.efficiency
        );
    }
    Ok(())
}
