//! Quickstart: build an 8-server edge cluster, run one episode with the
//! Greedy baseline and (if `make artifacts` has been run) one with the EAT
//! diffusion policy, and print the QoS metrics the paper optimises.
//!
//!     cargo run --release --example quickstart

use eat::config::{Algorithm, ExperimentConfig};
use eat::coordinator::run_episode;
use eat::policy::{build_policy, GreedyPolicy};
use eat::runtime::Runtime;
use eat::sim::env::EdgeEnv;

fn main() -> anyhow::Result<()> {
    // 1. Configure the paper's 8-node cluster at arrival rate 0.1.
    let cfg = ExperimentConfig::preset_8node(0.1);

    // 2. Run the Greedy baseline (no artifacts needed).
    let mut env = EdgeEnv::new(cfg.env.clone(), cfg.seed);
    let mut greedy = GreedyPolicy::new(cfg.env.clone());
    let report = run_episode(&mut env, &mut greedy, None);
    println!(
        "Greedy : quality {:.3}  response latency {:.1}s  reload rate {:.2}",
        report.avg_quality, report.avg_response_latency, report.reload_rate
    );

    // 3. Run the (untrained) EAT diffusion policy through the PJRT runtime.
    match Runtime::new(&cfg.artifacts_dir) {
        Ok(rt) => {
            let mut eat_cfg = cfg.clone();
            eat_cfg.algorithm = Algorithm::Eat;
            let mut policy = build_policy(&eat_cfg, Some(&rt))?;
            let mut env = EdgeEnv::new(cfg.env.clone(), cfg.seed);
            let report = run_episode(&mut env, policy.as_mut(), None);
            println!(
                "EAT    : quality {:.3}  response latency {:.1}s  reload rate {:.2}  \
                 (untrained weights; see `eat train`)",
                report.avg_quality, report.avg_response_latency, report.reload_rate
            );
        }
        Err(e) => println!("EAT    : skipped ({e}); run `make artifacts` first"),
    }
    Ok(())
}
