//! Compare every scheduling algorithm on one cluster configuration with
//! common random numbers — a compact version of the paper's Tables IX–XI
//! row set, runnable in seconds (heuristics) or minutes (with RL rows).
//!
//!     cargo run --release --example compare_policies -- \
//!         [--nodes 4] [--rate 0.05] [--episodes 3] [--algs greedy,random,...]

use eat::config::{Algorithm, ExperimentConfig};
use eat::coordinator::evaluate;
use eat::experiments::trained_policy;
use eat::runtime::Runtime;
use eat::util::cli::Args;
use eat::util::table::{f, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let nodes = args.get_usize("nodes", 4);
    let rate = args.get_f64("rate", 0.05);
    let episodes = args.get_usize("episodes", 3);
    let train_episodes = args.get_usize("train-episodes", 1);
    let algs: Vec<Algorithm> = match args.get("algs") {
        // Default to the fast heuristic set; add RL names to include them.
        None => vec![
            Algorithm::Greedy,
            Algorithm::Random,
            Algorithm::Harmony,
            Algorithm::Genetic,
        ],
        Some(s) => s
            .split(',')
            .map(|x| Algorithm::parse(x.trim()))
            .collect::<Result<_, _>>()?,
    };
    let needs_rt = algs.iter().any(|a| a.artifact_key().is_some());
    let rt = if needs_rt {
        Some(Runtime::new("artifacts")?)
    } else {
        None
    };

    let mut table = Table::new(
        &format!("Policy comparison ({nodes} nodes, rate {rate}, {episodes} episodes)"),
        &["Algorithm", "Quality", "Latency (s)", "Reload", "Efficiency", "Decision (s)"],
    );
    for alg in algs {
        let mut cfg = ExperimentConfig::preset(nodes);
        cfg.env.arrival_rate = rate;
        cfg.algorithm = alg;
        let mut policy = trained_policy(&cfg, rt.as_ref(), train_episodes, false)?;
        let s = evaluate(&cfg, policy.as_mut(), episodes);
        table.row(vec![
            s.algorithm.clone(),
            f(s.avg_quality, 3),
            f(s.avg_response_latency, 1),
            f(s.reload_rate, 3),
            format!("{:.2e}", s.efficiency),
            format!("{:.2e}", s.decision_latency_s),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}
