//! End-to-end serving driver: spawns four socket-based GPU workers (the
//! paper's container protocol, §VI.A.1), streams a workload of AIGC tasks
//! through the reuse-aware gang scheduler, and reports per-task latency
//! plus throughput / reload-rate totals. This is the full L3 request path:
//! scheduling decisions, JSON over TCP, concurrent gang dispatch,
//! asynchronous result collection.
//!
//!     cargo run --release --example serve_cluster

use eat::config::ExperimentConfig;
use eat::serving::{ServingHost, WorkerPool};
use eat::sim::cluster::{Cluster, Selection};
use eat::sim::quality::QualityModel;
use eat::sim::task::{ModelType, Workload};
use eat::util::rng::Pcg64;
use eat::util::stats::Welford;

fn main() -> anyhow::Result<()> {
    let workers = 4;
    let time_scale = 1e-3; // 1 simulated second sleeps 1 ms
    let mut cfg = ExperimentConfig::preset_4node(0.05).env;
    cfg.tasks_per_episode = 16;

    println!("spawning {workers} socket workers...");
    let pool = WorkerPool::spawn(workers, cfg.exec.clone(), time_scale, 7)?;
    let host = ServingHost::new(pool.addrs().to_vec());
    let quality = QualityModel::new(cfg.quality.clone());
    let mut tracker = Cluster::new(workers);
    let workload = Workload::generate(&cfg, &mut Pcg64::seeded(7));

    let mut lat = Welford::new();
    let mut reloads = 0usize;
    let t0 = std::time::Instant::now();
    for task in &workload.tasks {
        let (gang, reuse) = match tracker.select(ModelType(task.model.0), task.patches) {
            Selection::Reuse(v) => (v, true),
            Selection::Fresh(v) => (v, false),
            Selection::Infeasible => continue,
        };
        // Reuse-aware step choice (the Table II heuristic): cold starts run
        // fewer steps, reused gangs can afford full quality.
        let steps = if reuse { 25 } else { 17 };
        let out = host.dispatch(task.id, "prompt", steps, task.model.0, &gang)?;
        tracker.dispatch(&gang, 0.0, ModelType(task.model.0), reuse, task.arrival);
        let sim_s = out.sim_exec_seconds();
        lat.push(sim_s);
        if out.any_reload() {
            reloads += 1;
        }
        println!(
            "task {:>2}  c={}  gang {:?}  steps {}  exec {:>5.1}s  reload {:>5}  q {:.3}",
            task.id,
            task.patches,
            gang,
            steps,
            sim_s,
            out.any_reload(),
            quality.sample_quality(steps, task.prompt_id),
        );
    }
    println!(
        "\n{} tasks in {:.2}s wall | mean simulated exec {:.1}s (max {:.1}s) | reload rate {:.2}",
        workload.len(),
        t0.elapsed().as_secs_f64(),
        lat.mean(),
        lat.max(),
        reloads as f64 / workload.len() as f64
    );
    pool.shutdown();
    Ok(())
}
