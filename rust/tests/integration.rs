//! Cross-module integration tests: runtime + RL drivers + coordinator +
//! simulator working together, plus property tests over the whole
//! scheduling pipeline. RL cases are skipped when `make artifacts` hasn't
//! run (they print a notice instead of failing).

use eat::config::{Algorithm, ExperimentConfig};
use eat::coordinator::{evaluate, run_episode};
use eat::policy::{build_policy, GreedyPolicy, Policy, RandomPolicy};
use eat::rl::SacDriver;
use eat::runtime::Runtime;
use eat::sim::cluster::Selection;
use eat::sim::env::{Action, EdgeEnv};
use eat::testing::prop;
use eat::util::rng::Pcg64;

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(dir.to_str().unwrap()).unwrap())
}

#[test]
fn full_eval_pipeline_all_heuristics() {
    let cfg = ExperimentConfig::preset_4node(0.05);
    for alg in [Algorithm::Random, Algorithm::Greedy] {
        let mut c = cfg.clone();
        c.algorithm = alg;
        let mut p = build_policy(&c, None).unwrap();
        let s = evaluate(&c, p.as_mut(), 2);
        assert!(s.avg_quality >= 0.0 && s.avg_quality <= 0.272);
        assert!(s.reload_rate >= 0.0 && s.reload_rate <= 1.0);
        assert!(s.avg_response_latency > 0.0);
    }
}

#[test]
fn rl_policy_runs_episode_through_runtime() {
    let Some(rt) = runtime() else { return };
    let mut cfg = ExperimentConfig::preset_8node(0.1);
    cfg.algorithm = Algorithm::Eat;
    cfg.env.tasks_per_episode = 8;
    cfg.env.step_limit = 200;
    cfg.env.time_limit = 200.0;
    let mut policy = build_policy(&cfg, Some(&rt)).unwrap();
    let mut env = EdgeEnv::new(cfg.env.clone(), 9);
    let rep = run_episode(&mut env, policy.as_mut(), None);
    assert!(rep.decision_steps > 0);
}

#[test]
fn short_training_improves_reward_trend() {
    let Some(rt) = runtime() else { return };
    let mut cfg = ExperimentConfig::preset_8node(0.1);
    cfg.algorithm = Algorithm::EatDa; // cheapest variant
    cfg.env.tasks_per_episode = 8;
    cfg.env.step_limit = 120;
    cfg.env.time_limit = 120.0;
    cfg.train.warmup_steps = 32;
    let mut driver = SacDriver::new(&rt, &cfg).unwrap();
    let curve = driver.train_loop(&cfg, 3, |_| {}).unwrap();
    assert_eq!(curve.len(), 3);
    assert!(driver.grad_steps() > 0.0, "updates must have happened");
    for p in &curve {
        assert!(p.reward.is_finite());
    }
}

#[test]
fn gang_constraint_never_violated() {
    // Property: whatever random actions we throw at the env, a scheduled
    // task always gets exactly c_k distinct, previously idle servers.
    prop::check("gang scheduling invariant", 40, |g| {
        let nodes = *g.pick(&[4usize, 8, 12]);
        let mut cfg = ExperimentConfig::preset(nodes).env;
        cfg.tasks_per_episode = 12;
        cfg.step_limit = 300;
        cfg.time_limit = 300.0;
        let seed = g.usize_in(0, 10_000) as u64;
        let mut env = EdgeEnv::new(cfg.clone(), seed);
        let mut rng = Pcg64::new(seed, 77);
        loop {
            let idle_before: Vec<bool> =
                env.cluster.servers.iter().map(|s| s.is_idle()).collect();
            let mut scores = vec![0f32; cfg.queue_window];
            rng.fill_normal_f32(&mut scores);
            let action = Action {
                exec_gate: rng.uniform(-1.0, 1.0) as f32,
                steps_raw: rng.uniform(-1.0, 1.0) as f32,
                task_scores: scores,
            };
            let out = env.step(&action);
            if let Some(sch) = &out.scheduled {
                // Distinct servers.
                let mut ids = sch.servers.clone();
                ids.sort_unstable();
                ids.dedup();
                assert_eq!(ids.len(), sch.servers.len(), "duplicate servers in gang");
                // All were idle at decision time.
                for &id in &sch.servers {
                    assert!(idle_before[id], "scheduled onto busy server {id}");
                }
                // Step bounds (constraint 4d).
                assert!(sch.steps >= cfg.s_min && sch.steps <= cfg.s_max);
            }
            if out.done {
                break;
            }
        }
    });
}

#[test]
fn model_reuse_is_always_sound() {
    // Property: whenever the env reports a reuse, the selected servers all
    // held the task's model before dispatch.
    prop::check("reuse soundness", 30, |g| {
        let mut cfg = ExperimentConfig::preset_8node(0.1).env;
        cfg.num_models = g.usize_in(1, 4);
        cfg.tasks_per_episode = 16;
        cfg.step_limit = 400;
        cfg.time_limit = 400.0;
        let seed = g.usize_in(0, 10_000) as u64;
        let mut env = EdgeEnv::new(cfg.clone(), seed);
        loop {
            let models_before: Vec<_> =
                env.cluster.servers.iter().map(|s| s.model).collect();
            // Greedy-ish action: always try to schedule slot 0.
            let mut scores = vec![-1.0f32; cfg.queue_window];
            scores[0] = 1.0;
            let queue_model = env.queue().front().map(|t| t.model);
            let action = Action {
                exec_gate: -1.0,
                steps_raw: 0.5,
                task_scores: scores,
            };
            let out = env.step(&action);
            if let (Some(sch), Some(model)) = (&out.scheduled, queue_model) {
                if sch.reused_model {
                    for &id in &sch.servers {
                        assert_eq!(
                            models_before[id],
                            Some(model),
                            "reuse claimed but server {id} had {:?}",
                            models_before[id]
                        );
                    }
                }
            }
            if out.done {
                break;
            }
        }
    });
}

#[test]
fn response_latency_accounting_is_consistent() {
    // Property: response = waiting + duration, and the episode average
    // matches the trace.
    prop::check("latency accounting", 20, |g| {
        let cfg = ExperimentConfig::preset_4node(0.05).env;
        let seed = g.usize_in(0, 10_000) as u64;
        let mut env = EdgeEnv::new(cfg.clone(), seed);
        let mut p = GreedyPolicy::new(cfg.clone());
        let rep = run_episode(&mut env, &mut p, None);
        let trace = env.trace();
        assert_eq!(trace.len(), rep.completed_tasks);
        if trace.is_empty() {
            return;
        }
        let mut sum = 0.0;
        for sch in trace {
            assert!((sch.response - (sch.waiting + sch.duration)).abs() < 1e-9);
            sum += sch.response;
        }
        let avg = sum / trace.len() as f64;
        assert!((avg - rep.avg_response_latency).abs() < 1e-6);
    });
}

#[test]
fn common_random_numbers_make_policies_comparable() {
    // Two different policies evaluated via `evaluate` must see identical
    // workloads: the underlying arrivals are a function of (seed, episode)
    // only. We verify by running the SAME policy type twice and a
    // different one in between (which must not perturb the others).
    let cfg = ExperimentConfig::preset_4node(0.05);
    let a1 = evaluate(&cfg, &mut GreedyPolicy::new(cfg.env.clone()), 2);
    let _ = evaluate(&cfg, &mut RandomPolicy::new(cfg.env.clone(), 1), 2);
    let a2 = evaluate(&cfg, &mut GreedyPolicy::new(cfg.env.clone()), 2);
    assert_eq!(a1.avg_response_latency, a2.avg_response_latency);
    assert_eq!(a1.avg_quality, a2.avg_quality);
}

#[test]
fn infeasible_tasks_wait_not_dropped() {
    // Two 8-patch tasks arriving back-to-back: the second is infeasible
    // until the first finishes — it must stay queued, never vanish.
    use eat::sim::task::Workload;
    let mut cfg = ExperimentConfig::preset_8node(0.01).env;
    cfg.tasks_per_episode = 2;
    cfg.patch_choices = vec![8];
    cfg.patch_weights = vec![1.0];
    cfg.num_models = 1;
    let wl = Workload::fixed(&[(0.0, 8, 0), (1.0, 8, 0)]);
    let mut env = EdgeEnv::with_workload(cfg.clone(), wl, Pcg64::seeded(3));
    let mut p = GreedyPolicy::new(cfg.clone());
    let rep = run_episode(&mut env, &mut p, None);
    // Both 8-patch tasks must eventually run (sequentially).
    assert_eq!(rep.completed_tasks, 2);
}

#[test]
fn selection_prefers_reuse_over_fresh_when_available() {
    let mut env = EdgeEnv::new(ExperimentConfig::preset_8node(0.1).env, 4);
    // Manufacture a reusable gang: schedule, let it finish.
    use eat::sim::task::ModelType;
    let ids = vec![0, 1];
    env.cluster.dispatch(&ids, 1.0, ModelType(0), false, 0.0);
    env.cluster.advance(1.0, 1.0);
    match env.cluster.select(ModelType(0), 2) {
        Selection::Reuse(v) => assert_eq!(v, ids),
        other => panic!("expected reuse, got {other:?}"),
    }
}

#[test]
fn every_scenario_family_runs_end_to_end() {
    // The whole stack — scenario config → arrival process + mix →
    // evaluate with CRN seeding → percentile-grade summary — for every
    // preset family and two heuristic policies.
    use eat::workload::WorkloadConfig;
    for name in WorkloadConfig::scenario_names() {
        let mut cfg = ExperimentConfig::preset_4node(0.05);
        cfg.env.tasks_per_episode = 12;
        cfg.env.workload = Some(WorkloadConfig::preset(name, 0.05).unwrap());
        for alg in [Algorithm::Greedy, Algorithm::Random] {
            let mut c = cfg.clone();
            c.algorithm = alg;
            let mut p = build_policy(&c, None).unwrap();
            let s = evaluate(&c, p.as_mut(), 1);
            assert!(
                s.p50_latency <= s.p90_latency && s.p90_latency <= s.p99_latency,
                "{name}/{:?}: unordered percentiles",
                alg
            );
            assert!(s.p99_latency.is_finite(), "{name}: non-finite p99");
            assert!(
                (0.0..=1.0).contains(&s.avg_utilization),
                "{name}: utilization {}",
                s.avg_utilization
            );
        }
    }
}

#[test]
fn trace_file_replay_reproduces_episode_bit_exactly() {
    // Acceptance criterion: a recorded trace replayed through EdgeEnv
    // under the same policy and seed reproduces identical EpisodeReport
    // numbers — across a real file round-trip.
    use eat::workload::{trace, WorkloadConfig};
    let mut cfg = ExperimentConfig::preset_4node(0.05);
    cfg.env.workload = Some(WorkloadConfig::preset("flash", 0.05).unwrap());
    let mut wl_rng = Pcg64::new(cfg.seed, 0xC0FFEE);
    let workload = eat::sim::task::Workload::generate(&cfg.env, &mut wl_rng);

    let dir = std::env::temp_dir().join("eat_integration_trace");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("flash_ep0.jsonl");
    let path = path.to_str().unwrap();
    trace::write_file(&workload, path).unwrap();
    let replayed = trace::read_file(path).unwrap();
    std::fs::remove_file(path).ok();

    let run = |w: eat::sim::task::Workload| {
        let mut env = EdgeEnv::with_workload(cfg.env.clone(), w, Pcg64::new(cfg.seed, 0xE21));
        let mut p = GreedyPolicy::new(cfg.env.clone());
        run_episode(&mut env, &mut p, None)
    };
    let a = run(workload);
    let b = run(replayed);
    assert_eq!(a.completed_tasks, b.completed_tasks);
    assert_eq!(a.total_reward.to_bits(), b.total_reward.to_bits());
    assert_eq!(a.avg_response_latency.to_bits(), b.avg_response_latency.to_bits());
    assert_eq!(a.p50_latency.to_bits(), b.p50_latency.to_bits());
    assert_eq!(a.p90_latency.to_bits(), b.p90_latency.to_bits());
    assert_eq!(a.p99_latency.to_bits(), b.p99_latency.to_bits());
    assert_eq!(a.avg_quality.to_bits(), b.avg_quality.to_bits());
    assert_eq!(a.reloads, b.reloads);
}
