//! Offline subset of the `anyhow` crate.
//!
//! This environment builds with no registry access, so the crate vendors
//! the slice of anyhow's API the codebase actually uses: an opaque
//! [`Error`] holding a message, the [`Result`] alias, a blanket
//! `From<E: std::error::Error>` conversion so `?` works on std errors, and
//! the `anyhow!` / `bail!` / `ensure!` macros. Like upstream, `Error`
//! deliberately does NOT implement `std::error::Error` (that is what makes
//! the blanket `From` impl coherent).

use std::fmt;

/// An error message chain. Only the rendered message is retained.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e.to_string())
    }
}

/// `anyhow::Result<T>` — `Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: `", stringify!($cond), "`"));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(format!("{e}").contains("disk on fire"));
    }

    #[test]
    fn macros_build_messages() {
        let x = 3;
        let e: Error = anyhow!("bad value {x}");
        assert_eq!(format!("{e:?}"), "bad value 3");
        let e: Error = anyhow!("bad {} of {}", "kind", 7);
        assert_eq!(e.to_string(), "bad kind of 7");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "wanted ok, got {ok}");
            Ok(1)
        }
        assert_eq!(f(true).unwrap(), 1);
        assert!(f(false).unwrap_err().to_string().contains("wanted ok"));
        fn g() -> Result<()> {
            bail!("always")
        }
        assert!(g().is_err());
    }
}
