//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate links libxla/PJRT, which is not present in this build
//! environment. This stub mirrors exactly the API surface the `eat` crate
//! uses (`runtime::exec`, `rl::sac`) so everything type-checks and the
//! heuristic / simulator paths run; any attempt to actually create a PJRT
//! client or execute an HLO module returns an [`Error`] explaining that
//! the backend is unavailable. Every RL code path already guards on
//! `artifacts/manifest.json` existing, so tests skip rather than fail.
//!
//! To use real XLA, repoint the `xla` dependency in the workspace
//! Cargo.toml at the upstream bindings — no `eat` source changes needed.

use std::borrow::Borrow;
use std::fmt;

/// Error type; rendered with `{:?}` at every call site in `eat`.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT backend unavailable (offline xla stub; see rust/vendor/xla)"
    ))
}

/// Element types accepted by host↔device transfers.
pub trait ElementType: Copy + 'static {}
impl ElementType for f32 {}
impl ElementType for f64 {}
impl ElementType for i32 {}
impl ElementType for i64 {}
impl ElementType for u32 {}
impl ElementType for u64 {}
impl ElementType for u8 {}

/// Host-side tensor literal (only f32 payloads are used by `eat`).
#[derive(Clone, Debug)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            data: data.to_vec(),
            dims: vec![data.len() as i64],
        }
    }

    /// Reshape without changing element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Decompose a tuple literal. Stub literals are never tuples.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    /// Copy out as a flat host vector.
    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Device-resident buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// PJRT client handle.
#[derive(Clone, Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn buffer_from_host_buffer<T: ElementType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b<B: Borrow<PjRtBuffer>>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// Parsed HLO module text.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips_shape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn backend_calls_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        let err = format!("{:?}", PjRtClient::cpu().unwrap_err());
        assert!(err.contains("stub"));
    }
}
