//! AIGC tasks and the episode workload container.
//!
//! Each task k = (g_k, c_k, t^a_k): a prompt, a collaboration requirement
//! (number of parallel patch workers, c_k ~ D_c over {1,2,4,8}) and an
//! arrival time. Tasks also carry the AIGC service (model) type they need,
//! which drives model-reuse decisions, and an optional per-task quality
//! demand. Generation itself lives in `crate::workload` — arrival
//! processes and task mixes are pluggable there; `Workload::generate`
//! keeps the seed's bit-exact behaviour when no scenario is configured.

use crate::config::EnvConfig;
use crate::util::rng::Pcg64;

/// Identifier of an AIGC model/service type (e.g. a Stable Diffusion
/// checkpoint). `ModelType(0)` is a valid type; "no model loaded" is
/// represented separately on servers.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModelType(pub u32);

/// A user-submitted AIGC task.
#[derive(Clone, Debug)]
pub struct Task {
    pub id: u64,
    /// Prompt identifier (stands in for the text prompt g_k; the quality
    /// model uses it to derive per-prompt jitter deterministically).
    pub prompt_id: u64,
    /// Collaboration requirement c_k: number of servers / patches.
    pub patches: usize,
    /// Required model/service type m_k.
    pub model: ModelType,
    /// Arrival timestamp t^a_k (s).
    pub arrival: f64,
    /// Per-task minimum quality demand; `None` falls back to the
    /// episode-wide `RewardConfig::q_min`.
    pub q_min: Option<f64>,
    /// Index into the episode's tenant registry (`EnvConfig::tenants`);
    /// `None` for single-tenant workloads.
    pub tenant: Option<u32>,
    /// Absolute response deadline (arrival + the tenant's latency SLO
    /// budget); drives EDF ordering and SLO-attainment accounting.
    pub deadline: Option<f64>,
}

/// Stream of tasks for one episode, pre-generated from the arrival process
/// so an episode replays identically for every algorithm under test
/// (common-random-numbers variance reduction across algorithms).
#[derive(Clone, Debug)]
pub struct Workload {
    pub tasks: Vec<Task>,
}

impl Workload {
    /// Sample `cfg.tasks_per_episode` tasks. With `cfg.workload = None`
    /// this is the paper's generator — Exp(arrival_rate) inter-arrivals,
    /// uniform D_c and model mix — drawing the exact same RNG sequence as
    /// the seed implementation. With a scenario configured, that
    /// scenario's arrival process and task mix drive generation instead.
    pub fn generate(cfg: &EnvConfig, rng: &mut Pcg64) -> Workload {
        if let Some(tenants) = &cfg.tenants {
            let reg = crate::qos::TenantRegistry::new(tenants);
            return crate::qos::generate_workload(cfg, &reg, cfg.tasks_per_episode, rng);
        }
        let (mut arrival, mix) = crate::workload::build_for_env(cfg);
        crate::workload::generate(arrival.as_mut(), &mix, cfg.tasks_per_episode, rng)
    }

    /// A deterministic workload with fixed arrivals (used by the
    /// motivation-example experiments, Tables II–IV: 4 tasks, 10 s apart).
    /// Arrivals are sorted if given out of order: `absorb_arrivals` walks
    /// a monotone cursor, so an out-of-order task behind the cursor would
    /// silently never arrive.
    pub fn fixed(arrivals: &[(f64, usize, u32)]) -> Workload {
        let mut arrivals = arrivals.to_vec();
        // eat-lint: allow(unwrap, "a NaN arrival time is a caller bug worth a loud panic")
        arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("NaN arrival"));
        let tasks = arrivals
            .iter()
            .enumerate()
            .map(|(i, &(t, patches, model))| Task {
                id: i as u64,
                prompt_id: i as u64,
                patches,
                model: ModelType(model),
                arrival: t,
                q_min: None,
                tenant: None,
                deadline: None,
            })
            .collect();
        Workload { tasks }
    }

    /// Wrap explicit tasks (trace replay), normalising arrival order with
    /// a stable sort when needed.
    pub fn from_tasks(mut tasks: Vec<Task>) -> Workload {
        let sorted = tasks.windows(2).all(|w| w[0].arrival <= w[1].arrival);
        if !sorted {
            // eat-lint: allow(unwrap, "a NaN arrival time is a caller bug worth a loud panic")
            tasks.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).expect("NaN arrival"));
        }
        Workload { tasks }
    }

    /// True when arrivals are non-decreasing (the invariant the
    /// environment's arrival cursor relies on).
    pub fn is_sorted(&self) -> bool {
        self.tasks.windows(2).all(|w| w[0].arrival <= w[1].arrival)
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnvConfig;
    use crate::workload::WorkloadConfig;

    #[test]
    fn arrivals_increase_and_patches_valid() {
        let cfg = EnvConfig::default();
        let mut rng = Pcg64::seeded(9);
        let w = Workload::generate(&cfg, &mut rng);
        assert_eq!(w.len(), cfg.tasks_per_episode);
        let mut prev = 0.0;
        for t in &w.tasks {
            assert!(t.arrival >= prev);
            prev = t.arrival;
            assert!(cfg.patch_choices.contains(&t.patches));
            assert!((t.model.0 as usize) < cfg.num_models);
            assert!(t.q_min.is_none());
        }
    }

    #[test]
    fn interarrival_mean_matches_rate() {
        let mut cfg = EnvConfig::default();
        cfg.arrival_rate = 0.1;
        cfg.tasks_per_episode = 20_000;
        let mut rng = Pcg64::seeded(10);
        let w = Workload::generate(&cfg, &mut rng);
        let total = w.tasks.last().unwrap().arrival;
        let mean_gap = total / w.len() as f64;
        assert!((mean_gap - 10.0).abs() < 0.3, "mean gap {mean_gap}");
    }

    #[test]
    fn workloads_replay_identically() {
        let cfg = EnvConfig::default();
        let a = Workload::generate(&cfg, &mut Pcg64::seeded(5));
        let b = Workload::generate(&cfg, &mut Pcg64::seeded(5));
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.patches, y.patches);
            assert_eq!(x.model, y.model);
        }
    }

    #[test]
    fn fixed_workload_layout() {
        let w = Workload::fixed(&[(0.0, 2, 0), (10.0, 2, 0), (20.0, 4, 0), (30.0, 2, 0)]);
        assert_eq!(w.len(), 4);
        assert_eq!(w.tasks[2].patches, 4);
        assert_eq!(w.tasks[3].arrival, 30.0);
    }

    #[test]
    fn fixed_workload_sorts_out_of_order_arrivals() {
        // Out-of-order input used to strand tasks behind the arrival
        // cursor in `absorb_arrivals`; now it is normalised up front.
        let w = Workload::fixed(&[(20.0, 4, 1), (0.0, 2, 0), (10.0, 2, 0)]);
        assert!(w.is_sorted());
        assert_eq!(w.tasks[0].arrival, 0.0);
        assert_eq!(w.tasks[2].arrival, 20.0);
        assert_eq!(w.tasks[2].patches, 4);
        // Ids follow sorted order so they stay unique and stable.
        assert_eq!(w.tasks.iter().map(|t| t.id).collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn from_tasks_sorts_only_when_needed() {
        let sorted = Workload::fixed(&[(0.0, 1, 0), (5.0, 1, 0)]);
        let again = Workload::from_tasks(sorted.tasks.clone());
        assert_eq!(again.tasks[0].id, 0);
        let mut rev = sorted.tasks.clone();
        rev.reverse();
        let fixed = Workload::from_tasks(rev);
        assert!(fixed.is_sorted());
    }

    #[test]
    fn scenario_config_changes_generation() {
        let mut cfg = EnvConfig::default();
        cfg.tasks_per_episode = 256;
        let legacy = Workload::generate(&cfg, &mut Pcg64::seeded(3));
        cfg.workload = Some(WorkloadConfig::preset("bursty", cfg.arrival_rate).unwrap());
        let bursty = Workload::generate(&cfg, &mut Pcg64::seeded(3));
        assert_eq!(bursty.len(), 256);
        assert!(bursty.is_sorted());
        // Same seed, different process → different realisation.
        assert_ne!(
            legacy.tasks.last().unwrap().arrival,
            bursty.tasks.last().unwrap().arrival
        );
    }
}
