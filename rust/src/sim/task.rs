//! AIGC tasks and the stochastic workload generator.
//!
//! Each task k = (g_k, c_k, t^a_k): a prompt, a collaboration requirement
//! (number of parallel patch workers, c_k ~ D_c over {1,2,4,8}) and an
//! arrival time (inter-arrival t^g ~ D_g = Exp(rate)). Tasks also carry the
//! AIGC service (model) type they need, which drives model-reuse decisions.

use crate::config::EnvConfig;
use crate::util::rng::Pcg64;

/// Identifier of an AIGC model/service type (e.g. a Stable Diffusion
/// checkpoint). `ModelType(0)` is a valid type; "no model loaded" is
/// represented separately on servers.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModelType(pub u32);

/// A user-submitted AIGC task.
#[derive(Clone, Debug)]
pub struct Task {
    pub id: u64,
    /// Prompt identifier (stands in for the text prompt g_k; the quality
    /// model uses it to derive per-prompt jitter deterministically).
    pub prompt_id: u64,
    /// Collaboration requirement c_k: number of servers / patches.
    pub patches: usize,
    /// Required model/service type m_k.
    pub model: ModelType,
    /// Arrival timestamp t^a_k (s).
    pub arrival: f64,
}

/// Stream of tasks for one episode, pre-generated from the arrival process
/// so an episode replays identically for every algorithm under test
/// (common-random-numbers variance reduction across algorithms).
#[derive(Clone, Debug)]
pub struct Workload {
    pub tasks: Vec<Task>,
}

impl Workload {
    /// Sample `cfg.tasks_per_episode` tasks with Exp(arrival_rate)
    /// inter-arrivals and D_c patch counts.
    pub fn generate(cfg: &EnvConfig, rng: &mut Pcg64) -> Workload {
        let mut tasks = Vec::with_capacity(cfg.tasks_per_episode);
        let mut t = 0.0;
        for id in 0..cfg.tasks_per_episode as u64 {
            t += rng.exponential(cfg.arrival_rate);
            let patches = cfg.patch_choices[rng.categorical(&cfg.patch_weights)];
            let model = ModelType(rng.next_below(cfg.num_models as u64) as u32);
            tasks.push(Task {
                id,
                prompt_id: rng.next_u64(),
                patches,
                model,
                arrival: t,
            });
        }
        Workload { tasks }
    }

    /// A deterministic workload with fixed arrivals (used by the
    /// motivation-example experiments, Tables II–IV: 4 tasks, 10 s apart).
    pub fn fixed(arrivals: &[(f64, usize, u32)]) -> Workload {
        let tasks = arrivals
            .iter()
            .enumerate()
            .map(|(i, &(t, patches, model))| Task {
                id: i as u64,
                prompt_id: i as u64,
                patches,
                model: ModelType(model),
                arrival: t,
            })
            .collect();
        Workload { tasks }
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnvConfig;

    #[test]
    fn arrivals_increase_and_patches_valid() {
        let cfg = EnvConfig::default();
        let mut rng = Pcg64::seeded(9);
        let w = Workload::generate(&cfg, &mut rng);
        assert_eq!(w.len(), cfg.tasks_per_episode);
        let mut prev = 0.0;
        for t in &w.tasks {
            assert!(t.arrival >= prev);
            prev = t.arrival;
            assert!(cfg.patch_choices.contains(&t.patches));
            assert!((t.model.0 as usize) < cfg.num_models);
        }
    }

    #[test]
    fn interarrival_mean_matches_rate() {
        let mut cfg = EnvConfig::default();
        cfg.arrival_rate = 0.1;
        cfg.tasks_per_episode = 20_000;
        let mut rng = Pcg64::seeded(10);
        let w = Workload::generate(&cfg, &mut rng);
        let total = w.tasks.last().unwrap().arrival;
        let mean_gap = total / w.len() as f64;
        assert!((mean_gap - 10.0).abs() < 0.3, "mean gap {mean_gap}");
    }

    #[test]
    fn workloads_replay_identically() {
        let cfg = EnvConfig::default();
        let a = Workload::generate(&cfg, &mut Pcg64::seeded(5));
        let b = Workload::generate(&cfg, &mut Pcg64::seeded(5));
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.patches, y.patches);
            assert_eq!(x.model, y.model);
        }
    }

    #[test]
    fn fixed_workload_layout() {
        let w = Workload::fixed(&[(0.0, 2, 0), (10.0, 2, 0), (20.0, 4, 0), (30.0, 2, 0)]);
        assert_eq!(w.len(), 4);
        assert_eq!(w.tasks[2].patches, 4);
        assert_eq!(w.tasks[3].arrival, 30.0);
    }
}
