//! CLIP-score proxy: quality as a function of inference steps (Eq. 2).
//!
//! Calibration: the paper's measured (steps → CLIP·w_q) points
//! (17, 0.240), (20, 0.251), (25, 0.270) are exactly collinear
//! (slope 0.00375/step); below ~12 steps CLIP scores collapse quickly
//! (few-step DDIM output is mostly noise), which we model as a power-law
//! drop. The combination reproduces the paper's Table IX orderings:
//! Greedy (s=25) ≈ 0.270, SAC-family (s≈17–19) ≈ 0.26, PPO's fixed
//! step ≈ 0.228, Random (uniform steps) ≈ 0.19.

use crate::config::QualityConfig;
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct QualityModel {
    cfg: QualityConfig,
}

impl QualityModel {
    pub fn new(cfg: QualityConfig) -> Self {
        QualityModel { cfg }
    }

    pub fn cfg(&self) -> &QualityConfig {
        &self.cfg
    }

    /// Deterministic mean quality for a step count.
    pub fn mean_quality(&self, steps: u32) -> f64 {
        let c = &self.cfg;
        let s = steps as f64;
        let q_knee = c.line_q17 + c.slope * (c.knee - 17.0);
        let q = if s >= c.knee {
            c.line_q17 + c.slope * (s - 17.0)
        } else {
            q_knee * (s / c.knee).powf(c.drop_pow)
        };
        q.clamp(0.0, c.q_cap)
    }

    /// Realised quality: mean + per-prompt jitter, deterministic in
    /// (prompt_id, steps) so replays are stable.
    pub fn sample_quality(&self, steps: u32, prompt_id: u64) -> f64 {
        let mut rng = Pcg64::new(prompt_id ^ 0xC11F_5C0E, steps as u64);
        (self.mean_quality(steps) + rng.normal_ms(0.0, self.cfg.noise_sigma))
            .clamp(0.0, self.cfg.q_cap)
    }

    /// Quality penalty I_k (Eq. 3).
    pub fn penalty(&self, quality: f64, q_min: f64, p_quality: f64) -> f64 {
        if quality < q_min {
            p_quality
        } else {
            0.0
        }
    }

    /// Smallest step count whose mean quality meets `q_min` (used by
    /// quality-aware baselines).
    pub fn min_steps_for(&self, q_min: f64, s_min: u32, s_max: u32) -> u32 {
        for s in s_min..=s_max {
            if self.mean_quality(s) >= q_min {
                return s;
            }
        }
        s_max
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QualityConfig;

    fn model() -> QualityModel {
        QualityModel::new(QualityConfig::default())
    }

    #[test]
    fn matches_paper_calibration_points() {
        let m = model();
        assert!((m.mean_quality(17) - 0.240).abs() < 1e-6);
        assert!((m.mean_quality(20) - 0.25125).abs() < 1e-6);
        assert!((m.mean_quality(25) - 0.270).abs() < 1e-6);
    }

    #[test]
    fn monotone_in_steps() {
        let m = model();
        let mut prev = -1.0;
        for s in 1..=25 {
            let q = m.mean_quality(s);
            assert!(q >= prev, "q({s})={q} < {prev}");
            prev = q;
        }
    }

    #[test]
    fn random_uniform_steps_mean_matches_paper() {
        // Table IX Random ≈ 0.186–0.200 across the grid.
        let m = model();
        let mean: f64 = (1..=25).map(|s| m.mean_quality(s)).sum::<f64>() / 25.0;
        assert!((0.17..0.21).contains(&mean), "mean={mean}");
    }

    #[test]
    fn ppo_fixed_step_point() {
        // PPO's constant 0.228 corresponds to a fixed step near 14.
        let m = model();
        let q14 = m.mean_quality(14);
        assert!((q14 - 0.228).abs() < 0.004, "q14={q14}");
    }

    #[test]
    fn sample_deterministic_per_prompt() {
        let m = model();
        assert_eq!(m.sample_quality(20, 7), m.sample_quality(20, 7));
        // Different prompts jitter differently (almost surely).
        assert_ne!(m.sample_quality(20, 7), m.sample_quality(20, 8));
    }

    #[test]
    fn penalty_thresholds() {
        let m = model();
        assert_eq!(m.penalty(0.19, 0.2, 1.0), 1.0);
        assert_eq!(m.penalty(0.21, 0.2, 1.0), 0.0);
    }

    #[test]
    fn min_steps_for_threshold() {
        let m = model();
        let s = m.min_steps_for(0.2, 1, 25);
        assert!(m.mean_quality(s) >= 0.2);
        assert!(s == 1 || m.mean_quality(s - 1) < 0.2);
    }
}
