//! The server cluster E: gang lookup (Eq. 1's G_m groups), idle counting,
//! and the greedy, fragmentation-minimising server selection strategy from
//! §V.B.4 ("Server Selector").
//!
//! Selection and advance used to scan every server on every call; at
//! metro scale (10^5 servers) those O(fleet) walks dominated the step
//! time. The cluster now maintains an incremental index: a busy set
//! (`advance_into` touches only running servers), idle servers bucketed
//! by their selection score and ordered by the (idle_since, id) LRU key,
//! and a (model, gang size) → intact-gang map for O(log) reuse lookup.
//! Every mutation flows through `remove_idx`/`add_idx` around the state
//! change, so the index is always consistent with the scan semantics; in
//! debug builds every selection cross-checks the index against the
//! original full scan (`select_filtered_scan`). An `epoch` counter bumps
//! whenever idle capacity can have *increased* (completion, abort,
//! failure, recovery) so callers can memoise infeasibility verdicts.
//!
//! External code may read `servers` freely but must mutate server state
//! only through cluster methods (`dispatch`, `set_health`, `fail_server`,
//! `recover_server`, `abort_server`, ...) or the index desynchronises.

use std::collections::{BTreeMap, BTreeSet};

use super::server::{GangId, Server};
use super::task::ModelType;

/// Outcome of a server-selection query.
#[derive(Clone, Debug, PartialEq)]
pub enum Selection {
    /// An idle gang with the right model and exact size exists: reuse it
    /// (no initialisation cost).
    Reuse(Vec<usize>),
    /// Enough idle servers exist but the model must be (re)initialised on
    /// them (cold start).
    Fresh(Vec<usize>),
    /// Not enough idle servers: the gang constraint (4b/4c) cannot be met.
    Infeasible,
}

impl Selection {
    pub fn servers(&self) -> Option<&[usize]> {
        match self {
            Selection::Reuse(v) | Selection::Fresh(v) => Some(v),
            Selection::Infeasible => None,
        }
    }

    pub fn is_reuse(&self) -> bool {
        matches!(self, Selection::Reuse(_))
    }
}

/// Index record for one gang instance: which servers carry it and how many
/// of them are currently idle. `members` stays sorted ascending; a gang is
/// *intact* (reusable) iff all `size` original members still point at it
/// and all are idle.
#[derive(Clone, Debug)]
struct GangInfo {
    model: ModelType,
    size: usize,
    members: Vec<usize>,
    idle_count: usize,
}

impl GangInfo {
    fn is_intact(&self) -> bool {
        self.members.len() == self.size && self.idle_count == self.size
    }
}

/// Cluster of edge servers.
#[derive(Clone, Debug)]
pub struct Cluster {
    pub servers: Vec<Server>,
    next_gang: u64,
    /// Bumped whenever idle capacity may have increased; see module docs.
    epoch: u64,
    /// Ids with remaining work (up or down — a down busy server stays
    /// busy, it just makes no progress until recovery or abort).
    busy: BTreeSet<usize>,
    /// Idle servers with no model loaded (selection score 0), keyed by
    /// (idle_since bits, id) — the LRU order `select` sorts by. Times are
    /// non-negative so the IEEE bit pattern is order-isomorphic to f64.
    idle_empty: BTreeSet<(u64, usize)>,
    /// Idle servers holding a model outside an intact gang (score 1).
    idle_broken: BTreeSet<(u64, usize)>,
    /// Idle members of intact (fully idle, complete) gangs (score 2).
    idle_intact: BTreeSet<(u64, usize)>,
    /// Gang id → membership/idleness record.
    gangs: BTreeMap<u64, GangInfo>,
    /// (model, gang size) → intact gang ids, ascending (reuse picks the
    /// lowest id, matching the scan's BTreeMap iteration order).
    reuse: BTreeMap<(u32, usize), BTreeSet<u64>>,
    /// Idle *and up* servers (healthy-mode feasibility count).
    idle_up: usize,
    /// Servers currently down.
    down_count: usize,
    /// Down servers that still hold a model (possible only after a
    /// fault-blind dispatch onto a down server): in that corner the
    /// healthy-scan's intactness differs from the blind index, so
    /// selection falls back to the scan while any such server exists.
    down_loaded: usize,
    /// Reusable scratch for `advance_into` (busy ids of the tick).
    busy_scratch: Vec<usize>,
}

impl Cluster {
    pub fn new(n: usize) -> Self {
        Cluster {
            servers: (0..n).map(Server::new).collect(),
            next_gang: 0,
            epoch: 0,
            busy: BTreeSet::new(),
            idle_empty: (0..n).map(|id| (0.0f64.to_bits(), id)).collect(),
            idle_broken: BTreeSet::new(),
            idle_intact: BTreeSet::new(),
            gangs: BTreeMap::new(),
            reuse: BTreeMap::new(),
            idle_up: n,
            down_count: 0,
            down_loaded: 0,
            busy_scratch: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.servers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.servers.is_empty()
    }

    pub fn idle_count(&self) -> usize {
        let n = self.idle_empty.len() + self.idle_broken.len() + self.idle_intact.len();
        debug_assert_eq!(n, self.servers.iter().filter(|s| s.is_idle()).count());
        n
    }

    /// Monotone counter bumped whenever idle capacity can have increased
    /// (completion, abort, failure, recovery, health flip). An
    /// `Infeasible` verdict for (model, count) stays valid until the
    /// epoch changes — the basis of `EdgeEnv`'s infeasibility memo.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Currently-down server count (0 whenever faults are disabled).
    pub fn down_count(&self) -> usize {
        self.down_count
    }

    /// Ids of servers with remaining work, ascending.
    pub fn busy_ids(&self) -> &BTreeSet<usize> {
        &self.busy
    }

    /// True when no server has remaining work.
    pub fn all_idle(&self) -> bool {
        self.busy.is_empty()
    }

    pub fn fresh_gang_id(&mut self) -> GangId {
        self.next_gang += 1;
        GangId(self.next_gang)
    }

    // ---- incremental index maintenance ---------------------------------

    /// Drop `id` from the index, based on its *current* (pre-mutation)
    /// state. Always paired with an `add_idx` after the mutation.
    fn remove_idx(&mut self, id: usize) {
        let s = &self.servers[id];
        if !s.up {
            self.down_count -= 1;
            if s.model.is_some() {
                self.down_loaded -= 1;
            }
        }
        if !s.is_idle() {
            self.busy.remove(&id);
            return;
        }
        if s.up {
            self.idle_up -= 1;
        }
        let key = (s.idle_since.to_bits(), id);
        match (s.model, s.gang) {
            (None, _) => {
                let had = self.idle_empty.remove(&key);
                debug_assert!(had, "server {id} missing from idle_empty");
            }
            (Some(_), None) => {
                let had = self.idle_broken.remove(&key);
                debug_assert!(had, "server {id} missing from idle_broken");
            }
            (Some(_), Some(g)) => {
                let gid = g.0;
                // eat-lint: allow(unwrap, "index invariant: a server's gang ref always resolves; cross-checked by debug_asserts")
                let gi = self.gangs.get_mut(&gid).expect("gang missing from index");
                let was_intact = gi.is_intact();
                gi.idle_count -= 1;
                if was_intact {
                    // The gang breaks: its other idle members drop from
                    // score 2 to score 1, and it leaves the reuse map.
                    let model = gi.model;
                    let size = gi.size;
                    let members = std::mem::take(&mut gi.members);
                    if let Some(set) = self.reuse.get_mut(&(model.0, size)) {
                        set.remove(&gid);
                        if set.is_empty() {
                            self.reuse.remove(&(model.0, size));
                        }
                    }
                    for &m in &members {
                        if m != id {
                            let mkey = (self.servers[m].idle_since.to_bits(), m);
                            let moved = self.idle_intact.remove(&mkey);
                            debug_assert!(moved, "gang mate {m} not in idle_intact");
                            self.idle_broken.insert(mkey);
                        }
                    }
                    // eat-lint: allow(unwrap, "index invariant: the gang was just looked up above")
                    self.gangs.get_mut(&gid).expect("gang vanished").members = members;
                    let had = self.idle_intact.remove(&key);
                    debug_assert!(had, "server {id} missing from idle_intact");
                } else {
                    let had = self.idle_broken.remove(&key);
                    debug_assert!(had, "server {id} missing from idle_broken");
                }
            }
        }
    }

    /// Insert `id` into the index, based on its *new* (post-mutation)
    /// state.
    fn add_idx(&mut self, id: usize) {
        let s = &self.servers[id];
        if !s.up {
            self.down_count += 1;
            if s.model.is_some() {
                self.down_loaded += 1;
            }
        }
        if !s.is_idle() {
            self.busy.insert(id);
            return;
        }
        if s.up {
            self.idle_up += 1;
        }
        let key = (s.idle_since.to_bits(), id);
        match (s.model, s.gang) {
            (None, _) => {
                self.idle_empty.insert(key);
            }
            (Some(_), None) => {
                self.idle_broken.insert(key);
            }
            (Some(_), Some(g)) => {
                let gid = g.0;
                // eat-lint: allow(unwrap, "index invariant: a server's gang ref always resolves; cross-checked by debug_asserts")
                let gi = self.gangs.get_mut(&gid).expect("gang missing from index");
                gi.idle_count += 1;
                if gi.is_intact() {
                    // Last member came home: promote the whole gang.
                    let model = gi.model;
                    let size = gi.size;
                    let members = std::mem::take(&mut gi.members);
                    for &m in &members {
                        if m != id {
                            let mkey = (self.servers[m].idle_since.to_bits(), m);
                            let moved = self.idle_broken.remove(&mkey);
                            debug_assert!(moved, "gang mate {m} not in idle_broken");
                            self.idle_intact.insert(mkey);
                        }
                    }
                    // eat-lint: allow(unwrap, "index invariant: the gang was just looked up above")
                    self.gangs.get_mut(&gid).expect("gang vanished").members = members;
                    self.reuse.entry((model.0, size)).or_default().insert(gid);
                    self.idle_intact.insert(key);
                } else {
                    self.idle_broken.insert(key);
                }
            }
        }
    }

    /// Forget that `id` belongs to its gang (called between `remove_idx`
    /// and a mutation that clears `gang`: unload, abort, failure). Once a
    /// member detaches the gang can never be intact again, matching the
    /// scan semantics where a gang missing a loaded member never reaches
    /// its full idle count.
    fn detach_gang(&mut self, id: usize) {
        let Some(g) = self.servers[id].gang else {
            return;
        };
        // eat-lint: allow(unwrap, "index invariant: a server's gang ref always resolves; cross-checked by debug_asserts")
        let gi = self.gangs.get_mut(&g.0).expect("gang missing from index");
        gi.members.retain(|&m| m != id);
        if gi.members.is_empty() {
            self.gangs.remove(&g.0);
        }
    }

    // ---- queries -------------------------------------------------------

    /// G^t_m restricted to complete idle gangs: groups of idle servers that
    /// share a gang id, model `m`, and whose full gang (gang_size members)
    /// is idle. Returns (gang id, member server ids) pairs, ascending by
    /// gang id with members ascending — read from the reuse index.
    pub fn idle_gangs(&self, model: ModelType) -> Vec<(GangId, Vec<usize>)> {
        let mut out: Vec<(GangId, Vec<usize>)> = Vec::new();
        for set in self
            .reuse
            .range((model.0, 0)..=(model.0, usize::MAX))
            .map(|(_, set)| set)
        {
            for &gid in set {
                out.push((GangId(gid), self.gangs[&gid].members.clone()));
            }
        }
        out.sort_by_key(|(g, _)| g.0);
        debug_assert_eq!(out, self.idle_gangs_scan(model));
        out
    }

    /// Original full-scan implementation of [`idle_gangs`], kept as the
    /// debug cross-check oracle and for the legacy tick-scan mode.
    pub fn idle_gangs_scan(&self, model: ModelType) -> Vec<(GangId, Vec<usize>)> {
        let mut groups: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
        let mut sizes: BTreeMap<u64, usize> = BTreeMap::new();
        for s in &self.servers {
            if s.is_idle() && s.model == Some(model) {
                if let Some(g) = s.gang {
                    groups.entry(g.0).or_default().push(s.id);
                    sizes.insert(g.0, s.gang_size);
                }
            }
        }
        groups
            .into_iter()
            .filter(|(gid, members)| sizes.get(gid) == Some(&members.len()))
            .map(|(gid, members)| (GangId(gid), members))
            .collect()
    }

    /// §V.B.4 greedy server selection for a task needing `count` servers of
    /// model `model`:
    /// 1. If an idle gang of exactly `count` servers already holds the
    ///    model, reuse it (zero initialisation).
    /// 2. Otherwise pick `count` idle servers minimising "idle group
    ///    fragmentation": prefer empty servers, then members of already
    ///    broken (partially busy) gangs, then break the least-recently-used
    ///    complete idle gang.
    ///
    /// This variant is *fault-blind*: a down server has no remaining work,
    /// so it counts as idle and can be chosen (and the dispatch will be
    /// killed by the fault sweep). Health-aware callers use
    /// [`select_healthy`](Self::select_healthy). With the fault subsystem
    /// disabled every server is up and the two are identical.
    pub fn select(&self, model: ModelType, count: usize) -> Selection {
        self.select_filtered(model, count, false)
    }

    /// [`select`](Self::select) restricted to up servers: down servers are
    /// masked out of fresh placement (reuse needs loaded weights, which a
    /// failed server has already lost, so it is masked implicitly).
    pub fn select_healthy(&self, model: ModelType, count: usize) -> Selection {
        self.select_filtered(model, count, true)
    }

    fn select_filtered(&self, model: ModelType, count: usize, healthy_only: bool) -> Selection {
        let fast = if self.down_loaded == 0 {
            self.select_indexed(model, count, healthy_only)
        } else {
            self.select_filtered_scan(model, count, healthy_only)
        };
        debug_assert_eq!(fast, self.select_filtered_scan(model, count, healthy_only));
        fast
    }

    /// Index-backed selection; exact replay of the scan's outcome.
    fn select_indexed(&self, model: ModelType, count: usize, healthy_only: bool) -> Selection {
        // 1. Exact reuse: lowest intact gang id of this (model, size). The
        //    scan's reuse check precedes its health filter, so reuse is
        //    deliberately not gated on `up` here either (with no model
        //    loaded on any down server — the `down_loaded == 0` fast-path
        //    precondition — an intact gang cannot contain a down member).
        if let Some(set) = self.reuse.get(&(model.0, count)) {
            // eat-lint: allow(unwrap, "index invariant: empty reuse sets are removed eagerly, never left behind")
            let gid = *set.iter().next().expect("empty reuse entry");
            return Selection::Reuse(self.gangs[&gid].members.clone());
        }
        // 2. Feasibility.
        let avail = if healthy_only {
            self.idle_up
        } else {
            self.idle_empty.len() + self.idle_broken.len() + self.idle_intact.len()
        };
        if avail < count {
            return Selection::Infeasible;
        }
        // 3. Fresh placement: empty servers first, then broken-gang ones,
        //    then break an intact gang — each bucket in (idle_since, id)
        //    order, exactly the scan's (score, idle_since, id) sort.
        let mut chosen = Vec::with_capacity(count);
        for &(_, id) in self
            .idle_empty
            .iter()
            .chain(self.idle_broken.iter())
            .chain(self.idle_intact.iter())
        {
            if healthy_only && !self.servers[id].up {
                continue;
            }
            chosen.push(id);
            if chosen.len() == count {
                break;
            }
        }
        debug_assert_eq!(chosen.len(), count);
        Selection::Fresh(chosen)
    }

    /// Original full-scan selection, kept verbatim: the debug cross-check
    /// oracle for [`select_indexed`] and the baseline the `eat bench`
    /// tick-vs-event comparison measures.
    pub fn select_filtered_scan(
        &self,
        model: ModelType,
        count: usize,
        healthy_only: bool,
    ) -> Selection {
        // 1. Exact reuse.
        for (_gid, members) in self.idle_gangs_scan(model) {
            if members.len() == count {
                return Selection::Reuse(members);
            }
        }
        // 2. Fresh placement.
        let idle: Vec<&Server> = self
            .servers
            .iter()
            .filter(|s| s.is_idle() && (!healthy_only || s.up))
            .collect();
        if idle.len() < count {
            return Selection::Infeasible;
        }
        // Completeness of each gang among idle servers: a gang is "intact"
        // if all its members are idle (breaking it destroys a reusable
        // group; avoid if possible).
        let mut idle_by_gang: BTreeMap<u64, usize> = BTreeMap::new();
        for s in &idle {
            if let Some(g) = s.gang {
                *idle_by_gang.entry(g.0).or_default() += 1;
            }
        }
        let mut scored: Vec<(u64, f64, usize)> = idle
            .iter()
            .map(|s| {
                // Lower score = pick first.
                let score: u64 = match (s.model, s.gang) {
                    (None, _) => 0, // empty server: free real estate
                    (Some(_), Some(g)) => {
                        let intact = idle_by_gang.get(&g.0) == Some(&s.gang_size);
                        if intact {
                            2 // breaking an intact gang loses reuse potential
                        } else {
                            1 // gang already broken: cheap to take
                        }
                    }
                    (Some(_), None) => 1,
                };
                (score, s.idle_since, s.id)
            })
            .collect();
        // Tie-break: LRU (oldest idle first), then id for determinism.
        scored.sort_by(|a, b| {
            a.0.cmp(&b.0)
                // eat-lint: allow(unwrap, "scores are sums/min of finite inputs; NaN cannot reach the sort")
                .then(a.1.partial_cmp(&b.1).unwrap())
                .then(a.2.cmp(&b.2))
        });
        let chosen = scored.iter().take(count).map(|x| x.2).collect();
        Selection::Fresh(chosen)
    }

    // ---- mutations -----------------------------------------------------

    /// Dispatch: mark servers busy for `duration`, loading `model` as a new
    /// gang (fresh) or keeping the existing gang (reuse). `now` stamps the
    /// eviction instant on freshly unloaded servers (LRU bookkeeping).
    pub fn dispatch(
        &mut self,
        server_ids: &[usize],
        duration: f64,
        model: ModelType,
        reuse: bool,
        now: f64,
    ) -> GangId {
        let gang = if reuse {
            // eat-lint: allow(unwrap, "reuse selection only returns members of an intact gang")
            self.servers[server_ids[0]].gang.expect("reuse without gang")
        } else {
            let g = self.fresh_gang_id();
            for &id in server_ids {
                self.remove_idx(id);
                self.detach_gang(id);
                self.servers[id].unload(now);
                self.add_idx(id);
            }
            let mut members = server_ids.to_vec();
            members.sort_unstable();
            self.gangs.insert(
                g.0,
                GangInfo {
                    model,
                    size: server_ids.len(),
                    members,
                    idle_count: 0,
                },
            );
            g
        };
        let size = server_ids.len();
        for &id in server_ids {
            self.remove_idx(id);
            self.servers[id].assign(duration, model, gang, size);
            self.add_idx(id);
        }
        gang
    }

    /// Mirror an external per-server health snapshot (e.g. the serving
    /// layer's `HealthRegistry`) into the cluster. A server transitioning
    /// up→down loses its in-flight work and loaded weights (`abort`), so
    /// the reuse path of [`select_healthy`](Self::select_healthy) can
    /// never hand out a gang with a dead member; a recovered server comes
    /// back up weight-cold. Extra snapshot entries are ignored.
    pub fn set_health(&mut self, up: &[bool], now: f64) {
        let n = self.servers.len().min(up.len());
        for (id, &u) in up.iter().enumerate().take(n) {
            if self.servers[id].up == u {
                continue;
            }
            self.remove_idx(id);
            if !u {
                self.detach_gang(id);
                self.servers[id].abort(now);
            }
            self.servers[id].up = u;
            self.add_idx(id);
            self.epoch += 1;
        }
    }

    /// Take `id` down: it loses its in-flight work, loaded weights and any
    /// straggler slowdown (the replacement hardware is nominal). Returns
    /// whether the server was up before the call (for failure accounting —
    /// the fault model may emit redundant Fail events).
    pub fn fail_server(&mut self, id: usize, now: f64) -> bool {
        let was_up = self.servers[id].up;
        self.remove_idx(id);
        self.detach_gang(id);
        let s = &mut self.servers[id];
        s.up = false;
        s.slowdown = 1.0;
        s.abort(now);
        self.add_idx(id);
        self.epoch += 1;
        was_up
    }

    /// Bring `id` back up, weight-cold, with its LRU clock restarted.
    pub fn recover_server(&mut self, id: usize, now: f64) {
        self.remove_idx(id);
        let s = &mut self.servers[id];
        s.up = true;
        s.idle_since = now;
        self.add_idx(id);
        self.epoch += 1;
    }

    /// Straggler on/off: execution speed changes, occupancy does not, so
    /// the index is untouched.
    pub fn set_slowdown(&mut self, id: usize, factor: f64) {
        self.servers[id].slowdown = factor;
    }

    /// Cancel `id`'s in-flight work without signalling completion; the
    /// server goes idle and weight-cold.
    pub fn abort_server(&mut self, id: usize, now: f64) {
        self.remove_idx(id);
        self.detach_gang(id);
        self.servers[id].abort(now);
        self.add_idx(id);
        self.epoch += 1;
    }

    /// Kill an in-flight gang: every member drops its work and goes
    /// weight-cold (the DistriFusion process group is gone and reloading
    /// pays in full). Used for mid-flight failures and speculative losers.
    pub fn abort_gang(&mut self, server_ids: &[usize], now: f64) {
        for &id in server_ids {
            self.abort_server(id, now);
        }
    }

    /// Advance all running servers by dt; pushes ids that completed this
    /// tick into `done` (cleared first), ascending. Touches only the busy
    /// set — O(busy), not O(fleet) — which is bit-exact with the full
    /// scan because `Server::advance` is a no-op on idle servers and the
    /// busy set iterates in the same ascending-id order.
    pub fn advance_into(&mut self, dt: f64, now: f64, done: &mut Vec<usize>) {
        done.clear();
        self.busy_scratch.clear();
        self.busy_scratch.extend(self.busy.iter().copied());
        for i in 0..self.busy_scratch.len() {
            let id = self.busy_scratch[i];
            if self.servers[id].advance(dt, now) {
                done.push(id);
                self.busy.remove(&id);
                self.add_idx(id);
                self.epoch += 1;
            }
        }
        debug_assert_eq!(
            self.busy.len(),
            self.servers.iter().filter(|s| !s.is_idle()).count()
        );
    }

    /// Legacy full-scan advance (every server, every tick): the baseline
    /// for the tick-vs-event benchmark. Identical results to
    /// [`advance_into`](Self::advance_into); still maintains the index.
    pub fn advance_scan_into(&mut self, dt: f64, now: f64, done: &mut Vec<usize>) {
        done.clear();
        for id in 0..self.servers.len() {
            if self.servers[id].advance(dt, now) {
                done.push(id);
                self.busy.remove(&id);
                self.add_idx(id);
                self.epoch += 1;
            }
        }
    }

    /// Advance all servers by dt; returns ids that completed this tick.
    pub fn advance(&mut self, dt: f64, now: f64) -> Vec<usize> {
        let mut done = Vec::new();
        self.advance_into(dt, now, &mut done);
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn busy_all(c: &mut Cluster, dur: f64) {
        let n = c.len();
        let ids: Vec<usize> = (0..n).collect();
        c.dispatch(&ids, dur, ModelType(0), false, 0.0);
    }

    #[test]
    fn reuse_found_for_exact_idle_gang() {
        let mut c = Cluster::new(4);
        // Run a 2-patch task on servers; after completion the gang is idle.
        let sel = c.select(ModelType(1), 2);
        let servers = sel.servers().unwrap().to_vec();
        assert!(!sel.is_reuse());
        c.dispatch(&servers, 5.0, ModelType(1), false, 0.0);
        c.advance(5.0, 5.0);
        let sel2 = c.select(ModelType(1), 2);
        assert!(sel2.is_reuse());
        assert_eq!(sel2.servers().unwrap(), &servers[..]);
    }

    #[test]
    fn no_reuse_for_wrong_size() {
        let mut c = Cluster::new(4);
        let sel = c.select(ModelType(1), 2);
        let servers = sel.servers().unwrap().to_vec();
        c.dispatch(&servers, 5.0, ModelType(1), false, 0.0);
        c.advance(5.0, 5.0);
        // Same model but needs 4 servers: the 2-gang can't be reused as-is.
        let sel2 = c.select(ModelType(1), 4);
        assert!(!sel2.is_reuse());
    }

    #[test]
    fn no_reuse_for_wrong_model() {
        let mut c = Cluster::new(4);
        let servers = c.select(ModelType(1), 2).servers().unwrap().to_vec();
        c.dispatch(&servers, 5.0, ModelType(1), false, 0.0);
        c.advance(5.0, 5.0);
        let sel2 = c.select(ModelType(2), 2);
        assert!(!sel2.is_reuse());
    }

    #[test]
    fn infeasible_when_busy() {
        let mut c = Cluster::new(4);
        busy_all(&mut c, 10.0);
        assert_eq!(c.select(ModelType(0), 1), Selection::Infeasible);
        c.advance(10.0, 10.0);
        assert!(c.select(ModelType(0), 4).servers().is_some());
    }

    #[test]
    fn selection_prefers_empty_then_broken_then_intact() {
        let mut c = Cluster::new(6);
        // Gang A: servers for a 2-patch model-1 task (intact after done).
        let a = c.select(ModelType(1), 2).servers().unwrap().to_vec();
        c.dispatch(&a, 1.0, ModelType(1), false, 0.0);
        c.advance(1.0, 1.0);
        // Gang B: 2-patch model-2, then one member re-occupied → broken.
        let b: Vec<usize> = c
            .servers
            .iter()
            .filter(|s| s.is_idle() && s.model.is_none())
            .take(2)
            .map(|s| s.id)
            .collect();
        c.dispatch(&b, 1.0, ModelType(2), false, 1.0);
        c.advance(1.0, 2.0);
        // Occupy one member of gang B with a fresh 1-patch model-0 task.
        c.dispatch(&[b[0]], 100.0, ModelType(0), false, 2.0);
        // Now: 2 empty servers, 1 broken-gang server (b[1]), 2 intact gang-A
        // servers. A fresh 3-server model-0 task should take the 2 empty +
        // the broken one, leaving gang A intact.
        let sel = c.select(ModelType(0), 3);
        let chosen = sel.servers().unwrap();
        assert!(!chosen.contains(&a[0]) && !chosen.contains(&a[1]), "{chosen:?} broke intact gang {a:?}");
        assert!(chosen.contains(&b[1]));
    }

    #[test]
    fn dispatch_reuse_keeps_gang_id() {
        let mut c = Cluster::new(2);
        let servers = c.select(ModelType(1), 2).servers().unwrap().to_vec();
        let g1 = c.dispatch(&servers, 1.0, ModelType(1), false, 0.0);
        c.advance(1.0, 1.0);
        let sel = c.select(ModelType(1), 2);
        assert!(sel.is_reuse());
        let g2 = c.dispatch(sel.servers().unwrap(), 1.0, ModelType(1), true, 1.0);
        assert_eq!(g1, g2);
    }

    #[test]
    fn select_healthy_masks_down_servers_but_select_stays_blind() {
        let mut c = Cluster::new(4);
        c.set_health(&[false, false, true, true], 0.0);
        // Blind selection still sees 4 "idle" servers.
        assert!(c.select(ModelType(0), 4).servers().is_some());
        // Health-aware selection only has 2 up servers left.
        assert_eq!(c.select_healthy(ModelType(0), 4), Selection::Infeasible);
        let sel = c.select_healthy(ModelType(0), 2);
        assert_eq!(sel.servers().unwrap(), &[2, 3]);
        // A recovered server is selectable again.
        c.set_health(&[true, false, true, true], 0.0);
        assert!(c.select_healthy(ModelType(0), 3).servers().is_some());
    }

    #[test]
    fn set_health_drops_down_servers_weights_and_masks_reuse() {
        let mut c = Cluster::new(3);
        // Load a 2-gang of model 1 on [0, 1] and let it finish: reusable.
        c.dispatch(&[0, 1], 1.0, ModelType(1), false, 0.0);
        c.advance(1.0, 1.0);
        assert!(c.select_healthy(ModelType(1), 2).is_reuse());
        // Server 1 goes down: the gang is no longer reusable (its weights
        // are gone) and healthy selection works around it.
        c.set_health(&[true, false, true], 2.0);
        assert!(!c.servers[1].up);
        assert_eq!(c.servers[1].model, None);
        assert_eq!(c.servers[1].idle_since, 2.0);
        let sel = c.select_healthy(ModelType(1), 2);
        assert!(!sel.is_reuse());
        assert!(!sel.servers().unwrap().contains(&1));
        // Recovery: selectable again, but weight-cold.
        c.set_health(&[true, true, true], 3.0);
        assert!(c.select_healthy(ModelType(1), 3).servers().is_some());
        assert_eq!(c.servers[1].model, None);
        // A short snapshot leaves the remaining servers untouched.
        c.set_health(&[false], 4.0);
        assert!(!c.servers[0].up && c.servers[1].up && c.servers[2].up);
    }

    #[test]
    fn abort_gang_frees_servers_weight_cold() {
        let mut c = Cluster::new(2);
        c.dispatch(&[0, 1], 50.0, ModelType(1), false, 0.0);
        c.abort_gang(&[0, 1], 3.0);
        assert_eq!(c.idle_count(), 2);
        assert!(c.servers.iter().all(|s| s.model.is_none()));
        assert!(c.servers.iter().all(|s| s.idle_since == 3.0));
        // No reusable gang survives an abort.
        assert!(c.idle_gangs(ModelType(1)).is_empty());
    }

    #[test]
    fn advance_reports_completions_once() {
        let mut c = Cluster::new(3);
        c.dispatch(&[0, 1], 2.0, ModelType(0), false, 0.0);
        assert!(c.advance(1.0, 1.0).is_empty());
        let done = c.advance(1.0, 2.0);
        assert_eq!(done, vec![0, 1]);
        assert!(c.advance(1.0, 3.0).is_empty());
    }

    #[test]
    fn busy_set_tracks_dispatch_and_completion() {
        let mut c = Cluster::new(4);
        assert!(c.all_idle());
        c.dispatch(&[1, 3], 2.0, ModelType(0), false, 0.0);
        assert_eq!(c.busy_ids().iter().copied().collect::<Vec<_>>(), vec![1, 3]);
        c.advance(2.0, 2.0);
        assert!(c.all_idle());
        assert_eq!(c.idle_count(), 4);
    }

    #[test]
    fn advance_into_reuses_buffer_and_matches_scan_advance() {
        let mut a = Cluster::new(6);
        let mut b = a.clone();
        a.dispatch(&[0, 2, 4], 3.0, ModelType(1), false, 0.0);
        b.dispatch(&[0, 2, 4], 3.0, ModelType(1), false, 0.0);
        let mut done_a = Vec::new();
        let mut done_b = Vec::new();
        for t in 1..=4 {
            a.advance_into(1.0, t as f64, &mut done_a);
            b.advance_scan_into(1.0, t as f64, &mut done_b);
            assert_eq!(done_a, done_b);
        }
        assert_eq!(a.select(ModelType(1), 3), b.select(ModelType(1), 3));
    }

    #[test]
    fn epoch_bumps_when_capacity_can_grow() {
        let mut c = Cluster::new(2);
        let e0 = c.epoch();
        c.dispatch(&[0, 1], 5.0, ModelType(0), false, 0.0);
        // Dispatch never frees capacity: no bump, memoised Infeasible
        // verdicts stay valid.
        assert_eq!(c.epoch(), e0);
        c.advance(5.0, 5.0);
        assert!(c.epoch() > e0, "completions must invalidate the memo");
        let e1 = c.epoch();
        c.fail_server(0, 6.0);
        assert!(c.epoch() > e1);
        let e2 = c.epoch();
        c.recover_server(0, 7.0);
        assert!(c.epoch() > e2);
    }

    #[test]
    fn fail_and_recover_maintain_index_and_counters() {
        let mut c = Cluster::new(3);
        c.dispatch(&[0, 1], 10.0, ModelType(1), false, 0.0);
        assert!(c.fail_server(0, 2.0), "first failure reports was_up");
        assert!(!c.fail_server(0, 2.5), "redundant failure reports !was_up");
        assert_eq!(c.down_count(), 1);
        // The downed server dropped its work; its gang mate is still busy.
        assert_eq!(c.busy_ids().iter().copied().collect::<Vec<_>>(), vec![1]);
        assert_eq!(c.servers[0].model, None);
        // Healthy selection sees only server 2; blind also sees server 0.
        assert_eq!(c.select_healthy(ModelType(0), 1).servers().unwrap(), &[2]);
        assert_eq!(c.select(ModelType(0), 2).servers().unwrap(), &[2, 0]);
        c.recover_server(0, 4.0);
        assert_eq!(c.down_count(), 0);
        assert_eq!(c.servers[0].idle_since, 4.0);
        // The finished gang mate can never form an intact gang again (its
        // partner detached on failure).
        c.advance(10.0, 10.0);
        assert!(c.idle_gangs(ModelType(1)).is_empty());
        assert!(!c.select(ModelType(1), 2).is_reuse());
    }

    #[test]
    fn duration_zero_dispatch_yields_immediately_reusable_gang() {
        // The serving layer uses the cluster as a residency tracker and
        // dispatches with duration 0: the gang must be intact (reusable)
        // straight away without an advance in between.
        let mut c = Cluster::new(4);
        let g1 = c.dispatch(&[0, 1], 0.0, ModelType(2), false, 1.0);
        assert!(c.all_idle());
        let sel = c.select(ModelType(2), 2);
        assert!(sel.is_reuse());
        assert_eq!(sel.servers().unwrap(), &[0, 1]);
        let g2 = c.dispatch(&[0, 1], 0.0, ModelType(2), true, 2.0);
        assert_eq!(g1, g2);
    }

    #[test]
    fn index_matches_scan_through_mixed_churn() {
        // Torture loop: deterministic mixed dispatch/advance/fail/recover
        // sequence; the debug_assert in select_filtered cross-checks the
        // index against the scan on every query.
        let mut c = Cluster::new(9);
        for step in 0..200u64 {
            let now = step as f64;
            let model = ModelType((step % 3) as u32);
            let count = 1 + (step % 4) as usize;
            match c.select(model, count) {
                Selection::Reuse(ids) => {
                    c.dispatch(&ids, 2.0 + (step % 5) as f64, model, true, now);
                }
                Selection::Fresh(ids) => {
                    c.dispatch(&ids, 2.0 + (step % 5) as f64, model, false, now);
                }
                Selection::Infeasible => {}
            }
            if step % 11 == 0 {
                c.fail_server((step % 9) as usize, now);
            }
            if step % 13 == 0 {
                c.recover_server((step.wrapping_mul(7) % 9) as usize, now);
            }
            c.advance(1.0, now + 1.0);
            // Cross-check healthy selection too (scan oracle in debug).
            let _ = c.select_healthy(model, count);
            let _ = c.idle_gangs(model);
            assert_eq!(
                c.idle_count(),
                c.servers.iter().filter(|s| s.is_idle()).count()
            );
        }
    }
}
