//! The server cluster E: gang lookup (Eq. 1's G_m groups), idle counting,
//! and the greedy, fragmentation-minimising server selection strategy from
//! §V.B.4 ("Server Selector").

use super::server::{GangId, Server};
use super::task::ModelType;

/// Outcome of a server-selection query.
#[derive(Clone, Debug, PartialEq)]
pub enum Selection {
    /// An idle gang with the right model and exact size exists: reuse it
    /// (no initialisation cost).
    Reuse(Vec<usize>),
    /// Enough idle servers exist but the model must be (re)initialised on
    /// them (cold start).
    Fresh(Vec<usize>),
    /// Not enough idle servers: the gang constraint (4b/4c) cannot be met.
    Infeasible,
}

impl Selection {
    pub fn servers(&self) -> Option<&[usize]> {
        match self {
            Selection::Reuse(v) | Selection::Fresh(v) => Some(v),
            Selection::Infeasible => None,
        }
    }

    pub fn is_reuse(&self) -> bool {
        matches!(self, Selection::Reuse(_))
    }
}

/// Cluster of edge servers.
#[derive(Clone, Debug)]
pub struct Cluster {
    pub servers: Vec<Server>,
    next_gang: u64,
}

impl Cluster {
    pub fn new(n: usize) -> Self {
        Cluster {
            servers: (0..n).map(Server::new).collect(),
            next_gang: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.servers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.servers.is_empty()
    }

    pub fn idle_count(&self) -> usize {
        self.servers.iter().filter(|s| s.is_idle()).count()
    }

    pub fn fresh_gang_id(&mut self) -> GangId {
        self.next_gang += 1;
        GangId(self.next_gang)
    }

    /// G^t_m restricted to complete idle gangs: groups of idle servers that
    /// share a gang id, model `m`, and whose full gang (gang_size members)
    /// is idle. Returns (gang id, member server ids) pairs.
    pub fn idle_gangs(&self, model: ModelType) -> Vec<(GangId, Vec<usize>)> {
        use std::collections::BTreeMap;
        let mut groups: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
        let mut sizes: BTreeMap<u64, usize> = BTreeMap::new();
        for s in &self.servers {
            if s.is_idle() && s.model == Some(model) {
                if let Some(g) = s.gang {
                    groups.entry(g.0).or_default().push(s.id);
                    sizes.insert(g.0, s.gang_size);
                }
            }
        }
        groups
            .into_iter()
            .filter(|(gid, members)| sizes.get(gid) == Some(&members.len()))
            .map(|(gid, members)| (GangId(gid), members))
            .collect()
    }

    /// §V.B.4 greedy server selection for a task needing `count` servers of
    /// model `model`:
    /// 1. If an idle gang of exactly `count` servers already holds the
    ///    model, reuse it (zero initialisation).
    /// 2. Otherwise pick `count` idle servers minimising "idle group
    ///    fragmentation": prefer empty servers, then members of already
    ///    broken (partially busy) gangs, then break the least-recently-used
    ///    complete idle gang.
    ///
    /// This variant is *fault-blind*: a down server has no remaining work,
    /// so it counts as idle and can be chosen (and the dispatch will be
    /// killed by the fault sweep). Health-aware callers use
    /// [`select_healthy`](Self::select_healthy). With the fault subsystem
    /// disabled every server is up and the two are identical.
    pub fn select(&self, model: ModelType, count: usize) -> Selection {
        self.select_filtered(model, count, false)
    }

    /// [`select`](Self::select) restricted to up servers: down servers are
    /// masked out of fresh placement (reuse needs loaded weights, which a
    /// failed server has already lost, so it is masked implicitly).
    pub fn select_healthy(&self, model: ModelType, count: usize) -> Selection {
        self.select_filtered(model, count, true)
    }

    fn select_filtered(&self, model: ModelType, count: usize, healthy_only: bool) -> Selection {
        // 1. Exact reuse.
        for (_gid, members) in self.idle_gangs(model) {
            if members.len() == count {
                return Selection::Reuse(members);
            }
        }
        // 2. Fresh placement.
        let idle: Vec<&Server> = self
            .servers
            .iter()
            .filter(|s| s.is_idle() && (!healthy_only || s.up))
            .collect();
        if idle.len() < count {
            return Selection::Infeasible;
        }
        // Completeness of each gang among idle servers: a gang is "intact"
        // if all its members are idle (breaking it destroys a reusable
        // group; avoid if possible).
        use std::collections::BTreeMap;
        let mut idle_by_gang: BTreeMap<u64, usize> = BTreeMap::new();
        for s in &idle {
            if let Some(g) = s.gang {
                *idle_by_gang.entry(g.0).or_default() += 1;
            }
        }
        let mut scored: Vec<(u64, f64, usize)> = idle
            .iter()
            .map(|s| {
                // Lower score = pick first.
                let score: u64 = match (s.model, s.gang) {
                    (None, _) => 0, // empty server: free real estate
                    (Some(_), Some(g)) => {
                        let intact = idle_by_gang.get(&g.0) == Some(&s.gang_size);
                        if intact {
                            2 // breaking an intact gang loses reuse potential
                        } else {
                            1 // gang already broken: cheap to take
                        }
                    }
                    (Some(_), None) => 1,
                };
                (score, s.idle_since, s.id)
            })
            .collect();
        // Tie-break: LRU (oldest idle first), then id for determinism.
        scored.sort_by(|a, b| {
            a.0.cmp(&b.0)
                .then(a.1.partial_cmp(&b.1).unwrap())
                .then(a.2.cmp(&b.2))
        });
        let chosen = scored.iter().take(count).map(|x| x.2).collect();
        Selection::Fresh(chosen)
    }

    /// Dispatch: mark servers busy for `duration`, loading `model` as a new
    /// gang (fresh) or keeping the existing gang (reuse). `now` stamps the
    /// eviction instant on freshly unloaded servers (LRU bookkeeping).
    pub fn dispatch(
        &mut self,
        server_ids: &[usize],
        duration: f64,
        model: ModelType,
        reuse: bool,
        now: f64,
    ) -> GangId {
        let gang = if reuse {
            self.servers[server_ids[0]].gang.expect("reuse without gang")
        } else {
            let g = self.fresh_gang_id();
            for &id in server_ids {
                self.servers[id].unload(now);
            }
            g
        };
        let size = server_ids.len();
        for &id in server_ids {
            self.servers[id].assign(duration, model, gang, size);
        }
        gang
    }

    /// Mirror an external per-server health snapshot (e.g. the serving
    /// layer's `HealthRegistry`) into the cluster. A server transitioning
    /// up→down loses its in-flight work and loaded weights (`abort`), so
    /// the reuse path of [`select_healthy`](Self::select_healthy) can
    /// never hand out a gang with a dead member; a recovered server comes
    /// back up weight-cold. Extra snapshot entries are ignored.
    pub fn set_health(&mut self, up: &[bool], now: f64) {
        for (s, &u) in self.servers.iter_mut().zip(up) {
            if s.up && !u {
                s.abort(now);
            }
            s.up = u;
        }
    }

    /// Kill an in-flight gang: every member drops its work and goes
    /// weight-cold (the DistriFusion process group is gone and reloading
    /// pays in full). Used for mid-flight failures and speculative losers.
    pub fn abort_gang(&mut self, server_ids: &[usize], now: f64) {
        for &id in server_ids {
            self.servers[id].abort(now);
        }
    }

    /// Advance all servers by dt; returns ids that completed this tick.
    pub fn advance(&mut self, dt: f64, now: f64) -> Vec<usize> {
        let mut done = Vec::new();
        for s in &mut self.servers {
            if s.advance(dt, now) {
                done.push(s.id);
            }
        }
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn busy_all(c: &mut Cluster, dur: f64) {
        let n = c.len();
        let ids: Vec<usize> = (0..n).collect();
        c.dispatch(&ids, dur, ModelType(0), false, 0.0);
    }

    #[test]
    fn reuse_found_for_exact_idle_gang() {
        let mut c = Cluster::new(4);
        // Run a 2-patch task on servers; after completion the gang is idle.
        let sel = c.select(ModelType(1), 2);
        let servers = sel.servers().unwrap().to_vec();
        assert!(!sel.is_reuse());
        c.dispatch(&servers, 5.0, ModelType(1), false, 0.0);
        c.advance(5.0, 5.0);
        let sel2 = c.select(ModelType(1), 2);
        assert!(sel2.is_reuse());
        assert_eq!(sel2.servers().unwrap(), &servers[..]);
    }

    #[test]
    fn no_reuse_for_wrong_size() {
        let mut c = Cluster::new(4);
        let sel = c.select(ModelType(1), 2);
        let servers = sel.servers().unwrap().to_vec();
        c.dispatch(&servers, 5.0, ModelType(1), false, 0.0);
        c.advance(5.0, 5.0);
        // Same model but needs 4 servers: the 2-gang can't be reused as-is.
        let sel2 = c.select(ModelType(1), 4);
        assert!(!sel2.is_reuse());
    }

    #[test]
    fn no_reuse_for_wrong_model() {
        let mut c = Cluster::new(4);
        let servers = c.select(ModelType(1), 2).servers().unwrap().to_vec();
        c.dispatch(&servers, 5.0, ModelType(1), false, 0.0);
        c.advance(5.0, 5.0);
        let sel2 = c.select(ModelType(2), 2);
        assert!(!sel2.is_reuse());
    }

    #[test]
    fn infeasible_when_busy() {
        let mut c = Cluster::new(4);
        busy_all(&mut c, 10.0);
        assert_eq!(c.select(ModelType(0), 1), Selection::Infeasible);
        c.advance(10.0, 10.0);
        assert!(c.select(ModelType(0), 4).servers().is_some());
    }

    #[test]
    fn selection_prefers_empty_then_broken_then_intact() {
        let mut c = Cluster::new(6);
        // Gang A: servers for a 2-patch model-1 task (intact after done).
        let a = c.select(ModelType(1), 2).servers().unwrap().to_vec();
        c.dispatch(&a, 1.0, ModelType(1), false, 0.0);
        c.advance(1.0, 1.0);
        // Gang B: 2-patch model-2, then one member re-occupied → broken.
        let b: Vec<usize> = c
            .servers
            .iter()
            .filter(|s| s.is_idle() && s.model.is_none())
            .take(2)
            .map(|s| s.id)
            .collect();
        c.dispatch(&b, 1.0, ModelType(2), false, 1.0);
        c.advance(1.0, 2.0);
        // Occupy one member of gang B with a fresh 1-patch model-0 task.
        c.dispatch(&[b[0]], 100.0, ModelType(0), false, 2.0);
        // Now: 2 empty servers, 1 broken-gang server (b[1]), 2 intact gang-A
        // servers. A fresh 3-server model-0 task should take the 2 empty +
        // the broken one, leaving gang A intact.
        let sel = c.select(ModelType(0), 3);
        let chosen = sel.servers().unwrap();
        assert!(!chosen.contains(&a[0]) && !chosen.contains(&a[1]), "{chosen:?} broke intact gang {a:?}");
        assert!(chosen.contains(&b[1]));
    }

    #[test]
    fn dispatch_reuse_keeps_gang_id() {
        let mut c = Cluster::new(2);
        let servers = c.select(ModelType(1), 2).servers().unwrap().to_vec();
        let g1 = c.dispatch(&servers, 1.0, ModelType(1), false, 0.0);
        c.advance(1.0, 1.0);
        let sel = c.select(ModelType(1), 2);
        assert!(sel.is_reuse());
        let g2 = c.dispatch(sel.servers().unwrap(), 1.0, ModelType(1), true, 1.0);
        assert_eq!(g1, g2);
    }

    #[test]
    fn select_healthy_masks_down_servers_but_select_stays_blind() {
        let mut c = Cluster::new(4);
        c.servers[0].up = false;
        c.servers[1].up = false;
        // Blind selection still sees 4 "idle" servers.
        assert!(c.select(ModelType(0), 4).servers().is_some());
        // Health-aware selection only has 2 up servers left.
        assert_eq!(c.select_healthy(ModelType(0), 4), Selection::Infeasible);
        let sel = c.select_healthy(ModelType(0), 2);
        assert_eq!(sel.servers().unwrap(), &[2, 3]);
        // A recovered server is selectable again.
        c.servers[0].up = true;
        assert!(c.select_healthy(ModelType(0), 3).servers().is_some());
    }

    #[test]
    fn set_health_drops_down_servers_weights_and_masks_reuse() {
        let mut c = Cluster::new(3);
        // Load a 2-gang of model 1 on [0, 1] and let it finish: reusable.
        c.dispatch(&[0, 1], 1.0, ModelType(1), false, 0.0);
        c.advance(1.0, 1.0);
        assert!(c.select_healthy(ModelType(1), 2).is_reuse());
        // Server 1 goes down: the gang is no longer reusable (its weights
        // are gone) and healthy selection works around it.
        c.set_health(&[true, false, true], 2.0);
        assert!(!c.servers[1].up);
        assert_eq!(c.servers[1].model, None);
        assert_eq!(c.servers[1].idle_since, 2.0);
        let sel = c.select_healthy(ModelType(1), 2);
        assert!(!sel.is_reuse());
        assert!(!sel.servers().unwrap().contains(&1));
        // Recovery: selectable again, but weight-cold.
        c.set_health(&[true, true, true], 3.0);
        assert!(c.select_healthy(ModelType(1), 3).servers().is_some());
        assert_eq!(c.servers[1].model, None);
        // A short snapshot leaves the remaining servers untouched.
        c.set_health(&[false], 4.0);
        assert!(!c.servers[0].up && c.servers[1].up && c.servers[2].up);
    }

    #[test]
    fn abort_gang_frees_servers_weight_cold() {
        let mut c = Cluster::new(2);
        c.dispatch(&[0, 1], 50.0, ModelType(1), false, 0.0);
        c.abort_gang(&[0, 1], 3.0);
        assert_eq!(c.idle_count(), 2);
        assert!(c.servers.iter().all(|s| s.model.is_none()));
        assert!(c.servers.iter().all(|s| s.idle_since == 3.0));
        // No reusable gang survives an abort.
        assert!(c.idle_gangs(ModelType(1)).is_empty());
    }

    #[test]
    fn advance_reports_completions_once() {
        let mut c = Cluster::new(3);
        c.dispatch(&[0, 1], 2.0, ModelType(0), false, 0.0);
        assert!(c.advance(1.0, 1.0).is_empty());
        let done = c.advance(1.0, 2.0);
        assert_eq!(done, vec![0, 1]);
        assert!(c.advance(1.0, 3.0).is_empty());
    }
}
