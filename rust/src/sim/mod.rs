//! Edge-cluster simulator: the substrate the paper evaluates on.
//!
//! The paper's testbed is 4–12 GPU workers running Stable Diffusion v1.4
//! under DistriFusion; the scheduler observes only (availability, remaining
//! time, loaded model) per server plus the waiting queue, and pays
//! measured initialisation/execution latencies. This module reproduces
//! those observables with models calibrated to the paper's measurements
//! (Tables I & VI, Fig 6) — see DESIGN.md §Substitutions.

pub mod cluster;
pub mod env;
pub mod events;
pub mod exec_model;
pub mod quality;
pub mod server;
pub mod task;
