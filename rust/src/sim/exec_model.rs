//! Calibrated execution-time and initialisation-time models, plus the
//! *predictor* the scheduler uses (paper §V.A.3: "The remaining time t^r_e
//! is predicted based on the characteristics of AIGC tasks").
//!
//! Ground truth (what the simulator charges) is the prediction plus
//! measured randomness: multiplicative lognormal jitter on initialisation
//! (Fig 6 shows heavy, cooperate-count-dependent spread) and small Gaussian
//! jitter on execution (Fig 7 shows near-deterministic linear scaling).

use crate::config::ExecModelConfig;
use crate::util::rng::Pcg64;

/// Deterministic predictions + stochastic realisations of task timing.
#[derive(Clone, Debug)]
pub struct ExecModel {
    cfg: ExecModelConfig,
}

impl ExecModel {
    pub fn new(cfg: ExecModelConfig) -> Self {
        ExecModel { cfg }
    }

    pub fn cfg(&self) -> &ExecModelConfig {
        &self.cfg
    }

    /// Predicted execution time f(s, c): linear in inference steps, with
    /// per-patch-count slope (Table VI) plus fixed dispatch overhead.
    pub fn predict_exec(&self, steps: u32, patches: usize) -> f64 {
        let idx = ExecModelConfig::patch_index(patches);
        steps as f64 * self.cfg.step_time[idx] + self.cfg.dispatch_overhead + self.cfg.comm_latency
    }

    /// Predicted initialisation time g(c, m): ≈ constant per patch count
    /// (Table VI: 33.5 / 31.9 / 35.0 s).
    pub fn predict_init(&self, patches: usize) -> f64 {
        self.cfg.init_base[ExecModelConfig::patch_index(patches)]
    }

    /// Realised execution time: prediction × (1 + N(0, jitter)).
    pub fn sample_exec(&self, steps: u32, patches: usize, rng: &mut Pcg64) -> f64 {
        let base = self.predict_exec(steps, patches);
        let jitter = 1.0 + rng.normal_ms(0.0, self.cfg.exec_jitter_rel);
        (base * jitter.max(0.5)).max(0.01)
    }

    /// Realised initialisation time: lognormal-jittered, spread growing
    /// with patch count (more process-group members to synchronise).
    pub fn sample_init(&self, patches: usize, rng: &mut Pcg64) -> f64 {
        let base = self.predict_init(patches);
        let sigma = self.cfg.init_jitter_sigma * (1.0 + 0.25 * (patches as f64).log2());
        base * rng.lognormal(0.0, sigma)
    }

    /// Speedup of running `steps` at `patches` vs single-patch (Table I).
    pub fn speedup(&self, steps: u32, patches: usize) -> f64 {
        self.predict_exec(steps, 1) / self.predict_exec(steps, patches)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExecModelConfig;

    fn model() -> ExecModel {
        ExecModel::new(ExecModelConfig::default())
    }

    #[test]
    fn exec_linear_in_steps() {
        let m = model();
        let t10 = m.predict_exec(10, 2);
        let t20 = m.predict_exec(20, 2);
        let slope = (t20 - t10) / 10.0;
        assert!((slope - 0.29).abs() < 1e-9, "slope={slope}");
    }

    #[test]
    fn table1_acceleration_shape() {
        // Table I: 1/2/4/8 patches → ×1 / ×1.8 / ×3.1 / ×4.9 at ~45 steps
        // (23.7 s / 0.53 ≈ 45 steps for the measured single-patch task).
        let m = model();
        let s = 45;
        assert!((m.speedup(s, 1) - 1.0).abs() < 1e-9);
        let a2 = m.speedup(s, 2);
        let a4 = m.speedup(s, 4);
        let a8 = m.speedup(s, 8);
        assert!((1.6..2.0).contains(&a2), "a2={a2}");
        assert!((2.4..3.3).contains(&a4), "a4={a4}");
        assert!((3.2..4.9).contains(&a8), "a8={a8}");
        assert!(a2 < a4 && a4 < a8);
    }

    #[test]
    fn init_near_constant_across_patches() {
        let m = model();
        for &c in &[1usize, 2, 4, 8] {
            let t = m.predict_init(c);
            assert!((30.0..38.0).contains(&t), "init({c})={t}");
        }
    }

    #[test]
    fn sampled_times_positive_and_centered() {
        let m = model();
        let mut rng = Pcg64::seeded(11);
        let mut sum = 0.0;
        let n = 5_000;
        for _ in 0..n {
            let t = m.sample_exec(20, 4, &mut rng);
            assert!(t > 0.0);
            sum += t;
        }
        let mean = sum / n as f64;
        let pred = m.predict_exec(20, 4);
        assert!((mean - pred).abs() / pred < 0.02, "mean={mean} pred={pred}");
    }

    #[test]
    fn init_jitter_grows_with_patches() {
        let m = model();
        let spread = |patches: usize| {
            let mut rng = Pcg64::seeded(12);
            let xs: Vec<f64> = (0..4000).map(|_| m.sample_init(patches, &mut rng)).collect();
            let mean = xs.iter().sum::<f64>() / xs.len() as f64;
            (xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64).sqrt() / mean
        };
        assert!(spread(8) > spread(1));
    }
}
