//! A single edge server (GPU worker) and its observable state
//! {a_e(t), t^r_e(t), d_e(t)} per §IV.A.2, extended with gang metadata:
//! DistriFusion loads one model instance *per process group*, so reuse
//! requires the exact previous gang (same model, same size, same members)
//! to be idle — matching the paper's |G_m| = c_k reuse condition and the
//! Table II trace where Task 4 reuses Init 1 on GPUs {1,2} — plus health
//! state for the fault subsystem: `up` (Markov churn / zone shocks) and a
//! transient straggler `slowdown` multiplier on execution speed.

use super::task::ModelType;

/// Identifier of a gang (process group) instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GangId(pub u64);

/// Mutable server state.
#[derive(Clone, Debug)]
pub struct Server {
    pub id: usize,
    /// Remaining busy time t^r_e (0 when idle).
    pub remaining: f64,
    /// Loaded model type d_e, if any.
    pub model: Option<ModelType>,
    /// Gang this server's loaded model instance belongs to.
    pub gang: Option<GangId>,
    /// Size of that gang (= patch count of the task that loaded it).
    pub gang_size: usize,
    /// Simulation time when the server last became idle (for LRU eviction).
    pub idle_since: f64,
    /// Health: a down server makes no progress and (under health-aware
    /// dispatch) is masked out of server selection. Always `true` when the
    /// fault subsystem is disabled.
    pub up: bool,
    /// Straggler multiplier >= 1: execution proceeds at 1/slowdown speed.
    /// 1.0 = nominal (and always 1.0 when faults are disabled).
    pub slowdown: f64,
}

impl Server {
    pub fn new(id: usize) -> Self {
        Server {
            id,
            remaining: 0.0,
            model: None,
            gang: None,
            gang_size: 0,
            idle_since: 0.0,
            up: true,
            slowdown: 1.0,
        }
    }

    /// Availability a_e(t): idle iff no remaining work. (A down server has
    /// no remaining work either — use [`is_available`](Self::is_available)
    /// when health matters.)
    pub fn is_idle(&self) -> bool {
        self.remaining <= 0.0
    }

    /// Idle *and* up: dispatchable under health-aware selection.
    pub fn is_available(&self) -> bool {
        self.is_idle() && self.up
    }

    /// Advance simulated time by dt; returns true if the server finished
    /// its current work during this tick. A straggling server processes
    /// work at 1/slowdown speed; a down server makes no progress at all
    /// (its gang is killed by the fault sweep anyway).
    pub fn advance(&mut self, dt: f64, now: f64) -> bool {
        if self.up && self.remaining > 0.0 {
            self.remaining = (self.remaining - dt / self.slowdown).max(0.0);
            if self.remaining == 0.0 {
                self.idle_since = now;
                return true;
            }
        }
        false
    }

    /// Assign work: busy for `duration`, loaded with `model` in `gang`.
    pub fn assign(&mut self, duration: f64, model: ModelType, gang: GangId, gang_size: usize) {
        debug_assert!(self.is_idle(), "assigning to busy server {}", self.id);
        self.remaining = duration;
        self.model = Some(model);
        self.gang = Some(gang);
        self.gang_size = gang_size;
    }

    /// Drop the loaded model (eviction before loading a different one, or
    /// weight loss on failure). Resets `idle_since` to `now`: a just-
    /// evicted server must not keep ranking by its pre-eviction idle time
    /// in the LRU tie-break of `Cluster::select`.
    pub fn unload(&mut self, now: f64) {
        self.model = None;
        self.gang = None;
        self.gang_size = 0;
        self.idle_since = now;
    }

    /// Cancel in-flight work without signalling completion (gang kill or
    /// speculative-loser abort): the server goes idle and weight-cold.
    pub fn abort(&mut self, now: f64) {
        self.remaining = 0.0;
        self.unload(now);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advance_counts_down_and_signals_completion() {
        let mut s = Server::new(0);
        s.assign(2.5, ModelType(1), GangId(7), 2);
        assert!(!s.is_idle());
        assert!(!s.advance(1.0, 1.0));
        assert!(!s.advance(1.0, 2.0));
        assert!(s.advance(1.0, 3.0)); // finishes here
        assert!(s.is_idle());
        assert_eq!(s.idle_since, 3.0);
        // Model stays loaded after completion (that's the whole point).
        assert_eq!(s.model, Some(ModelType(1)));
        assert_eq!(s.gang, Some(GangId(7)));
    }

    #[test]
    fn advance_on_idle_is_noop() {
        let mut s = Server::new(0);
        assert!(!s.advance(1.0, 1.0));
        assert!(s.is_idle());
    }

    #[test]
    fn unload_clears_model_and_resets_idle_since() {
        let mut s = Server::new(0);
        s.assign(1.0, ModelType(0), GangId(1), 1);
        s.advance(1.0, 1.0);
        assert_eq!(s.idle_since, 1.0);
        s.unload(5.0);
        assert_eq!(s.model, None);
        assert_eq!(s.gang, None);
        assert_eq!(s.gang_size, 0);
        // The LRU clock restarts at eviction, not at the pre-eviction idle
        // instant.
        assert_eq!(s.idle_since, 5.0);
    }

    #[test]
    fn slowdown_stretches_execution() {
        let mut s = Server::new(0);
        s.assign(2.0, ModelType(0), GangId(1), 1);
        s.slowdown = 2.0; // half speed: 2 s of work takes 4 s
        assert!(!s.advance(1.0, 1.0));
        assert!(!s.advance(1.0, 2.0));
        assert!(!s.advance(1.0, 3.0));
        assert!(s.advance(1.0, 4.0));
    }

    #[test]
    fn down_server_makes_no_progress_and_abort_goes_cold() {
        let mut s = Server::new(0);
        s.assign(1.0, ModelType(2), GangId(3), 2);
        s.up = false;
        assert!(!s.advance(10.0, 10.0));
        assert_eq!(s.remaining, 1.0);
        assert!(!s.is_available());
        s.abort(10.0);
        assert!(s.is_idle());
        assert_eq!(s.model, None);
        assert_eq!(s.idle_since, 10.0);
        s.up = true;
        assert!(s.is_available());
    }
}
