//! A single edge server (GPU worker) and its observable state
//! {a_e(t), t^r_e(t), d_e(t)} per §IV.A.2, extended with gang metadata:
//! DistriFusion loads one model instance *per process group*, so reuse
//! requires the exact previous gang (same model, same size, same members)
//! to be idle — matching the paper's |G_m| = c_k reuse condition and the
//! Table II trace where Task 4 reuses Init 1 on GPUs {1,2}.

use super::task::ModelType;

/// Identifier of a gang (process group) instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GangId(pub u64);

/// Mutable server state.
#[derive(Clone, Debug)]
pub struct Server {
    pub id: usize,
    /// Remaining busy time t^r_e (0 when idle).
    pub remaining: f64,
    /// Loaded model type d_e, if any.
    pub model: Option<ModelType>,
    /// Gang this server's loaded model instance belongs to.
    pub gang: Option<GangId>,
    /// Size of that gang (= patch count of the task that loaded it).
    pub gang_size: usize,
    /// Simulation time when the server last became idle (for LRU eviction).
    pub idle_since: f64,
}

impl Server {
    pub fn new(id: usize) -> Self {
        Server {
            id,
            remaining: 0.0,
            model: None,
            gang: None,
            gang_size: 0,
            idle_since: 0.0,
        }
    }

    /// Availability a_e(t): idle iff no remaining work.
    pub fn is_idle(&self) -> bool {
        self.remaining <= 0.0
    }

    /// Advance simulated time by dt; returns true if the server finished
    /// its current work during this tick.
    pub fn advance(&mut self, dt: f64, now: f64) -> bool {
        if self.remaining > 0.0 {
            self.remaining = (self.remaining - dt).max(0.0);
            if self.remaining == 0.0 {
                self.idle_since = now;
                return true;
            }
        }
        false
    }

    /// Assign work: busy for `duration`, loaded with `model` in `gang`.
    pub fn assign(&mut self, duration: f64, model: ModelType, gang: GangId, gang_size: usize) {
        debug_assert!(self.is_idle(), "assigning to busy server {}", self.id);
        self.remaining = duration;
        self.model = Some(model);
        self.gang = Some(gang);
        self.gang_size = gang_size;
    }

    /// Drop the loaded model (eviction before loading a different one).
    pub fn unload(&mut self) {
        self.model = None;
        self.gang = None;
        self.gang_size = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advance_counts_down_and_signals_completion() {
        let mut s = Server::new(0);
        s.assign(2.5, ModelType(1), GangId(7), 2);
        assert!(!s.is_idle());
        assert!(!s.advance(1.0, 1.0));
        assert!(!s.advance(1.0, 2.0));
        assert!(s.advance(1.0, 3.0)); // finishes here
        assert!(s.is_idle());
        assert_eq!(s.idle_since, 3.0);
        // Model stays loaded after completion (that's the whole point).
        assert_eq!(s.model, Some(ModelType(1)));
        assert_eq!(s.gang, Some(GangId(7)));
    }

    #[test]
    fn advance_on_idle_is_noop() {
        let mut s = Server::new(0);
        assert!(!s.advance(1.0, 1.0));
        assert!(s.is_idle());
    }

    #[test]
    fn unload_clears_model() {
        let mut s = Server::new(0);
        s.assign(1.0, ModelType(0), GangId(1), 1);
        s.advance(1.0, 1.0);
        s.unload();
        assert_eq!(s.model, None);
        assert_eq!(s.gang, None);
        assert_eq!(s.gang_size, 0);
    }
}
