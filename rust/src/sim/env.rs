//! The continuous-time, discrete-decision MDP of §V.A: state matrix
//! (Eq. 6), composite action vector (Eq. 8), transition dynamics, and
//! reciprocal-time reward.
//!
//! One decision per simulated second (Δt = decision_dt): the scheduler
//! observes the cluster + the top-l queue slots, and either schedules one
//! gang task (choosing which task, how many inference steps, and which
//! servers via the greedy selector) or does nothing.

use crate::config::EnvConfig;
use crate::faults::{FaultEvent, FaultKind, FaultModel, FaultsConfig};
use crate::obs::decisions::{
    Candidate, DecisionLedger, DecisionRecord, DecisionRecorder, Outcome as DecisionOutcome,
    OutcomeStatus,
};
use crate::obs::timeseries::{FleetGauges, FleetSampler, FleetSeries, TenantCum};
use crate::obs::trace::{DropReason, GangRef, SpanKind, TraceRecorder};
use crate::qos::{AdmissionConfig, AdmissionState, PendingQueue, QueueDiscipline, TenantRegistry};
use crate::sim::cluster::{Cluster, Selection};
use crate::sim::events::EventQueue;
use crate::sim::server::GangId;
use crate::sim::exec_model::ExecModel;
use crate::sim::quality::QualityModel;
use crate::sim::task::{ModelType, Task, Workload};
use crate::util::rng::Pcg64;
use crate::workload::{MetricsCollector, TaskSource, TaskStream, TenantReport};
use std::cell::RefCell;
use std::collections::{BTreeMap, VecDeque};

/// Decoded composite action (Eq. 8): `[a_c, a_s, a_k1..a_kl]`, every
/// component in [-1, 1] (the policy networks end in tanh).
#[derive(Clone, Debug)]
pub struct Action {
    /// Raw execution gate a_c: schedule iff a_c ≤ 0 (paper: a_c ≤ 0.5 on
    /// the [0,1] parameterisation).
    pub exec_gate: f32,
    /// Raw step knob a_s, mapped linearly onto [S_min, S_max].
    pub steps_raw: f32,
    /// Preference score per queue slot; argmax over occupied slots wins.
    pub task_scores: Vec<f32>,
}

impl Action {
    /// Decode from the flat vector the policy networks emit.
    pub fn from_vec(raw: &[f32]) -> Action {
        assert!(raw.len() >= 3, "action vector too short: {}", raw.len());
        Action {
            exec_gate: raw[0],
            steps_raw: raw[1],
            task_scores: raw[2..].to_vec(),
        }
    }

    pub fn to_vec(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(2 + self.task_scores.len());
        v.push(self.exec_gate);
        v.push(self.steps_raw);
        v.extend_from_slice(&self.task_scores);
        v
    }

    pub fn wants_exec(&self) -> bool {
        self.exec_gate <= 0.0
    }

    /// Map a_s ∈ [-1,1] → steps ∈ [s_min, s_max].
    pub fn steps(&self, s_min: u32, s_max: u32) -> u32 {
        let u = ((self.steps_raw + 1.0) * 0.5).clamp(0.0, 1.0) as f64;
        (s_min as f64 + u * (s_max - s_min) as f64).round() as u32
    }

    /// A no-op action (gate closed).
    pub fn noop(l: usize) -> Action {
        Action {
            exec_gate: 1.0,
            steps_raw: 0.0,
            task_scores: vec![0.0; l],
        }
    }
}

/// Details of a task scheduled by a step.
#[derive(Clone, Debug)]
pub struct Scheduled {
    pub task_id: u64,
    pub steps: u32,
    pub servers: Vec<usize>,
    pub reused_model: bool,
    /// Realised total duration charged to the gang (init + exec).
    pub duration: f64,
    /// Waiting time t^w at schedule instant.
    pub waiting: f64,
    /// Response time t^r = waiting + duration.
    pub response: f64,
    pub quality: f64,
    /// Quality floor in force for this task (its own demand, or the
    /// episode-wide `RewardConfig::q_min`).
    pub q_min: f64,
    /// Tenant index of the scheduled task (multi-tenant workloads).
    pub tenant: Option<u32>,
    /// Whether the response met the task's deadline; `None` when the task
    /// carried no deadline.
    pub deadline_met: Option<bool>,
}

/// Result of one environment step.
#[derive(Clone, Debug)]
pub struct StepOutcome {
    pub reward: f64,
    pub done: bool,
    pub scheduled: Option<Scheduled>,
    /// The action asked to schedule but the gang constraint failed or the
    /// queue was empty.
    pub infeasible: bool,
}

/// One scheduled attempt in flight under the fault subsystem: completion
/// (and all per-task accounting) is deferred until every gang member has
/// finished — or the gang is killed by a failure.
#[derive(Clone, Debug)]
struct InFlight {
    task: Task,
    steps: u32,
    servers: Vec<usize>,
    /// The gang id this attempt was dispatched as. A member that finishes
    /// its patch early goes idle and may be re-dispatched (which assigns a
    /// fresh gang id), so raw server ids are not enough to know whether a
    /// server is still working for this attempt — the gang id is.
    gang: GangId,
    /// Per-member patch completion, parallel to `servers`. A finished
    /// patch survives whatever happens to its server afterwards.
    done: Vec<bool>,
    reuse: bool,
    start: f64,
    /// Nominal duration charged at dispatch (init + exec before any
    /// straggler stretch); the unit of patch-second accounting.
    nominal: f64,
    speculative: bool,
    /// Monotone attempt id, the key under which this attempt's
    /// speculative-launch deadline sits in `FaultState::spec_events`.
    seq: u64,
}

impl InFlight {
    /// Nominal patch-seconds of this attempt (duration x gang size).
    fn work(&self) -> f64 {
        self.nominal * self.servers.len() as f64
    }

    fn all_done(&self) -> bool {
        self.done.iter().all(|&d| d)
    }
}

/// Abort exactly the servers still working for `att`: members whose patch
/// already finished — and servers since re-dispatched to another task
/// (their gang id changed) — are left alone.
fn abort_attempt(cluster: &mut Cluster, att: &InFlight, now: f64) {
    for (i, &m) in att.servers.iter().enumerate() {
        if !att.done[i] && cluster.servers[m].gang == Some(att.gang) {
            cluster.abort_server(m, now);
        }
    }
}

/// Runtime state of the fault subsystem: the health process, the in-flight
/// gang registry, per-task kill counts, and the event log (recordable into
/// JSONL traces and replayable via [`EdgeEnv::script_faults`]). Present
/// only when `EnvConfig::faults` is active — otherwise the env takes the
/// seed's code path bit-identically.
#[derive(Clone)]
struct FaultState {
    cfg: FaultsConfig,
    model: FaultModel,
    inflight: Vec<InFlight>,
    /// Kill count per still-live task id (dropped once resolved).
    attempts: BTreeMap<u64, u32>,
    events: Vec<FaultEvent>,
    /// Tasks dropped after exhausting their retry budget.
    failed_tasks: usize,
    /// Next attempt sequence number (keys for `spec_events`).
    next_seq: u64,
    /// Speculative-launch deadlines: one event per primary attempt at
    /// `start + spec_beta x nominal`. The fault tick only runs the
    /// phase-4 backup scan when an event is due, instead of scanning
    /// every in-flight attempt every tick. Stale keys (attempt already
    /// resolved) are dropped lazily; a due-but-not-launched candidate is
    /// re-armed one tick out so the scan keeps the original per-tick
    /// cadence while an attempt is "hot".
    spec_events: EventQueue,
    /// Reusable pop buffer for `spec_events`.
    spec_pop: Vec<u64>,
}

/// Aggregated per-episode metrics (feeds Tables IX–XI, Fig 5/8, and the
/// scenario sweep). Percentiles and utilization come from the streaming
/// `MetricsCollector`; when no task was ever scheduled they are censored
/// at the episode's simulated time, like the average.
#[derive(Clone, Debug, Default)]
pub struct EpisodeReport {
    pub completed_tasks: usize,
    pub total_tasks: usize,
    pub decision_steps: usize,
    pub sim_time: f64,
    pub total_reward: f64,
    pub avg_quality: f64,
    pub avg_response_latency: f64,
    /// Response-latency percentiles over completed tasks.
    pub p50_latency: f64,
    pub p90_latency: f64,
    pub p99_latency: f64,
    /// Mean per-server busy-time fraction over the episode.
    pub avg_utilization: f64,
    /// Fraction of scheduled tasks that required a model (re)load.
    pub reload_rate: f64,
    /// Absolute number of model (re)loads.
    pub reloads: usize,
    pub below_quality_min: usize,
    pub infeasible_actions: usize,
    pub avg_steps_chosen: f64,
    /// Average over completed tasks of quality / response (Fig 8).
    pub efficiency: f64,
    /// Arrivals rejected by admission control (shed load).
    pub dropped_tasks: usize,
    /// Per-tenant SLO attainment / drop-rate / latency percentiles (empty
    /// unless `EnvConfig::tenants` is configured).
    pub tenant_reports: Vec<TenantReport>,
    /// Completed tasks per simulated second (goodput under churn).
    pub goodput: f64,
    // --- fault-subsystem metrics (all zero when faults are disabled) ---
    /// Server failure events (independent churn + zone shocks).
    pub failures: usize,
    /// In-flight gangs killed by a member failure.
    pub gang_kills: usize,
    /// Killed tasks re-queued for another attempt.
    pub retries: usize,
    /// Tasks dropped after exhausting `FaultsConfig::max_retries`.
    pub failed_tasks: usize,
    /// Speculative backup attempts launched / won.
    pub spec_launches: usize,
    pub spec_wins: usize,
    /// Patch-second accounting: dispatched = completed + wasted +
    /// in-flight (the balance the acceptance test pins).
    pub dispatched_patch_s: f64,
    pub completed_patch_s: f64,
    pub wasted_patch_s: f64,
    pub inflight_patch_s: f64,
    /// wasted / dispatched patch-seconds (0 when nothing dispatched).
    pub wasted_work_frac: f64,
}

/// The EAT MDP environment. `Clone` supports the meta-heuristic baselines
/// (Harmony/Genetic), which evaluate candidate action sequences on cloned
/// rollouts of a planning environment.
#[derive(Clone)]
pub struct EdgeEnv {
    pub cfg: EnvConfig,
    pub cluster: Cluster,
    exec_model: ExecModel,
    quality_model: QualityModel,
    source: TaskSource,
    queue: PendingQueue,
    registry: Option<TenantRegistry>,
    admission: AdmissionState,
    faults: Option<FaultState>,
    now: f64,
    steps_taken: usize,
    rng: Pcg64,
    metrics: MetricsCollector,
    /// Infeasibility memo: (model, patches) → cluster epoch at which the
    /// gang constraint was last found unsatisfiable. A verdict stays
    /// valid until the epoch changes (dispatches never free capacity, so
    /// they don't bump it). Interior-mutable because `first_feasible`
    /// is a `&self` query.
    feas_memo: RefCell<BTreeMap<(u32, usize), u64>>,
    /// Reusable buffer for per-tick completed-server ids.
    finished_buf: Vec<usize>,
    /// Debug/bench switch: route selection, advance and the fault sweep
    /// through the original O(fleet)-per-tick scan paths. Set before the
    /// first step; the property tests pin bit-exactness against it and
    /// `eat bench` measures the speedup over it.
    legacy_scan: bool,
    // accumulators
    scheduled_count: usize,
    dropped_count: usize,
    reload_count: usize,
    sum_quality: f64,
    sum_response: f64,
    sum_steps_chosen: f64,
    sum_efficiency: f64,
    below_min: usize,
    infeasible: usize,
    total_reward: f64,
    trace: Vec<Scheduled>,
    /// Optional per-task lifecycle recorder (`obs::trace`). Off by
    /// default; when on, span events are emitted from both simulator
    /// cores. Recording never draws from any RNG stream, so episodes are
    /// bit-identical with tracing on or off (pinned by property tests).
    tracer: Option<TraceRecorder>,
    /// Optional fixed-cadence fleet sampler (`obs::timeseries`). Off by
    /// default; like the tracer it observes cumulative counters only and
    /// never draws from an RNG stream, so episodes are bit-identical with
    /// sampling on or off (pinned by property tests).
    sampler: Option<FleetSampler>,
    /// Optional per-decision ledger recorder (`obs::decisions`). Off by
    /// default; it captures the observed state, the feasible candidate
    /// set (deterministic `predict_*` estimates — never a sample), and
    /// joins realized outcomes by task id. Like the other observers it
    /// never draws from an RNG stream, so episodes are bit-identical
    /// with recording on or off (pinned by property tests).
    decisions: Option<DecisionRecorder>,
}

impl EdgeEnv {
    /// Build from a seed. With `cfg.workload = None` this pre-materialises
    /// the legacy Poisson workload (bit-identical to the seed); with a
    /// scenario configured it consumes the arrival process as a lazy
    /// stream — same tasks, generated on demand. Multi-tenant workloads
    /// (`cfg.tenants`) are merged from per-tenant arrival processes and
    /// pre-materialised (`Workload::generate` routes through the
    /// qos generator).
    pub fn new(cfg: EnvConfig, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed, 0xED6E);
        if cfg.workload.is_some() && cfg.tenants.is_none() {
            let (arrival, mix) = crate::workload::build_for_env(&cfg);
            let stream = TaskStream::new(arrival, mix, cfg.tasks_per_episode, rng.fork(1));
            Self::with_source(cfg, TaskSource::stream(stream), rng)
        } else {
            let workload = Workload::generate(&cfg, &mut rng.fork(1));
            Self::with_workload(cfg, workload, rng)
        }
    }

    /// Build with an explicit workload (common-random-number comparisons,
    /// trace replay, and the fixed motivation traces).
    pub fn with_workload(cfg: EnvConfig, workload: Workload, rng: Pcg64) -> Self {
        Self::with_source(cfg, TaskSource::fixed(workload), rng)
    }

    /// Build over any task source — a materialised workload or a live
    /// arrival-process stream.
    pub fn with_source(cfg: EnvConfig, source: TaskSource, rng: Pcg64) -> Self {
        let cluster = Cluster::new(cfg.num_servers);
        let exec_model = ExecModel::new(cfg.exec.clone());
        let quality_model = QualityModel::new(cfg.quality.clone());
        let registry = cfg.tenants.as_ref().map(TenantRegistry::new);
        // Queue discipline: the seed's FIFO unless a tenants section asks
        // for deadline-aware ordering.
        let queue = match (&registry, cfg.tenants.as_ref().map(|t| t.queue)) {
            (Some(reg), Some(QueueDiscipline::EdfWfq)) => PendingQueue::qos(reg.clone()),
            _ => PendingQueue::fifo(),
        };
        // Admission: tenants section first, then the scenario's policy,
        // else admit-all (the seed behaviour).
        let admission_cfg = cfg
            .tenants
            .as_ref()
            .map(|t| t.admission.clone())
            .or_else(|| cfg.workload.as_ref().map(|w| w.admission.clone()))
            .unwrap_or(AdmissionConfig::AdmitAll);
        let admission = AdmissionState::new(admission_cfg, registry.as_ref());
        let metrics = match &registry {
            Some(reg) => MetricsCollector::with_tenants(cfg.num_servers, reg),
            None => MetricsCollector::new(cfg.num_servers),
        };
        // The fault stream is seeded from a *clone* of the env RNG: the
        // main stream is bit-identical whether faults are on or off, so
        // arrivals and execution jitter stay common-random-number paired
        // across policies and across fault settings. An inert section
        // (`is_active` false) builds no runtime at all — the seed's exact
        // code path.
        let faults = cfg.faults.as_ref().filter(|f| f.is_active()).map(|f| {
            let seed = {
                let mut probe = rng.clone();
                probe.next_u64()
            };
            FaultState {
                cfg: f.clone(),
                model: FaultModel::stochastic(f.clone(), cfg.num_servers, Pcg64::new(seed, 0xFA17)),
                inflight: Vec::new(),
                attempts: BTreeMap::new(),
                events: Vec::new(),
                failed_tasks: 0,
                next_seq: 0,
                spec_events: EventQueue::new(),
                spec_pop: Vec::new(),
            }
        });
        let mut env = EdgeEnv {
            cfg,
            cluster,
            exec_model,
            quality_model,
            source,
            queue,
            registry,
            admission,
            faults,
            now: 0.0,
            steps_taken: 0,
            rng,
            metrics,
            feas_memo: RefCell::new(BTreeMap::new()),
            finished_buf: Vec::new(),
            legacy_scan: false,
            scheduled_count: 0,
            dropped_count: 0,
            reload_count: 0,
            sum_quality: 0.0,
            sum_response: 0.0,
            sum_steps_chosen: 0.0,
            sum_efficiency: 0.0,
            below_min: 0,
            infeasible: 0,
            total_reward: 0.0,
            trace: Vec::new(),
            tracer: None,
            sampler: None,
            decisions: None,
        };
        env.absorb_arrivals();
        env
    }

    /// Turn on lifecycle tracing with a ring capacity of `cap` events.
    /// Construction already absorbed any t ≤ 0 arrivals, so their
    /// admission spans are retro-emitted here (at their true arrival
    /// instants) — every queued task has a complete lifecycle no matter
    /// when tracing was enabled relative to construction.
    pub fn enable_tracing(&mut self, cap: usize) {
        let mut tr = TraceRecorder::new(cap);
        for (depth, task) in self.queue.items().iter().enumerate() {
            tr.record(task.arrival, task.id, task.tenant, SpanKind::Admitted);
            tr.record(
                task.arrival,
                task.id,
                task.tenant,
                SpanKind::Queued { depth: depth as u32 + 1 },
            );
        }
        self.tracer = Some(tr);
    }

    /// The lifecycle recorder, if tracing is enabled.
    pub fn tracer(&self) -> Option<&TraceRecorder> {
        self.tracer.as_ref()
    }

    /// Detach the lifecycle recorder (e.g. to export JSONL after a run).
    pub fn take_tracer(&mut self) -> Option<TraceRecorder> {
        self.tracer.take()
    }

    /// Turn on fleet telemetry sampling at a fixed `cadence` (simulated
    /// seconds per window) with a ring capacity of `cap` windows. Tenant
    /// labels follow the registry (empty for untenanted configs).
    pub fn enable_sampling(&mut self, cadence: f64, cap: usize) {
        let tenants = self.registry.as_ref().map_or_else(Vec::new, |r| {
            r.config().tenants.iter().map(|t| t.name.clone()).collect()
        });
        self.sampler = Some(FleetSampler::new(cadence, cap, tenants));
    }

    /// Detach the sampled fleet series (e.g. to export JSONL after a
    /// run). Closes any windows the clock has crossed plus one trailing
    /// partial window, so activity after the last boundary still lands
    /// in the export and window sums reconcile with the episode report.
    pub fn take_series(&mut self) -> Option<FleetSeries> {
        if self.sampler.is_some() {
            let (gauges, wasted, cum) = self.fleet_gauges();
            // eat-lint: allow(unwrap, "guarded by the is_some() check directly above")
            let sampler = self.sampler.as_mut().unwrap();
            sampler.advance(self.now, gauges, wasted, &cum);
            sampler.flush(gauges, wasted, &cum);
        }
        self.sampler.take().map(FleetSampler::into_series)
    }

    /// The fleet sampler's series so far, if sampling is enabled.
    pub fn series(&self) -> Option<&FleetSeries> {
        self.sampler.as_ref().map(FleetSampler::series)
    }

    /// Turn on per-decision ledger recording with a ring capacity of
    /// `cap` records, labelled with the dispatching `policy` name.
    pub fn enable_decisions(&mut self, policy: &str, cap: usize) {
        self.decisions = Some(DecisionRecorder::new(policy, cap));
    }

    /// The decision recorder, if recording is enabled.
    pub fn decisions(&self) -> Option<&DecisionRecorder> {
        self.decisions.as_ref()
    }

    /// Detach the decision ledger (e.g. to export JSONL after a run).
    /// Decisions whose tasks are still in flight keep `outcome: None`
    /// and are reported by the analyzer as in-flight, not lost.
    pub fn take_decisions(&mut self) -> Option<DecisionLedger> {
        self.decisions.take().map(DecisionRecorder::into_ledger)
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// The pending queue in scheduling order (dequeue order under a QoS
    /// discipline, arrival order otherwise); the top `queue_window` slots
    /// are what the policy observes.
    pub fn queue(&self) -> &VecDeque<Task> {
        self.queue.items()
    }

    pub fn exec_model(&self) -> &ExecModel {
        &self.exec_model
    }

    pub fn quality_model(&self) -> &QualityModel {
        &self.quality_model
    }

    pub fn trace(&self) -> &[Scheduled] {
        &self.trace
    }

    /// Streaming episode metrics (latency histogram, utilization, reloads).
    pub fn metrics(&self) -> &MetricsCollector {
        &self.metrics
    }

    /// Every health transition applied so far this episode (empty when
    /// faults are disabled). Recordable into the JSONL trace format and
    /// replayable via [`script_faults`](Self::script_faults).
    pub fn fault_events(&self) -> &[FaultEvent] {
        self.faults.as_ref().map_or(&[], |f| f.events.as_slice())
    }

    /// Replace the stochastic fault process with a scripted replay of
    /// `events` (recorded from a previous episode): the same workload,
    /// env seed, and policy then reproduce that episode bit-exactly.
    /// Must be called before the first step, on an env whose config has
    /// an active `faults` section.
    pub fn script_faults(&mut self, events: Vec<FaultEvent>) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.now == 0.0,
            "fault scripts must be installed before the first step"
        );
        let fs = self.faults.as_mut().ok_or_else(|| {
            anyhow::anyhow!("script_faults needs an active `faults` section in the env config")
        })?;
        fs.model = FaultModel::scripted(events);
        fs.events.clear();
        Ok(())
    }

    /// Server selection for a task, honouring health-aware dispatch: with
    /// an active fault section and `health_aware = true`, down servers are
    /// masked; otherwise (including every fault-free config) this is the
    /// seed's selector exactly. Heuristic policies route through this.
    pub fn select_for(&self, model: ModelType, patches: usize) -> Selection {
        let healthy = matches!(&self.faults, Some(fs) if fs.cfg.health_aware);
        if self.legacy_scan {
            return self.cluster.select_filtered_scan(model, patches, healthy);
        }
        if healthy {
            self.cluster.select_healthy(model, patches)
        } else {
            self.cluster.select(model, patches)
        }
    }

    /// Route selection, advance and the fault sweep through the original
    /// full-scan code paths (the pre-event tick core). For the
    /// bit-exactness property tests and the `eat bench` tick-vs-event
    /// comparison; call before the first step.
    pub fn set_legacy_scan(&mut self, on: bool) {
        self.legacy_scan = on;
    }

    /// Remaining (not yet arrived) + queued + in-flight tasks exist?
    /// Tasks shed by admission control — or dropped after exhausting
    /// their retry budget under churn — count as resolved.
    pub fn all_done(&self) -> bool {
        let failed = self.faults.as_ref().map_or(0, |f| f.failed_tasks);
        self.scheduled_count + self.dropped_count + failed == self.source.total()
            && self.cluster.all_idle()
            && self.faults.as_ref().map_or(true, |f| f.inflight.is_empty())
    }

    fn absorb_arrivals(&mut self) {
        let mut admitted = false;
        while let Some(task) = self.source.pop_if_arrived(self.now) {
            self.metrics.observe_offered(task.tenant);
            if self.admission.admit(task.tenant, self.now, self.queue.len()) {
                if let Some(tr) = self.tracer.as_mut() {
                    tr.record(task.arrival, task.id, task.tenant, SpanKind::Admitted);
                    tr.record(
                        task.arrival,
                        task.id,
                        task.tenant,
                        SpanKind::Queued { depth: self.queue.len() as u32 + 1 },
                    );
                }
                // Lazy push: the QoS view is rebuilt once per batch below,
                // not O(queue) per arrival.
                self.queue.push_lazy(task);
                admitted = true;
            } else {
                self.dropped_count += 1;
                self.metrics.observe_drop(task.tenant);
                if let Some(tr) = self.tracer.as_mut() {
                    tr.record(
                        task.arrival,
                        task.id,
                        task.tenant,
                        SpanKind::Dropped { reason: DropReason::Admission },
                    );
                }
            }
        }
        if admitted {
            self.queue.commit();
        }
    }

    /// Average waiting time of queued tasks, t^avg_{Q,t} (§V.A.4).
    pub fn avg_queue_wait(&self) -> f64 {
        if self.queue.is_empty() {
            return 0.0;
        }
        self.queue.items().iter().map(|t| self.now - t.arrival).sum::<f64>()
            / self.queue.len() as f64
    }

    /// Build the normalised state vector: the 3×(|E|+l) matrix of Eq. 6 in
    /// row-major order, scaled to roughly [0, 1] for the networks, plus
    /// any opt-in feature rows (`EnvConfig::state_features`).
    ///
    /// Layout: row 0 = [a_e ... | waiting_k ...], row 1 = [t^r_e ... |
    /// c_k ...], row 2 = [d_e ... | 0 ...]; then (optional) a health row
    /// (1/slowdown for up servers, 0 for down ones), then (optional) a
    /// deadline-slack row and a tenant-weight row over the queue slots.
    pub fn state(&self) -> Vec<f32> {
        let e = self.cfg.num_servers;
        let l = self.cfg.queue_window;
        let cols = e + l;
        let mut s = vec![0.0f32; self.cfg.state_len()];
        const T_SCALE: f32 = 1.0 / 100.0;
        for (i, srv) in self.cluster.servers.iter().enumerate() {
            s[i] = if srv.is_idle() { 1.0 } else { 0.0 };
            s[cols + i] = srv.remaining as f32 * T_SCALE;
            s[2 * cols + i] = match srv.model {
                // One-based so "no model" (0) is distinguishable.
                Some(m) => (m.0 + 1) as f32 / (self.cfg.num_models + 1) as f32,
                None => 0.0,
            };
        }
        for (j, task) in self.queue.items().iter().take(l).enumerate() {
            let c = e + j;
            s[c] = ((self.now - task.arrival) as f32 * T_SCALE).min(4.0);
            s[cols + c] = task.patches as f32 / 8.0;
            // Row 2 stays zero for queue columns (Eq. 6 pads with zeros);
            // we use it to mark slot occupancy, which the padded matrix
            // otherwise loses for a task with zero wait and c=0 normalise.
            s[2 * cols + c] = 1.0;
        }
        let mut row = 3 * cols;
        if self.cfg.state_features.health {
            for (i, srv) in self.cluster.servers.iter().enumerate() {
                s[row + i] = if srv.up { (1.0 / srv.slowdown) as f32 } else { 0.0 };
            }
            row += cols;
        }
        if self.cfg.state_features.tenancy {
            let max_w = self.registry.as_ref().map_or(1.0, |r| {
                r.config().tenants.iter().map(|t| t.weight).fold(1.0, f64::max)
            });
            for (j, task) in self.queue.items().iter().take(l).enumerate() {
                let c = row + e + j;
                // Deadline slack in the same time scale as the wait row;
                // negative = already past due, 4.0 = far-off / no deadline.
                s[c] = match task.deadline {
                    Some(d) => (((d - self.now) as f32) * T_SCALE).clamp(-1.0, 4.0),
                    None => 4.0,
                };
                let w = self.registry.as_ref().map_or(1.0, |r| r.weight(task.tenant));
                s[row + cols + e + j] = (w / max_w) as f32;
            }
        }
        s
    }

    /// One decision step. Decodes the action, possibly schedules one task,
    /// then advances simulated time by Δt.
    pub fn step(&mut self, action: &Action) -> StepOutcome {
        let mut outcome = StepOutcome {
            reward: 0.0,
            done: false,
            scheduled: None,
            infeasible: false,
        };
        if action.wants_exec() {
            match self.try_schedule(action) {
                Ok(Some(sch)) => {
                    outcome.reward = self.reward_for(&sch);
                    outcome.scheduled = Some(sch);
                }
                Ok(None) | Err(()) => {
                    // Gate open but nothing schedulable: mild shaping
                    // penalty teaches feasibility (implementation detail;
                    // the paper's Algorithm 1 just skips the step).
                    outcome.infeasible = true;
                    self.infeasible += 1;
                    outcome.reward = -0.1;
                }
            }
        } else if self.any_feasible() {
            // Idle-while-work-waits shaping: closing the gate when a task
            // could be gang-scheduled right now wastes cluster time; the
            // paper's μ_t·t^avg queue term plays the same role inside its
            // reward. Without this, briefly-trained policies can converge
            // to "never schedule" (reward 0 forever).
            outcome.reward = -0.1;
        }
        self.total_reward += outcome.reward;
        // Advance simulated time, crediting busy time before the tick.
        // A straggling server stays busy `slowdown` times longer than its
        // remaining nominal work; a down server processes nothing.
        let dt = self.cfg.decision_dt;
        if self.legacy_scan {
            for s in &self.cluster.servers {
                if s.up && !s.is_idle() {
                    self.metrics.observe_busy(s.id, (s.remaining * s.slowdown).min(dt));
                }
            }
        } else {
            // Only busy servers contribute credit; the busy set iterates
            // ascending, the same order (and f64 summation order) as the
            // full scan above.
            for &id in self.cluster.busy_ids() {
                let s = &self.cluster.servers[id];
                if s.up {
                    self.metrics.observe_busy(s.id, (s.remaining * s.slowdown).min(dt));
                }
            }
        }
        self.metrics.advance_time(dt);
        self.now += dt;
        let mut finished = std::mem::take(&mut self.finished_buf);
        if self.legacy_scan {
            self.cluster.advance_scan_into(dt, self.now, &mut finished);
        } else {
            self.cluster.advance_into(dt, self.now, &mut finished);
        }
        self.fault_tick(&finished, dt);
        self.finished_buf = finished;
        self.absorb_arrivals();
        self.sample_fleet();
        self.steps_taken += 1;
        outcome.done = self.is_done();
        outcome
    }

    /// Close any sampling windows the clock has crossed this step. The
    /// gauge scan is O(fleet) but runs only when a window actually
    /// closes, and only with sampling enabled — the hot path pays one
    /// `Option` check.
    fn sample_fleet(&mut self) {
        let pending = match &self.sampler {
            Some(s) => s.window_pending(self.now),
            None => return,
        };
        if !pending {
            return;
        }
        let (gauges, wasted, cum) = self.fleet_gauges();
        // eat-lint: allow(unwrap, "guarded by the is_none() early return above")
        let sampler = self.sampler.as_mut().expect("checked above");
        sampler.advance(self.now, gauges, wasted, &cum);
    }

    /// Snapshot the instantaneous fleet gauges and cumulative per-tenant
    /// counters for the sampler.
    fn fleet_gauges(&self) -> (FleetGauges, f64, TenantCum) {
        let mut busy = 0u64;
        let mut up = 0u64;
        let mut gangs: std::collections::BTreeSet<u64> = std::collections::BTreeSet::new();
        for s in &self.cluster.servers {
            if s.up {
                up += 1;
            }
            if !s.is_idle() {
                busy += 1;
                if let Some(g) = s.gang {
                    gangs.insert(g.0);
                }
            }
        }
        let inflight = match &self.faults {
            // Under churn the fault subsystem tracks attempts directly
            // (including speculative backups racing on warm gangs).
            Some(fs) => fs.inflight.len() as u64,
            None => gangs.len() as u64,
        };
        let gauges = FleetGauges {
            queue_depth: self.queue.len() as u64,
            busy,
            up,
            inflight,
        };
        let stats = self.metrics.tenant_stats();
        let cum = TenantCum {
            slo_met: stats.iter().map(|t| t.slo_met).collect(),
            completed: stats.iter().map(|t| t.completed).collect(),
            dropped: stats.iter().map(|t| t.dropped).collect(),
        };
        (gauges, self.metrics.wasted_ps(), cum)
    }

    fn is_done(&self) -> bool {
        self.all_done()
            || self.now >= self.cfg.time_limit
            || self.steps_taken >= self.cfg.step_limit
    }

    /// Attempt to schedule per the action; Ok(None) when the queue is
    /// empty, Err(()) when the gang constraint fails.
    fn try_schedule(&mut self, action: &Action) -> Result<Option<Scheduled>, ()> {
        if self.queue.is_empty() {
            return Ok(None);
        }
        let visible = self.queue.len().min(self.cfg.queue_window);
        // Argmax of preference scores over occupied slots.
        let mut best = 0usize;
        for j in 1..visible {
            if action.task_scores.get(j).copied().unwrap_or(f32::MIN)
                > action.task_scores.get(best).copied().unwrap_or(f32::MIN)
            {
                best = j;
            }
        }
        let steps = action.steps(self.cfg.s_min, self.cfg.s_max);
        match self.schedule_task_at(best, steps) {
            Some(sch) => Ok(Some(sch)),
            None => Err(()),
        }
    }

    /// Schedule the queue item at `index` with `steps` inference steps,
    /// if the gang constraint allows. Used by the action path and directly
    /// by heuristic policies.
    pub fn schedule_task_at(&mut self, index: usize, steps: u32) -> Option<Scheduled> {
        let task = self.queue.items().get(index)?.clone();
        let selection = self.select_for(task.model, task.patches);
        let (servers, reuse) = match &selection {
            Selection::Reuse(v) => (v.clone(), true),
            Selection::Fresh(v) => (v.clone(), false),
            Selection::Infeasible => return None,
        };
        self.dispatch_and_record(task, index, steps, servers, reuse)
    }

    /// Schedule on an *explicit* server set (used by the Traditional
    /// first-fit scheduler of the motivating example, Tables II–IV).
    /// Model reuse happens only if the chosen servers exactly form an idle
    /// gang already holding the task's model.
    pub fn schedule_task_on(
        &mut self,
        index: usize,
        steps: u32,
        server_ids: &[usize],
    ) -> Option<Scheduled> {
        let task = self.queue.items().get(index)?.clone();
        if server_ids.len() != task.patches
            || server_ids.iter().any(|&id| !self.cluster.servers[id].is_idle())
        {
            return None;
        }
        if let Some(fs) = &self.faults {
            if fs.cfg.health_aware
                && server_ids.iter().any(|&id| !self.cluster.servers[id].up)
            {
                return None;
            }
        }
        let reuse = self
            .cluster
            .idle_gangs(task.model)
            .iter()
            .any(|(_, members)| {
                let mut m = members.clone();
                let mut s = server_ids.to_vec();
                m.sort_unstable();
                s.sort_unstable();
                m == s
            });
        self.dispatch_and_record(task, index, steps, server_ids.to_vec(), reuse)
    }

    /// Build the decision record for a dispatch about to happen. Pure
    /// `&self` queries plus deterministic `predict_*` estimates — it
    /// never touches an RNG stream — and it must run before
    /// `Cluster::dispatch` mutates residency, like the tracer's warmth
    /// capture.
    fn capture_decision(
        &self,
        task: &Task,
        index: usize,
        steps: u32,
        servers: &[usize],
        reuse: bool,
    ) -> DecisionRecord {
        let pred_exec = self.exec_model.predict_exec(steps, task.patches);
        let full_init = self.exec_model.predict_init(task.patches);
        let mut candidates: Vec<Candidate> = Vec::new();
        let mut chosen = None;
        let mut chosen_sorted: Vec<usize> = servers.to_vec();
        chosen_sorted.sort_unstable();
        // Warm alternatives: every intact idle gang of the right shape.
        // The scan enumeration is deterministic (gang-id order) and reads
        // the same cluster state on both cores, so the candidate list is
        // identical under the event and tick cores.
        for (_gid, members) in self.cluster.idle_gangs_scan(task.model) {
            if members.len() != task.patches {
                continue;
            }
            if reuse && chosen.is_none() {
                let mut m = members.clone();
                m.sort_unstable();
                if m == chosen_sorted {
                    chosen = Some(candidates.len());
                }
            }
            candidates.push(Candidate {
                members: members.iter().map(|&m| m as u32).collect(),
                reuse: true,
                predicted: pred_exec,
                cold: false,
            });
        }
        if reuse && chosen.is_none() {
            // Explicit-server reuse (`schedule_task_on`) can pick a gang
            // the shape scan did not enumerate; record it verbatim.
            chosen = Some(candidates.len());
            candidates.push(Candidate {
                members: servers.iter().map(|&m| m as u32).collect(),
                reuse: true,
                predicted: pred_exec,
                cold: false,
            });
        }
        if !reuse {
            // The chosen fresh placement, with the reload it will be
            // charged: warm members only rebuild the process group.
            let frac = self.cfg.exec.group_rebuild_frac.clamp(0.0, 1.0);
            let pred_init = if frac >= 1.0 {
                full_init
            } else {
                let warm = servers
                    .iter()
                    .filter(|&&id| self.cluster.servers[id].model == Some(task.model))
                    .count() as f64;
                full_init * (1.0 - warm / servers.len() as f64 * (1.0 - frac))
            };
            chosen = Some(candidates.len());
            candidates.push(Candidate {
                members: servers.iter().map(|&m| m as u32).collect(),
                reuse: false,
                predicted: pred_exec + pred_init,
                cold: true,
            });
        } else {
            // Hypothetical fresh alternative, costed at a full reload (a
            // conservative bound: the group-rebuild discount depends on
            // which servers the selector would have picked).
            let healthy = matches!(&self.faults, Some(fs) if fs.cfg.health_aware);
            let idle = self
                .cluster
                .servers
                .iter()
                .filter(|s| s.is_idle() && (!healthy || s.up))
                .count();
            if idle >= task.patches {
                candidates.push(Candidate {
                    members: Vec::new(),
                    reuse: false,
                    predicted: pred_exec + full_init,
                    cold: true,
                });
            }
        }
        let attempt = self
            .faults
            .as_ref()
            .and_then(|fs| fs.attempts.get(&task.id).copied())
            .unwrap_or(0);
        // Eq. 8 action layout, synthesized one-hot for the heuristic
        // dispatch paths (the RL path drives the same slot/steps choice).
        let mut action = Vec::with_capacity(2 + self.cfg.queue_window);
        action.push(-1.0f32);
        action.push(crate::policy::steps_to_raw(steps, self.cfg.s_min, self.cfg.s_max));
        for j in 0..self.cfg.queue_window {
            action.push(if j == index { 1.0 } else { 0.0 });
        }
        DecisionRecord {
            seq: 0,                // stamped by the recorder
            episode: 0,            // stamped by the sweep driver
            t: self.now,
            policy: String::new(), // stamped by the recorder
            task: task.id,
            tenant: task.tenant,
            attempt,
            slot: index,
            steps,
            waiting: (self.now - task.arrival).max(0.0),
            deadline: task.deadline,
            state: self.state(),
            action,
            candidates,
            // eat-lint: allow(unwrap, "the candidate loop always records the action it chose")
            chosen: chosen.expect("dispatch decision always has its chosen candidate"),
            reward: 0.0,           // filled once the Scheduled is built
            outcome: None,
        }
    }

    fn dispatch_and_record(
        &mut self,
        task: Task,
        index: usize,
        steps: u32,
        servers: Vec<usize>,
        reuse: bool,
    ) -> Option<Scheduled> {
        let exec = self.exec_model.sample_exec(steps, task.patches, &mut self.rng);
        let init = if reuse {
            0.0
        } else {
            // §VII extension: servers that already hold the model's weights
            // (but in the wrong gang shape) only pay the process-group
            // rebuild fraction of a full load; weight-cold servers pay in
            // full. With group_rebuild_frac = 1.0 this reduces to the
            // paper's measured full-reload behaviour.
            let full = self.exec_model.sample_init(task.patches, &mut self.rng);
            let frac = self.cfg.exec.group_rebuild_frac.clamp(0.0, 1.0);
            if frac >= 1.0 {
                full
            } else {
                let warm = servers
                    .iter()
                    .filter(|&&id| self.cluster.servers[id].model == Some(task.model))
                    .count() as f64;
                let warm_frac = warm / servers.len() as f64;
                full * (1.0 - warm_frac * (1.0 - frac))
            }
        };
        let duration = exec + init;
        // Warmth must be captured before `dispatch` mutates residency.
        let gang_ref = self.tracer.as_ref().map(|_| {
            GangRef::capture(&servers, |i| {
                self.cluster.servers[servers[i]].model == Some(task.model)
            })
        });
        if let Some(sampler) = self.sampler.as_mut() {
            if !reuse {
                // Like the warmth capture above: residency must be read
                // before `dispatch` mutates it. Members already holding
                // the model only rebuild the process group — the weight
                // loads are the cold members.
                sampler.record_cold_start();
                let cold_members = servers
                    .iter()
                    .filter(|&&id| self.cluster.servers[id].model != Some(task.model))
                    .count() as u64;
                sampler.record_model_loads(cold_members);
            }
        }
        // Decision capture reads residency and enumerates candidates, so
        // like the two observers above it must run before `dispatch`
        // mutates the cluster. It draws no RNG: recording on/off is
        // bit-identical (pinned by property test).
        let decision = self
            .decisions
            .as_ref()
            .map(|_| self.capture_decision(&task, index, steps, &servers, reuse));
        let gang = self.cluster.dispatch(&servers, duration, task.model, reuse, self.now);
        self.queue.remove(index);
        let waiting = (self.now - task.arrival).max(0.0);
        let response = waiting + duration;
        let quality = self.quality_model.sample_quality(steps, task.prompt_id);
        let q_floor = task.q_min.unwrap_or(self.cfg.reward.q_min);
        // A task completes at now + duration; its (absolute) deadline is
        // met iff that instant lands within the SLO budget.
        let deadline_met = task.deadline.map(|d| self.now + duration <= d);
        let sch = Scheduled {
            task_id: task.id,
            steps,
            servers,
            reused_model: reuse,
            duration,
            waiting,
            response,
            quality,
            q_min: q_floor,
            tenant: task.tenant,
            deadline_met,
        };
        // The recorded reward is exactly what `step` reports for this
        // dispatch (`reward_for` is a pure read of post-removal queue
        // state), so exported experience tuples match the env's own
        // reward stream.
        let decision_seq = decision.map(|mut d| {
            d.reward = self.reward_for(&sch);
            self.decisions
                .as_mut()
                // eat-lint: allow(unwrap, "a decision is only captured while the recorder is enabled")
                .expect("decision captured implies recorder present")
                .record(d)
        });
        if let (Some(tr), Some(gref)) = (self.tracer.as_mut(), gang_ref) {
            let attempt = self
                .faults
                .as_ref()
                .and_then(|fs| fs.attempts.get(&task.id).copied())
                .unwrap_or(0);
            tr.record(
                self.now,
                task.id,
                task.tenant,
                SpanKind::Dispatched {
                    gang: gref,
                    cold: init,
                    exec,
                    attempt,
                    speculative: false,
                },
            );
            tr.record(self.now, task.id, task.tenant, SpanKind::ExecStart);
        }
        if self.faults.is_some() {
            // Under churn an attempt may be killed or stretched, so all
            // per-task accounting is deferred to actual completion
            // (`fault_tick`). The nominal `Scheduled` is still returned —
            // the immediate reward keeps its seed semantics. Loads are
            // counted at dispatch: a killed cold attempt really did load.
            if !reuse {
                self.reload_count += 1;
            }
            self.metrics.observe_dispatched_work(duration * sch.servers.len() as f64);
            let now = self.now;
            // eat-lint: allow(unwrap, "guarded by the faults.is_some() branch condition above")
            let fs = self.faults.as_mut().expect("checked above");
            let seq = fs.next_seq;
            fs.next_seq += 1;
            if fs.cfg.spec_beta > 1.0 {
                // Arm this attempt's speculative-launch deadline. The
                // heap time can round off the scan's exact
                // `now - start > beta * nominal` comparison, so the pop
                // horizon carries a one-tick slack and the scan itself
                // re-checks exactly.
                fs.spec_events.push(now + fs.cfg.spec_beta * duration, seq);
            }
            let att = InFlight {
                task,
                steps,
                done: vec![false; sch.servers.len()],
                servers: sch.servers.clone(),
                gang,
                reuse,
                start: now,
                nominal: duration,
                speculative: false,
                seq,
            };
            fs.inflight.push(att);
            if let Some(dseq) = decision_seq {
                // Under churn the outcome is unknown until the attempt
                // completes (or exhausts retries): join later by task id.
                self.decisions
                    .as_mut()
                    // eat-lint: allow(unwrap, "a decision is only captured while the recorder is enabled")
                    .expect("decision captured implies recorder present")
                    .defer(sch.task_id, dseq);
            }
            return Some(sch);
        }
        // Metrics.
        self.scheduled_count += 1;
        if !reuse {
            self.reload_count += 1;
        }
        self.sum_quality += quality;
        self.sum_response += response;
        self.sum_steps_chosen += steps as f64;
        self.sum_efficiency += quality / response.max(1e-9);
        if quality < q_floor {
            self.below_min += 1;
        }
        self.metrics.observe_task(response, waiting, !reuse);
        self.metrics.observe_tenant_task(task.tenant, response, deadline_met);
        if let Some(dseq) = decision_seq {
            // No faults: the completion just booked above is certain, so
            // the realized outcome joins immediately.
            self.decisions
                .as_mut()
                // eat-lint: allow(unwrap, "a decision is only captured while the recorder is enabled")
                .expect("decision captured implies recorder present")
                .resolve_now(
                    dseq,
                    DecisionOutcome {
                        status: OutcomeStatus::Completed,
                        response,
                        duration,
                        quality,
                        deadline_met,
                        cold: !reuse,
                        spec_win: false,
                    },
                );
        }
        if let Some(tr) = self.tracer.as_mut() {
            // Completion is certain (no faults): book it at its future
            // instant now. `response = waiting + duration` with `waiting =
            // now - arrival`, so the analyzer's queue component reproduces
            // the booked waiting time bit-exactly.
            tr.record(
                self.now + duration,
                task.id,
                task.tenant,
                SpanKind::Completed { response, start: self.now, speculative: false },
            );
        }
        self.trace.push(sch.clone());
        Some(sch)
    }

    /// One fault-subsystem tick (no-op without an active `faults`
    /// section): apply health transitions, kill gangs with a failed
    /// member (re-queueing their tasks, deadline and retry count intact),
    /// resolve completions (first finisher of a speculative race wins,
    /// losers are charged as wasted work), and launch speculative backups
    /// for gangs running past `spec_beta` x their nominal duration.
    fn fault_tick(&mut self, finished_servers: &[usize], dt: f64) {
        let Some(mut fs) = self.faults.take() else {
            return;
        };
        let now = self.now;
        // 0. Credit patches that finished this tick, matched by gang id —
        // a member that finished earlier may since have been re-dispatched
        // under a new gang, and its completion then belongs to that
        // attempt, not this one. A finished patch survives whatever
        // happens to its server afterwards.
        for &sid in finished_servers {
            let Some(sgang) = self.cluster.servers.get(sid).and_then(|s| s.gang) else {
                continue;
            };
            for att in fs.inflight.iter_mut() {
                if att.gang == sgang {
                    if let Some(pos) = att.servers.iter().position(|&m| m == sid) {
                        att.done[pos] = true;
                    }
                    break;
                }
            }
        }
        // 1. Health transitions. A failing server loses its work and its
        // model weights; a recovering one comes back up weight-cold. All
        // state changes route through the cluster so its incremental
        // index (and the epoch counter) stay consistent.
        let events = fs.model.step(now - dt, dt);
        let mut downed: Vec<usize> = Vec::new();
        for ev in &events {
            if ev.server >= self.cluster.len() {
                continue;
            }
            match &ev.kind {
                FaultKind::Fail => {
                    if self.cluster.fail_server(ev.server, now) {
                        self.metrics.observe_failure();
                    }
                    downed.push(ev.server);
                }
                FaultKind::Recover => {
                    self.cluster.recover_server(ev.server, now);
                }
                FaultKind::SlowStart { factor, .. } => {
                    self.cluster.set_slowdown(ev.server, factor.max(1.0));
                }
                FaultKind::SlowEnd => {
                    self.cluster.set_slowdown(ev.server, 1.0);
                }
            }
        }
        fs.events.extend(events);
        // 2. Kill every in-flight gang with a *still-working* failed
        // member (including one that failed and recovered within this
        // tick, whose work is gone regardless). Members whose patch
        // already finished don't kill their gang by failing afterwards.
        // With no down server and no failure this tick the kill
        // predicate is vacuously false, so the sweep is skipped (a down
        // server from an *earlier* tick can still be hosting a
        // fault-blind dispatch, hence the `down_count` guard).
        if self.legacy_scan || !downed.is_empty() || self.cluster.down_count() > 0 {
            let (killed, alive): (Vec<InFlight>, Vec<InFlight>) =
                fs.inflight.drain(..).partition(|att| {
                    att.servers.iter().enumerate().any(|(i, &id)| {
                        !att.done[i]
                            && (!self.cluster.servers[id].up || downed.contains(&id))
                    })
                });
            fs.inflight = alive;
            let mut handled: Vec<u64> = Vec::new();
            for att in killed {
                abort_attempt(&mut self.cluster, &att, now);
                self.metrics.observe_gang_kill(att.work());
                let tid = att.task.id;
                if let Some(tr) = self.tracer.as_mut() {
                    let attempt = fs.attempts.get(&tid).copied().unwrap_or(0);
                    tr.record(now, tid, att.task.tenant, SpanKind::Killed { attempt });
                }
                if att.speculative && !self.legacy_scan {
                    // A surviving primary just lost its backup: the old
                    // per-tick scan would reconsider it next tick, so
                    // re-arm its deadline event.
                    if let Some(primary) =
                        fs.inflight.iter().find(|a| a.task.id == tid && !a.speculative)
                    {
                        fs.spec_events.push(now + dt, primary.seq);
                    }
                }
                // Re-queue once per task, and only if no sibling attempt is
                // still racing.
                if handled.contains(&tid) || fs.inflight.iter().any(|a| a.task.id == tid) {
                    continue;
                }
                handled.push(tid);
                let count = fs.attempts.entry(tid).or_insert(0);
                *count += 1;
                let attempt = *count;
                if attempt > fs.cfg.max_retries {
                    fs.attempts.remove(&tid);
                    fs.failed_tasks += 1;
                    self.metrics.observe_task_failure();
                    if let Some(tr) = self.tracer.as_mut() {
                        tr.record(
                            now,
                            tid,
                            att.task.tenant,
                            SpanKind::Dropped { reason: DropReason::RetriesExhausted },
                        );
                    }
                    if let Some(rec) = self.decisions.as_mut() {
                        // A dropped task still closes its decisions — no
                        // silent joins. Response covers the whole doomed
                        // residence; there is no useful exec duration.
                        rec.resolve_task(
                            tid,
                            DecisionOutcome {
                                status: OutcomeStatus::Dropped,
                                response: (now - att.task.arrival).max(0.0),
                                duration: 0.0,
                                quality: 0.0,
                                deadline_met: att.task.deadline.map(|_| false),
                                cold: !att.reuse,
                                spec_win: false,
                            },
                        );
                    }
                } else {
                    self.metrics.observe_retry();
                    if let Some(tr) = self.tracer.as_mut() {
                        tr.record(now, tid, att.task.tenant, SpanKind::Retried { attempt });
                    }
                    self.queue.push_retry(att.task);
                }
            }
        }
        // 3. Completions: a gang is done when every member's patch has
        // finished (detected at heartbeat cadence). First finisher of a
        // task wins; racing siblings are aborted and charged as wasted
        // work. Done flags only flip in phase 0, so with no completed
        // server this tick no attempt can have newly become all-done.
        if self.legacy_scan || !finished_servers.is_empty() {
            let (finished, running): (Vec<InFlight>, Vec<InFlight>) =
                fs.inflight.drain(..).partition(InFlight::all_done);
            fs.inflight = running;
            let mut won: Vec<u64> = Vec::new();
            for att in finished {
                let tid = att.task.id;
                if won.contains(&tid) {
                    self.metrics.observe_wasted_work(att.work());
                    continue;
                }
                won.push(tid);
                let mut keep = Vec::with_capacity(fs.inflight.len());
                for sib in fs.inflight.drain(..) {
                    if sib.task.id == tid {
                        abort_attempt(&mut self.cluster, &sib, now);
                        self.metrics.observe_wasted_work(sib.work());
                        if let Some(tr) = self.tracer.as_mut() {
                            // Lost a speculative race: the attempt dies,
                            // the task does not.
                            tr.record(now, tid, sib.task.tenant, SpanKind::Killed { attempt: 0 });
                        }
                    } else {
                        keep.push(sib);
                    }
                }
                fs.inflight = keep;
                fs.attempts.remove(&tid);
                self.complete_attempt(att);
            }
        }
        // 4. Speculative re-execution: a primary past beta x nominal gets
        // one backup, launched only onto an idle *warm* gang of the right
        // shape (a backup that must cold-load would lose the race to the
        // reload itself). The scan over in-flight attempts only runs when
        // a deadline event is due (it has no side effect unless it
        // launches, so extra runs are harmless and missed runs are not);
        // the one-tick pop slack absorbs the heap time's rounding vs the
        // scan's exact comparison.
        if fs.cfg.spec_beta > 1.0 {
            let mut pop = std::mem::take(&mut fs.spec_pop);
            let due = fs.spec_events.pop_due_into(now + dt, &mut pop) > 0;
            fs.spec_pop = pop;
            if due || self.legacy_scan {
                let mut next_seq = fs.next_seq;
                let mut backups: Vec<InFlight> = Vec::new();
                for att in &fs.inflight {
                    if att.speculative || now - att.start <= fs.cfg.spec_beta * att.nominal {
                        continue;
                    }
                    let tid = att.task.id;
                    if fs.inflight.iter().any(|a| a.task.id == tid && a.speculative)
                        || backups.iter().any(|b| b.task.id == tid)
                    {
                        continue;
                    }
                    let sel = if fs.cfg.health_aware {
                        self.cluster.select_healthy(att.task.model, att.task.patches)
                    } else {
                        self.cluster.select(att.task.model, att.task.patches)
                    };
                    let Selection::Reuse(servers) = sel else {
                        continue;
                    };
                    let exec =
                        self.exec_model
                            .sample_exec(att.steps, att.task.patches, &mut self.rng);
                    // Backups only land on warm gangs (Selection::Reuse).
                    // Emitted after the exec draw: recording must never
                    // reorder or add RNG consumption.
                    let gang_ref =
                        self.tracer.as_ref().map(|_| GangRef::capture(&servers, |_| true));
                    let gang = self.cluster.dispatch(&servers, exec, att.task.model, true, now);
                    self.metrics.observe_spec_launch();
                    self.metrics.observe_dispatched_work(exec * servers.len() as f64);
                    if let (Some(tr), Some(gref)) = (self.tracer.as_mut(), gang_ref) {
                        tr.record(
                            now,
                            att.task.id,
                            att.task.tenant,
                            SpanKind::SpecLaunched { gang: gref, exec },
                        );
                    }
                    let seq = next_seq;
                    next_seq += 1;
                    backups.push(InFlight {
                        task: att.task.clone(),
                        steps: att.steps,
                        done: vec![false; servers.len()],
                        servers,
                        gang,
                        reuse: true,
                        start: now,
                        nominal: exec,
                        speculative: true,
                        seq,
                    });
                }
                fs.next_seq = next_seq;
                fs.inflight.extend(backups);
                if !self.legacy_scan {
                    // Keep hot candidates (due but unlaunched, e.g. no
                    // warm gang free yet) on the per-tick cadence.
                    for att in &fs.inflight {
                        if !att.speculative
                            && att.start + fs.cfg.spec_beta * att.nominal <= now + dt
                            && !fs
                                .inflight
                                .iter()
                                .any(|a| a.task.id == att.task.id && a.speculative)
                        {
                            fs.spec_events.push(now + dt, att.seq);
                        }
                    }
                }
            }
        }
        self.faults = Some(fs);
    }

    /// Deferred completion accounting for one winning attempt (fault
    /// subsystem only): realised response runs to the detection instant,
    /// so stragglers and retries show up in every latency metric.
    fn complete_attempt(&mut self, att: InFlight) {
        let now = self.now;
        let waiting = (att.start - att.task.arrival).max(0.0);
        let response = (now - att.task.arrival).max(0.0);
        let quality = self.quality_model.sample_quality(att.steps, att.task.prompt_id);
        let q_floor = att.task.q_min.unwrap_or(self.cfg.reward.q_min);
        let deadline_met = att.task.deadline.map(|d| now <= d);
        let sch = Scheduled {
            task_id: att.task.id,
            steps: att.steps,
            servers: att.servers.clone(),
            reused_model: att.reuse,
            duration: now - att.start,
            waiting,
            response,
            quality,
            q_min: q_floor,
            tenant: att.task.tenant,
            deadline_met,
        };
        self.scheduled_count += 1;
        self.sum_quality += quality;
        self.sum_response += response;
        self.sum_steps_chosen += att.steps as f64;
        self.sum_efficiency += quality / response.max(1e-9);
        if quality < q_floor {
            self.below_min += 1;
        }
        self.metrics.observe_task(response, waiting, !att.reuse);
        self.metrics.observe_tenant_task(att.task.tenant, response, deadline_met);
        self.metrics.observe_completed_work(att.work());
        if att.speculative {
            self.metrics.observe_spec_win();
        }
        if let Some(tr) = self.tracer.as_mut() {
            // `start` links the completion to its winning dispatch-like
            // event; the speculative flag disambiguates a retry dispatch
            // and a backup launch sharing a tick.
            tr.record(
                now,
                att.task.id,
                att.task.tenant,
                SpanKind::Completed { response, start: att.start, speculative: att.speculative },
            );
        }
        if let Some(rec) = self.decisions.as_mut() {
            // Joins every deferred decision for this task id: a retried
            // task's earlier dispatch decisions share the final outcome,
            // which is exactly what the regret analysis wants (the retry
            // cost is part of what the original choice realized).
            rec.resolve_task(
                att.task.id,
                DecisionOutcome {
                    status: OutcomeStatus::Completed,
                    response,
                    duration: now - att.start,
                    quality,
                    deadline_met,
                    cold: !att.reuse,
                    spec_win: att.speculative,
                },
            );
        }
        self.trace.push(sch);
    }

    /// Immediate reward (§V.A.4):
    /// R = α_q·q − λ_q·I + 1 / (β_t·t^r + μ_t·t^avg_Q) − p_d·w·miss.
    /// The quality indicator I uses the task's own demand when it has one
    /// (scenario mixes with per-task QoS tiers), else the global q_min.
    /// The deadline term charges a missed SLO in proportion to the
    /// tenant's weight; deadline-less tasks (the paper's regime) never
    /// trip it, keeping legacy rewards bit-identical.
    fn reward_for(&self, sch: &Scheduled) -> f64 {
        let r = &self.cfg.reward;
        let penalty = if sch.quality < sch.q_min { r.p_quality } else { 0.0 };
        let denom = r.beta_t * sch.response + r.mu_t * self.avg_queue_wait() + 1e-3;
        let mut reward = r.alpha_q * sch.quality - r.lambda_q * penalty + 1.0 / denom;
        if sch.deadline_met == Some(false) {
            let weight = self
                .registry
                .as_ref()
                .map_or(1.0, |reg| reg.weight(sch.tenant));
            reward -= r.p_deadline * weight;
        }
        reward
    }

    /// Index of the first queue-feasible task among the visible slots, in
    /// queue order (down servers masked under health-aware dispatch). The
    /// head-first dispatchers of `eat qos` / `eat faults` drive this.
    ///
    /// An infeasibility memo keyed by `(model, patches)` short-circuits
    /// repeat probes: feasibility of a shape can only change when cluster
    /// capacity changes, which bumps the cluster epoch, so a shape found
    /// infeasible at the current epoch stays infeasible until the epoch
    /// moves. Dispatching between probes never *adds* capacity, so memo
    /// entries stay valid across the dispatch loop within one tick.
    pub fn first_feasible(&self) -> Option<usize> {
        if self.legacy_scan {
            return self
                .queue
                .items()
                .iter()
                .take(self.cfg.queue_window)
                .position(|t| {
                    !matches!(self.select_for(t.model, t.patches), Selection::Infeasible)
                });
        }
        let epoch = self.cluster.epoch();
        let mut memo = self.feas_memo.borrow_mut();
        self.queue
            .items()
            .iter()
            .take(self.cfg.queue_window)
            .position(|t| {
                let key = (t.model.0, t.patches);
                if memo.get(&key) == Some(&epoch) {
                    return false;
                }
                if matches!(self.select_for(t.model, t.patches), Selection::Infeasible) {
                    memo.insert(key, epoch);
                    false
                } else {
                    true
                }
            })
    }

    /// Can any queued task currently be gang-scheduled?
    pub fn any_feasible(&self) -> bool {
        self.first_feasible().is_some()
    }

    /// Arrival times of the underlying workload (testing / diagnostics).
    /// Empty for a streamed source — a stream retains no history and
    /// cannot report future arrivals without consuming randomness.
    pub fn workload_arrivals(&self) -> Vec<f64> {
        self.source.known_arrivals()
    }

    /// Fault-subsystem report fields (all zero without an active
    /// section), shared by both report branches.
    fn fill_fault_fields(&self, rep: &mut EpisodeReport) {
        rep.goodput = if self.now > 0.0 {
            self.scheduled_count as f64 / self.now
        } else {
            0.0
        };
        rep.failures = self.metrics.failures() as usize;
        rep.gang_kills = self.metrics.gang_kills() as usize;
        rep.retries = self.metrics.retries() as usize;
        rep.failed_tasks = self.faults.as_ref().map_or(0, |f| f.failed_tasks);
        rep.spec_launches = self.metrics.spec_launches() as usize;
        rep.spec_wins = self.metrics.spec_wins() as usize;
        rep.dispatched_patch_s = self.metrics.dispatched_ps();
        rep.completed_patch_s = self.metrics.completed_ps();
        rep.wasted_patch_s = self.metrics.wasted_ps();
        rep.inflight_patch_s = self
            .faults
            .as_ref()
            .map_or(0.0, |f| f.inflight.iter().map(InFlight::work).sum());
        rep.wasted_work_frac = self.metrics.wasted_frac();
    }

    /// Final episode report. If the policy never scheduled anything the
    /// latency (and its percentiles) is censored at the episode's
    /// simulated time (otherwise a do-nothing policy would report a
    /// perfect 0-second latency).
    pub fn report(&self) -> EpisodeReport {
        if self.scheduled_count == 0 {
            let mut rep = EpisodeReport {
                completed_tasks: 0,
                total_tasks: self.source.total(),
                decision_steps: self.steps_taken,
                sim_time: self.now,
                total_reward: self.total_reward,
                avg_quality: 0.0,
                avg_response_latency: self.now,
                p50_latency: self.now,
                p90_latency: self.now,
                p99_latency: self.now,
                avg_utilization: self.metrics.avg_utilization(),
                reload_rate: 0.0,
                reloads: 0,
                below_quality_min: 0,
                infeasible_actions: self.infeasible,
                avg_steps_chosen: 0.0,
                efficiency: 0.0,
                dropped_tasks: self.dropped_count,
                tenant_reports: self.metrics.tenant_reports(),
                ..EpisodeReport::default()
            };
            self.fill_fault_fields(&mut rep);
            return rep;
        }
        let n = self.scheduled_count as f64;
        let mut rep = EpisodeReport {
            completed_tasks: self.scheduled_count,
            total_tasks: self.source.total(),
            decision_steps: self.steps_taken,
            sim_time: self.now,
            total_reward: self.total_reward,
            avg_quality: self.sum_quality / n,
            avg_response_latency: self.sum_response / n,
            p50_latency: self.metrics.latency.p50(),
            p90_latency: self.metrics.latency.p90(),
            p99_latency: self.metrics.latency.p99(),
            avg_utilization: self.metrics.avg_utilization(),
            reload_rate: self.reload_count as f64 / n,
            reloads: self.reload_count,
            below_quality_min: self.below_min,
            infeasible_actions: self.infeasible,
            avg_steps_chosen: self.sum_steps_chosen / n,
            efficiency: self.sum_efficiency / n,
            dropped_tasks: self.dropped_count,
            tenant_reports: self.metrics.tenant_reports(),
            ..EpisodeReport::default()
        };
        self.fill_fault_fields(&mut rep);
        rep
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn env(seed: u64) -> EdgeEnv {
        let cfg = ExperimentConfig::preset_8node(0.1);
        EdgeEnv::new(cfg.env, seed)
    }

    fn schedule_action(l: usize, slot: usize, steps_raw: f32) -> Action {
        let mut scores = vec![-1.0f32; l];
        scores[slot] = 1.0;
        Action {
            exec_gate: -1.0,
            steps_raw,
            task_scores: scores,
        }
    }

    #[test]
    fn state_dims_match_config() {
        let e = env(1);
        assert_eq!(e.state().len(), e.cfg.state_len());
    }

    #[test]
    fn noop_steps_advance_time_only() {
        let mut e = env(2);
        let l = e.cfg.queue_window;
        let before_queue = e.queue().len();
        let out = e.step(&Action::noop(l));
        assert_eq!(out.reward, 0.0);
        assert!(out.scheduled.is_none());
        assert!(!out.infeasible);
        assert_eq!(e.now(), e.cfg.decision_dt);
        // Queue can only have grown (arrivals).
        assert!(e.queue().len() >= before_queue);
    }

    #[test]
    fn scheduling_consumes_queue_and_busies_servers() {
        let mut e = env(3);
        // Run until something is queued.
        let l = e.cfg.queue_window;
        while e.queue().is_empty() {
            e.step(&Action::noop(l));
        }
        let patches = e.queue()[0].patches;
        let out = e.step(&schedule_action(l, 0, 1.0));
        let sch = out.scheduled.expect("should schedule");
        assert_eq!(sch.servers.len(), patches);
        assert!(out.reward > 0.0, "reward={}", out.reward);
        assert_eq!(sch.steps, e.cfg.s_max);
        let busy = e.cluster.servers.iter().filter(|s| !s.is_idle()).count();
        assert_eq!(busy, patches);
    }

    #[test]
    fn infeasible_penalised_when_queue_empty() {
        let cfg = ExperimentConfig::preset_8node(0.0001); // ~no arrivals
        let mut e = EdgeEnv::new(cfg.env, 4);
        let l = e.cfg.queue_window;
        let out = e.step(&schedule_action(l, 0, 0.0));
        assert!(out.infeasible);
        assert!(out.reward < 0.0);
    }

    #[test]
    fn episode_terminates() {
        let mut e = env(5);
        let l = e.cfg.queue_window;
        let mut done = false;
        for _ in 0..e.cfg.step_limit + 1 {
            // Greedy-ish: always try to schedule slot 0 with max steps.
            let out = e.step(&schedule_action(l, 0, 1.0));
            if out.done {
                done = true;
                break;
            }
        }
        assert!(done);
        let rep = e.report();
        assert!(rep.completed_tasks > 0);
        assert!(rep.avg_quality > 0.2);
        assert!(rep.reload_rate > 0.0 && rep.reload_rate <= 1.0);
    }

    #[test]
    fn reward_prefers_more_steps_when_idle() {
        // With an empty system, higher steps → higher quality → higher
        // reward (the time term barely moves) — this is why Greedy maxes
        // steps in the paper.
        let mk = |steps_raw: f32, seed: u64| {
            let mut e = env(seed);
            let l = e.cfg.queue_window;
            while e.queue().is_empty() {
                e.step(&Action::noop(l));
            }
            e.step(&schedule_action(l, 0, steps_raw)).reward
        };
        // Same seed → same task/workload, different steps.
        assert!(mk(1.0, 77) > mk(-1.0, 77));
    }

    #[test]
    fn model_reuse_reflected_in_reload_rate() {
        // Single model type: after the first load, same-size gangs reuse.
        let mut cfg = ExperimentConfig::preset_4node(0.05).env;
        cfg.num_models = 1;
        cfg.patch_choices = vec![2];
        cfg.patch_weights = vec![1.0];
        cfg.tasks_per_episode = 12;
        let mut e = EdgeEnv::new(cfg, 6);
        let l = e.cfg.queue_window;
        for _ in 0..e.cfg.step_limit {
            let out = e.step(&schedule_action(l, 0, 0.5));
            if out.done {
                break;
            }
        }
        let rep = e.report();
        assert!(rep.completed_tasks >= 10, "completed={}", rep.completed_tasks);
        // Two gangs of 2 on 4 servers: after ≤2 loads everything reuses.
        assert!(rep.reload_rate < 0.4, "reload={}", rep.reload_rate);
    }

    #[test]
    fn partial_group_rebuild_reduces_init_cost() {
        // §VII extension: with one model type and warm weights everywhere,
        // group_rebuild_frac < 1 should cut response latency vs the full
        // reload default on the same workload/seed.
        let run = |frac: f64| {
            let mut cfg = ExperimentConfig::preset_4node(0.05).env;
            cfg.num_models = 1;
            cfg.exec.group_rebuild_frac = frac;
            // Alternate 2- and 4-patch tasks so gang shapes keep changing
            // (forcing rebuilds rather than exact reuse).
            cfg.patch_choices = vec![2, 4];
            cfg.patch_weights = vec![1.0, 1.0];
            cfg.tasks_per_episode = 12;
            let mut e = EdgeEnv::new(cfg, 42);
            let l = e.cfg.queue_window;
            for _ in 0..e.cfg.step_limit {
                if e.step(&schedule_action(l, 0, 0.5)).done {
                    break;
                }
            }
            e.report().avg_response_latency
        };
        let full = run(1.0);
        let partial = run(0.3);
        assert!(
            partial < full * 0.9,
            "partial rebuild {partial} should beat full reload {full}"
        );
    }

    #[test]
    fn argmax_selects_highest_scored_slot() {
        let mut e = env(8);
        let l = e.cfg.queue_window;
        while e.queue().len() < 2 {
            e.step(&Action::noop(l));
        }
        let second_id = e.queue()[1].id;
        let out = e.step(&schedule_action(l, 1, 0.0));
        assert_eq!(out.scheduled.unwrap().task_id, second_id);
    }

    #[test]
    fn report_efficiency_positive() {
        let mut e = env(9);
        let l = e.cfg.queue_window;
        for _ in 0..200 {
            let out = e.step(&schedule_action(l, 0, 1.0));
            if out.done {
                break;
            }
        }
        let rep = e.report();
        assert!(rep.efficiency > 0.0);
        assert!(rep.avg_steps_chosen > 0.0);
    }

    #[test]
    fn report_percentiles_bracket_the_mean() {
        let mut e = env(10);
        let l = e.cfg.queue_window;
        loop {
            if e.step(&schedule_action(l, 0, 0.5)).done {
                break;
            }
        }
        let rep = e.report();
        assert!(rep.completed_tasks > 1);
        assert!(rep.p50_latency <= rep.p90_latency && rep.p90_latency <= rep.p99_latency);
        assert!(rep.p50_latency > 0.0 && rep.p99_latency.is_finite());
        assert!(rep.avg_utilization > 0.0 && rep.avg_utilization <= 1.0);
        assert_eq!(rep.reloads, (rep.reload_rate * rep.completed_tasks as f64).round() as usize);
    }

    #[test]
    fn streamed_scenario_matches_materialised_replay() {
        use crate::sim::task::Workload;
        use crate::util::rng::Pcg64;
        use crate::workload::WorkloadConfig;
        // The same seed must yield the same episode whether the scenario
        // is consumed as a stream (EdgeEnv::new) or pre-materialised and
        // replayed (EdgeEnv::with_workload) — the trace-replay guarantee.
        let mut cfg = ExperimentConfig::preset_8node(0.1).env;
        cfg.workload = Some(WorkloadConfig::preset("flash", 0.1).unwrap());
        let seed = 21;
        let run = |mut e: EdgeEnv| {
            let l = e.cfg.queue_window;
            loop {
                if e.step(&schedule_action(l, 0, 0.7)).done {
                    break;
                }
            }
            e.report()
        };
        let streamed = run(EdgeEnv::new(cfg.clone(), seed));
        let mut rng = Pcg64::new(seed, 0xED6E);
        let workload = Workload::generate(&cfg, &mut rng.fork(1));
        let materialised = run(EdgeEnv::with_workload(cfg, workload, rng));
        assert_eq!(streamed.completed_tasks, materialised.completed_tasks);
        assert_eq!(streamed.total_reward, materialised.total_reward);
        assert_eq!(streamed.avg_response_latency, materialised.avg_response_latency);
        assert_eq!(streamed.p99_latency, materialised.p99_latency);
        assert_eq!(streamed.avg_quality, materialised.avg_quality);
    }

    fn tenant_cfg(total_rate: f64) -> EnvConfig {
        use crate::qos::TenantsConfig;
        let mut cfg = ExperimentConfig::preset_8node(0.1).env;
        cfg.tenants = Some(TenantsConfig::three_tier(total_rate));
        cfg.tasks_per_episode = 48;
        cfg
    }

    #[test]
    fn tenant_episode_reports_per_tenant_metrics() {
        let mut e = EdgeEnv::new(tenant_cfg(0.3), 31);
        let l = e.cfg.queue_window;
        loop {
            if e.step(&schedule_action(l, 0, 0.5)).done {
                break;
            }
        }
        let rep = e.report();
        assert!(rep.completed_tasks > 0);
        assert_eq!(rep.tenant_reports.len(), 3);
        let offered: u64 = rep.tenant_reports.iter().map(|t| t.offered).sum();
        let completed: u64 = rep.tenant_reports.iter().map(|t| t.completed).sum();
        assert!(offered > 0);
        assert_eq!(completed as usize, rep.completed_tasks);
        for t in &rep.tenant_reports {
            assert!((0.0..=1.0).contains(&t.slo_attainment), "{}: {}", t.name, t.slo_attainment);
            assert!((0.0..=1.0).contains(&t.drop_rate));
        }
    }

    #[test]
    fn drop_tail_sheds_load_and_episode_still_terminates() {
        use crate::qos::AdmissionConfig;
        let mut cfg = tenant_cfg(2.0); // ~7 arrivals/s: massive overload
        if let Some(t) = &mut cfg.tenants {
            t.admission = AdmissionConfig::DropTail { max_queue: 4 };
        }
        cfg.tasks_per_episode = 40;
        let mut e = EdgeEnv::new(cfg, 32);
        let l = e.cfg.queue_window;
        let mut done = false;
        for _ in 0..e.cfg.step_limit + 1 {
            if e.step(&schedule_action(l, 0, 0.5)).done {
                done = true;
                break;
            }
        }
        assert!(done);
        let rep = e.report();
        assert!(rep.dropped_tasks > 0, "overload with a 4-slot queue must shed");
        assert!(rep.completed_tasks + rep.dropped_tasks <= rep.total_tasks);
        assert!(e.queue().len() <= 4, "queue exceeded its bound: {}", e.queue().len());
        let dropped: u64 = rep.tenant_reports.iter().map(|t| t.dropped).sum();
        assert_eq!(dropped as usize, rep.dropped_tasks);
    }

    #[test]
    fn qos_queue_surfaces_premium_ahead_of_backlog() {
        // Under overload the visible window (EDF/WFQ order) must show
        // premium-tier tasks ahead of batch tasks that arrived earlier.
        let mut e = EdgeEnv::new(tenant_cfg(2.0), 33);
        let l = e.cfg.queue_window;
        // Build a backlog without scheduling anything.
        for _ in 0..200 {
            if e.step(&Action::noop(l)).done {
                break;
            }
        }
        let q = e.queue();
        assert!(q.len() > l, "need a backlog for the test to bite");
        // Count premium tasks among the visible slots vs the whole queue:
        // the weighted queue must over-represent premium at the head.
        let premium_visible = q.iter().take(l).filter(|t| t.tenant == Some(0)).count();
        let premium_total = q.iter().filter(|t| t.tenant == Some(0)).count();
        let visible_share = premium_visible as f64 / l as f64;
        let overall_share = premium_total as f64 / q.len() as f64;
        assert!(
            visible_share >= overall_share,
            "premium visible share {visible_share} < overall {overall_share}"
        );
        // EDF within the visible window: premium tasks appear in deadline
        // order.
        let mut last = f64::NEG_INFINITY;
        for t in q.iter().take(l).filter(|t| t.tenant == Some(0)) {
            let d = t.deadline.expect("tenant tasks carry deadlines");
            assert!(d >= last);
            last = d;
        }
    }

    #[test]
    fn deadline_misses_penalise_reward_by_weight() {
        // Same scheduled outcome, one with a met deadline and one missed:
        // the missed one must earn strictly less reward.
        let cfg = tenant_cfg(0.3);
        let mut e = EdgeEnv::new(cfg, 34);
        let l = e.cfg.queue_window;
        while e.queue().is_empty() {
            e.step(&Action::noop(l));
        }
        // Run two clones: one schedules now (meets the 120 s budget), one
        // waits far past every queued deadline first.
        let mut prompt_env = e.clone();
        let now_reward = prompt_env.step(&schedule_action(l, 0, 0.5)).reward;
        let mut late_env = e.clone();
        for _ in 0..200 {
            late_env.step(&Action::noop(l));
            if late_env.now() > 300.0 {
                break;
            }
        }
        if late_env.queue().is_empty() {
            return; // everything arrived and nothing queued: nothing to miss
        }
        let late_out = late_env.step(&schedule_action(l, 0, 0.5));
        if let Some(sch) = &late_out.scheduled {
            assert_eq!(sch.deadline_met, Some(false));
            assert!(
                late_out.reward < now_reward,
                "missed-deadline reward {} should trail met-deadline {}",
                late_out.reward,
                now_reward
            );
        }
    }

    #[test]
    fn flash_scenario_bounds_its_queue() {
        use crate::workload::WorkloadConfig;
        // The flash preset now ships a drop-tail admission default: under
        // its 6x spike the pending queue must stay within the bound.
        let mut cfg = ExperimentConfig::preset_8node(0.1).env;
        cfg.workload = Some(WorkloadConfig::preset("flash", 0.1).unwrap());
        cfg.tasks_per_episode = 96;
        let mut e = EdgeEnv::new(cfg, 35);
        let l = e.cfg.queue_window;
        let mut max_queue = 0usize;
        loop {
            max_queue = max_queue.max(e.queue().len());
            if e.step(&Action::noop(l)).done {
                break;
            }
        }
        assert!(max_queue <= 16, "flash queue grew to {max_queue}");
        let rep = e.report();
        assert!(rep.dropped_tasks > 0, "the spike must shed load");
        assert_eq!(rep.completed_tasks + rep.dropped_tasks, rep.total_tasks - e.queue().len());
    }

    /// A 2-server, 2-patch, single-model env with an active (but inert
    /// unless scripted) fault section: scripted tests drive the health
    /// timeline deterministically.
    fn scripted_fault_cfg(max_retries: u32, spec_beta: f64) -> EnvConfig {
        let mut cfg = ExperimentConfig::preset_4node(0.05).env;
        cfg.num_servers = 2;
        cfg.num_models = 1;
        cfg.patch_choices = vec![2];
        cfg.patch_weights = vec![1.0];
        cfg.tasks_per_episode = 1;
        cfg.faults = Some(FaultsConfig {
            mtbf: 0.0,
            zone_shock_rate: 0.0,
            straggler_rate: 1e-9, // active, but never fires before scripting
            spec_beta,
            max_retries,
            ..FaultsConfig::default()
        });
        cfg
    }

    fn run_to_done(e: &mut EdgeEnv) -> EpisodeReport {
        let l = e.cfg.queue_window;
        for _ in 0..=e.cfg.step_limit {
            if e.step(&schedule_action(l, 0, 0.5)).done {
                break;
            }
        }
        e.report()
    }

    fn assert_work_balance(rep: &EpisodeReport) {
        let sum = rep.completed_patch_s + rep.wasted_patch_s + rep.inflight_patch_s;
        assert!(
            (sum - rep.dispatched_patch_s).abs() <= 1e-6 * rep.dispatched_patch_s.max(1.0),
            "patch-second books don't balance: dispatched {} vs completed {} + wasted {} + inflight {}",
            rep.dispatched_patch_s,
            rep.completed_patch_s,
            rep.wasted_patch_s,
            rep.inflight_patch_s
        );
    }

    #[test]
    fn inert_faults_section_is_bit_identical_to_none() {
        // The regression guard of this PR: `faults: Some(off)` builds no
        // fault runtime, so episodes match `faults: None` bit-for-bit.
        let run = |faults: Option<FaultsConfig>| {
            let mut cfg = ExperimentConfig::preset_8node(0.1).env;
            cfg.faults = faults;
            let mut e = EdgeEnv::new(cfg, 91);
            let rep = run_to_done(&mut e);
            assert!(e.fault_events().is_empty());
            rep
        };
        let a = run(None);
        let b = run(Some(FaultsConfig::off()));
        assert_eq!(a.completed_tasks, b.completed_tasks);
        assert_eq!(a.total_reward.to_bits(), b.total_reward.to_bits());
        assert_eq!(a.avg_response_latency.to_bits(), b.avg_response_latency.to_bits());
        assert_eq!(a.p99_latency.to_bits(), b.p99_latency.to_bits());
        assert_eq!(a.avg_quality.to_bits(), b.avg_quality.to_bits());
        assert_eq!(a.reloads, b.reloads);
        assert_eq!(b.failures, 0);
        assert_eq!(b.dispatched_patch_s, 0.0);
    }

    #[test]
    fn active_faults_keep_arrivals_and_exec_draws_crn_paired() {
        // The fault stream forks from a *clone* of the env RNG: enabling
        // churn must not move the arrival sequence or the first dispatch's
        // execution-jitter draw.
        let first_sch = |faults: Option<FaultsConfig>| {
            let mut cfg = ExperimentConfig::preset_8node(0.1).env;
            cfg.faults = faults;
            let mut e = EdgeEnv::new(cfg, 17);
            let arrivals = e.workload_arrivals();
            let l = e.cfg.queue_window;
            loop {
                if let Some(sch) = e.step(&schedule_action(l, 0, 0.5)).scheduled {
                    return (arrivals, sch.duration);
                }
            }
        };
        let (arr_a, dur_a) = first_sch(None);
        let (arr_b, dur_b) = first_sch(Some(FaultsConfig::default()));
        assert_eq!(arr_a.len(), arr_b.len());
        for (x, y) in arr_a.iter().zip(&arr_b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(dur_a.to_bits(), dur_b.to_bits());
    }

    #[test]
    fn scripted_failure_kills_gang_requeues_and_recovers_cold() {
        let cfg = scripted_fault_cfg(3, 0.0);
        let wl = Workload::fixed(&[(0.0, 2, 0)]);
        let mut e = EdgeEnv::with_workload(cfg, wl, Pcg64::seeded(5));
        e.script_faults(vec![
            FaultEvent { t: 5.0, server: 0, kind: FaultKind::Fail },
            FaultEvent { t: 6.0, server: 0, kind: FaultKind::Recover },
        ])
        .unwrap();
        let rep = run_to_done(&mut e);
        assert_eq!(rep.completed_tasks, 1, "the retried task must finish");
        assert_eq!(rep.failures, 1);
        assert_eq!(rep.gang_kills, 1);
        assert_eq!(rep.retries, 1);
        assert_eq!(rep.failed_tasks, 0);
        assert!(rep.wasted_patch_s > 0.0, "the killed attempt is wasted work");
        assert_work_balance(&rep);
        // Two fresh loads: the killed attempt's and the retry's — the
        // recovered server came back weight-cold.
        assert_eq!(rep.reloads, 2);
        // The re-queued task kept its arrival: its waiting spans the kill.
        let done = e.trace().last().unwrap();
        assert!(done.waiting >= 5.0, "waiting {} must span the failure", done.waiting);
        assert!(rep.goodput > 0.0);
    }

    #[test]
    fn retry_budget_exhaustion_drops_the_task() {
        let cfg = scripted_fault_cfg(1, 0.0);
        let wl = Workload::fixed(&[(0.0, 2, 0)]);
        let mut e = EdgeEnv::with_workload(cfg, wl, Pcg64::seeded(6));
        e.script_faults(vec![
            FaultEvent { t: 2.0, server: 0, kind: FaultKind::Fail },
            FaultEvent { t: 3.0, server: 0, kind: FaultKind::Recover },
            FaultEvent { t: 6.0, server: 0, kind: FaultKind::Fail },
        ])
        .unwrap();
        let rep = run_to_done(&mut e);
        assert_eq!(rep.completed_tasks, 0);
        assert_eq!(rep.failed_tasks, 1, "second kill exceeds max_retries=1");
        assert_eq!(rep.gang_kills, 2);
        assert_eq!(rep.retries, 1);
        assert_work_balance(&rep);
        // The episode resolves (dropped task counts as done) long before
        // the step limit.
        assert!(rep.decision_steps < 100, "steps {}", rep.decision_steps);
    }

    #[test]
    fn speculative_backup_beats_straggling_primary() {
        let mut cfg = scripted_fault_cfg(3, 1.5);
        cfg.patch_choices = vec![1];
        cfg.tasks_per_episode = 2;
        let wl = Workload::fixed(&[(0.0, 1, 0), (1.0, 1, 0)]);
        let mut e = EdgeEnv::with_workload(cfg, wl, Pcg64::seeded(7));
        // Server 0 (task 0's host) slows 20x shortly after dispatch; the
        // warm server 1 hosts the backup once beta x nominal elapses.
        e.script_faults(vec![FaultEvent {
            t: 2.0,
            server: 0,
            kind: FaultKind::SlowStart { factor: 20.0, duration: 1000.0 },
        }])
        .unwrap();
        let rep = run_to_done(&mut e);
        assert_eq!(rep.completed_tasks, 2);
        assert_eq!(rep.spec_launches, 1);
        assert_eq!(rep.spec_wins, 1, "the warm backup must win the race");
        assert!(rep.wasted_patch_s > 0.0, "the aborted primary is wasted work");
        assert_work_balance(&rep);
        // Without speculation the 20x-slowed primary would run ~800 s;
        // the backup resolves the episode in a fraction of that.
        assert!(rep.sim_time < 300.0, "sim_time {}", rep.sim_time);
    }

    #[test]
    fn early_finished_member_can_serve_another_task_without_corruption() {
        // A straggler desynchronises a gang: the fast member finishes its
        // patch early and is re-dispatched to another task. The straggling
        // gang's completion must wait only for its own straggler, and the
        // re-hosted task must run to its own completion.
        let mut cfg = scripted_fault_cfg(3, 0.0);
        cfg.patch_choices = vec![1, 2];
        cfg.patch_weights = vec![1.0, 1.0];
        cfg.tasks_per_episode = 2;
        let wl = Workload::fixed(&[(0.0, 2, 0), (1.0, 1, 0)]);
        let mut e = EdgeEnv::with_workload(cfg, wl, Pcg64::seeded(9));
        e.script_faults(vec![FaultEvent {
            t: 2.0,
            server: 1,
            kind: FaultKind::SlowStart { factor: 5.0, duration: 1000.0 },
        }])
        .unwrap();
        let rep = run_to_done(&mut e);
        assert_eq!(rep.completed_tasks, 2);
        assert_eq!(rep.gang_kills, 0);
        assert_eq!(rep.failed_tasks, 0);
        assert_work_balance(&rep);
        let find = |id: u64| e.trace().iter().find(|s| s.task_id == id).unwrap();
        let (slow, quick) = (find(0), find(1));
        // The re-hosted task's duration is its own full run, not a stub.
        assert!(quick.duration > 30.0, "duration {}", quick.duration);
        // The gang task is paced by its 5x straggler, far past the other.
        assert!(
            slow.response > quick.response + 50.0,
            "slow {} quick {}",
            slow.response,
            quick.response
        );
    }

    #[test]
    fn straggler_gang_kill_spares_a_rehosted_member() {
        // While task 0's gang straggles on server 1, server 0 has already
        // finished its patch and is running task 1. Killing task 0's gang
        // (server 1 fails) must not destroy server 0's new work.
        let mut cfg = scripted_fault_cfg(3, 0.0);
        cfg.patch_choices = vec![1, 2];
        cfg.patch_weights = vec![1.0, 1.0];
        cfg.tasks_per_episode = 2;
        let wl = Workload::fixed(&[(0.0, 2, 0), (1.0, 1, 0)]);
        let mut e = EdgeEnv::with_workload(cfg, wl, Pcg64::seeded(10));
        e.script_faults(vec![
            FaultEvent {
                t: 2.0,
                server: 1,
                kind: FaultKind::SlowStart { factor: 5.0, duration: 1000.0 },
            },
            FaultEvent { t: 50.0, server: 1, kind: FaultKind::Fail },
            FaultEvent { t: 60.0, server: 1, kind: FaultKind::Recover },
        ])
        .unwrap();
        let rep = run_to_done(&mut e);
        assert_eq!(rep.completed_tasks, 2, "both tasks must finish");
        assert_eq!(rep.gang_kills, 1);
        assert_eq!(rep.retries, 1);
        assert_work_balance(&rep);
        let find = |id: u64| e.trace().iter().find(|s| s.task_id == id).unwrap();
        // Task 1 survived the kill of the gang its server used to host:
        // its ~44 s run is intact, not truncated at the failure instant.
        assert!(find(1).duration > 30.0, "duration {}", find(1).duration);
        // Task 0 was re-queued and completed on its second attempt, after
        // waiting out the failure and the busy fast server.
        assert!(find(0).waiting >= 50.0, "waiting {}", find(0).waiting);
    }

    #[test]
    fn health_state_row_tracks_churn() {
        let mut cfg = scripted_fault_cfg(3, 0.0);
        cfg.state_features.health = true;
        cfg.tasks_per_episode = 1;
        let wl = Workload::fixed(&[(500.0, 2, 0)]); // keep the cluster idle
        let mut e = EdgeEnv::with_workload(cfg, wl, Pcg64::seeded(8));
        e.script_faults(vec![
            FaultEvent { t: 1.0, server: 0, kind: FaultKind::Fail },
            FaultEvent {
                t: 1.0,
                server: 1,
                kind: FaultKind::SlowStart { factor: 2.0, duration: 50.0 },
            },
        ])
        .unwrap();
        let l = e.cfg.queue_window;
        assert_eq!(e.state().len(), e.cfg.state_len());
        e.step(&Action::noop(l));
        let s = e.state();
        let cols = e.cfg.state_cols();
        assert_eq!(s[3 * cols], 0.0, "down server reads 0 health");
        assert_eq!(s[3 * cols + 1], 0.5, "2x straggler reads 1/2 health");
        // Queue columns of the health row stay zero.
        assert_eq!(s[3 * cols + 2], 0.0);
    }

    #[test]
    fn tenancy_state_rows_expose_slack_and_weight() {
        let mut cfg = tenant_cfg(0.3);
        cfg.state_features.tenancy = true;
        let mut e = EdgeEnv::new(cfg, 44);
        let l = e.cfg.queue_window;
        while e.queue().is_empty() {
            e.step(&Action::noop(l));
        }
        let s = e.state();
        assert_eq!(s.len(), e.cfg.state_len());
        let cols = e.cfg.state_cols();
        let e_servers = e.cfg.num_servers;
        let head = &e.queue()[0];
        let slack_row = 3 * cols;
        let weight_row = 4 * cols;
        let expect_slack =
            (((head.deadline.unwrap() - e.now()) as f32) / 100.0).clamp(-1.0, 4.0);
        assert!((s[slack_row + e_servers] - expect_slack).abs() < 1e-6);
        let w = s[weight_row + e_servers];
        assert!(w > 0.0 && w <= 1.0, "weight feature {w} outside (0,1]");
        // Server columns of the tenancy rows stay zero.
        assert_eq!(s[slack_row], 0.0);
        assert_eq!(s[weight_row], 0.0);
    }

    #[test]
    fn per_task_quality_demand_drives_below_min_accounting() {
        use crate::workload::{ModelMix, QualityDemand, WorkloadConfig};
        // An impossibly strict demand on every task: everything scheduled
        // must count as below its quality floor.
        let mut cfg = ExperimentConfig::preset_8node(0.1).env;
        cfg.workload = Some(WorkloadConfig {
            arrival: crate::workload::ArrivalConfig::Poisson { rate: 0.1 },
            model_mix: ModelMix::Uniform,
            quality_demand: QualityDemand::Uniform { lo: 0.9, hi: 0.95 },
            admission: crate::qos::AdmissionConfig::AdmitAll,
        });
        cfg.tasks_per_episode = 8;
        let mut e = EdgeEnv::new(cfg, 22);
        let l = e.cfg.queue_window;
        loop {
            if e.step(&schedule_action(l, 0, 1.0)).done {
                break;
            }
        }
        let rep = e.report();
        assert!(rep.completed_tasks > 0);
        assert_eq!(rep.below_quality_min, rep.completed_tasks);
    }

    // --- event-driven core vs tick-scan core: bit-exact CRN pairing ---
    //
    // `set_legacy_scan(true)` routes every hot path back through the
    // seed's full-fleet scans (selection, busy credit, advance, fault
    // sweeps, per-tick speculative scan). These tests pin that the
    // indexed/evented paths produce byte-identical episodes.

    fn assert_reports_bit_identical(a: &EpisodeReport, b: &EpisodeReport) {
        assert_eq!(a.completed_tasks, b.completed_tasks);
        assert_eq!(a.total_tasks, b.total_tasks);
        assert_eq!(a.decision_steps, b.decision_steps);
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits());
        assert_eq!(a.total_reward.to_bits(), b.total_reward.to_bits());
        assert_eq!(a.avg_quality.to_bits(), b.avg_quality.to_bits());
        assert_eq!(
            a.avg_response_latency.to_bits(),
            b.avg_response_latency.to_bits()
        );
        assert_eq!(a.p50_latency.to_bits(), b.p50_latency.to_bits());
        assert_eq!(a.p90_latency.to_bits(), b.p90_latency.to_bits());
        assert_eq!(a.p99_latency.to_bits(), b.p99_latency.to_bits());
        assert_eq!(a.avg_utilization.to_bits(), b.avg_utilization.to_bits());
        assert_eq!(a.reload_rate.to_bits(), b.reload_rate.to_bits());
        assert_eq!(a.reloads, b.reloads);
        assert_eq!(a.below_quality_min, b.below_quality_min);
        assert_eq!(a.infeasible_actions, b.infeasible_actions);
        assert_eq!(a.avg_steps_chosen.to_bits(), b.avg_steps_chosen.to_bits());
        assert_eq!(a.efficiency.to_bits(), b.efficiency.to_bits());
        assert_eq!(a.dropped_tasks, b.dropped_tasks);
        assert_eq!(a.goodput.to_bits(), b.goodput.to_bits());
        assert_eq!(a.failures, b.failures);
        assert_eq!(a.gang_kills, b.gang_kills);
        assert_eq!(a.retries, b.retries);
        assert_eq!(a.failed_tasks, b.failed_tasks);
        assert_eq!(a.spec_launches, b.spec_launches);
        assert_eq!(a.spec_wins, b.spec_wins);
        assert_eq!(a.dispatched_patch_s.to_bits(), b.dispatched_patch_s.to_bits());
        assert_eq!(a.completed_patch_s.to_bits(), b.completed_patch_s.to_bits());
        assert_eq!(a.wasted_patch_s.to_bits(), b.wasted_patch_s.to_bits());
        assert_eq!(a.inflight_patch_s.to_bits(), b.inflight_patch_s.to_bits());
        assert_eq!(a.wasted_work_frac.to_bits(), b.wasted_work_frac.to_bits());
        assert_eq!(a.tenant_reports.len(), b.tenant_reports.len());
        for (ta, tb) in a.tenant_reports.iter().zip(&b.tenant_reports) {
            assert_eq!(ta.completed, tb.completed);
            assert_eq!(ta.dropped, tb.dropped);
        }
    }

    /// The greedy head-first dispatcher the experiment runners use: it
    /// exercises `first_feasible` (and so the infeasibility memo), the
    /// selection index, and the busy-set advance on every tick.
    fn run_head_first(mut e: EdgeEnv, legacy: bool) -> EpisodeReport {
        e.set_legacy_scan(legacy);
        let l = e.cfg.queue_window;
        let s_max = e.cfg.s_max;
        for _ in 0..=e.cfg.step_limit {
            while let Some(idx) = e.first_feasible() {
                if e.schedule_task_at(idx, s_max).is_none() {
                    break;
                }
            }
            if e.step(&Action::noop(l)).done {
                break;
            }
        }
        e.report()
    }

    #[test]
    fn event_core_matches_tick_core_plain() {
        for seed in [11_u64, 12, 13] {
            let cfg = ExperimentConfig::preset_8node(0.1).env;
            let tick = run_head_first(EdgeEnv::new(cfg.clone(), seed), true);
            let event = run_head_first(EdgeEnv::new(cfg, seed), false);
            assert!(event.completed_tasks > 0, "trivial episode at seed {seed}");
            assert_reports_bit_identical(&tick, &event);
        }
    }

    #[test]
    fn event_core_matches_tick_core_policy_driven() {
        // The action path (policy scheduling via `step`) instead of the
        // head-first loop, over a scenario-style mixed workload.
        for seed in [21_u64, 22] {
            let run = |legacy: bool| {
                let mut cfg = ExperimentConfig::preset_8node(0.12).env;
                cfg.tasks_per_episode = 40;
                let mut e = EdgeEnv::new(cfg, seed);
                e.set_legacy_scan(legacy);
                run_to_done(&mut e)
            };
            assert_reports_bit_identical(&run(true), &run(false));
        }
    }

    #[test]
    fn event_core_matches_tick_core_with_tenants() {
        for seed in [31_u64, 32] {
            let tick = run_head_first(EdgeEnv::new(tenant_cfg(0.3), seed), true);
            let event = run_head_first(EdgeEnv::new(tenant_cfg(0.3), seed), false);
            assert_reports_bit_identical(&tick, &event);
        }
    }

    #[test]
    fn event_core_matches_tick_core_under_stochastic_faults() {
        // Churn + stragglers + speculation, under both fault-blind and
        // health-aware dispatch — the full fault sweep incl. the evented
        // speculative-deadline path.
        for health_aware in [false, true] {
            for seed in [41_u64, 42] {
                let cfg = || {
                    let mut cfg = ExperimentConfig::preset_8node(0.1).env;
                    cfg.tasks_per_episode = 40;
                    cfg.faults = Some(FaultsConfig {
                        mtbf: 150.0,
                        mttr: 60.0,
                        zones: 4,
                        zone_shock_rate: 0.002,
                        straggler_rate: 0.01,
                        spec_beta: 1.5,
                        max_retries: 3,
                        health_aware,
                        ..FaultsConfig::default()
                    });
                    cfg
                };
                let tick = run_head_first(EdgeEnv::new(cfg(), seed), true);
                let event = run_head_first(EdgeEnv::new(cfg(), seed), false);
                assert_reports_bit_identical(&tick, &event);
            }
        }
    }

    #[test]
    fn event_core_matches_tick_core_on_scripted_fault_replay() {
        // Record a live churn episode's fault trace, then replay it
        // scripted on both cores: all three must agree bit-for-bit.
        let cfg = || {
            let mut cfg = ExperimentConfig::preset_8node(0.1).env;
            cfg.tasks_per_episode = 32;
            cfg.faults = Some(FaultsConfig {
                mtbf: 120.0,
                mttr: 40.0,
                zones: 4,
                straggler_rate: 0.01,
                spec_beta: 1.4,
                max_retries: 3,
                ..FaultsConfig::default()
            });
            cfg
        };
        let mut live = EdgeEnv::new(cfg(), 51);
        let l = live.cfg.queue_window;
        let s_max = live.cfg.s_max;
        for _ in 0..=live.cfg.step_limit {
            while let Some(idx) = live.first_feasible() {
                if live.schedule_task_at(idx, s_max).is_none() {
                    break;
                }
            }
            if live.step(&Action::noop(l)).done {
                break;
            }
        }
        let trace = live.fault_events().to_vec();
        let live_rep = live.report();
        let replay = |legacy: bool| {
            let mut e = EdgeEnv::new(cfg(), 51);
            e.script_faults(trace.clone()).unwrap();
            run_head_first(e, legacy)
        };
        assert_reports_bit_identical(&live_rep, &replay(true));
        assert_reports_bit_identical(&live_rep, &replay(false));
    }

    // --- lifecycle tracing: determinism, core-agnosticism, exact books ---

    fn churn_cfg() -> EnvConfig {
        let mut cfg = ExperimentConfig::preset_8node(0.1).env;
        cfg.tasks_per_episode = 40;
        cfg.faults = Some(FaultsConfig {
            mtbf: 150.0,
            mttr: 60.0,
            zones: 4,
            zone_shock_rate: 0.002,
            straggler_rate: 0.01,
            spec_beta: 1.5,
            max_retries: 3,
            ..FaultsConfig::default()
        });
        cfg
    }

    #[test]
    fn tracing_on_or_off_is_bit_identical() {
        // Recording draws from no RNG stream and touches no accounting:
        // episodes must not move by a bit when tracing is enabled — plain
        // and under churn, on both cores.
        for legacy in [false, true] {
            let cases = [(ExperimentConfig::preset_8node(0.1).env, 71_u64), (churn_cfg(), 72)];
            for (cfg, seed) in cases {
                let plain = run_head_first(EdgeEnv::new(cfg.clone(), seed), legacy);
                let mut e = EdgeEnv::new(cfg, seed);
                e.enable_tracing(1 << 14);
                let traced = run_head_first(e, legacy);
                assert_reports_bit_identical(&plain, &traced);
            }
        }
    }

    #[test]
    fn sampling_on_or_off_is_bit_identical() {
        // The sampler reads cumulative counters and draws from no RNG
        // stream: episodes must not move by a bit when sampling is
        // enabled — plain, under churn, and with tenants, on both cores.
        for legacy in [false, true] {
            let cases = [
                (ExperimentConfig::preset_8node(0.1).env, 71_u64),
                (churn_cfg(), 72),
                (tenant_cfg(0.3), 73),
            ];
            for (cfg, seed) in cases {
                let plain = run_head_first(EdgeEnv::new(cfg.clone(), seed), legacy);
                let mut e = EdgeEnv::new(cfg, seed);
                e.enable_sampling(25.0, FleetSeries::default_capacity());
                let sampled = run_head_first(e, legacy);
                assert_reports_bit_identical(&plain, &sampled);
            }
        }
    }

    fn sampled_head_first(mut e: EdgeEnv, legacy: bool) -> FleetSeries {
        e.enable_sampling(25.0, FleetSeries::default_capacity());
        e.set_legacy_scan(legacy);
        let l = e.cfg.queue_window;
        let s_max = e.cfg.s_max;
        for _ in 0..=e.cfg.step_limit {
            while let Some(idx) = e.first_feasible() {
                if e.schedule_task_at(idx, s_max).is_none() {
                    break;
                }
            }
            if e.step(&Action::noop(l)).done {
                break;
            }
        }
        e.take_series().unwrap()
    }

    #[test]
    fn both_cores_sample_identical_series() {
        for (cfg, seed) in [(ExperimentConfig::preset_8node(0.1).env, 81_u64), (tenant_cfg(0.3), 83)] {
            let tick = sampled_head_first(EdgeEnv::new(cfg.clone(), seed), true).to_jsonl();
            let event = sampled_head_first(EdgeEnv::new(cfg.clone(), seed), false).to_jsonl();
            assert!(tick.lines().count() > 1, "no windows sampled:\n{tick}");
            assert_eq!(tick, event, "fleet series diverge between cores");
        }
    }

    #[test]
    fn sharded_series_merge_is_bit_identical_across_thread_counts() {
        // N episodes sampled under par::map_cells fan-out, merged in
        // slot order: the pooled series must be byte-identical no matter
        // how many threads ran the shards.
        let episode =
            |ep: u64| sampled_head_first(EdgeEnv::new(tenant_cfg(0.3), 100 + ep), false);
        let merged_with = |threads: usize| {
            let shards =
                crate::util::par::map_cells((0..6u64).collect::<Vec<_>>(), threads, episode);
            let mut pooled: Option<FleetSeries> = None;
            for s in &shards {
                match pooled.as_mut() {
                    Some(p) => p.merge(s),
                    None => pooled = Some(s.clone()),
                }
            }
            pooled.unwrap().to_jsonl()
        };
        let single = merged_with(1);
        assert!(single.lines().count() > 1, "no windows sampled");
        for threads in [3usize, 4] {
            assert_eq!(single, merged_with(threads), "merge diverges at {threads} threads");
        }
    }

    #[test]
    fn sampled_series_counters_reconcile_with_the_report() {
        // Window sums must add back up to the episode's own accounting:
        // per-tenant hits+misses cover every resolved outcome, and the
        // wasted patch-seconds total matches the report bit-for-bit in
        // sum (same fold order as the sampler's diffs).
        let mut e = EdgeEnv::new(tenant_cfg(0.3), 97);
        e.enable_sampling(25.0, FleetSeries::default_capacity());
        let l = e.cfg.queue_window;
        let s_max = e.cfg.s_max;
        for _ in 0..=e.cfg.step_limit {
            while let Some(idx) = e.first_feasible() {
                if e.schedule_task_at(idx, s_max).is_none() {
                    break;
                }
            }
            if e.step(&Action::noop(l)).done {
                break;
            }
        }
        let rep = e.report();
        let series = e.take_series().unwrap();
        assert_eq!(series.tenants(), ["premium", "standard", "batch"]);
        let mut hits = vec![0u64; 3];
        let mut misses = vec![0u64; 3];
        let mut loads = 0u64;
        for w in series.samples() {
            for i in 0..3 {
                hits[i] += w.hits[i];
                misses[i] += w.misses[i];
            }
            loads += w.model_loads;
        }
        for (i, tr) in rep.tenant_reports.iter().enumerate() {
            assert_eq!(hits[i], tr.slo_met, "tenant {i} hits");
            assert_eq!(
                hits[i] + misses[i],
                tr.completed + tr.dropped,
                "tenant {i} outcomes"
            );
        }
        assert!(loads > 0, "an episode with reloads must sample model loads");
    }

    #[test]
    fn event_and_tick_cores_emit_identical_traces() {
        // The span stream is part of the bit-exactness contract: both
        // simulator cores must emit byte-identical JSONL.
        for (cfg, seed) in [(ExperimentConfig::preset_8node(0.1).env, 81_u64), (churn_cfg(), 82)] {
            let run = |legacy: bool| {
                let mut e = EdgeEnv::new(cfg.clone(), seed);
                e.enable_tracing(1 << 14);
                e.set_legacy_scan(legacy);
                let l = e.cfg.queue_window;
                let s_max = e.cfg.s_max;
                for _ in 0..=e.cfg.step_limit {
                    while let Some(idx) = e.first_feasible() {
                        if e.schedule_task_at(idx, s_max).is_none() {
                            break;
                        }
                    }
                    if e.step(&Action::noop(l)).done {
                        break;
                    }
                }
                e.take_tracer().unwrap().to_jsonl()
            };
            let tick = run(true);
            let event = run(false);
            assert!(!tick.is_empty());
            assert_eq!(tick, event, "span streams diverge between cores");
        }
    }

    #[test]
    fn fault_episode_trace_decomposes_every_task_exactly() {
        use crate::obs::analyze::analyze;
        let mut e = EdgeEnv::new(churn_cfg(), 91);
        e.enable_tracing(1 << 14);
        let rep = run_head_first(e.clone(), false);
        // Re-run on the traced env itself (clone above kept the tracer).
        let rep2 = {
            let l = e.cfg.queue_window;
            let s_max = e.cfg.s_max;
            for _ in 0..=e.cfg.step_limit {
                while let Some(idx) = e.first_feasible() {
                    if e.schedule_task_at(idx, s_max).is_none() {
                        break;
                    }
                }
                if e.step(&Action::noop(l)).done {
                    break;
                }
            }
            e.report()
        };
        assert_reports_bit_identical(&rep, &rep2);
        let tr = e.take_tracer().unwrap();
        assert_eq!(tr.evicted(), 0, "ring must be large enough for this episode");
        let a = analyze(&tr.events());
        // Books: every completed task decomposes to its measured latency
        // bit-exactly, through kills, retries and speculative races.
        a.check_books().unwrap();
        assert_eq!(a.tasks.len(), rep.completed_tasks, "one decomposition per completion");
        // Anything not completed/dropped was still queued or in flight
        // when the episode ended — skipped, never mis-attributed.
        assert!(
            a.incomplete <= rep.total_tasks - rep.completed_tasks,
            "incomplete {} exceeds unresolved tasks",
            a.incomplete
        );
        assert_eq!(a.dropped, rep.dropped_tasks + rep.failed_tasks);
        assert_eq!(a.suspect, 0, "no materially negative residuals");
        if rep.retries > 0 {
            assert!(
                a.tasks.iter().any(|d| d.retry > 0.0),
                "an episode with retries must show retry latency"
            );
        }
        if rep.spec_wins > 0 {
            assert_eq!(a.tasks.iter().filter(|d| d.spec_win).count(), rep.spec_wins);
        }
        // JSONL round trip preserves the books bit-exactly.
        let reparsed = crate::obs::trace::parse_jsonl(&tr.to_jsonl()).unwrap();
        analyze(&reparsed).check_books().unwrap();
    }

    // --- decision ledger: determinism, joins, regret, shard merge ---

    fn decisions_head_first(mut e: EdgeEnv, legacy: bool) -> DecisionLedger {
        e.enable_decisions("head-first", DecisionLedger::default_capacity());
        e.set_legacy_scan(legacy);
        let l = e.cfg.queue_window;
        let s_max = e.cfg.s_max;
        for _ in 0..=e.cfg.step_limit {
            while let Some(idx) = e.first_feasible() {
                if e.schedule_task_at(idx, s_max).is_none() {
                    break;
                }
            }
            if e.step(&Action::noop(l)).done {
                break;
            }
        }
        e.take_decisions().unwrap()
    }

    #[test]
    fn decision_recording_on_or_off_is_bit_identical() {
        // The recorder draws from no RNG stream and reads cluster state
        // before `dispatch` mutates it: episodes must not move by a bit
        // when recording is enabled — plain, under churn, and with
        // tenants, on both cores.
        for legacy in [false, true] {
            let cases = [
                (ExperimentConfig::preset_8node(0.1).env, 71_u64),
                (churn_cfg(), 72),
                (tenant_cfg(0.3), 73),
            ];
            for (cfg, seed) in cases {
                let plain = run_head_first(EdgeEnv::new(cfg.clone(), seed), legacy);
                let mut e = EdgeEnv::new(cfg, seed);
                e.enable_decisions("head-first", 1 << 14);
                let recorded = run_head_first(e, legacy);
                assert_reports_bit_identical(&plain, &recorded);
            }
        }
    }

    #[test]
    fn both_cores_record_identical_decision_ledgers() {
        // Candidate enumeration uses the deterministic gang-id scan, so
        // the ledger (state, candidates, outcomes) is part of the
        // core-agnosticism contract: byte-identical JSONL.
        for (cfg, seed) in [(ExperimentConfig::preset_8node(0.1).env, 84_u64), (churn_cfg(), 85)] {
            let tick = decisions_head_first(EdgeEnv::new(cfg.clone(), seed), true).to_jsonl();
            let event = decisions_head_first(EdgeEnv::new(cfg.clone(), seed), false).to_jsonl();
            assert!(tick.lines().count() > 1, "no decisions recorded:\n{tick}");
            assert_eq!(tick, event, "decision ledgers diverge between cores");
        }
    }

    #[test]
    fn fault_episode_decisions_join_and_regret_books_balance() {
        // End-to-end over a churn episode (kills, retries, speculative
        // races, drops): every decision joins to a realized outcome or is
        // reported in-flight, regret is non-negative with the oracle
        // bounded by the realized response, and the experience export
        // round-trips into the replay buffer at the env's own dims.
        let mut e = EdgeEnv::new(churn_cfg(), 91);
        e.enable_decisions("head-first", 1 << 14);
        let sdim = e.state().len();
        let adim = 2 + e.cfg.queue_window;
        let l = e.cfg.queue_window;
        let s_max = e.cfg.s_max;
        for _ in 0..=e.cfg.step_limit {
            while let Some(idx) = e.first_feasible() {
                if e.schedule_task_at(idx, s_max).is_none() {
                    break;
                }
            }
            if e.step(&Action::noop(l)).done {
                break;
            }
        }
        let rep = e.report();
        let ledger = e.take_decisions().unwrap();
        assert_eq!(ledger.evicted(), 0, "ring must be large enough for this episode");
        assert!(
            ledger.len() >= rep.completed_tasks,
            "every completion implies at least one dispatch decision"
        );
        for r in ledger.records() {
            assert!(!r.candidates.is_empty(), "decision {} has no candidates", r.seq);
            assert!(r.chosen < r.candidates.len());
            if let (Some(oracle), Some(out)) = (r.oracle_response(), r.outcome) {
                assert!(oracle <= out.response + 1e-12, "oracle beats physics at {}", r.seq);
                assert!(r.regret().unwrap() >= 0.0, "negative regret at {}", r.seq);
            }
        }
        let a = crate::obs::decisions::analyze(&ledger);
        a.check_books().unwrap();
        assert_eq!(
            a.completed + a.dropped + a.inflight,
            ledger.len(),
            "decisions neither joined nor reported in-flight"
        );
        assert!(a.dropped > 0 || rep.failed_tasks == 0, "drops must join too");
        assert!(a.groups[0].count > 0, "aggregate regret group is empty");
        // JSONL round trip preserves the books.
        let reparsed = DecisionLedger::parse_jsonl(&ledger.to_jsonl()).unwrap();
        crate::obs::decisions::analyze(&reparsed).check_books().unwrap();
        // Offline experience: loads into the RL tier's replay buffer.
        let text = crate::obs::decisions::export_experience(&ledger).unwrap();
        let rb = crate::rl::replay::ReplayBuffer::from_experience_jsonl(&text, 1 << 16).unwrap();
        assert!(!rb.is_empty(), "no experience tuples exported");
        let b = rb.sample(4, &mut Pcg64::seeded(11));
        assert_eq!(b.s.len(), 4 * sdim, "state dim differs from the env's");
        assert_eq!(b.a.len(), 4 * adim, "action dim differs from the env's");
    }

    #[test]
    fn sharded_decision_merge_is_bit_identical_across_thread_counts() {
        // N episodes recorded under par::map_cells fan-out, merged in
        // slot order: the pooled ledger must be byte-identical no matter
        // how many threads ran the shards.
        let episode = |ep: u64| {
            let mut led = decisions_head_first(EdgeEnv::new(tenant_cfg(0.3), 100 + ep), false);
            led.tag_episode(ep);
            led
        };
        let merged_with = |threads: usize| {
            let shards =
                crate::util::par::map_cells((0..6u64).collect::<Vec<_>>(), threads, episode);
            let mut pooled: Option<DecisionLedger> = None;
            for s in &shards {
                match pooled.as_mut() {
                    Some(p) => p.merge(s),
                    None => pooled = Some(s.clone()),
                }
            }
            pooled.unwrap().to_jsonl()
        };
        let single = merged_with(1);
        assert!(single.lines().count() > 1, "no decisions recorded");
        for threads in [3usize, 4] {
            assert_eq!(single, merged_with(threads), "merge diverges at {threads} threads");
        }
    }

    #[test]
    fn traced_speculative_win_is_attributed_to_the_backup() {
        use crate::obs::analyze::analyze;
        let mut cfg = scripted_fault_cfg(3, 1.5);
        cfg.patch_choices = vec![1];
        cfg.tasks_per_episode = 2;
        let wl = Workload::fixed(&[(0.0, 1, 0), (1.0, 1, 0)]);
        let mut e = EdgeEnv::with_workload(cfg, wl, Pcg64::seeded(7));
        e.enable_tracing(1 << 10);
        e.script_faults(vec![FaultEvent {
            t: 2.0,
            server: 0,
            kind: FaultKind::SlowStart { factor: 20.0, duration: 1000.0 },
        }])
        .unwrap();
        let rep = run_to_done(&mut e);
        assert_eq!(rep.spec_wins, 1);
        let a = analyze(&e.take_tracer().unwrap().events());
        a.check_books().unwrap();
        let winner = a.tasks.iter().find(|d| d.spec_win).expect("a spec win must be traced");
        // The backup launched past beta x nominal: its decomposition books
        // that lead time as retry latency, warm (no cold component).
        assert!(winner.retry > 0.0, "retry {}", winner.retry);
        assert_eq!(winner.cold, 0.0);
        assert!(!winner.cold_start);
        assert!(winner.attempts >= 2);
    }

    #[test]
    fn first_feasible_memo_matches_full_rescan() {
        // At every decision point of a driven episode, the memo-backed
        // `first_feasible` must agree with the seed's full rescan on an
        // identical clone.
        let mut e = EdgeEnv::new(ExperimentConfig::preset_8node(0.15).env, 61);
        let l = e.cfg.queue_window;
        let s_max = e.cfg.s_max;
        for _ in 0..=e.cfg.step_limit {
            loop {
                let mut scan = e.clone();
                scan.set_legacy_scan(true);
                assert_eq!(e.first_feasible(), scan.first_feasible());
                let Some(idx) = e.first_feasible() else { break };
                if e.schedule_task_at(idx, s_max).is_none() {
                    break;
                }
            }
            if e.step(&Action::noop(l)).done {
                break;
            }
        }
    }
}
