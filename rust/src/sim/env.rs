//! The continuous-time, discrete-decision MDP of §V.A: state matrix
//! (Eq. 6), composite action vector (Eq. 8), transition dynamics, and
//! reciprocal-time reward.
//!
//! One decision per simulated second (Δt = decision_dt): the scheduler
//! observes the cluster + the top-l queue slots, and either schedules one
//! gang task (choosing which task, how many inference steps, and which
//! servers via the greedy selector) or does nothing.

use crate::config::EnvConfig;
use crate::qos::{AdmissionConfig, AdmissionState, PendingQueue, QueueDiscipline, TenantRegistry};
use crate::sim::cluster::{Cluster, Selection};
use crate::sim::exec_model::ExecModel;
use crate::sim::quality::QualityModel;
use crate::sim::task::{Task, Workload};
use crate::util::rng::Pcg64;
use crate::workload::{MetricsCollector, TaskSource, TaskStream, TenantReport};
use std::collections::VecDeque;

/// Decoded composite action (Eq. 8): `[a_c, a_s, a_k1..a_kl]`, every
/// component in [-1, 1] (the policy networks end in tanh).
#[derive(Clone, Debug)]
pub struct Action {
    /// Raw execution gate a_c: schedule iff a_c ≤ 0 (paper: a_c ≤ 0.5 on
    /// the [0,1] parameterisation).
    pub exec_gate: f32,
    /// Raw step knob a_s, mapped linearly onto [S_min, S_max].
    pub steps_raw: f32,
    /// Preference score per queue slot; argmax over occupied slots wins.
    pub task_scores: Vec<f32>,
}

impl Action {
    /// Decode from the flat vector the policy networks emit.
    pub fn from_vec(raw: &[f32]) -> Action {
        assert!(raw.len() >= 3, "action vector too short: {}", raw.len());
        Action {
            exec_gate: raw[0],
            steps_raw: raw[1],
            task_scores: raw[2..].to_vec(),
        }
    }

    pub fn to_vec(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(2 + self.task_scores.len());
        v.push(self.exec_gate);
        v.push(self.steps_raw);
        v.extend_from_slice(&self.task_scores);
        v
    }

    pub fn wants_exec(&self) -> bool {
        self.exec_gate <= 0.0
    }

    /// Map a_s ∈ [-1,1] → steps ∈ [s_min, s_max].
    pub fn steps(&self, s_min: u32, s_max: u32) -> u32 {
        let u = ((self.steps_raw + 1.0) * 0.5).clamp(0.0, 1.0) as f64;
        (s_min as f64 + u * (s_max - s_min) as f64).round() as u32
    }

    /// A no-op action (gate closed).
    pub fn noop(l: usize) -> Action {
        Action {
            exec_gate: 1.0,
            steps_raw: 0.0,
            task_scores: vec![0.0; l],
        }
    }
}

/// Details of a task scheduled by a step.
#[derive(Clone, Debug)]
pub struct Scheduled {
    pub task_id: u64,
    pub steps: u32,
    pub servers: Vec<usize>,
    pub reused_model: bool,
    /// Realised total duration charged to the gang (init + exec).
    pub duration: f64,
    /// Waiting time t^w at schedule instant.
    pub waiting: f64,
    /// Response time t^r = waiting + duration.
    pub response: f64,
    pub quality: f64,
    /// Quality floor in force for this task (its own demand, or the
    /// episode-wide `RewardConfig::q_min`).
    pub q_min: f64,
    /// Tenant index of the scheduled task (multi-tenant workloads).
    pub tenant: Option<u32>,
    /// Whether the response met the task's deadline; `None` when the task
    /// carried no deadline.
    pub deadline_met: Option<bool>,
}

/// Result of one environment step.
#[derive(Clone, Debug)]
pub struct StepOutcome {
    pub reward: f64,
    pub done: bool,
    pub scheduled: Option<Scheduled>,
    /// The action asked to schedule but the gang constraint failed or the
    /// queue was empty.
    pub infeasible: bool,
}

/// Aggregated per-episode metrics (feeds Tables IX–XI, Fig 5/8, and the
/// scenario sweep). Percentiles and utilization come from the streaming
/// `MetricsCollector`; when no task was ever scheduled they are censored
/// at the episode's simulated time, like the average.
#[derive(Clone, Debug, Default)]
pub struct EpisodeReport {
    pub completed_tasks: usize,
    pub total_tasks: usize,
    pub decision_steps: usize,
    pub sim_time: f64,
    pub total_reward: f64,
    pub avg_quality: f64,
    pub avg_response_latency: f64,
    /// Response-latency percentiles over completed tasks.
    pub p50_latency: f64,
    pub p90_latency: f64,
    pub p99_latency: f64,
    /// Mean per-server busy-time fraction over the episode.
    pub avg_utilization: f64,
    /// Fraction of scheduled tasks that required a model (re)load.
    pub reload_rate: f64,
    /// Absolute number of model (re)loads.
    pub reloads: usize,
    pub below_quality_min: usize,
    pub infeasible_actions: usize,
    pub avg_steps_chosen: f64,
    /// Average over completed tasks of quality / response (Fig 8).
    pub efficiency: f64,
    /// Arrivals rejected by admission control (shed load).
    pub dropped_tasks: usize,
    /// Per-tenant SLO attainment / drop-rate / latency percentiles (empty
    /// unless `EnvConfig::tenants` is configured).
    pub tenant_reports: Vec<TenantReport>,
}

/// The EAT MDP environment. `Clone` supports the meta-heuristic baselines
/// (Harmony/Genetic), which evaluate candidate action sequences on cloned
/// rollouts of a planning environment.
#[derive(Clone)]
pub struct EdgeEnv {
    pub cfg: EnvConfig,
    pub cluster: Cluster,
    exec_model: ExecModel,
    quality_model: QualityModel,
    source: TaskSource,
    queue: PendingQueue,
    registry: Option<TenantRegistry>,
    admission: AdmissionState,
    now: f64,
    steps_taken: usize,
    rng: Pcg64,
    metrics: MetricsCollector,
    // accumulators
    scheduled_count: usize,
    dropped_count: usize,
    reload_count: usize,
    sum_quality: f64,
    sum_response: f64,
    sum_steps_chosen: f64,
    sum_efficiency: f64,
    below_min: usize,
    infeasible: usize,
    total_reward: f64,
    trace: Vec<Scheduled>,
}

impl EdgeEnv {
    /// Build from a seed. With `cfg.workload = None` this pre-materialises
    /// the legacy Poisson workload (bit-identical to the seed); with a
    /// scenario configured it consumes the arrival process as a lazy
    /// stream — same tasks, generated on demand. Multi-tenant workloads
    /// (`cfg.tenants`) are merged from per-tenant arrival processes and
    /// pre-materialised (`Workload::generate` routes through the
    /// qos generator).
    pub fn new(cfg: EnvConfig, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed, 0xED6E);
        if cfg.workload.is_some() && cfg.tenants.is_none() {
            let (arrival, mix) = crate::workload::build_for_env(&cfg);
            let stream = TaskStream::new(arrival, mix, cfg.tasks_per_episode, rng.fork(1));
            Self::with_source(cfg, TaskSource::stream(stream), rng)
        } else {
            let workload = Workload::generate(&cfg, &mut rng.fork(1));
            Self::with_workload(cfg, workload, rng)
        }
    }

    /// Build with an explicit workload (common-random-number comparisons,
    /// trace replay, and the fixed motivation traces).
    pub fn with_workload(cfg: EnvConfig, workload: Workload, rng: Pcg64) -> Self {
        Self::with_source(cfg, TaskSource::fixed(workload), rng)
    }

    /// Build over any task source — a materialised workload or a live
    /// arrival-process stream.
    pub fn with_source(cfg: EnvConfig, source: TaskSource, rng: Pcg64) -> Self {
        let cluster = Cluster::new(cfg.num_servers);
        let exec_model = ExecModel::new(cfg.exec.clone());
        let quality_model = QualityModel::new(cfg.quality.clone());
        let registry = cfg.tenants.as_ref().map(TenantRegistry::new);
        // Queue discipline: the seed's FIFO unless a tenants section asks
        // for deadline-aware ordering.
        let queue = match (&registry, cfg.tenants.as_ref().map(|t| t.queue)) {
            (Some(reg), Some(QueueDiscipline::EdfWfq)) => PendingQueue::qos(reg.clone()),
            _ => PendingQueue::fifo(),
        };
        // Admission: tenants section first, then the scenario's policy,
        // else admit-all (the seed behaviour).
        let admission_cfg = cfg
            .tenants
            .as_ref()
            .map(|t| t.admission.clone())
            .or_else(|| cfg.workload.as_ref().map(|w| w.admission.clone()))
            .unwrap_or(AdmissionConfig::AdmitAll);
        let admission = AdmissionState::new(admission_cfg, registry.as_ref());
        let metrics = match &registry {
            Some(reg) => MetricsCollector::with_tenants(cfg.num_servers, reg),
            None => MetricsCollector::new(cfg.num_servers),
        };
        let mut env = EdgeEnv {
            cfg,
            cluster,
            exec_model,
            quality_model,
            source,
            queue,
            registry,
            admission,
            now: 0.0,
            steps_taken: 0,
            rng,
            metrics,
            scheduled_count: 0,
            dropped_count: 0,
            reload_count: 0,
            sum_quality: 0.0,
            sum_response: 0.0,
            sum_steps_chosen: 0.0,
            sum_efficiency: 0.0,
            below_min: 0,
            infeasible: 0,
            total_reward: 0.0,
            trace: Vec::new(),
        };
        env.absorb_arrivals();
        env
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// The pending queue in scheduling order (dequeue order under a QoS
    /// discipline, arrival order otherwise); the top `queue_window` slots
    /// are what the policy observes.
    pub fn queue(&self) -> &VecDeque<Task> {
        self.queue.items()
    }

    pub fn exec_model(&self) -> &ExecModel {
        &self.exec_model
    }

    pub fn quality_model(&self) -> &QualityModel {
        &self.quality_model
    }

    pub fn trace(&self) -> &[Scheduled] {
        &self.trace
    }

    /// Streaming episode metrics (latency histogram, utilization, reloads).
    pub fn metrics(&self) -> &MetricsCollector {
        &self.metrics
    }

    /// Remaining (not yet arrived) + queued + in-flight tasks exist?
    /// Tasks shed by admission control count as resolved.
    pub fn all_done(&self) -> bool {
        self.scheduled_count + self.dropped_count == self.source.total()
            && self.cluster.servers.iter().all(|s| s.is_idle())
    }

    fn absorb_arrivals(&mut self) {
        let mut admitted = false;
        while let Some(task) = self.source.pop_if_arrived(self.now) {
            self.metrics.observe_offered(task.tenant);
            if self.admission.admit(task.tenant, self.now, self.queue.len()) {
                // Lazy push: the QoS view is rebuilt once per batch below,
                // not O(queue) per arrival.
                self.queue.push_lazy(task);
                admitted = true;
            } else {
                self.dropped_count += 1;
                self.metrics.observe_drop(task.tenant);
            }
        }
        if admitted {
            self.queue.commit();
        }
    }

    /// Average waiting time of queued tasks, t^avg_{Q,t} (§V.A.4).
    pub fn avg_queue_wait(&self) -> f64 {
        if self.queue.is_empty() {
            return 0.0;
        }
        self.queue.items().iter().map(|t| self.now - t.arrival).sum::<f64>()
            / self.queue.len() as f64
    }

    /// Build the normalised state vector: the 3×(|E|+l) matrix of Eq. 6 in
    /// row-major order, scaled to roughly [0, 1] for the networks.
    ///
    /// Layout: row 0 = [a_e ... | waiting_k ...], row 1 = [t^r_e ... |
    /// c_k ...], row 2 = [d_e ... | 0 ...].
    pub fn state(&self) -> Vec<f32> {
        let e = self.cfg.num_servers;
        let l = self.cfg.queue_window;
        let cols = e + l;
        let mut s = vec![0.0f32; 3 * cols];
        const T_SCALE: f32 = 1.0 / 100.0;
        for (i, srv) in self.cluster.servers.iter().enumerate() {
            s[i] = if srv.is_idle() { 1.0 } else { 0.0 };
            s[cols + i] = srv.remaining as f32 * T_SCALE;
            s[2 * cols + i] = match srv.model {
                // One-based so "no model" (0) is distinguishable.
                Some(m) => (m.0 + 1) as f32 / (self.cfg.num_models + 1) as f32,
                None => 0.0,
            };
        }
        for (j, task) in self.queue.items().iter().take(l).enumerate() {
            let c = e + j;
            s[c] = ((self.now - task.arrival) as f32 * T_SCALE).min(4.0);
            s[cols + c] = task.patches as f32 / 8.0;
            // Row 2 stays zero for queue columns (Eq. 6 pads with zeros);
            // we use it to mark slot occupancy, which the padded matrix
            // otherwise loses for a task with zero wait and c=0 normalise.
            s[2 * cols + c] = 1.0;
        }
        s
    }

    /// One decision step. Decodes the action, possibly schedules one task,
    /// then advances simulated time by Δt.
    pub fn step(&mut self, action: &Action) -> StepOutcome {
        let mut outcome = StepOutcome {
            reward: 0.0,
            done: false,
            scheduled: None,
            infeasible: false,
        };
        if action.wants_exec() {
            match self.try_schedule(action) {
                Ok(Some(sch)) => {
                    outcome.reward = self.reward_for(&sch);
                    outcome.scheduled = Some(sch);
                }
                Ok(None) | Err(()) => {
                    // Gate open but nothing schedulable: mild shaping
                    // penalty teaches feasibility (implementation detail;
                    // the paper's Algorithm 1 just skips the step).
                    outcome.infeasible = true;
                    self.infeasible += 1;
                    outcome.reward = -0.1;
                }
            }
        } else if self.any_feasible() {
            // Idle-while-work-waits shaping: closing the gate when a task
            // could be gang-scheduled right now wastes cluster time; the
            // paper's μ_t·t^avg queue term plays the same role inside its
            // reward. Without this, briefly-trained policies can converge
            // to "never schedule" (reward 0 forever).
            outcome.reward = -0.1;
        }
        self.total_reward += outcome.reward;
        // Advance simulated time, crediting busy time before the tick.
        let dt = self.cfg.decision_dt;
        for s in &self.cluster.servers {
            if !s.is_idle() {
                self.metrics.observe_busy(s.id, s.remaining.min(dt));
            }
        }
        self.metrics.advance_time(dt);
        self.now += dt;
        self.cluster.advance(dt, self.now);
        self.absorb_arrivals();
        self.steps_taken += 1;
        outcome.done = self.is_done();
        outcome
    }

    fn is_done(&self) -> bool {
        self.all_done()
            || self.now >= self.cfg.time_limit
            || self.steps_taken >= self.cfg.step_limit
    }

    /// Attempt to schedule per the action; Ok(None) when the queue is
    /// empty, Err(()) when the gang constraint fails.
    fn try_schedule(&mut self, action: &Action) -> Result<Option<Scheduled>, ()> {
        if self.queue.is_empty() {
            return Ok(None);
        }
        let visible = self.queue.len().min(self.cfg.queue_window);
        // Argmax of preference scores over occupied slots.
        let mut best = 0usize;
        for j in 1..visible {
            if action.task_scores.get(j).copied().unwrap_or(f32::MIN)
                > action.task_scores.get(best).copied().unwrap_or(f32::MIN)
            {
                best = j;
            }
        }
        let steps = action.steps(self.cfg.s_min, self.cfg.s_max);
        match self.schedule_task_at(best, steps) {
            Some(sch) => Ok(Some(sch)),
            None => Err(()),
        }
    }

    /// Schedule the queue item at `index` with `steps` inference steps,
    /// if the gang constraint allows. Used by the action path and directly
    /// by heuristic policies.
    pub fn schedule_task_at(&mut self, index: usize, steps: u32) -> Option<Scheduled> {
        let task = self.queue.items().get(index)?.clone();
        let selection = self.cluster.select(task.model, task.patches);
        let (servers, reuse) = match &selection {
            Selection::Reuse(v) => (v.clone(), true),
            Selection::Fresh(v) => (v.clone(), false),
            Selection::Infeasible => return None,
        };
        self.dispatch_and_record(task, index, steps, servers, reuse)
    }

    /// Schedule on an *explicit* server set (used by the Traditional
    /// first-fit scheduler of the motivating example, Tables II–IV).
    /// Model reuse happens only if the chosen servers exactly form an idle
    /// gang already holding the task's model.
    pub fn schedule_task_on(
        &mut self,
        index: usize,
        steps: u32,
        server_ids: &[usize],
    ) -> Option<Scheduled> {
        let task = self.queue.items().get(index)?.clone();
        if server_ids.len() != task.patches
            || server_ids.iter().any(|&id| !self.cluster.servers[id].is_idle())
        {
            return None;
        }
        let reuse = self
            .cluster
            .idle_gangs(task.model)
            .iter()
            .any(|(_, members)| {
                let mut m = members.clone();
                let mut s = server_ids.to_vec();
                m.sort_unstable();
                s.sort_unstable();
                m == s
            });
        self.dispatch_and_record(task, index, steps, server_ids.to_vec(), reuse)
    }

    fn dispatch_and_record(
        &mut self,
        task: Task,
        index: usize,
        steps: u32,
        servers: Vec<usize>,
        reuse: bool,
    ) -> Option<Scheduled> {
        let exec = self.exec_model.sample_exec(steps, task.patches, &mut self.rng);
        let init = if reuse {
            0.0
        } else {
            // §VII extension: servers that already hold the model's weights
            // (but in the wrong gang shape) only pay the process-group
            // rebuild fraction of a full load; weight-cold servers pay in
            // full. With group_rebuild_frac = 1.0 this reduces to the
            // paper's measured full-reload behaviour.
            let full = self.exec_model.sample_init(task.patches, &mut self.rng);
            let frac = self.cfg.exec.group_rebuild_frac.clamp(0.0, 1.0);
            if frac >= 1.0 {
                full
            } else {
                let warm = servers
                    .iter()
                    .filter(|&&id| self.cluster.servers[id].model == Some(task.model))
                    .count() as f64;
                let warm_frac = warm / servers.len() as f64;
                full * (1.0 - warm_frac * (1.0 - frac))
            }
        };
        let duration = exec + init;
        self.cluster.dispatch(&servers, duration, task.model, reuse);
        self.queue.remove(index);
        let waiting = (self.now - task.arrival).max(0.0);
        let response = waiting + duration;
        let quality = self.quality_model.sample_quality(steps, task.prompt_id);
        let q_floor = task.q_min.unwrap_or(self.cfg.reward.q_min);
        // A task completes at now + duration; its (absolute) deadline is
        // met iff that instant lands within the SLO budget.
        let deadline_met = task.deadline.map(|d| self.now + duration <= d);
        let sch = Scheduled {
            task_id: task.id,
            steps,
            servers,
            reused_model: reuse,
            duration,
            waiting,
            response,
            quality,
            q_min: q_floor,
            tenant: task.tenant,
            deadline_met,
        };
        // Metrics.
        self.scheduled_count += 1;
        if !reuse {
            self.reload_count += 1;
        }
        self.sum_quality += quality;
        self.sum_response += response;
        self.sum_steps_chosen += steps as f64;
        self.sum_efficiency += quality / response.max(1e-9);
        if quality < q_floor {
            self.below_min += 1;
        }
        self.metrics.observe_task(response, waiting, !reuse);
        self.metrics.observe_tenant_task(task.tenant, response, deadline_met);
        self.trace.push(sch.clone());
        Some(sch)
    }

    /// Immediate reward (§V.A.4):
    /// R = α_q·q − λ_q·I + 1 / (β_t·t^r + μ_t·t^avg_Q) − p_d·w·miss.
    /// The quality indicator I uses the task's own demand when it has one
    /// (scenario mixes with per-task QoS tiers), else the global q_min.
    /// The deadline term charges a missed SLO in proportion to the
    /// tenant's weight; deadline-less tasks (the paper's regime) never
    /// trip it, keeping legacy rewards bit-identical.
    fn reward_for(&self, sch: &Scheduled) -> f64 {
        let r = &self.cfg.reward;
        let penalty = if sch.quality < sch.q_min { r.p_quality } else { 0.0 };
        let denom = r.beta_t * sch.response + r.mu_t * self.avg_queue_wait() + 1e-3;
        let mut reward = r.alpha_q * sch.quality - r.lambda_q * penalty + 1.0 / denom;
        if sch.deadline_met == Some(false) {
            let weight = self
                .registry
                .as_ref()
                .map_or(1.0, |reg| reg.weight(sch.tenant));
            reward -= r.p_deadline * weight;
        }
        reward
    }

    /// Can any queued task currently be gang-scheduled?
    pub fn any_feasible(&self) -> bool {
        self.queue
            .items()
            .iter()
            .take(self.cfg.queue_window)
            .any(|t| !matches!(self.cluster.select(t.model, t.patches), Selection::Infeasible))
    }

    /// Arrival times of the underlying workload (testing / diagnostics).
    /// Empty for a streamed source — a stream retains no history and
    /// cannot report future arrivals without consuming randomness.
    pub fn workload_arrivals(&self) -> Vec<f64> {
        self.source.known_arrivals()
    }

    /// Final episode report. If the policy never scheduled anything the
    /// latency (and its percentiles) is censored at the episode's
    /// simulated time (otherwise a do-nothing policy would report a
    /// perfect 0-second latency).
    pub fn report(&self) -> EpisodeReport {
        if self.scheduled_count == 0 {
            return EpisodeReport {
                completed_tasks: 0,
                total_tasks: self.source.total(),
                decision_steps: self.steps_taken,
                sim_time: self.now,
                total_reward: self.total_reward,
                avg_quality: 0.0,
                avg_response_latency: self.now,
                p50_latency: self.now,
                p90_latency: self.now,
                p99_latency: self.now,
                avg_utilization: self.metrics.avg_utilization(),
                reload_rate: 0.0,
                reloads: 0,
                below_quality_min: 0,
                infeasible_actions: self.infeasible,
                avg_steps_chosen: 0.0,
                efficiency: 0.0,
                dropped_tasks: self.dropped_count,
                tenant_reports: self.metrics.tenant_reports(),
            };
        }
        let n = self.scheduled_count as f64;
        EpisodeReport {
            completed_tasks: self.scheduled_count,
            total_tasks: self.source.total(),
            decision_steps: self.steps_taken,
            sim_time: self.now,
            total_reward: self.total_reward,
            avg_quality: self.sum_quality / n,
            avg_response_latency: self.sum_response / n,
            p50_latency: self.metrics.latency.p50(),
            p90_latency: self.metrics.latency.p90(),
            p99_latency: self.metrics.latency.p99(),
            avg_utilization: self.metrics.avg_utilization(),
            reload_rate: self.reload_count as f64 / n,
            reloads: self.reload_count,
            below_quality_min: self.below_min,
            infeasible_actions: self.infeasible,
            avg_steps_chosen: self.sum_steps_chosen / n,
            efficiency: self.sum_efficiency / n,
            dropped_tasks: self.dropped_count,
            tenant_reports: self.metrics.tenant_reports(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn env(seed: u64) -> EdgeEnv {
        let cfg = ExperimentConfig::preset_8node(0.1);
        EdgeEnv::new(cfg.env, seed)
    }

    fn schedule_action(l: usize, slot: usize, steps_raw: f32) -> Action {
        let mut scores = vec![-1.0f32; l];
        scores[slot] = 1.0;
        Action {
            exec_gate: -1.0,
            steps_raw,
            task_scores: scores,
        }
    }

    #[test]
    fn state_dims_match_config() {
        let e = env(1);
        assert_eq!(e.state().len(), e.cfg.state_len());
    }

    #[test]
    fn noop_steps_advance_time_only() {
        let mut e = env(2);
        let l = e.cfg.queue_window;
        let before_queue = e.queue().len();
        let out = e.step(&Action::noop(l));
        assert_eq!(out.reward, 0.0);
        assert!(out.scheduled.is_none());
        assert!(!out.infeasible);
        assert_eq!(e.now(), e.cfg.decision_dt);
        // Queue can only have grown (arrivals).
        assert!(e.queue().len() >= before_queue);
    }

    #[test]
    fn scheduling_consumes_queue_and_busies_servers() {
        let mut e = env(3);
        // Run until something is queued.
        let l = e.cfg.queue_window;
        while e.queue().is_empty() {
            e.step(&Action::noop(l));
        }
        let patches = e.queue()[0].patches;
        let out = e.step(&schedule_action(l, 0, 1.0));
        let sch = out.scheduled.expect("should schedule");
        assert_eq!(sch.servers.len(), patches);
        assert!(out.reward > 0.0, "reward={}", out.reward);
        assert_eq!(sch.steps, e.cfg.s_max);
        let busy = e.cluster.servers.iter().filter(|s| !s.is_idle()).count();
        assert_eq!(busy, patches);
    }

    #[test]
    fn infeasible_penalised_when_queue_empty() {
        let cfg = ExperimentConfig::preset_8node(0.0001); // ~no arrivals
        let mut e = EdgeEnv::new(cfg.env, 4);
        let l = e.cfg.queue_window;
        let out = e.step(&schedule_action(l, 0, 0.0));
        assert!(out.infeasible);
        assert!(out.reward < 0.0);
    }

    #[test]
    fn episode_terminates() {
        let mut e = env(5);
        let l = e.cfg.queue_window;
        let mut done = false;
        for _ in 0..e.cfg.step_limit + 1 {
            // Greedy-ish: always try to schedule slot 0 with max steps.
            let out = e.step(&schedule_action(l, 0, 1.0));
            if out.done {
                done = true;
                break;
            }
        }
        assert!(done);
        let rep = e.report();
        assert!(rep.completed_tasks > 0);
        assert!(rep.avg_quality > 0.2);
        assert!(rep.reload_rate > 0.0 && rep.reload_rate <= 1.0);
    }

    #[test]
    fn reward_prefers_more_steps_when_idle() {
        // With an empty system, higher steps → higher quality → higher
        // reward (the time term barely moves) — this is why Greedy maxes
        // steps in the paper.
        let mk = |steps_raw: f32, seed: u64| {
            let mut e = env(seed);
            let l = e.cfg.queue_window;
            while e.queue().is_empty() {
                e.step(&Action::noop(l));
            }
            e.step(&schedule_action(l, 0, steps_raw)).reward
        };
        // Same seed → same task/workload, different steps.
        assert!(mk(1.0, 77) > mk(-1.0, 77));
    }

    #[test]
    fn model_reuse_reflected_in_reload_rate() {
        // Single model type: after the first load, same-size gangs reuse.
        let mut cfg = ExperimentConfig::preset_4node(0.05).env;
        cfg.num_models = 1;
        cfg.patch_choices = vec![2];
        cfg.patch_weights = vec![1.0];
        cfg.tasks_per_episode = 12;
        let mut e = EdgeEnv::new(cfg, 6);
        let l = e.cfg.queue_window;
        for _ in 0..e.cfg.step_limit {
            let out = e.step(&schedule_action(l, 0, 0.5));
            if out.done {
                break;
            }
        }
        let rep = e.report();
        assert!(rep.completed_tasks >= 10, "completed={}", rep.completed_tasks);
        // Two gangs of 2 on 4 servers: after ≤2 loads everything reuses.
        assert!(rep.reload_rate < 0.4, "reload={}", rep.reload_rate);
    }

    #[test]
    fn partial_group_rebuild_reduces_init_cost() {
        // §VII extension: with one model type and warm weights everywhere,
        // group_rebuild_frac < 1 should cut response latency vs the full
        // reload default on the same workload/seed.
        let run = |frac: f64| {
            let mut cfg = ExperimentConfig::preset_4node(0.05).env;
            cfg.num_models = 1;
            cfg.exec.group_rebuild_frac = frac;
            // Alternate 2- and 4-patch tasks so gang shapes keep changing
            // (forcing rebuilds rather than exact reuse).
            cfg.patch_choices = vec![2, 4];
            cfg.patch_weights = vec![1.0, 1.0];
            cfg.tasks_per_episode = 12;
            let mut e = EdgeEnv::new(cfg, 42);
            let l = e.cfg.queue_window;
            for _ in 0..e.cfg.step_limit {
                if e.step(&schedule_action(l, 0, 0.5)).done {
                    break;
                }
            }
            e.report().avg_response_latency
        };
        let full = run(1.0);
        let partial = run(0.3);
        assert!(
            partial < full * 0.9,
            "partial rebuild {partial} should beat full reload {full}"
        );
    }

    #[test]
    fn argmax_selects_highest_scored_slot() {
        let mut e = env(8);
        let l = e.cfg.queue_window;
        while e.queue().len() < 2 {
            e.step(&Action::noop(l));
        }
        let second_id = e.queue()[1].id;
        let out = e.step(&schedule_action(l, 1, 0.0));
        assert_eq!(out.scheduled.unwrap().task_id, second_id);
    }

    #[test]
    fn report_efficiency_positive() {
        let mut e = env(9);
        let l = e.cfg.queue_window;
        for _ in 0..200 {
            let out = e.step(&schedule_action(l, 0, 1.0));
            if out.done {
                break;
            }
        }
        let rep = e.report();
        assert!(rep.efficiency > 0.0);
        assert!(rep.avg_steps_chosen > 0.0);
    }

    #[test]
    fn report_percentiles_bracket_the_mean() {
        let mut e = env(10);
        let l = e.cfg.queue_window;
        loop {
            if e.step(&schedule_action(l, 0, 0.5)).done {
                break;
            }
        }
        let rep = e.report();
        assert!(rep.completed_tasks > 1);
        assert!(rep.p50_latency <= rep.p90_latency && rep.p90_latency <= rep.p99_latency);
        assert!(rep.p50_latency > 0.0 && rep.p99_latency.is_finite());
        assert!(rep.avg_utilization > 0.0 && rep.avg_utilization <= 1.0);
        assert_eq!(rep.reloads, (rep.reload_rate * rep.completed_tasks as f64).round() as usize);
    }

    #[test]
    fn streamed_scenario_matches_materialised_replay() {
        use crate::sim::task::Workload;
        use crate::util::rng::Pcg64;
        use crate::workload::WorkloadConfig;
        // The same seed must yield the same episode whether the scenario
        // is consumed as a stream (EdgeEnv::new) or pre-materialised and
        // replayed (EdgeEnv::with_workload) — the trace-replay guarantee.
        let mut cfg = ExperimentConfig::preset_8node(0.1).env;
        cfg.workload = Some(WorkloadConfig::preset("flash", 0.1).unwrap());
        let seed = 21;
        let run = |mut e: EdgeEnv| {
            let l = e.cfg.queue_window;
            loop {
                if e.step(&schedule_action(l, 0, 0.7)).done {
                    break;
                }
            }
            e.report()
        };
        let streamed = run(EdgeEnv::new(cfg.clone(), seed));
        let mut rng = Pcg64::new(seed, 0xED6E);
        let workload = Workload::generate(&cfg, &mut rng.fork(1));
        let materialised = run(EdgeEnv::with_workload(cfg, workload, rng));
        assert_eq!(streamed.completed_tasks, materialised.completed_tasks);
        assert_eq!(streamed.total_reward, materialised.total_reward);
        assert_eq!(streamed.avg_response_latency, materialised.avg_response_latency);
        assert_eq!(streamed.p99_latency, materialised.p99_latency);
        assert_eq!(streamed.avg_quality, materialised.avg_quality);
    }

    fn tenant_cfg(total_rate: f64) -> EnvConfig {
        use crate::qos::TenantsConfig;
        let mut cfg = ExperimentConfig::preset_8node(0.1).env;
        cfg.tenants = Some(TenantsConfig::three_tier(total_rate));
        cfg.tasks_per_episode = 48;
        cfg
    }

    #[test]
    fn tenant_episode_reports_per_tenant_metrics() {
        let mut e = EdgeEnv::new(tenant_cfg(0.3), 31);
        let l = e.cfg.queue_window;
        loop {
            if e.step(&schedule_action(l, 0, 0.5)).done {
                break;
            }
        }
        let rep = e.report();
        assert!(rep.completed_tasks > 0);
        assert_eq!(rep.tenant_reports.len(), 3);
        let offered: u64 = rep.tenant_reports.iter().map(|t| t.offered).sum();
        let completed: u64 = rep.tenant_reports.iter().map(|t| t.completed).sum();
        assert!(offered > 0);
        assert_eq!(completed as usize, rep.completed_tasks);
        for t in &rep.tenant_reports {
            assert!((0.0..=1.0).contains(&t.slo_attainment), "{}: {}", t.name, t.slo_attainment);
            assert!((0.0..=1.0).contains(&t.drop_rate));
        }
    }

    #[test]
    fn drop_tail_sheds_load_and_episode_still_terminates() {
        use crate::qos::AdmissionConfig;
        let mut cfg = tenant_cfg(2.0); // ~7 arrivals/s: massive overload
        if let Some(t) = &mut cfg.tenants {
            t.admission = AdmissionConfig::DropTail { max_queue: 4 };
        }
        cfg.tasks_per_episode = 40;
        let mut e = EdgeEnv::new(cfg, 32);
        let l = e.cfg.queue_window;
        let mut done = false;
        for _ in 0..e.cfg.step_limit + 1 {
            if e.step(&schedule_action(l, 0, 0.5)).done {
                done = true;
                break;
            }
        }
        assert!(done);
        let rep = e.report();
        assert!(rep.dropped_tasks > 0, "overload with a 4-slot queue must shed");
        assert!(rep.completed_tasks + rep.dropped_tasks <= rep.total_tasks);
        assert!(e.queue().len() <= 4, "queue exceeded its bound: {}", e.queue().len());
        let dropped: u64 = rep.tenant_reports.iter().map(|t| t.dropped).sum();
        assert_eq!(dropped as usize, rep.dropped_tasks);
    }

    #[test]
    fn qos_queue_surfaces_premium_ahead_of_backlog() {
        // Under overload the visible window (EDF/WFQ order) must show
        // premium-tier tasks ahead of batch tasks that arrived earlier.
        let mut e = EdgeEnv::new(tenant_cfg(2.0), 33);
        let l = e.cfg.queue_window;
        // Build a backlog without scheduling anything.
        for _ in 0..200 {
            if e.step(&Action::noop(l)).done {
                break;
            }
        }
        let q = e.queue();
        assert!(q.len() > l, "need a backlog for the test to bite");
        // Count premium tasks among the visible slots vs the whole queue:
        // the weighted queue must over-represent premium at the head.
        let premium_visible = q.iter().take(l).filter(|t| t.tenant == Some(0)).count();
        let premium_total = q.iter().filter(|t| t.tenant == Some(0)).count();
        let visible_share = premium_visible as f64 / l as f64;
        let overall_share = premium_total as f64 / q.len() as f64;
        assert!(
            visible_share >= overall_share,
            "premium visible share {visible_share} < overall {overall_share}"
        );
        // EDF within the visible window: premium tasks appear in deadline
        // order.
        let mut last = f64::NEG_INFINITY;
        for t in q.iter().take(l).filter(|t| t.tenant == Some(0)) {
            let d = t.deadline.expect("tenant tasks carry deadlines");
            assert!(d >= last);
            last = d;
        }
    }

    #[test]
    fn deadline_misses_penalise_reward_by_weight() {
        // Same scheduled outcome, one with a met deadline and one missed:
        // the missed one must earn strictly less reward.
        let cfg = tenant_cfg(0.3);
        let mut e = EdgeEnv::new(cfg, 34);
        let l = e.cfg.queue_window;
        while e.queue().is_empty() {
            e.step(&Action::noop(l));
        }
        // Run two clones: one schedules now (meets the 120 s budget), one
        // waits far past every queued deadline first.
        let mut prompt_env = e.clone();
        let now_reward = prompt_env.step(&schedule_action(l, 0, 0.5)).reward;
        let mut late_env = e.clone();
        for _ in 0..200 {
            late_env.step(&Action::noop(l));
            if late_env.now() > 300.0 {
                break;
            }
        }
        if late_env.queue().is_empty() {
            return; // everything arrived and nothing queued: nothing to miss
        }
        let late_out = late_env.step(&schedule_action(l, 0, 0.5));
        if let Some(sch) = &late_out.scheduled {
            assert_eq!(sch.deadline_met, Some(false));
            assert!(
                late_out.reward < now_reward,
                "missed-deadline reward {} should trail met-deadline {}",
                late_out.reward,
                now_reward
            );
        }
    }

    #[test]
    fn flash_scenario_bounds_its_queue() {
        use crate::workload::WorkloadConfig;
        // The flash preset now ships a drop-tail admission default: under
        // its 6x spike the pending queue must stay within the bound.
        let mut cfg = ExperimentConfig::preset_8node(0.1).env;
        cfg.workload = Some(WorkloadConfig::preset("flash", 0.1).unwrap());
        cfg.tasks_per_episode = 96;
        let mut e = EdgeEnv::new(cfg, 35);
        let l = e.cfg.queue_window;
        let mut max_queue = 0usize;
        loop {
            max_queue = max_queue.max(e.queue().len());
            if e.step(&Action::noop(l)).done {
                break;
            }
        }
        assert!(max_queue <= 16, "flash queue grew to {max_queue}");
        let rep = e.report();
        assert!(rep.dropped_tasks > 0, "the spike must shed load");
        assert_eq!(rep.completed_tasks + rep.dropped_tasks, rep.total_tasks - e.queue().len());
    }

    #[test]
    fn per_task_quality_demand_drives_below_min_accounting() {
        use crate::workload::{ModelMix, QualityDemand, WorkloadConfig};
        // An impossibly strict demand on every task: everything scheduled
        // must count as below its quality floor.
        let mut cfg = ExperimentConfig::preset_8node(0.1).env;
        cfg.workload = Some(WorkloadConfig {
            arrival: crate::workload::ArrivalConfig::Poisson { rate: 0.1 },
            model_mix: ModelMix::Uniform,
            quality_demand: QualityDemand::Uniform { lo: 0.9, hi: 0.95 },
            admission: crate::qos::AdmissionConfig::AdmitAll,
        });
        cfg.tasks_per_episode = 8;
        let mut e = EdgeEnv::new(cfg, 22);
        let l = e.cfg.queue_window;
        loop {
            if e.step(&schedule_action(l, 0, 1.0)).done {
                break;
            }
        }
        let rep = e.report();
        assert!(rep.completed_tasks > 0);
        assert_eq!(rep.below_quality_min, rep.completed_tasks);
    }
}
