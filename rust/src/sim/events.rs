//! Calendar queue for the event-driven simulator core.
//!
//! The environment keeps its fixed decision cadence (`EdgeEnv::step` is one
//! `decision_dt` tick — per-tick busy credit, per-tick stochastic fault
//! draws and per-tick `remaining` decrements are all observable, so ticks
//! cannot be skipped without changing results bit-for-bit). What *can* be
//! evented away is the per-tick scanning:
//!
//! - **Completions** come from the cluster's incremental busy set
//!   (`Cluster::advance_into` walks O(busy) servers, not O(fleet)).
//! - **Arrivals** are already O(1) per tick: `TaskSource` keeps a one-task
//!   lookahead cursor.
//! - **Fault transitions** are either scripted (a sorted cursor) or
//!   per-server stochastic draws whose RNG order is part of the CRN
//!   contract and must be replayed tick by tick.
//! - **Speculative-launch deadlines** are the one genuinely sparse,
//!   future-dated condition (`now - start > beta * nominal` per in-flight
//!   attempt), and this queue hosts them: the fault sweep consults
//!   `next_time()` instead of scanning every in-flight attempt every tick.
//!
//! Keys are caller-defined (attempt sequence numbers); cancellation is
//! lazy — stale keys are dropped by the consumer when they no longer map
//! to a live attempt. Ordering is (time, key) ascending; times are
//! non-negative finite f64s compared via their IEEE bit patterns, which is
//! order-preserving for non-negative floats and keeps the queue totally
//! ordered (and `Ord`-safe) without wrapping comparators around NaN.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Min-heap of (time, key) events.
#[derive(Clone, Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<(u64, u64)>>,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Schedule `key` at simulated time `time` (non-negative, finite).
    pub fn push(&mut self, time: f64, key: u64) {
        debug_assert!(time >= 0.0 && time.is_finite(), "event time {time}");
        self.heap.push(Reverse((time.to_bits(), key)));
    }

    /// Time of the earliest pending event.
    pub fn next_time(&self) -> Option<f64> {
        self.heap.peek().map(|Reverse((t, _))| f64::from_bits(*t))
    }

    /// Pop every event with time <= `horizon` into `out` (cleared first),
    /// in (time, key) order. Returns the number popped.
    pub fn pop_due_into(&mut self, horizon: f64, out: &mut Vec<u64>) -> usize {
        out.clear();
        while let Some(Reverse((t, _))) = self.heap.peek() {
            if f64::from_bits(*t) > horizon {
                break;
            }
            // eat-lint: allow(unwrap, "pop follows a successful peek on the same heap")
            let Reverse((_, key)) = self.heap.pop().expect("peeked");
            out.push(key);
        }
        out.len()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_with_key_tiebreak() {
        let mut q = EventQueue::new();
        q.push(5.0, 2);
        q.push(1.0, 9);
        q.push(5.0, 1);
        q.push(0.5, 3);
        assert_eq!(q.len(), 4);
        assert_eq!(q.next_time(), Some(0.5));
        let mut out = Vec::new();
        assert_eq!(q.pop_due_into(f64::INFINITY, &mut out), 4);
        assert_eq!(out, vec![3, 9, 1, 2]);
        assert!(q.is_empty());
    }

    #[test]
    fn horizon_gates_pops() {
        let mut q = EventQueue::new();
        q.push(1.0, 1);
        q.push(2.0, 2);
        q.push(3.0, 3);
        let mut out = Vec::new();
        q.pop_due_into(2.0, &mut out);
        assert_eq!(out, vec![1, 2]);
        assert_eq!(q.next_time(), Some(3.0));
        // The buffer is cleared on each call.
        q.pop_due_into(10.0, &mut out);
        assert_eq!(out, vec![3]);
        assert!(q.next_time().is_none());
    }

    #[test]
    fn fractional_times_order_correctly_via_bits() {
        let mut q = EventQueue::new();
        q.push(0.1 + 0.2, 1); // 0.30000000000000004
        q.push(0.3, 2);
        let mut out = Vec::new();
        q.pop_due_into(1.0, &mut out);
        assert_eq!(out, vec![2, 1]);
    }

    #[test]
    fn clone_is_independent() {
        let mut a = EventQueue::new();
        a.push(1.0, 1);
        let mut b = a.clone();
        b.push(0.5, 2);
        assert_eq!(a.len(), 1);
        assert_eq!(b.len(), 2);
        a.clear();
        assert!(a.is_empty() && !b.is_empty());
    }
}
