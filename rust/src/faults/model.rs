//! The fault process: per-server Markov up/down churn, correlated
//! zone-level shocks, and transient lognormal straggler slowdowns —
//! stepped at the simulator's decision cadence, emitting [`FaultEvent`]s
//! that `EdgeEnv` applies to the cluster.
//!
//! Two modes share one type:
//!
//! - **Stochastic**: transitions drawn from a dedicated [`Pcg64`] stream.
//!   The draw sequence depends only on the health state (never on
//!   scheduling decisions), so two runs of the same seed and fault config
//!   see the *same* failure timeline regardless of policy — the fault
//!   dimension is common-random-number paired across a sweep.
//! - **Scripted**: replays a recorded event list by timestamp. Recording
//!   a stochastic episode's events and replaying them through a fresh env
//!   reproduces the episode bit-exactly (see `testing::prop`).

use super::FaultsConfig;
use crate::util::json::Value;
use crate::util::rng::Pcg64;

/// What happened to one server.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultKind {
    /// The server crashed: any gang it hosts dies, its model state is
    /// lost (it will come back weight-cold).
    Fail,
    /// The server is back up (weight-cold).
    Recover,
    /// A transient slowdown began: execution proceeds at 1/factor speed
    /// for ~`duration` seconds.
    SlowStart { factor: f64, duration: f64 },
    /// The slowdown ended; the server runs at nominal speed again.
    SlowEnd,
}

/// One health transition, stamped with simulated time.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultEvent {
    pub t: f64,
    pub server: usize,
    pub kind: FaultKind,
}

impl FaultEvent {
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        let kind = match &self.kind {
            FaultKind::Fail => "fail",
            FaultKind::Recover => "recover",
            FaultKind::SlowStart { .. } => "slow_start",
            FaultKind::SlowEnd => "slow_end",
        };
        v.set("fault", kind).set("t", self.t).set("server", self.server);
        if let FaultKind::SlowStart { factor, duration } = &self.kind {
            v.set("factor", *factor).set("duration", *duration);
        }
        v
    }

    pub fn from_json(v: &Value) -> anyhow::Result<FaultEvent> {
        let num = |key: &str| -> anyhow::Result<f64> {
            v.req(key)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("fault field '{key}' is not a number"))
        };
        let kind_str = v
            .req("fault")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("fault 'fault' must be a string"))?;
        let kind = match kind_str {
            "fail" => FaultKind::Fail,
            "recover" => FaultKind::Recover,
            "slow_start" => FaultKind::SlowStart {
                factor: num("factor")?,
                duration: num("duration")?,
            },
            "slow_end" => FaultKind::SlowEnd,
            other => anyhow::bail!("unknown fault kind '{other}'"),
        };
        let t = num("t")?;
        anyhow::ensure!(t.is_finite() && t >= 0.0, "fault t {t} must be finite and >= 0");
        Ok(FaultEvent {
            t,
            server: num("server")? as usize,
            kind,
        })
    }
}

#[derive(Clone, Debug)]
enum Mode {
    Stochastic {
        cfg: FaultsConfig,
        rng: Pcg64,
        /// Per-server health (true = up).
        up: Vec<bool>,
        /// Per-server slowdown-bout end time (NEG_INFINITY = not slowed).
        slow_until: Vec<f64>,
    },
    Scripted {
        events: Vec<FaultEvent>,
        cursor: usize,
    },
}

/// The server-health process. See module docs for the two modes.
#[derive(Clone, Debug)]
pub struct FaultModel {
    mode: Mode,
}

impl FaultModel {
    /// Stochastic dynamics for `num_servers` servers, all initially up.
    pub fn stochastic(cfg: FaultsConfig, num_servers: usize, rng: Pcg64) -> FaultModel {
        FaultModel {
            mode: Mode::Stochastic {
                cfg,
                rng,
                up: vec![true; num_servers],
                slow_until: vec![f64::NEG_INFINITY; num_servers],
            },
        }
    }

    /// Replay a recorded event list (sorted by timestamp; sorted here
    /// defensively with a stable sort).
    pub fn scripted(mut events: Vec<FaultEvent>) -> FaultModel {
        events.sort_by(|a, b| a.t.partial_cmp(&b.t).expect("NaN fault time"));
        FaultModel {
            mode: Mode::Scripted { events, cursor: 0 },
        }
    }

    /// Advance the process over the tick ending at `now_start + dt`,
    /// returning the transitions that occurred (stamped at the tick end in
    /// stochastic mode — failures are detected at heartbeat cadence).
    pub fn step(&mut self, now_start: f64, dt: f64) -> Vec<FaultEvent> {
        match &mut self.mode {
            Mode::Scripted { events, cursor } => {
                let end = now_start + dt;
                let mut out = Vec::new();
                while *cursor < events.len() && events[*cursor].t <= end {
                    out.push(events[*cursor].clone());
                    *cursor += 1;
                }
                out
            }
            Mode::Stochastic {
                cfg,
                rng,
                up,
                slow_until,
            } => {
                let end = now_start + dt;
                let mut out = Vec::new();
                let p_fail = if cfg.mtbf > 0.0 { 1.0 - (-dt / cfg.mtbf).exp() } else { 0.0 };
                let p_repair = 1.0 - (-dt / cfg.mttr).exp();
                // 1. Independent per-server churn.
                for i in 0..up.len() {
                    if up[i] {
                        if cfg.mtbf > 0.0 && rng.next_f64() < p_fail {
                            up[i] = false;
                            slow_until[i] = f64::NEG_INFINITY;
                            out.push(FaultEvent { t: end, server: i, kind: FaultKind::Fail });
                        }
                    } else if rng.next_f64() < p_repair {
                        up[i] = true;
                        out.push(FaultEvent { t: end, server: i, kind: FaultKind::Recover });
                    }
                }
                // 2. Correlated zone shock: one draw per tick; a shock
                // downs every still-up server in a uniformly chosen zone.
                if cfg.zone_shock_rate > 0.0 {
                    let p_shock = 1.0 - (-cfg.zone_shock_rate * dt).exp();
                    if rng.next_f64() < p_shock {
                        let zone = rng.next_below(cfg.zones as u64) as usize;
                        for i in 0..up.len() {
                            if i % cfg.zones == zone && up[i] {
                                up[i] = false;
                                slow_until[i] = f64::NEG_INFINITY;
                                out.push(FaultEvent { t: end, server: i, kind: FaultKind::Fail });
                            }
                        }
                    }
                }
                // 3. Straggler bouts on up servers: end expired bouts,
                // then maybe start new ones.
                if cfg.straggler_rate > 0.0 {
                    let p_slow = 1.0 - (-cfg.straggler_rate * dt).exp();
                    for i in 0..up.len() {
                        if !up[i] {
                            continue;
                        }
                        if slow_until[i] > f64::NEG_INFINITY && end >= slow_until[i] {
                            slow_until[i] = f64::NEG_INFINITY;
                            out.push(FaultEvent { t: end, server: i, kind: FaultKind::SlowEnd });
                        }
                        if slow_until[i] == f64::NEG_INFINITY && rng.next_f64() < p_slow {
                            let factor = rng
                                .lognormal(cfg.straggler_mu, cfg.straggler_sigma)
                                .max(1.0);
                            let duration =
                                rng.exponential(1.0 / cfg.straggler_mean_duration);
                            slow_until[i] = end + duration;
                            out.push(FaultEvent {
                                t: end,
                                server: i,
                                kind: FaultKind::SlowStart { factor, duration },
                            });
                        }
                    }
                }
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn churn_cfg() -> FaultsConfig {
        FaultsConfig {
            mtbf: 100.0,
            mttr: 20.0,
            zones: 4,
            zone_shock_rate: 0.0,
            straggler_rate: 0.0,
            ..FaultsConfig::default()
        }
    }

    #[test]
    fn churn_matches_mtbf_mttr_steady_state() {
        // Down fraction converges to mttr / (mtbf + mttr) = 1/6.
        let mut m = FaultModel::stochastic(churn_cfg(), 64, Pcg64::seeded(1));
        let mut down = 0usize;
        let mut samples = 0usize;
        let mut down_now = vec![false; 64];
        for step in 0..40_000 {
            for ev in m.step(step as f64, 1.0) {
                match ev.kind {
                    FaultKind::Fail => down_now[ev.server] = true,
                    FaultKind::Recover => down_now[ev.server] = false,
                    _ => {}
                }
            }
            if step >= 2_000 {
                down += down_now.iter().filter(|&&d| d).count();
                samples += 64;
            }
        }
        let frac = down as f64 / samples as f64;
        assert!((frac - 1.0 / 6.0).abs() < 0.02, "down frac {frac}");
    }

    #[test]
    fn zone_shock_downs_a_whole_zone_at_once() {
        let cfg = FaultsConfig {
            mtbf: 0.0,
            zone_shock_rate: 0.05,
            zones: 4,
            straggler_rate: 0.0,
            ..FaultsConfig::default()
        };
        let mut m = FaultModel::stochastic(cfg, 8, Pcg64::seeded(2));
        for step in 0..2_000 {
            let evs = m.step(step as f64, 1.0);
            let fails: Vec<usize> = evs
                .iter()
                .filter(|e| e.kind == FaultKind::Fail)
                .map(|e| e.server)
                .collect();
            if fails.len() >= 2 {
                // 8 servers / 4 zones: a shock hits exactly {z, z+4}.
                let zone = fails[0] % 4;
                assert!(fails.iter().all(|s| s % 4 == zone), "{fails:?}");
                return;
            }
        }
        panic!("no zone shock observed in 2000 ticks at rate 0.05");
    }

    #[test]
    fn stragglers_start_and_end_with_sane_factors() {
        let cfg = FaultsConfig {
            mtbf: 0.0,
            zone_shock_rate: 0.0,
            straggler_rate: 0.05,
            straggler_mean_duration: 10.0,
            ..FaultsConfig::default()
        };
        let mut m = FaultModel::stochastic(cfg, 4, Pcg64::seeded(3));
        let (mut starts, mut ends) = (0, 0);
        for step in 0..4_000 {
            for ev in m.step(step as f64, 1.0) {
                match ev.kind {
                    FaultKind::SlowStart { factor, duration } => {
                        assert!(factor >= 1.0 && factor.is_finite());
                        assert!(duration > 0.0);
                        starts += 1;
                    }
                    FaultKind::SlowEnd => ends += 1,
                    _ => {}
                }
            }
        }
        assert!(starts > 20, "only {starts} bouts started");
        // Every bout eventually ends (the last may still be open).
        assert!(ends >= starts - 4, "starts {starts} ends {ends}");
    }

    #[test]
    fn stochastic_is_deterministic_and_policy_independent() {
        let cfg = FaultsConfig::default();
        let mut a = FaultModel::stochastic(cfg.clone(), 16, Pcg64::seeded(7));
        let mut b = FaultModel::stochastic(cfg, 16, Pcg64::seeded(7));
        for step in 0..500 {
            assert_eq!(a.step(step as f64, 1.0), b.step(step as f64, 1.0));
        }
    }

    #[test]
    fn scripted_replays_recorded_events_bit_exactly() {
        let cfg = FaultsConfig {
            mtbf: 50.0,
            mttr: 10.0,
            straggler_rate: 0.02,
            ..FaultsConfig::default()
        };
        let mut live = FaultModel::stochastic(cfg, 8, Pcg64::seeded(9));
        let mut recorded = Vec::new();
        let mut per_tick = Vec::new();
        for step in 0..300 {
            let evs = live.step(step as f64, 1.0);
            recorded.extend(evs.clone());
            per_tick.push(evs);
        }
        let mut replay = FaultModel::scripted(recorded);
        for (step, expect) in per_tick.iter().enumerate() {
            assert_eq!(&replay.step(step as f64, 1.0), expect, "tick {step}");
        }
    }

    #[test]
    fn event_json_roundtrip() {
        for ev in [
            FaultEvent { t: 12.5, server: 3, kind: FaultKind::Fail },
            FaultEvent { t: 40.0, server: 0, kind: FaultKind::Recover },
            FaultEvent {
                t: 7.25,
                server: 11,
                kind: FaultKind::SlowStart { factor: 2.375, duration: 33.5 },
            },
            FaultEvent { t: 9.0, server: 11, kind: FaultKind::SlowEnd },
        ] {
            let back = FaultEvent::from_json(&ev.to_json()).unwrap();
            assert_eq!(back, ev);
        }
        assert!(FaultEvent::from_json(&crate::util::json::parse(
            "{\"fault\":\"melt\",\"t\":1.0,\"server\":0}"
        )
        .unwrap())
        .is_err());
    }
}
