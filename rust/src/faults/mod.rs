//! Fault & straggler resilience subsystem: the single source of
//! server-health dynamics for the simulator and the serving layer.
//!
//! The paper's gang scheduling makes every task only as fast as its
//! slowest patch, yet the seed simulator assumed servers never fail and
//! never slow down. Edge deployments are exactly where that assumption
//! breaks: heterogeneous, loosely managed servers crash, whole racks or
//! zones lose power together, and load-dependent slowdowns turn one
//! server into a straggler that stalls its entire gang. This module adds
//! that axis:
//!
//! - [`FaultsConfig`] — a serialisable description of the health dynamics
//!   (per-server Markov up/down churn with exponential MTBF/MTTR,
//!   correlated zone-level shocks, transient lognormal straggler
//!   slowdowns, speculative re-execution threshold, retry budget, and the
//!   health-aware-dispatch switch), living in `EnvConfig::faults`.
//! - [`FaultModel`] — the runtime process: stochastic stepping from a
//!   dedicated RNG stream (forked from a *clone* of the env RNG, so the
//!   main stream — and with it common-random-number pairing of arrivals
//!   and execution jitter across policies — is bit-identical whether
//!   faults are enabled or not), or scripted replay of a recorded
//!   [`FaultEvent`] sequence for bit-exact episode reproduction.
//! - [`FaultEvent`] — one health transition (fail / recover / slowdown
//!   start / slowdown end), serialisable into the JSONL workload-trace
//!   format (`workload::trace`) so a recorded episode replays with its
//!   exact failure timeline.
//!
//! `EdgeEnv` consumes the events: a mid-flight failure kills the whole
//! gang, re-queues the task (deadline and retry count intact), and the
//! recovered server comes back weight-cold; stragglers stretch execution
//! until speculative backups race them. `eat faults`
//! (`experiments::faults`) sweeps MTBF × zone shocks × straggler rate ×
//! dispatch mode and reports goodput, wasted work, retries, and
//! per-tenant SLO attainment under churn.

pub mod model;

pub use model::{FaultEvent, FaultKind, FaultModel};

use crate::util::json::Value;

/// Serialisable description of server-health dynamics. `None` in
/// `EnvConfig::faults` (or an [`FaultsConfig::off`] section) keeps the
/// seed's fault-free behaviour bit-identically.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultsConfig {
    /// Mean time between failures per up server (s); 0 disables
    /// independent churn.
    pub mtbf: f64,
    /// Mean time to repair per down server (s).
    pub mttr: f64,
    /// Servers are striped into this many zones (server id mod `zones`);
    /// a zone shock downs every up server in one zone at once.
    pub zones: usize,
    /// Cluster-wide rate of zone shocks (per simulated second); 0
    /// disables correlated failures.
    pub zone_shock_rate: f64,
    /// Per-server onset rate of transient slowdowns (per s); 0 disables
    /// stragglers.
    pub straggler_rate: f64,
    /// Lognormal(mu, sigma) slowdown multiplier, clamped to >= 1.
    pub straggler_mu: f64,
    pub straggler_sigma: f64,
    /// Mean duration (s) of one slowdown bout (exponential).
    pub straggler_mean_duration: f64,
    /// Speculative re-execution: when a gang's elapsed time exceeds
    /// `spec_beta` x its nominal duration and an idle *warm* gang of the
    /// right shape exists, launch a backup; first finisher wins and the
    /// loser is charged as wasted work. 0 disables speculation.
    pub spec_beta: f64,
    /// A task is dropped (counted failed) once it has been killed more
    /// than this many times.
    pub max_retries: u32,
    /// Health-aware dispatch: mask down servers out of server selection.
    /// `false` is the fault-blind baseline — the scheduler happily
    /// dispatches onto down servers and pays for it with killed gangs.
    pub health_aware: bool,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        FaultsConfig {
            mtbf: 600.0,
            mttr: 45.0,
            zones: 4,
            zone_shock_rate: 0.001,
            straggler_rate: 0.005,
            straggler_mu: 0.9,
            straggler_sigma: 0.35,
            straggler_mean_duration: 40.0,
            spec_beta: 2.0,
            max_retries: 3,
            health_aware: true,
        }
    }
}

impl FaultsConfig {
    /// An inert section: no churn, no shocks, no stragglers. An env built
    /// with it takes the exact seed code path (no fault runtime at all),
    /// which the regression property test pins against `faults: None`.
    pub fn off() -> FaultsConfig {
        FaultsConfig {
            mtbf: 0.0,
            zone_shock_rate: 0.0,
            straggler_rate: 0.0,
            spec_beta: 0.0,
            ..FaultsConfig::default()
        }
    }

    /// Does this section produce any health dynamics at all?
    pub fn is_active(&self) -> bool {
        self.mtbf > 0.0 || self.zone_shock_rate > 0.0 || self.straggler_rate > 0.0
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        let nonneg = |name: &str, x: f64| -> anyhow::Result<()> {
            anyhow::ensure!(x >= 0.0 && x.is_finite(), "faults.{name} must be finite and >= 0, got {x}");
            Ok(())
        };
        nonneg("mtbf", self.mtbf)?;
        nonneg("zone_shock_rate", self.zone_shock_rate)?;
        nonneg("straggler_rate", self.straggler_rate)?;
        nonneg("straggler_sigma", self.straggler_sigma)?;
        anyhow::ensure!(
            self.mttr > 0.0 && self.mttr.is_finite(),
            "faults.mttr must be > 0, got {}",
            self.mttr
        );
        anyhow::ensure!(self.zones >= 1, "faults.zones must be >= 1");
        anyhow::ensure!(
            self.straggler_mu.is_finite(),
            "faults.straggler_mu must be finite"
        );
        anyhow::ensure!(
            self.straggler_mean_duration > 0.0 && self.straggler_mean_duration.is_finite(),
            "faults.straggler_mean_duration must be > 0"
        );
        anyhow::ensure!(
            self.spec_beta == 0.0 || (self.spec_beta > 1.0 && self.spec_beta.is_finite()),
            "faults.spec_beta must be 0 (off) or > 1, got {}",
            self.spec_beta
        );
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("mtbf", self.mtbf)
            .set("mttr", self.mttr)
            .set("zones", self.zones)
            .set("zone_shock_rate", self.zone_shock_rate)
            .set("straggler_rate", self.straggler_rate)
            .set("straggler_mu", self.straggler_mu)
            .set("straggler_sigma", self.straggler_sigma)
            .set("straggler_mean_duration", self.straggler_mean_duration)
            .set("spec_beta", self.spec_beta)
            .set("max_retries", self.max_retries as usize)
            .set("health_aware", self.health_aware);
        v
    }

    pub fn from_json(v: &Value) -> anyhow::Result<FaultsConfig> {
        let mut cfg = FaultsConfig::default();
        macro_rules! num {
            ($key:literal, $field:expr, $ty:ty) => {
                if let Some(x) = v.get($key).and_then(Value::as_f64) {
                    $field = x as $ty;
                }
            };
        }
        num!("mtbf", cfg.mtbf, f64);
        num!("mttr", cfg.mttr, f64);
        num!("zones", cfg.zones, usize);
        num!("zone_shock_rate", cfg.zone_shock_rate, f64);
        num!("straggler_rate", cfg.straggler_rate, f64);
        num!("straggler_mu", cfg.straggler_mu, f64);
        num!("straggler_sigma", cfg.straggler_sigma, f64);
        num!("straggler_mean_duration", cfg.straggler_mean_duration, f64);
        num!("spec_beta", cfg.spec_beta, f64);
        num!("max_retries", cfg.max_retries, u32);
        if let Some(b) = v.get("health_aware").and_then(Value::as_bool) {
            cfg.health_aware = b;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_active_and_valid() {
        let cfg = FaultsConfig::default();
        cfg.validate().unwrap();
        assert!(cfg.is_active());
        assert!(!FaultsConfig::off().is_active());
        FaultsConfig::off().validate().unwrap();
    }

    #[test]
    fn json_roundtrip_preserves_fields() {
        let cfg = FaultsConfig {
            mtbf: 321.0,
            zones: 2,
            spec_beta: 1.75,
            max_retries: 7,
            health_aware: false,
            ..FaultsConfig::default()
        };
        let back = FaultsConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn invalid_sections_rejected() {
        let bad = |f: FaultsConfig| assert!(f.validate().is_err());
        bad(FaultsConfig { mttr: 0.0, ..FaultsConfig::default() });
        bad(FaultsConfig { zones: 0, ..FaultsConfig::default() });
        // Backups launched before the nominal finish would be nonsense.
        bad(FaultsConfig { spec_beta: 0.5, ..FaultsConfig::default() });
        bad(FaultsConfig { mtbf: -1.0, ..FaultsConfig::default() });
    }

    #[test]
    fn json_rejects_invalid() {
        let mut v = FaultsConfig::default().to_json();
        v.set("mttr", -3.0);
        assert!(FaultsConfig::from_json(&v).is_err());
    }
}
