//! Deterministic fork-join over independent sweep cells.
//!
//! The experiment grids (`eat scenarios` / `eat qos` / `eat faults`)
//! evaluate many (config, seed) cells whose RNG streams are forked
//! per-cell up front, so cells share no state and can run concurrently
//! without touching the common-random-number pairing *within* a cell.
//! [`map_cells`] farms the cells out to a scoped thread pool and returns
//! results in input order, so the output is byte-identical regardless of
//! thread count or completion order — pinned by a property test in the
//! experiments layer.
//!
//! No ecosystem crates are available offline (see `util/mod.rs`), so this
//! is a minimal `std::thread::scope` pool over a shared atomic cursor.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Default worker count: the machine's available parallelism, falling
/// back to 1 when it cannot be queried.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Apply `f` to every item, using up to `threads` workers, returning
/// results in input order.
///
/// `f` must be deterministic per item for the thread-count independence
/// guarantee to mean anything; each worker claims items off a shared
/// cursor, computes, and writes the result into the item's own slot.
/// With `threads <= 1` (or a single item) everything runs inline on the
/// caller's thread — no spawn, identical results.
pub fn map_cells<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if threads <= 1 || n <= 1 {
        return items.into_iter().map(f).collect();
    }
    let workers = threads.min(n);
    // Hand out items through a cursor over Option slots; collect results
    // into pre-sized Option slots keyed by the same index.
    let jobs: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = jobs[i].lock().expect("job slot").take().expect("unclaimed job");
                let r = f(item);
                *results[i].lock().expect("result slot") = Some(r);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().expect("result lock").expect("worker wrote slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..37).collect();
        let out = map_cells(items, 4, |i| i * i);
        assert_eq!(out, (0..37).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn thread_count_does_not_change_output() {
        let work = |i: usize| {
            // Unequal per-item cost so completion order differs from
            // claim order under real parallelism.
            let mut acc = i as u64;
            for k in 0..(i % 7) * 1000 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k);
            }
            (i, acc)
        };
        let base = map_cells((0..25).collect(), 1, work);
        for threads in [2, 3, 8] {
            assert_eq!(map_cells((0..25).collect(), threads, work), base);
        }
    }

    #[test]
    fn single_item_runs_inline() {
        let out = map_cells(vec![41usize], 8, |i| i + 1);
        assert_eq!(out, vec![42]);
    }

    #[test]
    fn empty_input_yields_empty_output() {
        let out: Vec<usize> = map_cells(Vec::<usize>::new(), 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
    }
}
