//! Plain-text table rendering for the experiment harness — every
//! reproduction binary prints the same rows/columns the paper's tables
//! report, via this formatter.

/// A simple column-aligned table with a title.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
        self
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, cell) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", cell, width = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Render as CSV (for EXPERIMENTS.md appendices / plotting).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with fixed decimals, used across experiment tables.
pub fn f(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["alg", "latency"]);
        t.row(vec!["EAT".into(), "39.7".into()]);
        t.row(vec!["Greedy".into(), "154.2".into()]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        assert!(s.contains("EAT"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let csv = t.to_csv();
        assert_eq!(csv, "a,b\n1,2\n");
    }
}
