//! Tiny argument parser for the `eat` binary and examples: positional
//! subcommands plus `--key value` / `--flag` options.

use std::collections::BTreeMap;

/// Parsed command line: positionals in order, options by name.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` if next token isn't another option,
                    // else a bare flag.
                    match iter.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = iter.next().unwrap();
                            args.options.insert(name.to_string(), v);
                        }
                        _ => args.flags.push(name.to_string()),
                    }
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get_usize_opt(key).unwrap_or(default)
    }

    /// `Some(parsed)` when the option is present (panicking on a bad
    /// value, like [`get_usize`](Self::get_usize)), `None` when absent.
    pub fn get_usize_opt(&self, key: &str) -> Option<usize> {
        self.get(key).map(|s| {
            s.parse()
                .unwrap_or_else(|_| panic!("--{key} expects an integer, got {s:?}"))
        })
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {s:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {s:?}")))
            .unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("experiment table9 --nodes 8 --rate 0.1 --verbose");
        assert_eq!(a.positional, vec!["experiment", "table9"]);
        assert_eq!(a.get_usize("nodes", 4), 8);
        assert!((a.get_f64("rate", 0.0) - 0.1).abs() < 1e-12);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("train --alg=eat --steps=100");
        assert_eq!(a.get("alg"), Some("eat"));
        assert_eq!(a.get_usize("steps", 0), 100);
    }

    #[test]
    fn defaults() {
        let a = parse("eval");
        assert_eq!(a.get_or("alg", "eat"), "eat");
        assert_eq!(a.get_usize("episodes", 5), 5);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn optional_integers_distinguish_absent_from_zero() {
        let a = parse("serve --kill-at 0");
        assert_eq!(a.get_usize_opt("kill-at"), Some(0));
        assert_eq!(a.get_usize_opt("respawn-at"), None);
    }

    #[test]
    fn flag_before_option() {
        let a = parse("--quiet --out file.json run");
        assert!(a.has_flag("quiet"));
        assert_eq!(a.get("out"), Some("file.json"));
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn negative_number_value() {
        let a = parse("--bias=-1.5");
        assert!((a.get_f64("bias", 0.0) + 1.5).abs() < 1e-12);
    }
}
