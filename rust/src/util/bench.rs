//! Micro-benchmark harness (criterion is not available offline).
//!
//! Each `[[bench]]` target sets `harness = false` and drives this runner:
//! warmup, then timed batches until a wall-clock budget or iteration cap is
//! hit; reports mean/p50/p99 per iteration. Deterministic ordering, plain
//! text output that `cargo bench` streams through.

use std::time::{Duration, Instant};

use super::stats::{percentile, Welford};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub std_ns: f64,
}

impl BenchResult {
    pub fn throughput_per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

/// Benchmark runner with a per-case time budget.
pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    max_iters: u64,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new(Duration::from_millis(200), Duration::from_secs(2), 1_000_000)
    }
}

impl Bencher {
    pub fn new(warmup: Duration, budget: Duration, max_iters: u64) -> Self {
        Bencher {
            warmup,
            budget,
            max_iters,
            results: Vec::new(),
        }
    }

    /// Quick settings for CI-ish runs.
    pub fn quick() -> Self {
        Self::new(Duration::from_millis(50), Duration::from_millis(500), 100_000)
    }

    /// Time `f` repeatedly; `f` should perform one logical operation and
    /// return a value that is black-boxed to stop the optimizer.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // Timed samples: batches sized so each batch is ≥ ~100µs to keep
        // timer overhead negligible, collecting per-iter estimates.
        let batch = {
            let t0 = Instant::now();
            black_box(f());
            let one = t0.elapsed().as_nanos().max(1) as u64;
            (100_000 / one).clamp(1, 10_000)
        };
        let mut samples: Vec<f64> = Vec::new();
        let mut w = Welford::new();
        let mut iters = 0u64;
        let run_start = Instant::now();
        while run_start.elapsed() < self.budget && iters < self.max_iters {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            let per_iter = t0.elapsed().as_nanos() as f64 / batch as f64;
            samples.push(per_iter);
            w.push(per_iter);
            iters += batch;
        }
        let res = BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: w.mean(),
            p50_ns: percentile(&samples, 0.5),
            p99_ns: percentile(&samples, 0.99),
            std_ns: w.std(),
        };
        // eat-lint: allow(logging, "cargo-bench style per-case result line belongs on stdout")
        println!(
            "bench {:<44} {:>12.1} ns/iter  p50 {:>12.1}  p99 {:>12.1}  ({} iters)",
            res.name, res.mean_ns, res.p50_ns, res.p99_ns, res.iters
        );
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Render all results as a summary table (printed at the end of each
    /// bench binary, captured into bench_output.txt).
    pub fn summary(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<44} {:>14} {:>14} {:>14} {:>12}\n",
            "benchmark", "mean", "p50", "p99", "ops/sec"
        ));
        for r in &self.results {
            out.push_str(&format!(
                "{:<44} {:>14} {:>14} {:>14} {:>12.0}\n",
                r.name,
                fmt_ns(r.mean_ns),
                fmt_ns(r.p50_ns),
                fmt_ns(r.p99_ns),
                r.throughput_per_sec()
            ));
        }
        out
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Prevent the optimizer from discarding a value (stable-Rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut b = Bencher::new(
            Duration::from_millis(1),
            Duration::from_millis(20),
            100_000,
        );
        let r = b.bench("add", || 2u64.wrapping_add(3)).clone();
        assert!(r.iters > 0);
        assert!(r.mean_ns > 0.0);
        assert!(r.p99_ns >= r.p50_ns * 0.5);
        assert!(!b.summary().is_empty());
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with(" s"));
    }
}
