//! Self-contained substrates: RNG, JSON, statistics, CLI parsing, timing.
//!
//! The offline crate registry in this environment carries only the `xla`
//! closure, so the usual ecosystem crates (rand, serde, clap, criterion)
//! are re-implemented here at the scale this project needs. Each module is
//! fully unit-tested; see DESIGN.md §Substitutions.

pub mod bench;
pub mod cli;
pub mod json;
pub mod par;
pub mod rng;
pub mod stats;
pub mod table;
