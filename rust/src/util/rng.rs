//! PCG64 pseudo-random number generator plus the distributions the
//! simulator needs (uniform, normal, exponential, lognormal, categorical).
//!
//! PCG-XSL-RR 128/64 (O'Neill 2014). Deterministic, seedable, fast, and
//! good enough statistically for simulation workloads; every stochastic
//! component in the system (task arrivals, execution jitter, diffusion
//! noise fed into the HLO networks, exploration noise, baselines'
//! mutation/improvisation operators) draws from this generator so entire
//! experiments replay bit-identically from a seed.

/// PCG-XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Create a generator from a seed and stream id. Distinct streams are
    /// statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    /// Convenience constructor on stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive a child generator; used to give each subsystem its own stream.
    pub fn fork(&mut self, stream: u64) -> Self {
        let seed = self.next_u64();
        Self::new(seed, stream)
    }

    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (polar form avoided for simplicity;
    /// the trig form has no rejection loop and deterministic draw count).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE); // (0, 1]
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with the given rate (mean = 1/rate).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0, "exponential rate must be > 0");
        -(1.0 - self.next_f64()).ln() / rate
    }

    /// Lognormal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical weights must sum > 0");
        let mut u = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Pick a uniformly random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.next_below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fill a buffer with standard-normal f32 draws (noise tensors for the
    /// diffusion policy's reverse chain and exploration noise).
    pub fn fill_normal_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal() as f32;
        }
    }

    /// Fill a buffer with U[0,1) f32 draws.
    pub fn fill_uniform_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.next_f32();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg64::seeded(7);
        let mut b = Pcg64::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(7, 1);
        let mut b = Pcg64::new(7, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_range() {
        let mut rng = Pcg64::seeded(1);
        for _ in 0..1000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_unbiased_support() {
        let mut rng = Pcg64::seeded(2);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.next_below(5) as usize] += 1;
        }
        for &c in &counts {
            // Each bucket expects 10_000; allow 5% tolerance.
            assert!((9_500..10_500).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(3);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg64::seeded(4);
        let rate = 0.1;
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.2, "mean={mean}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Pcg64::seeded(5);
        let w = [1.0, 3.0];
        let ones = (0..40_000).filter(|_| rng.categorical(&w) == 1).count();
        let frac = ones as f64 / 40_000.0;
        assert!((frac - 0.75).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(6);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn lognormal_positive() {
        let mut rng = Pcg64::seeded(8);
        for _ in 0..1000 {
            assert!(rng.lognormal(0.0, 0.5) > 0.0);
        }
    }
}
