//! Minimal JSON: a `Value` tree, a recursive-descent parser, and a writer.
//!
//! Used for the artifact manifest (`artifacts/manifest.json` produced by
//! `python/compile/aot.py`), experiment/config files, result dumps, and the
//! host↔worker socket protocol in `serving/` (the paper ships task
//! descriptions and results as JSON strings over sockets, §VI.A.1).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a BTreeMap so output is
/// deterministic (stable diffs in tests and golden files).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn obj() -> Value {
        Value::Obj(BTreeMap::new())
    }

    /// Insert into an object value; panics if not an object.
    pub fn set(&mut self, key: &str, v: impl Into<Value>) -> &mut Self {
        match self {
            Value::Obj(m) => {
                m.insert(key.to_string(), v.into());
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that errors with the path, for manifest parsing.
    pub fn req(&self, key: &str) -> anyhow::Result<&Value> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Array of numbers → Vec<usize> (shape lists in the manifest).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    /// Serialize compactly.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        write_value(&mut s, self);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_json_pretty(&self) -> String {
        let mut s = String::new();
        write_pretty(&mut s, self, 0);
        s
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<f64> for Value {
    fn from(x: f64) -> Self {
        Value::Num(x)
    }
}
impl From<f32> for Value {
    fn from(x: f32) -> Self {
        Value::Num(x as f64)
    }
}
impl From<usize> for Value {
    fn from(x: usize) -> Self {
        Value::Num(x as f64)
    }
}
impl From<u64> for Value {
    fn from(x: u64) -> Self {
        Value::Num(x as f64)
    }
}
impl From<i64> for Value {
    fn from(x: i64) -> Self {
        Value::Num(x as f64)
    }
}
impl From<u32> for Value {
    fn from(x: u32) -> Self {
        Value::Num(x as f64)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_value(out: &mut String, v: &Value) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(x) => write_num(out, *x),
        Value::Str(s) => write_str(out, s),
        Value::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(out, item);
            }
            out.push(']');
        }
        Value::Obj(map) => {
            out.push('{');
            for (i, (k, item)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(out, k);
                out.push(':');
                write_value(out, item);
            }
            out.push('}');
        }
    }
}

fn write_pretty(out: &mut String, v: &Value, indent: usize) {
    match v {
        Value::Arr(items) if !items.is_empty() => {
            out.push_str("[\n");
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                for _ in 0..indent + 2 {
                    out.push(' ');
                }
                write_pretty(out, item, indent + 2);
            }
            out.push('\n');
            for _ in 0..indent {
                out.push(' ');
            }
            out.push(']');
        }
        Value::Obj(map) if !map.is_empty() => {
            out.push_str("{\n");
            for (i, (k, item)) in map.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                for _ in 0..indent + 2 {
                    out.push(' ');
                }
                write_str(out, k);
                out.push_str(": ");
                write_pretty(out, item, indent + 2);
            }
            out.push('\n');
            for _ in 0..indent {
                out.push(' ');
            }
            out.push('}');
        }
        _ => write_value(out, v),
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            out.push_str(&format!("{}", x as i64));
        } else {
            out.push_str(&format!("{x}"));
        }
    } else {
        // JSON has no NaN/Inf; emit null like most tolerant writers.
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Errors carry byte offsets.
pub fn parse(input: &str) -> anyhow::Result<Value> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> anyhow::Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> anyhow::Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> anyhow::Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                other => anyhow::bail!("expected ',' or '}}' at byte {}, got {:?}", self.pos, other),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                other => anyhow::bail!("expected ',' or ']' at byte {}, got {:?}", self.pos, other),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos + 1..self.pos + 5)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            // Surrogate pairs: decode if a high surrogate.
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes.get(self.pos + 1..self.pos + 3) == Some(b"\\u") {
                                    let hex2 = std::str::from_utf8(
                                        self.bytes
                                            .get(self.pos + 3..self.pos + 7)
                                            .ok_or_else(|| anyhow::anyhow!("bad surrogate"))?,
                                    )?;
                                    let lo = u32::from_str_radix(hex2, 16)?;
                                    self.pos += 6;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    s.push(char::from_u32(c).unwrap_or('\u{FFFD}'));
                                } else {
                                    s.push('\u{FFFD}');
                                }
                            } else {
                                s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            }
                        }
                        other => anyhow::bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Value::Num(text.parse::<f64>()?))
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let mut v = Value::obj();
        v.set("name", "eat").set("n", 42usize).set("ok", true);
        v.set("xs", vec![1.0f64, 2.5, -3.0]);
        let text = v.to_json();
        let back = parse(&text).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, {"b": null}, "s"], "c": -1.5e2}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_f64(), Some(-150.0));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""line\nquote\" tab\t uA""#).unwrap();
        assert_eq!(v.as_str(), Some("line\nquote\" tab\t uA"));
        let back = parse(&v.to_json()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn rejects_trailing() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn integers_stay_integers_in_output() {
        assert_eq!(Value::Num(3.0).to_json(), "3");
        assert_eq!(Value::Num(3.25).to_json(), "3.25");
    }

    #[test]
    fn pretty_parses_back() {
        let mut v = Value::obj();
        v.set("arr", vec![1usize, 2, 3]);
        let mut inner = Value::obj();
        inner.set("k", "v");
        v.set("obj", inner);
        let text = v.to_json_pretty();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn usize_vec_helper() {
        let v = parse("[3, 20]").unwrap();
        assert_eq!(v.as_usize_vec(), Some(vec![3, 20]));
    }
}
