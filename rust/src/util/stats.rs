//! Streaming and batch statistics used by metrics recorders, the experiment
//! harness, and the bench framework: Welford mean/variance, percentiles,
//! simple linear regression (for the time predictor), and EMA smoothing
//! (for training curves).

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator (parallel aggregation).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2 + other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Percentile of a sample via linear interpolation (type-7, like numpy).
/// `q` in [0, 1]. Sorts a copy; fine at experiment scale.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    assert!((0.0..=1.0).contains(&q));
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = pos - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Ordinary least squares y = a + b·x. Returns (intercept, slope, r²).
/// Used by the execution-time predictor: per-step time is linear in the
/// number of inference steps (paper Table VI / Fig 7).
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for i in 0..xs.len() {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        sxx += dx * dx;
        sxy += dx * dy;
        syy += dy * dy;
    }
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let r2 = if syy == 0.0 {
        1.0
    } else {
        (sxy * sxy) / (sxx * syy)
    };
    let _ = n;
    (intercept, slope, r2)
}

/// Exponential moving average smoother for training curves.
#[derive(Clone, Debug)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ema { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn value(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // Sample variance of this classic dataset is 32/7.
        assert!((w.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Welford::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-10);
        assert!((a.var() - all.var()).abs() < 1e-10);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert!((percentile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn linreg_exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0];
        let (a, b, r2) = linreg(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..64 {
            e.push(10.0);
        }
        assert!((e.value().unwrap() - 10.0).abs() < 1e-6);
    }
}
