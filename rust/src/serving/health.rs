//! Live worker health: a registry of per-worker up/down state maintained
//! by periodic heartbeat probes on a background thread, feeding both gang
//! selection (`Cluster::select_healthy` with the registry mirrored in) and
//! resilient dispatch (spares drawn from healthy workers, excluded workers
//! marked down until a probe revives them). This is the serving-side twin
//! of the simulator's fault subsystem: edge AIGC serving treats server
//! churn as a first-class concern, not an error path.

use super::host::ServingHost;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Per-worker probe state.
#[derive(Clone, Copy, Debug)]
struct WorkerHealth {
    up: bool,
    /// Consecutive missed probes (reset by any successful probe).
    misses: u32,
    /// Bumped by every `mark_down`: a successful probe that *started*
    /// before a mark-down (stale pong from a worker killed meanwhile)
    /// must not revive it.
    generation: u64,
}

/// Aggregate probe statistics, surfaced in the serving summary.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HealthStats {
    /// Total heartbeat probes sent.
    pub probes: u64,
    /// up→down transitions (probe misses or dispatch-observed failures).
    pub downs: u64,
    /// down→up transitions (a probe reached a revived worker).
    pub recoveries: u64,
}

/// Shared up/down registry. Probes and dispatch failures write it; gang
/// selection and spare-picking read it. All methods take `&self` (interior
/// mutex) so the registry can sit behind an `Arc` shared with the probe
/// thread.
pub struct HealthRegistry {
    state: Mutex<Vec<WorkerHealth>>,
    stats: Mutex<HealthStats>,
    /// Consecutive missed probes before a worker is marked down.
    down_after: u32,
}

impl HealthRegistry {
    /// All workers start up (optimistic until the first probe says
    /// otherwise). `down_after` is clamped to at least 1.
    pub fn new(workers: usize, down_after: u32) -> Self {
        let fresh = WorkerHealth {
            up: true,
            misses: 0,
            generation: 0,
        };
        HealthRegistry {
            state: Mutex::new(vec![fresh; workers]),
            stats: Mutex::new(HealthStats::default()),
            down_after: down_after.max(1),
        }
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Token to capture *before* sending a probe; pass it to
    /// [`record_probe_from`](Self::record_probe_from) so a pong that was
    /// in flight when `mark_down` hit the worker cannot revive it.
    pub fn probe_token(&self, worker: usize) -> u64 {
        self.state
            .lock()
            .unwrap()
            .get(worker)
            .map_or(0, |w| w.generation)
    }

    /// Record one probe outcome. A success revives the worker (the only
    /// way back up); a miss marks it down after `down_after` consecutive
    /// misses.
    pub fn record_probe(&self, worker: usize, ok: bool) {
        let token = self.probe_token(worker);
        self.record_probe_from(worker, ok, token);
    }

    /// [`record_probe`](Self::record_probe) for a probe that started when
    /// [`probe_token`](Self::probe_token) returned `token`: a successful
    /// probe from a previous generation (a `mark_down` landed while the
    /// ping was in flight) is discarded instead of reviving the worker.
    pub fn record_probe_from(&self, worker: usize, ok: bool, token: u64) {
        let mut state = self.state.lock().unwrap();
        let Some(w) = state.get_mut(worker) else {
            return;
        };
        let mut stats = self.stats.lock().unwrap();
        stats.probes += 1;
        if ok {
            if w.generation != token {
                return; // stale pong: the worker was marked down meanwhile
            }
            w.misses = 0;
            if !w.up {
                w.up = true;
                stats.recoveries += 1;
            }
        } else {
            w.misses = w.misses.saturating_add(1);
            if w.up && w.misses >= self.down_after {
                w.up = false;
                stats.downs += 1;
            }
        }
    }

    /// Mark a worker down immediately (a dispatch observed it failing —
    /// stronger evidence than a missed probe). It stays down until a
    /// heartbeat probe succeeds against it again.
    pub fn mark_down(&self, worker: usize) {
        let mut state = self.state.lock().unwrap();
        let Some(w) = state.get_mut(worker) else {
            return;
        };
        w.misses = self.down_after;
        w.generation += 1; // invalidate in-flight probes
        if w.up {
            w.up = false;
            self.stats.lock().unwrap().downs += 1;
        }
    }

    /// Whether a worker is currently believed up. Unknown ids are down.
    pub fn up(&self, worker: usize) -> bool {
        self.state.lock().unwrap().get(worker).is_some_and(|w| w.up)
    }

    /// Per-worker up/down snapshot, index-aligned with the worker pool
    /// (mirror into `Cluster::set_health` before gang selection).
    pub fn snapshot(&self) -> Vec<bool> {
        self.state.lock().unwrap().iter().map(|w| w.up).collect()
    }

    /// Ids of all workers currently believed up.
    pub fn healthy(&self) -> Vec<usize> {
        self.state
            .lock()
            .unwrap()
            .iter()
            .enumerate()
            .filter(|(_, w)| w.up)
            .map(|(i, _)| i)
            .collect()
    }

    pub fn up_count(&self) -> usize {
        self.state.lock().unwrap().iter().filter(|w| w.up).count()
    }

    /// `(up, total)` under one lock acquisition — the consistent snapshot
    /// the metrics endpoint exports as `eat_workers_up` / `eat_workers`.
    pub fn counts(&self) -> (usize, usize) {
        let state = self.state.lock().unwrap();
        (state.iter().filter(|w| w.up).count(), state.len())
    }

    pub fn stats(&self) -> HealthStats {
        *self.stats.lock().unwrap()
    }
}

/// Background heartbeat prober: one long-lived thread per worker, each
/// probing every `interval` and recording outcomes into the shared
/// registry until stopped. Per-worker threads mean a hung worker (probe
/// blocked until `timeout`) never delays detection on the others, with
/// zero steady-state thread creation.
pub struct HealthMonitor {
    stop: Arc<AtomicBool>,
    handles: Vec<JoinHandle<()>>,
}

impl HealthMonitor {
    pub fn start(
        host: ServingHost,
        registry: Arc<HealthRegistry>,
        interval: Duration,
        timeout: Duration,
    ) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let host = Arc::new(host);
        let handles = (0..host.worker_count())
            .map(|w| {
                let (host, registry, stop) = (host.clone(), registry.clone(), stop.clone());
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        let token = registry.probe_token(w);
                        let ok = host.heartbeat(w, timeout);
                        registry.record_probe_from(w, ok, token);
                        std::thread::sleep(interval);
                    }
                })
            })
            .collect();
        HealthMonitor { stop, handles }
    }

    /// Stop probing and join the prober threads.
    pub fn stop(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for h in self.handles.drain(..) {
            h.join().ok();
        }
    }
}

impl Drop for HealthMonitor {
    fn drop(&mut self) {
        self.halt();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExecModelConfig;
    use crate::serving::worker::WorkerPool;
    use std::time::Instant;

    #[test]
    fn registry_needs_consecutive_misses_to_mark_down() {
        let reg = HealthRegistry::new(2, 2);
        assert!(reg.up(0) && reg.up(1));
        reg.record_probe(0, false);
        assert!(reg.up(0), "one miss of two must not down the worker");
        reg.record_probe(0, true); // miss streak broken
        reg.record_probe(0, false);
        assert!(reg.up(0));
        reg.record_probe(0, false);
        assert!(!reg.up(0), "two consecutive misses must down the worker");
        assert_eq!(reg.healthy(), vec![1]);
        assert_eq!(reg.snapshot(), vec![false, true]);
        // A successful probe is the only way back up.
        reg.record_probe(0, true);
        assert!(reg.up(0));
        let stats = reg.stats();
        assert_eq!(stats.downs, 1);
        assert_eq!(stats.recoveries, 1);
        assert_eq!(stats.probes, 6);
    }

    #[test]
    fn mark_down_is_immediate_and_sticky_until_probe() {
        let reg = HealthRegistry::new(3, 3);
        reg.mark_down(1);
        assert!(!reg.up(1));
        assert_eq!(reg.up_count(), 2);
        assert_eq!(reg.counts(), (2, 3));
        // Repeated marks don't double-count the transition.
        reg.mark_down(1);
        assert_eq!(reg.stats().downs, 1);
        // Out-of-range ids are ignored (and considered down).
        reg.mark_down(99);
        assert!(!reg.up(99));
        reg.record_probe(1, true);
        assert!(reg.up(1));
        assert_eq!(reg.stats().recoveries, 1);
    }

    #[test]
    fn stale_pong_cannot_revive_a_marked_down_worker() {
        let reg = HealthRegistry::new(1, 2);
        // A probe starts (token captured), then dispatch observes the
        // worker failing, then the probe's stale pong arrives.
        let token = reg.probe_token(0);
        reg.mark_down(0);
        reg.record_probe_from(0, true, token);
        assert!(!reg.up(0), "a pre-kill pong must not revive the worker");
        assert_eq!(reg.stats().recoveries, 0);
        // A fresh probe (current token) does revive it.
        reg.record_probe(0, true);
        assert!(reg.up(0));
        assert_eq!(reg.stats().recoveries, 1);
    }

    fn wait_until(deadline: Duration, mut cond: impl FnMut() -> bool) -> bool {
        let t0 = Instant::now();
        while t0.elapsed() < deadline {
            if cond() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        cond()
    }

    #[test]
    fn monitor_marks_killed_worker_down_and_revives_after_respawn() {
        let mut pool = WorkerPool::spawn(2, ExecModelConfig::default(), 1e-4, 21).unwrap();
        let host = ServingHost::new(pool.addrs().to_vec());
        let registry = Arc::new(HealthRegistry::new(2, 2));
        let monitor = HealthMonitor::start(
            host,
            registry.clone(),
            Duration::from_millis(25),
            Duration::from_millis(400),
        );
        let patient = Duration::from_secs(10);
        assert!(
            wait_until(patient, || registry.stats().probes >= 2),
            "monitor never probed"
        );
        assert!(registry.up(0) && registry.up(1));

        pool.kill(1);
        assert!(
            wait_until(patient, || !registry.up(1)),
            "killed worker never marked down"
        );
        assert!(registry.up(0), "healthy worker must stay up");

        pool.respawn(1).unwrap();
        assert!(
            wait_until(patient, || registry.up(1)),
            "respawned worker never revived"
        );
        let stats = registry.stats();
        assert!(stats.downs >= 1 && stats.recoveries >= 1, "{stats:?}");
        monitor.stop();
        pool.shutdown();
    }
}
