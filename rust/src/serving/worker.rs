//! Worker processes: one TCP listener per simulated GPU container. Each
//! accepted connection carries one newline-terminated JSON task request;
//! the worker "executes" it (sleeping the calibrated duration x
//! `time_scale`), tracks which model instance it has loaded (charging
//! initialisation time on change, like DistriFusion's model load), and
//! replies with a result JSON. Connections are handled on their own
//! threads: tasks serialise on the simulated GPU (one runs at a time),
//! but heartbeat pings bypass it, so a busy worker still answers probes.
//!
//! The pool supports controlled fault injection so the fault-aware serving
//! loop is demonstrable end-to-end: `kill` (listener gone, connections
//! refused — a crashed container), `wedge` (accepts connections but never
//! replies — a hung GPU process, detectable only via timeouts), and
//! `respawn` (a fresh worker on the same address, weight-cold).

use super::protocol::{self, TaskRequest, TaskResult};
use crate::config::ExecModelConfig;
use crate::sim::exec_model::ExecModel;
use crate::util::json;
use crate::util::rng::Pcg64;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Per-worker loaded-model state.
#[derive(Clone, Copy, Debug, PartialEq)]
struct Loaded {
    model: u32,
    patches: usize,
}

/// The simulated GPU: model state + jitter RNG behind one mutex. Task
/// execution holds the lock for its whole (scaled) duration — one GPU
/// runs one patch at a time — while heartbeat pings never touch it, so a
/// busy worker still answers probes instantly (a real container serves
/// health checks off the execution thread; without this, a long task
/// would starve the probe loop and get the worker falsely marked down).
struct GpuState {
    loaded: Option<Loaded>,
    rng: Pcg64,
}

fn handle(
    stream: TcpStream,
    worker_id: usize,
    exec: &ExecModel,
    gpu: &Mutex<GpuState>,
    time_scale: f64,
) -> anyhow::Result<()> {
    let t_recv = std::time::Instant::now();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    if line.trim().is_empty() {
        return Ok(());
    }
    // Heartbeat: answer pings immediately, without touching model state
    // or sleeping — the host uses them to detect dead/wedged workers.
    if let Ok(v) = json::parse(line.trim()) {
        if protocol::is_ping(&v) {
            let mut out = stream;
            out.write_all(protocol::pong_json(worker_id).as_bytes())?;
            out.write_all(b"\n")?;
            return Ok(());
        }
    }
    let req = TaskRequest::from_json(line.trim())?;
    let recv = t_recv.elapsed().as_secs_f64();
    let want = Loaded {
        model: req.model,
        patches: req.patches,
    };
    let (reused, load_time, exec_time, lock_wait, load_span, exec_span) = {
        let t_lock = std::time::Instant::now();
        let mut g = gpu.lock().unwrap();
        let lock_wait = t_lock.elapsed().as_secs_f64();
        // Model reuse: a loaded instance matches only if both the model
        // type and the gang size agree (DistriFusion loads per process
        // group).
        let reused = g.loaded == Some(want);
        let load_time = if reused {
            0.0
        } else {
            exec.sample_init(req.patches, &mut g.rng)
        };
        g.loaded = Some(want);
        let exec_time = exec.sample_exec(req.steps, req.patches, &mut g.rng);
        // Sleep while holding the lock: the GPU is busy for the duration.
        // Weight-load and denoise sleep separately (same total as one
        // combined sleep) so the reply can report each span's wall time.
        let t_load = std::time::Instant::now();
        std::thread::sleep(std::time::Duration::from_secs_f64(load_time * time_scale));
        let load_span = t_load.elapsed().as_secs_f64();
        let t_exec = std::time::Instant::now();
        std::thread::sleep(std::time::Duration::from_secs_f64(exec_time * time_scale));
        let exec_span = t_exec.elapsed().as_secs_f64();
        (reused, load_time, exec_time, lock_wait, load_span, exec_span)
    };
    let mut result = TaskResult {
        task_id: req.task_id,
        worker_id,
        exec_time,
        load_time,
        reused,
        image: format!("image:{}:{}:{}", req.task_id, req.rank, req.prompt.len()),
        timings: None,
    };
    if req.trace_id.is_some() {
        // Reply span: serialisation cost, probed on the timing-less
        // result (the socket write itself cannot be timed from inside
        // the payload; it lands in the host's network residual).
        let t_reply = std::time::Instant::now();
        let _ = result.to_json();
        let reply = t_reply.elapsed().as_secs_f64();
        result.timings = Some(protocol::WireTimings {
            recv,
            lock_wait,
            load: load_span,
            exec: exec_span,
            reply,
        });
    }
    let mut out = stream;
    out.write_all(result.to_json().as_bytes())?;
    out.write_all(b"\n")?;
    Ok(())
}

/// The accept loop of one worker. Owns the listener: when the loop exits
/// (stop flag), the listener drops and further connections are refused,
/// exactly like a crashed container.
fn run_worker(
    listener: TcpListener,
    worker_id: usize,
    exec_cfg: ExecModelConfig,
    time_scale: f64,
    seed: u64,
    stop: Arc<AtomicBool>,
    wedged: Arc<AtomicBool>,
) {
    let exec = Arc::new(ExecModel::new(exec_cfg));
    let gpu = Arc::new(Mutex::new(GpuState {
        loaded: None,
        rng: Pcg64::new(seed, worker_id as u64 + 0xB0),
    }));
    // Wedged-mode connections are parked here: accepted, request line
    // consumed, never answered. The client only notices via its read
    // timeout — the signature of a hung (not crashed) worker.
    let mut parked: Vec<TcpStream> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        if !wedged.load(Ordering::Relaxed) {
            parked.clear(); // unwedged: release the held connections (EOF)
        } else {
            // Shed parked connections whose client already gave up (its
            // read timeout fired and it closed), so a long wedge holds at
            // most the currently-waiting clients and cannot leak FDs.
            parked.retain(|s| {
                s.set_nonblocking(true).ok();
                let mut buf = [0u8; 1];
                match s.peek(&mut buf) {
                    Ok(0) => false, // peer closed
                    Ok(_) => true,
                    Err(e) => e.kind() == std::io::ErrorKind::WouldBlock,
                }
            });
        }
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false).ok();
                if wedged.load(Ordering::Relaxed) {
                    // Bounded read: a client that connects but never
                    // writes must not wedge the accept thread itself
                    // (kill/respawn/shutdown join it).
                    stream
                        .set_read_timeout(Some(std::time::Duration::from_millis(250)))
                        .ok();
                    let mut line = String::new();
                    if let Ok(clone) = stream.try_clone() {
                        BufReader::new(clone).read_line(&mut line).ok();
                    }
                    parked.push(stream);
                } else {
                    // One thread per connection: pings answer while a
                    // task sleeps on the GPU lock.
                    let (exec, gpu) = (exec.clone(), gpu.clone());
                    std::thread::spawn(move || {
                        if let Err(e) = handle(stream, worker_id, &exec, &gpu, time_scale) {
                            crate::log_warn!("worker {worker_id}: {e}");
                        }
                    });
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            Err(e) => {
                crate::log_warn!("worker {worker_id} accept: {e}");
                break;
            }
        }
    }
}

/// Control block for one live worker thread.
struct WorkerSlot {
    stop: Arc<AtomicBool>,
    wedged: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

/// A pool of worker listeners bound to ephemeral localhost ports, with
/// per-worker lifecycle control for fault injection.
pub struct WorkerPool {
    addrs: Vec<SocketAddr>,
    exec_cfg: ExecModelConfig,
    time_scale: f64,
    seed: u64,
    slots: Vec<WorkerSlot>,
}

impl WorkerPool {
    /// Spawn `n` workers. `time_scale` compresses simulated seconds into
    /// real sleeping time (e.g. 0.01 → a 33 s model load sleeps 330 ms).
    pub fn spawn(
        n: usize,
        exec_cfg: ExecModelConfig,
        time_scale: f64,
        seed: u64,
    ) -> anyhow::Result<WorkerPool> {
        let mut pool = WorkerPool {
            addrs: Vec::with_capacity(n),
            exec_cfg,
            time_scale,
            seed,
            slots: Vec::with_capacity(n),
        };
        for worker_id in 0..n {
            let listener = TcpListener::bind("127.0.0.1:0")?;
            listener.set_nonblocking(true)?;
            pool.addrs.push(listener.local_addr()?);
            let slot = pool.launch(listener, worker_id);
            pool.slots.push(slot);
        }
        Ok(pool)
    }

    fn launch(&self, listener: TcpListener, worker_id: usize) -> WorkerSlot {
        let stop = Arc::new(AtomicBool::new(false));
        let wedged = Arc::new(AtomicBool::new(false));
        let (stop_flag, wedged_flag) = (stop.clone(), wedged.clone());
        let cfg = self.exec_cfg.clone();
        let (time_scale, seed) = (self.time_scale, self.seed);
        let handle = std::thread::spawn(move || {
            run_worker(listener, worker_id, cfg, time_scale, seed, stop_flag, wedged_flag)
        });
        WorkerSlot {
            stop,
            wedged,
            handle: Some(handle),
        }
    }

    pub fn addrs(&self) -> &[SocketAddr] {
        &self.addrs
    }

    pub fn len(&self) -> usize {
        self.addrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.addrs.is_empty()
    }

    /// Whether the worker's thread is still running (killed workers are
    /// not; wedged workers are).
    pub fn is_alive(&self, worker: usize) -> bool {
        self.slots.get(worker).is_some_and(|s| s.handle.is_some())
    }

    /// Kill one worker: stop its thread and drop its listener, so further
    /// connections are refused. In-flight requests finish first (a crash
    /// mid-request is modelled by `wedge`). Idempotent.
    pub fn kill(&mut self, worker: usize) {
        if let Some(slot) = self.slots.get_mut(worker) {
            slot.stop.store(true, Ordering::Relaxed);
            if let Some(h) = slot.handle.take() {
                h.join().ok();
            }
        }
    }

    /// Wedge one worker: it keeps accepting connections and reading
    /// requests but never replies — only a timeout can detect it.
    pub fn wedge(&self, worker: usize) {
        if let Some(slot) = self.slots.get(worker) {
            slot.wedged.store(true, Ordering::Relaxed);
        }
    }

    /// Undo `wedge`: parked connections are dropped (their clients already
    /// timed out) and new requests are served normally again.
    pub fn unwedge(&self, worker: usize) {
        if let Some(slot) = self.slots.get(worker) {
            slot.wedged.store(false, Ordering::Relaxed);
        }
    }

    /// Restart a worker on its original address, weight-cold (a fresh
    /// container remembers nothing). Kills the old thread first if it is
    /// still running. The old listener may linger briefly after a kill, so
    /// the re-bind retries for a short grace period.
    pub fn respawn(&mut self, worker: usize) -> anyhow::Result<()> {
        anyhow::ensure!(worker < self.addrs.len(), "unknown worker {worker}");
        self.kill(worker);
        let addr = self.addrs[worker];
        let mut listener = None;
        for _ in 0..100 {
            match TcpListener::bind(addr) {
                Ok(l) => {
                    listener = Some(l);
                    break;
                }
                Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
            }
        }
        let listener =
            listener.ok_or_else(|| anyhow::anyhow!("worker {worker}: cannot rebind {addr}"))?;
        listener.set_nonblocking(true)?;
        self.slots[worker] = self.launch(listener, worker);
        Ok(())
    }

    fn stop_all(&mut self) {
        for slot in &self.slots {
            slot.stop.store(true, Ordering::Relaxed);
        }
        for slot in &mut self.slots {
            if let Some(h) = slot.handle.take() {
                h.join().ok();
            }
        }
    }

    /// Signal workers to stop and join their threads.
    pub fn shutdown(mut self) {
        self.stop_all();
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.stop_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};

    fn send_to(addr: SocketAddr, req: &TaskRequest) -> anyhow::Result<TaskResult> {
        let mut stream = TcpStream::connect(addr)?;
        stream.write_all(req.to_json().as_bytes())?;
        stream.write_all(b"\n")?;
        let mut line = String::new();
        BufReader::new(stream).read_line(&mut line)?;
        anyhow::ensure!(!line.trim().is_empty(), "worker closed without a result");
        TaskResult::from_json(line.trim())
    }

    fn request(task_id: u64) -> TaskRequest {
        TaskRequest {
            task_id,
            prompt: "p".into(),
            steps: 20,
            patches: 2,
            model: 0,
            rank: 0,
            tenant: None,
            trace_id: None,
        }
    }

    #[test]
    fn worker_executes_and_reports_reuse() {
        let pool = WorkerPool::spawn(1, ExecModelConfig::default(), 1e-4, 1).unwrap();
        let addr = pool.addrs()[0];
        let r1 = send_to(addr, &request(1)).unwrap();
        assert!(!r1.reused);
        assert!(r1.load_time > 20.0, "load={}", r1.load_time);
        // Same model + gang size again: reused, zero load.
        let r2 = send_to(addr, &request(2)).unwrap();
        assert!(r2.reused);
        assert_eq!(r2.load_time, 0.0);
        // Different model: reload.
        let r3 = send_to(addr, &TaskRequest { model: 1, ..request(3) }).unwrap();
        assert!(!r3.reused);
        pool.shutdown();
    }

    #[test]
    fn traced_requests_report_span_timings_untraced_do_not() {
        let pool = WorkerPool::spawn(1, ExecModelConfig::default(), 1e-4, 7).unwrap();
        let addr = pool.addrs()[0];
        let plain = send_to(addr, &request(1)).unwrap();
        assert_eq!(plain.timings, None, "no trace id, no timings on the wire");
        let traced =
            send_to(addr, &TaskRequest { model: 1, trace_id: Some(41), ..request(2) }).unwrap();
        let t = traced.timings.expect("trace id must elicit timings");
        // Cold dispatch: both simulated sleeps ran, so each span has real
        // wall width; recv/lock_wait/reply merely must be sane.
        assert!(t.load > 0.0, "cold load span: {t:?}");
        assert!(t.exec > 0.0, "exec span: {t:?}");
        assert!(t.recv >= 0.0 && t.lock_wait >= 0.0 && t.reply >= 0.0, "{t:?}");
        // Warm repeat: the load sleep is zero-length, exec still runs.
        let warm =
            send_to(addr, &TaskRequest { model: 1, trace_id: Some(42), ..request(3) }).unwrap();
        assert!(warm.reused);
        let w = warm.timings.unwrap();
        assert!(w.exec > 0.0, "{w:?}");
        assert!(w.load < t.load, "warm load span must shrink: {w:?} vs {t:?}");
        pool.shutdown();
    }

    #[test]
    fn worker_answers_pings_without_touching_model_state() {
        use crate::serving::protocol;
        let pool = WorkerPool::spawn(1, ExecModelConfig::default(), 1e-4, 2).unwrap();
        let addr = pool.addrs()[0];
        let ping = || -> Option<usize> {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.write_all(protocol::ping_json().as_bytes()).unwrap();
            stream.write_all(b"\n").unwrap();
            let mut line = String::new();
            BufReader::new(stream).read_line(&mut line).unwrap();
            protocol::pong_worker(line.trim())
        };
        assert_eq!(ping(), Some(0));
        // A task after pings still cold-loads (pings didn't fake a model).
        let res = send_to(addr, &TaskRequest { patches: 1, ..request(1) }).unwrap();
        assert!(!res.reused);
        assert_eq!(ping(), Some(0));
        pool.shutdown();
    }

    #[test]
    fn busy_worker_still_answers_pings() {
        use crate::serving::protocol;
        // Time scale chosen so one cold task sleeps roughly 300-600 ms.
        let pool = WorkerPool::spawn(1, ExecModelConfig::default(), 1e-2, 9).unwrap();
        let addr = pool.addrs()[0];
        let task = std::thread::spawn(move || send_to(addr, &request(1)).unwrap());
        // Give the task time to reach its GPU sleep, then probe: the ping
        // must be answered while the task is still executing.
        std::thread::sleep(std::time::Duration::from_millis(50));
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(std::time::Duration::from_millis(200)))
            .unwrap();
        stream.write_all(protocol::ping_json().as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        let mut line = String::new();
        BufReader::new(stream).read_line(&mut line).unwrap();
        assert_eq!(
            protocol::pong_worker(line.trim()),
            Some(0),
            "a worker busy executing must still answer heartbeats"
        );
        let res = task.join().unwrap();
        assert!(!res.reused);
        pool.shutdown();
    }

    #[test]
    fn killed_worker_refuses_connections_and_respawn_revives_it_cold() {
        let mut pool = WorkerPool::spawn(2, ExecModelConfig::default(), 1e-4, 3).unwrap();
        let addr = pool.addrs()[1];
        let warm = send_to(addr, &request(1)).unwrap();
        assert!(!warm.reused);
        assert!(pool.is_alive(1));
        pool.kill(1);
        assert!(!pool.is_alive(1));
        assert!(send_to(addr, &request(2)).is_err(), "killed worker must refuse");
        // The other worker is unaffected.
        assert!(send_to(pool.addrs()[0], &request(3)).is_ok());
        pool.respawn(1).unwrap();
        assert!(pool.is_alive(1));
        let back = send_to(addr, &request(4)).unwrap();
        assert!(!back.reused, "a respawned worker must come back weight-cold");
        pool.shutdown();
    }

    #[test]
    fn wedged_worker_accepts_but_never_replies() {
        let pool = WorkerPool::spawn(1, ExecModelConfig::default(), 1e-4, 4).unwrap();
        let addr = pool.addrs()[0];
        pool.wedge(0);
        let mut stream = TcpStream::connect(addr).unwrap(); // still accepts
        stream
            .set_read_timeout(Some(std::time::Duration::from_millis(200)))
            .unwrap();
        stream.write_all(request(1).to_json().as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        let mut line = String::new();
        let got = BufReader::new(stream).read_line(&mut line);
        assert!(
            got.is_err() || line.trim().is_empty(),
            "wedged worker must not reply, got {line:?}"
        );
        pool.unwedge(0);
        let res = send_to(addr, &request(2)).unwrap();
        assert_eq!(res.task_id, 2);
        pool.shutdown();
    }
}
