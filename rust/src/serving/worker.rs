//! Worker processes: one TCP listener per simulated GPU container. Each
//! accepted connection carries one newline-terminated JSON task request;
//! the worker "executes" it (sleeping the calibrated duration x
//! `time_scale`), tracks which model instance it has loaded (charging
//! initialisation time on change, like DistriFusion's model load), and
//! replies with a result JSON.

use super::protocol::{self, TaskRequest, TaskResult};
use crate::config::ExecModelConfig;
use crate::sim::exec_model::ExecModel;
use crate::util::json;
use crate::util::rng::Pcg64;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Per-worker loaded-model state.
#[derive(Clone, Copy, Debug, PartialEq)]
struct Loaded {
    model: u32,
    patches: usize,
}

fn handle(
    stream: TcpStream,
    worker_id: usize,
    exec: &ExecModel,
    loaded: &mut Option<Loaded>,
    rng: &mut Pcg64,
    time_scale: f64,
) -> anyhow::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    if line.trim().is_empty() {
        return Ok(());
    }
    // Heartbeat: answer pings immediately, without touching model state
    // or sleeping — the host uses them to detect dead/wedged workers.
    if let Ok(v) = json::parse(line.trim()) {
        if protocol::is_ping(&v) {
            let mut out = stream;
            out.write_all(protocol::pong_json(worker_id).as_bytes())?;
            out.write_all(b"\n")?;
            return Ok(());
        }
    }
    let req = TaskRequest::from_json(line.trim())?;
    let want = Loaded {
        model: req.model,
        patches: req.patches,
    };
    // Model reuse: a loaded instance matches only if both the model type
    // and the gang size agree (DistriFusion loads per process group).
    let reused = *loaded == Some(want);
    let load_time = if reused {
        0.0
    } else {
        exec.sample_init(req.patches, rng)
    };
    *loaded = Some(want);
    let exec_time = exec.sample_exec(req.steps, req.patches, rng);
    let simulated = (load_time + exec_time) * time_scale;
    std::thread::sleep(std::time::Duration::from_secs_f64(simulated));
    let result = TaskResult {
        task_id: req.task_id,
        worker_id,
        exec_time,
        load_time,
        reused,
        image: format!("image:{}:{}:{}", req.task_id, req.rank, req.prompt.len()),
    };
    let mut out = stream;
    out.write_all(result.to_json().as_bytes())?;
    out.write_all(b"\n")?;
    Ok(())
}

/// A pool of worker listeners bound to ephemeral localhost ports.
pub struct WorkerPool {
    addrs: Vec<SocketAddr>,
    stop: Arc<AtomicBool>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `n` workers. `time_scale` compresses simulated seconds into
    /// real sleeping time (e.g. 0.01 → a 33 s model load sleeps 330 ms).
    pub fn spawn(n: usize, exec_cfg: ExecModelConfig, time_scale: f64, seed: u64) -> anyhow::Result<WorkerPool> {
        let stop = Arc::new(AtomicBool::new(false));
        let mut addrs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for worker_id in 0..n {
            let listener = TcpListener::bind("127.0.0.1:0")?;
            listener.set_nonblocking(true)?;
            addrs.push(listener.local_addr()?);
            let stop_flag = stop.clone();
            let cfg = exec_cfg.clone();
            handles.push(std::thread::spawn(move || {
                let exec = ExecModel::new(cfg);
                let mut rng = Pcg64::new(seed, worker_id as u64 + 0xB0);
                let mut loaded: Option<Loaded> = None;
                while !stop_flag.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            stream.set_nonblocking(false).ok();
                            if let Err(e) = handle(
                                stream,
                                worker_id,
                                &exec,
                                &mut loaded,
                                &mut rng,
                                time_scale,
                            ) {
                                eprintln!("worker {worker_id}: {e}");
                            }
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                        Err(e) => {
                            eprintln!("worker {worker_id} accept: {e}");
                            break;
                        }
                    }
                }
            }));
        }
        Ok(WorkerPool {
            addrs,
            stop,
            handles,
        })
    }

    pub fn addrs(&self) -> &[SocketAddr] {
        &self.addrs
    }

    pub fn len(&self) -> usize {
        self.addrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.addrs.is_empty()
    }

    /// Signal workers to stop and join their threads.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for h in self.handles.drain(..) {
            h.join().ok();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for h in self.handles.drain(..) {
            h.join().ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};

    #[test]
    fn worker_executes_and_reports_reuse() {
        let pool = WorkerPool::spawn(1, ExecModelConfig::default(), 1e-4, 1).unwrap();
        let addr = pool.addrs()[0];
        let send = |req: &TaskRequest| -> TaskResult {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.write_all(req.to_json().as_bytes()).unwrap();
            stream.write_all(b"\n").unwrap();
            let mut line = String::new();
            BufReader::new(stream).read_line(&mut line).unwrap();
            TaskResult::from_json(line.trim()).unwrap()
        };
        let req = TaskRequest {
            task_id: 1,
            prompt: "p".into(),
            steps: 20,
            patches: 2,
            model: 0,
            rank: 0,
            tenant: 0,
        };
        let r1 = send(&req);
        assert!(!r1.reused);
        assert!(r1.load_time > 20.0, "load={}", r1.load_time);
        // Same model + gang size again: reused, zero load.
        let r2 = send(&TaskRequest { task_id: 2, ..req.clone() });
        assert!(r2.reused);
        assert_eq!(r2.load_time, 0.0);
        // Different model: reload.
        let r3 = send(&TaskRequest { task_id: 3, model: 1, ..req });
        assert!(!r3.reused);
        pool.shutdown();
    }

    #[test]
    fn worker_answers_pings_without_touching_model_state() {
        use crate::serving::protocol;
        let pool = WorkerPool::spawn(1, ExecModelConfig::default(), 1e-4, 2).unwrap();
        let addr = pool.addrs()[0];
        let ping = || -> Option<usize> {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.write_all(protocol::ping_json().as_bytes()).unwrap();
            stream.write_all(b"\n").unwrap();
            let mut line = String::new();
            BufReader::new(stream).read_line(&mut line).unwrap();
            protocol::pong_worker(line.trim())
        };
        assert_eq!(ping(), Some(0));
        // A task after pings still cold-loads (pings didn't fake a model).
        let req = TaskRequest {
            task_id: 1,
            prompt: "p".into(),
            steps: 20,
            patches: 1,
            model: 0,
            rank: 0,
            tenant: 0,
        };
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(req.to_json().as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        let mut line = String::new();
        BufReader::new(stream).read_line(&mut line).unwrap();
        let res = TaskResult::from_json(line.trim()).unwrap();
        assert!(!res.reused);
        assert_eq!(ping(), Some(0));
        pool.shutdown();
    }
}
