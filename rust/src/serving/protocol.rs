//! Wire protocol: newline-delimited JSON task requests and results,
//! mirroring the paper's host→container JSON strings (prompt p_k and draw
//! steps s_k in; result image + measured timings back), plus a heartbeat
//! ping/pong used by the host to probe worker liveness under timeouts.

use crate::util::json::{self, Value};

/// The heartbeat request line: a worker answers with [`pong_json`]
/// instead of executing anything.
pub fn ping_json() -> String {
    "{\"ping\":true}".to_string()
}

/// True when a parsed request line is a heartbeat ping.
pub fn is_ping(v: &Value) -> bool {
    v.get("ping").and_then(Value::as_bool) == Some(true)
}

/// The heartbeat reply carrying the worker's id.
pub fn pong_json(worker_id: usize) -> String {
    let mut v = Value::obj();
    v.set("pong", worker_id);
    v.to_json()
}

/// Parse a heartbeat reply; `None` if the line is not a pong.
pub fn pong_worker(text: &str) -> Option<usize> {
    json::parse(text).ok()?.get("pong")?.as_usize()
}

/// A task command sent from the host to one worker of a gang.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskRequest {
    pub task_id: u64,
    /// Prompt text g_k (stand-in string; drives per-prompt quality jitter).
    pub prompt: String,
    /// Inference steps s_k chosen by the scheduler.
    pub steps: u32,
    /// Gang size c_k (number of patch workers for this task).
    pub patches: usize,
    /// Model/service type to load.
    pub model: u32,
    /// Rank of this worker within the gang (0-based).
    pub rank: usize,
    /// Tenant class of the task; carried on the wire so workers/containers
    /// can tag logs and billing. `None` for untenanted workloads — kept
    /// distinct from tenant 0 (a real, configurable tenant) and omitted
    /// from the wire format entirely, so pre-tenant traces stay parseable.
    pub tenant: Option<u32>,
}

impl TaskRequest {
    pub fn to_json(&self) -> String {
        let mut v = Value::obj();
        v.set("task_id", self.task_id)
            .set("prompt", self.prompt.as_str())
            .set("steps", self.steps as usize)
            .set("patches", self.patches)
            .set("model", self.model as usize)
            .set("rank", self.rank);
        if let Some(t) = self.tenant {
            v.set("tenant", t as usize);
        }
        v.to_json()
    }

    pub fn from_json(text: &str) -> anyhow::Result<TaskRequest> {
        let v = json::parse(text)?;
        Ok(TaskRequest {
            task_id: v.req("task_id")?.as_f64().unwrap_or(0.0) as u64,
            prompt: v.req("prompt")?.as_str().unwrap_or("").to_string(),
            steps: v.req("steps")?.as_f64().unwrap_or(0.0) as u32,
            patches: v.req("patches")?.as_usize().unwrap_or(1),
            model: v.req("model")?.as_f64().unwrap_or(0.0) as u32,
            rank: v.req("rank")?.as_usize().unwrap_or(0),
            // Absent on the wire for untenanted tasks (and in pre-tenant
            // traces): parses to `None`, never conflated with tenant 0.
            tenant: v.get("tenant").and_then(Value::as_f64).map(|t| t as u32),
        })
    }
}

/// Result returned by a worker after executing its patch.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskResult {
    pub task_id: u64,
    pub worker_id: usize,
    /// Actual (simulated) execution seconds, pre-scaling.
    pub exec_time: f64,
    /// Actual (simulated) model-loading seconds (0 when reused).
    pub load_time: f64,
    /// Whether the worker reused an already-loaded model instance.
    pub reused: bool,
    /// Stand-in for the generated image patch (base64 in the real system).
    pub image: String,
}

impl TaskResult {
    pub fn to_json(&self) -> String {
        let mut v = Value::obj();
        v.set("task_id", self.task_id)
            .set("worker_id", self.worker_id)
            .set("exec_time", self.exec_time)
            .set("load_time", self.load_time)
            .set("reused", self.reused)
            .set("image", self.image.as_str());
        v.to_json()
    }

    pub fn from_json(text: &str) -> anyhow::Result<TaskResult> {
        let v = json::parse(text)?;
        Ok(TaskResult {
            task_id: v.req("task_id")?.as_f64().unwrap_or(0.0) as u64,
            worker_id: v.req("worker_id")?.as_usize().unwrap_or(0),
            exec_time: v.req("exec_time")?.as_f64().unwrap_or(0.0),
            load_time: v.req("load_time")?.as_f64().unwrap_or(0.0),
            reused: v.req("reused")?.as_bool().unwrap_or(false),
            image: v.req("image")?.as_str().unwrap_or("").to_string(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let req = TaskRequest {
            task_id: 42,
            prompt: "a lighthouse at dawn".into(),
            steps: 20,
            patches: 4,
            model: 2,
            rank: 3,
            tenant: Some(1),
        };
        let back = TaskRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(back, req);
        // Tenant 0 is a real tenant and survives the round trip distinctly
        // from "no tenant".
        let zero = TaskRequest { tenant: Some(0), ..req.clone() };
        assert_eq!(TaskRequest::from_json(&zero.to_json()).unwrap().tenant, Some(0));
        let untenanted = TaskRequest { tenant: None, ..req };
        let json = untenanted.to_json();
        assert!(!json.contains("tenant"), "absent tenant must be omitted: {json}");
        assert_eq!(TaskRequest::from_json(&json).unwrap(), untenanted);
    }

    #[test]
    fn request_without_tenant_parses_as_untenanted() {
        // Pre-tenant wire format (no `tenant` key) stays parseable and is
        // NOT conflated with tenant 0.
        let req = TaskRequest::from_json(
            "{\"task_id\":1,\"prompt\":\"p\",\"steps\":20,\"patches\":2,\"model\":0,\"rank\":0}",
        )
        .unwrap();
        assert_eq!(req.tenant, None);
    }

    #[test]
    fn ping_pong_roundtrip() {
        let ping = json::parse(&ping_json()).unwrap();
        assert!(is_ping(&ping));
        assert!(!is_ping(&json::parse("{\"task_id\":1}").unwrap()));
        assert_eq!(pong_worker(&pong_json(3)), Some(3));
        assert_eq!(pong_worker("{\"nope\":1}"), None);
        assert_eq!(pong_worker("garbage"), None);
    }

    #[test]
    fn result_roundtrip() {
        let res = TaskResult {
            task_id: 7,
            worker_id: 1,
            exec_time: 5.8,
            load_time: 28.0,
            reused: false,
            image: "patch-7-1".into(),
        };
        let back = TaskResult::from_json(&res.to_json()).unwrap();
        assert_eq!(back, res);
    }
}
