//! Wire protocol: newline-delimited JSON task requests and results,
//! mirroring the paper's host→container JSON strings (prompt p_k and draw
//! steps s_k in; result image + measured timings back), plus a heartbeat
//! ping/pong used by the host to probe worker liveness under timeouts.

use crate::util::json::{self, Value};

/// The heartbeat request line: a worker answers with [`pong_json`]
/// instead of executing anything.
pub fn ping_json() -> String {
    "{\"ping\":true}".to_string()
}

/// True when a parsed request line is a heartbeat ping.
pub fn is_ping(v: &Value) -> bool {
    v.get("ping").and_then(Value::as_bool) == Some(true)
}

/// The heartbeat reply carrying the worker's id.
pub fn pong_json(worker_id: usize) -> String {
    let mut v = Value::obj();
    v.set("pong", worker_id);
    v.to_json()
}

/// Parse a heartbeat reply; `None` if the line is not a pong.
pub fn pong_worker(text: &str) -> Option<usize> {
    json::parse(text).ok()?.get("pong")?.as_usize()
}

/// A task command sent from the host to one worker of a gang.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskRequest {
    pub task_id: u64,
    /// Prompt text g_k (stand-in string; drives per-prompt quality jitter).
    pub prompt: String,
    /// Inference steps s_k chosen by the scheduler.
    pub steps: u32,
    /// Gang size c_k (number of patch workers for this task).
    pub patches: usize,
    /// Model/service type to load.
    pub model: u32,
    /// Rank of this worker within the gang (0-based).
    pub rank: usize,
    /// Tenant class of the task; carried on the wire so workers/containers
    /// can tag logs and billing. `None` for untenanted workloads — kept
    /// distinct from tenant 0 (a real, configurable tenant) and omitted
    /// from the wire format entirely, so pre-tenant traces stay parseable.
    pub tenant: Option<u32>,
    /// Host-assigned trace id propagated through the worker so its reply
    /// timings can be merged into the host-side lifecycle trace. Omitted
    /// from the wire when tracing is off (pre-span requests stay parseable).
    pub trace_id: Option<u64>,
}

impl TaskRequest {
    pub fn to_json(&self) -> String {
        let mut v = Value::obj();
        v.set("task_id", self.task_id)
            .set("prompt", self.prompt.as_str())
            .set("steps", self.steps as usize)
            .set("patches", self.patches)
            .set("model", self.model as usize)
            .set("rank", self.rank);
        if let Some(t) = self.tenant {
            v.set("tenant", t as usize);
        }
        if let Some(id) = self.trace_id {
            v.set("trace_id", id);
        }
        v.to_json()
    }

    pub fn from_json(text: &str) -> anyhow::Result<TaskRequest> {
        let v = json::parse(text)?;
        Ok(TaskRequest {
            task_id: v.req("task_id")?.as_f64().unwrap_or(0.0) as u64,
            prompt: v.req("prompt")?.as_str().unwrap_or("").to_string(),
            steps: v.req("steps")?.as_f64().unwrap_or(0.0) as u32,
            patches: v.req("patches")?.as_usize().unwrap_or(1),
            model: v.req("model")?.as_f64().unwrap_or(0.0) as u32,
            rank: v.req("rank")?.as_usize().unwrap_or(0),
            // Absent on the wire for untenanted tasks (and in pre-tenant
            // traces): parses to `None`, never conflated with tenant 0.
            tenant: v.get("tenant").and_then(Value::as_f64).map(|t| t as u32),
            trace_id: v.get("trace_id").and_then(Value::as_f64).map(|t| t as u64),
        })
    }
}

/// Wall-clock spans a worker measured while serving one request, reported
/// back in the [`TaskResult`] so the host can decompose live latency.
/// All fields are seconds on the worker's own clock; the host never
/// compares them against its clock directly — it folds them into the
/// round trip as a residual, so clock skew cannot unbalance the books.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WireTimings {
    /// Reading + parsing the request line off the socket.
    pub recv: f64,
    /// Waiting on the worker's GPU mutex behind other ranks.
    pub lock_wait: f64,
    /// Simulated weight-load sleep (0 when the model was resident).
    pub load: f64,
    /// Simulated denoise/execute sleep.
    pub exec: f64,
    /// Serialising + writing the reply line.
    pub reply: f64,
}

impl WireTimings {
    fn to_value(self) -> Value {
        let mut v = Value::obj();
        v.set("recv", self.recv)
            .set("lock_wait", self.lock_wait)
            .set("load", self.load)
            .set("exec", self.exec)
            .set("reply", self.reply);
        v
    }

    fn from_value(v: &Value) -> WireTimings {
        let f = |k: &str| v.get(k).and_then(Value::as_f64).unwrap_or(0.0);
        WireTimings {
            recv: f("recv"),
            lock_wait: f("lock_wait"),
            load: f("load"),
            exec: f("exec"),
            reply: f("reply"),
        }
    }
}

/// Result returned by a worker after executing its patch.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskResult {
    pub task_id: u64,
    pub worker_id: usize,
    /// Actual (simulated) execution seconds, pre-scaling.
    pub exec_time: f64,
    /// Actual (simulated) model-loading seconds (0 when reused).
    pub load_time: f64,
    /// Whether the worker reused an already-loaded model instance.
    pub reused: bool,
    /// Stand-in for the generated image patch (base64 in the real system).
    pub image: String,
    /// Wall-clock spans measured on the worker, present only when the
    /// request carried a `trace_id`. Omitted from the wire otherwise so
    /// pre-span replies stay parseable.
    pub timings: Option<WireTimings>,
}

impl TaskResult {
    pub fn to_json(&self) -> String {
        let mut v = Value::obj();
        v.set("task_id", self.task_id)
            .set("worker_id", self.worker_id)
            .set("exec_time", self.exec_time)
            .set("load_time", self.load_time)
            .set("reused", self.reused)
            .set("image", self.image.as_str());
        if let Some(t) = self.timings {
            v.set("timings", t.to_value());
        }
        v.to_json()
    }

    pub fn from_json(text: &str) -> anyhow::Result<TaskResult> {
        let v = json::parse(text)?;
        Ok(TaskResult {
            task_id: v.req("task_id")?.as_f64().unwrap_or(0.0) as u64,
            worker_id: v.req("worker_id")?.as_usize().unwrap_or(0),
            exec_time: v.req("exec_time")?.as_f64().unwrap_or(0.0),
            load_time: v.req("load_time")?.as_f64().unwrap_or(0.0),
            reused: v.req("reused")?.as_bool().unwrap_or(false),
            image: v.req("image")?.as_str().unwrap_or("").to_string(),
            timings: v.get("timings").map(WireTimings::from_value),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let req = TaskRequest {
            task_id: 42,
            prompt: "a lighthouse at dawn".into(),
            steps: 20,
            patches: 4,
            model: 2,
            rank: 3,
            tenant: Some(1),
            trace_id: Some(9001),
        };
        let back = TaskRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(back, req);
        // Tenant 0 is a real tenant and survives the round trip distinctly
        // from "no tenant".
        let zero = TaskRequest { tenant: Some(0), ..req.clone() };
        assert_eq!(TaskRequest::from_json(&zero.to_json()).unwrap().tenant, Some(0));
        let untenanted = TaskRequest { tenant: None, trace_id: None, ..req };
        let json = untenanted.to_json();
        assert!(!json.contains("tenant"), "absent tenant must be omitted: {json}");
        assert!(!json.contains("trace_id"), "absent trace id must be omitted: {json}");
        assert_eq!(TaskRequest::from_json(&json).unwrap(), untenanted);
    }

    #[test]
    fn request_without_tenant_parses_as_untenanted() {
        // Pre-tenant wire format (no `tenant` key) stays parseable and is
        // NOT conflated with tenant 0.
        let req = TaskRequest::from_json(
            "{\"task_id\":1,\"prompt\":\"p\",\"steps\":20,\"patches\":2,\"model\":0,\"rank\":0}",
        )
        .unwrap();
        assert_eq!(req.tenant, None);
        assert_eq!(req.trace_id, None);
    }

    #[test]
    fn ping_pong_roundtrip() {
        let ping = json::parse(&ping_json()).unwrap();
        assert!(is_ping(&ping));
        assert!(!is_ping(&json::parse("{\"task_id\":1}").unwrap()));
        assert_eq!(pong_worker(&pong_json(3)), Some(3));
        assert_eq!(pong_worker("{\"nope\":1}"), None);
        assert_eq!(pong_worker("garbage"), None);
    }

    #[test]
    fn result_roundtrip() {
        let res = TaskResult {
            task_id: 7,
            worker_id: 1,
            exec_time: 5.8,
            load_time: 28.0,
            reused: false,
            image: "patch-7-1".into(),
            timings: None,
        };
        let json = res.to_json();
        assert!(!json.contains("timings"), "absent timings must be omitted: {json}");
        let back = TaskResult::from_json(&json).unwrap();
        assert_eq!(back, res);
    }

    #[test]
    fn result_timings_roundtrip_bit_exactly() {
        let res = TaskResult {
            task_id: 7,
            worker_id: 1,
            exec_time: 5.8,
            load_time: 0.0,
            reused: true,
            image: "patch-7-1".into(),
            timings: Some(WireTimings {
                recv: 1.25e-4,
                lock_wait: 0.1 + 0.2, // deliberately non-representable sum
                load: 0.0,
                exec: 5.8e-3,
                reply: 3.0e-5,
            }),
        };
        let back = TaskResult::from_json(&res.to_json()).unwrap();
        assert_eq!(back, res);
        let (a, b) = (back.timings.unwrap(), res.timings.unwrap());
        assert_eq!(a.lock_wait.to_bits(), b.lock_wait.to_bits());
        // Pre-span replies (no `timings` key) still parse.
        let legacy = TaskResult::from_json(
            "{\"task_id\":1,\"worker_id\":0,\"exec_time\":1.0,\"load_time\":0.0,\
             \"reused\":true,\"image\":\"x\"}",
        )
        .unwrap();
        assert_eq!(legacy.timings, None);
    }
}
