//! Serving system emulation: the paper's real deployment runs one Docker
//! container per GPU, each listening on a socket; the host packages task
//! details as a JSON string, sends it to every server of the gang, and
//! asynchronously collects result JSONs carrying the actual execution and
//! model-loading times (§VI.A.1).
//!
//! This module reproduces that wire architecture faithfully — TCP sockets,
//! newline-delimited JSON, one worker per simulated GPU, concurrent gang
//! dispatch, asynchronous result collection — with the GPU replaced by the
//! calibrated execution model (a worker "executes" by sleeping the
//! predicted duration scaled by `time_scale`). See DESIGN.md
//! §Substitutions.

pub mod health;
pub mod host;
pub mod protocol;
pub mod worker;

pub use health::{HealthMonitor, HealthRegistry, HealthStats};
pub use host::{ServingHost, DEFAULT_DISPATCH_TIMEOUT};
pub use protocol::{TaskRequest, TaskResult};
pub use worker::WorkerPool;
