//! Host side of the serving system: gang dispatch over sockets and
//! asynchronous result collection, mirroring the paper's host process that
//! "packages the task details into a JSON string and sends it via the
//! socket to the server responsible for execution ... then asynchronously
//! monitors the server's result port".

use super::protocol::{TaskRequest, TaskResult};
use crate::workload::MetricsCollector;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::mpsc;
use std::time::Instant;

/// Outcome of one gang-scheduled task: per-worker results plus wall time.
#[derive(Clone, Debug)]
pub struct GangOutcome {
    pub task_id: u64,
    pub results: Vec<TaskResult>,
    /// Host-observed wall-clock seconds for the whole gang (max worker).
    pub wall_seconds: f64,
}

impl GangOutcome {
    /// Simulated execution seconds (max over the gang — patches run in
    /// parallel and the task completes when the slowest patch does).
    pub fn sim_exec_seconds(&self) -> f64 {
        self.results
            .iter()
            .map(|r| r.exec_time + r.load_time)
            .fold(0.0, f64::max)
    }

    pub fn any_reload(&self) -> bool {
        self.results.iter().any(|r| !r.reused)
    }
}

/// The host: knows every worker's address and dispatches gangs.
pub struct ServingHost {
    workers: Vec<SocketAddr>,
}

impl ServingHost {
    pub fn new(workers: Vec<SocketAddr>) -> Self {
        ServingHost { workers }
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Dispatch one task to `gang` (worker indices), concurrently, and
    /// wait for every patch result (gang semantics: the task is complete
    /// only when all patches are). Single-tenant convenience wrapper.
    pub fn dispatch(
        &self,
        task_id: u64,
        prompt: &str,
        steps: u32,
        model: u32,
        gang: &[usize],
    ) -> anyhow::Result<GangOutcome> {
        self.dispatch_tagged(task_id, prompt, steps, model, 0, gang)
    }

    /// `dispatch` with an explicit tenant class: every worker request on
    /// the wire carries the tenant tag, so container-side logs and billing
    /// can attribute GPU time per tenant.
    pub fn dispatch_tagged(
        &self,
        task_id: u64,
        prompt: &str,
        steps: u32,
        model: u32,
        tenant: u32,
        gang: &[usize],
    ) -> anyhow::Result<GangOutcome> {
        anyhow::ensure!(!gang.is_empty(), "empty gang");
        anyhow::ensure!(
            gang.iter().all(|&w| w < self.workers.len()),
            "gang references unknown worker"
        );
        let started = Instant::now();
        let (tx, rx) = mpsc::channel::<anyhow::Result<TaskResult>>();
        for (rank, &w) in gang.iter().enumerate() {
            let addr = self.workers[w];
            let req = TaskRequest {
                task_id,
                prompt: prompt.to_string(),
                steps,
                patches: gang.len(),
                model,
                rank,
                tenant,
            };
            let tx = tx.clone();
            std::thread::spawn(move || {
                let send = || -> anyhow::Result<TaskResult> {
                    let mut stream = TcpStream::connect(addr)?;
                    stream.write_all(req.to_json().as_bytes())?;
                    stream.write_all(b"\n")?;
                    let mut line = String::new();
                    BufReader::new(stream).read_line(&mut line)?;
                    TaskResult::from_json(line.trim())
                };
                tx.send(send()).ok();
            });
        }
        drop(tx);
        let mut results = Vec::with_capacity(gang.len());
        for r in rx {
            results.push(r?);
        }
        results.sort_by_key(|r| r.worker_id);
        Ok(GangOutcome {
            task_id,
            results,
            wall_seconds: started.elapsed().as_secs_f64(),
        })
    }

    /// `dispatch`, additionally feeding the streaming metrics collector:
    /// response latency (`waiting` + simulated gang execution), reload
    /// flag, and per-worker busy time. The caller advances the collector's
    /// clock (`advance_time`) according to its own notion of elapsed time.
    pub fn dispatch_collect(
        &self,
        task_id: u64,
        prompt: &str,
        steps: u32,
        model: u32,
        tenant: u32,
        gang: &[usize],
        waiting: f64,
        metrics: &mut MetricsCollector,
    ) -> anyhow::Result<GangOutcome> {
        let out = self.dispatch_tagged(task_id, prompt, steps, model, tenant, gang)?;
        metrics.observe_task(waiting + out.sim_exec_seconds(), waiting, out.any_reload());
        // Busy time is per worker: patches run in parallel and each worker
        // is free again after its own exec+load, not after the slowest
        // peer's (gang-max would inflate fast workers' utilization).
        for r in &out.results {
            metrics.observe_busy(r.worker_id, r.exec_time + r.load_time);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExecModelConfig;
    use crate::serving::worker::WorkerPool;

    #[test]
    fn gang_dispatch_collects_all_patches() {
        let pool = WorkerPool::spawn(4, ExecModelConfig::default(), 1e-4, 2).unwrap();
        let host = ServingHost::new(pool.addrs().to_vec());
        let out = host.dispatch(9, "gang test", 20, 0, &[0, 1, 2, 3]).unwrap();
        assert_eq!(out.results.len(), 4);
        assert!(out.any_reload());
        assert!(out.sim_exec_seconds() > 0.0);
        // Reuse on the second dispatch with same model + gang size.
        let out2 = host.dispatch(10, "again", 20, 0, &[0, 1, 2, 3]).unwrap();
        assert!(!out2.any_reload());
        assert!(out2.sim_exec_seconds() < out.sim_exec_seconds());
        pool.shutdown();
    }

    #[test]
    fn dispatch_validates_gang() {
        let host = ServingHost::new(vec![]);
        assert!(host.dispatch(0, "x", 10, 0, &[]).is_err());
        assert!(host.dispatch(0, "x", 10, 0, &[3]).is_err());
    }

    #[test]
    fn dispatch_collect_feeds_metrics() {
        let pool = WorkerPool::spawn(2, ExecModelConfig::default(), 1e-4, 3).unwrap();
        let host = ServingHost::new(pool.addrs().to_vec());
        let mut m = MetricsCollector::new(2);
        let out = host
            .dispatch_collect(1, "p", 20, 0, 0, &[0, 1], 2.5, &mut m)
            .unwrap();
        m.advance_time(out.sim_exec_seconds());
        assert_eq!(m.completed(), 1);
        assert_eq!(m.reloads(), 1); // first dispatch always loads
        assert!(m.latency.p50() >= 2.5);
        assert!(m.avg_utilization() > 0.0);
        pool.shutdown();
    }
}
