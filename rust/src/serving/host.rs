//! Host side of the serving system: gang dispatch over sockets and
//! asynchronous result collection, mirroring the paper's host process that
//! "packages the task details into a JSON string and sends it via the
//! socket to the server responsible for execution ... then asynchronously
//! monitors the server's result port".

use super::protocol::{self, TaskRequest, TaskResult};
use crate::workload::MetricsCollector;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Outcome of one gang-scheduled task: per-worker results plus wall time.
#[derive(Clone, Debug)]
pub struct GangOutcome {
    pub task_id: u64,
    pub results: Vec<TaskResult>,
    /// Host-observed wall-clock seconds for the whole gang (max worker).
    pub wall_seconds: f64,
}

impl GangOutcome {
    /// Simulated execution seconds (max over the gang — patches run in
    /// parallel and the task completes when the slowest patch does).
    pub fn sim_exec_seconds(&self) -> f64 {
        self.results
            .iter()
            .map(|r| r.exec_time + r.load_time)
            .fold(0.0, f64::max)
    }

    pub fn any_reload(&self) -> bool {
        self.results.iter().any(|r| !r.reused)
    }
}

/// The host: knows every worker's address and dispatches gangs.
pub struct ServingHost {
    workers: Vec<SocketAddr>,
}

impl ServingHost {
    pub fn new(workers: Vec<SocketAddr>) -> Self {
        ServingHost { workers }
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Dispatch one task to `gang` (worker indices), concurrently, and
    /// wait for every patch result (gang semantics: the task is complete
    /// only when all patches are). Single-tenant convenience wrapper.
    pub fn dispatch(
        &self,
        task_id: u64,
        prompt: &str,
        steps: u32,
        model: u32,
        gang: &[usize],
    ) -> anyhow::Result<GangOutcome> {
        self.dispatch_tagged(task_id, prompt, steps, model, 0, gang)
    }

    /// `dispatch` with an explicit tenant class: every worker request on
    /// the wire carries the tenant tag, so container-side logs and billing
    /// can attribute GPU time per tenant.
    pub fn dispatch_tagged(
        &self,
        task_id: u64,
        prompt: &str,
        steps: u32,
        model: u32,
        tenant: u32,
        gang: &[usize],
    ) -> anyhow::Result<GangOutcome> {
        anyhow::ensure!(!gang.is_empty(), "empty gang");
        anyhow::ensure!(
            gang.iter().all(|&w| w < self.workers.len()),
            "gang references unknown worker"
        );
        let started = Instant::now();
        let (tx, rx) = mpsc::channel::<anyhow::Result<TaskResult>>();
        for (rank, &w) in gang.iter().enumerate() {
            let addr = self.workers[w];
            let req = TaskRequest {
                task_id,
                prompt: prompt.to_string(),
                steps,
                patches: gang.len(),
                model,
                rank,
                tenant,
            };
            let tx = tx.clone();
            std::thread::spawn(move || {
                let send = || -> anyhow::Result<TaskResult> {
                    let mut stream = TcpStream::connect(addr)?;
                    stream.write_all(req.to_json().as_bytes())?;
                    stream.write_all(b"\n")?;
                    let mut line = String::new();
                    BufReader::new(stream).read_line(&mut line)?;
                    TaskResult::from_json(line.trim())
                };
                tx.send(send()).ok();
            });
        }
        drop(tx);
        let mut results = Vec::with_capacity(gang.len());
        for r in rx {
            results.push(r?);
        }
        results.sort_by_key(|r| r.worker_id);
        Ok(GangOutcome {
            task_id,
            results,
            wall_seconds: started.elapsed().as_secs_f64(),
        })
    }

    /// Probe one worker with a heartbeat ping. `false` on connect
    /// failure, timeout, or a malformed reply — the caller should treat
    /// the worker as down and exclude it from gangs.
    pub fn heartbeat(&self, worker: usize, timeout: Duration) -> bool {
        let Some(addr) = self.workers.get(worker) else {
            return false;
        };
        let probe = || -> anyhow::Result<bool> {
            let mut stream = TcpStream::connect_timeout(addr, timeout)?;
            stream.set_read_timeout(Some(timeout))?;
            stream.set_write_timeout(Some(timeout))?;
            stream.write_all(protocol::ping_json().as_bytes())?;
            stream.write_all(b"\n")?;
            let mut line = String::new();
            BufReader::new(stream).read_line(&mut line)?;
            Ok(protocol::pong_worker(line.trim()).is_some())
        };
        probe().unwrap_or(false)
    }

    /// One gang round with per-worker connect/read/write timeouts.
    /// Returns the successful results plus the worker ids that failed
    /// (connection refused, heartbeat timeout, or a garbled reply).
    #[allow(clippy::too_many_arguments)]
    fn try_dispatch(
        &self,
        task_id: u64,
        prompt: &str,
        steps: u32,
        model: u32,
        tenant: u32,
        gang: &[usize],
        timeout: Duration,
    ) -> (Vec<TaskResult>, Vec<usize>) {
        let (tx, rx) = mpsc::channel::<(usize, anyhow::Result<TaskResult>)>();
        for (rank, &w) in gang.iter().enumerate() {
            let addr = self.workers[w];
            let req = TaskRequest {
                task_id,
                prompt: prompt.to_string(),
                steps,
                patches: gang.len(),
                model,
                rank,
                tenant,
            };
            let tx = tx.clone();
            std::thread::spawn(move || {
                let send = || -> anyhow::Result<TaskResult> {
                    let mut stream = TcpStream::connect_timeout(&addr, timeout)?;
                    stream.set_read_timeout(Some(timeout))?;
                    stream.set_write_timeout(Some(timeout))?;
                    stream.write_all(req.to_json().as_bytes())?;
                    stream.write_all(b"\n")?;
                    let mut line = String::new();
                    BufReader::new(stream).read_line(&mut line)?;
                    anyhow::ensure!(!line.trim().is_empty(), "worker closed without a result");
                    TaskResult::from_json(line.trim())
                };
                tx.send((w, send())).ok();
            });
        }
        drop(tx);
        let mut results = Vec::with_capacity(gang.len());
        let mut failed = Vec::new();
        for (w, r) in rx {
            match r {
                Ok(res) => results.push(res),
                Err(_) => failed.push(w),
            }
        }
        (results, failed)
    }

    /// Fault-tolerant gang dispatch: per-worker heartbeat timeouts, and on
    /// failure the whole gang retries on a server set that *excludes* every
    /// worker observed failing so far, refilled from `spares` (gang
    /// semantics: partial patch results are useless, but surviving members
    /// keep their loaded model, so the retry round reuses it). Returns the
    /// outcome plus the excluded worker ids, so the caller can mark them
    /// down and route around them (mirroring `EdgeEnv`'s health-aware
    /// dispatch).
    #[allow(clippy::too_many_arguments)]
    pub fn dispatch_resilient(
        &self,
        task_id: u64,
        prompt: &str,
        steps: u32,
        model: u32,
        tenant: u32,
        gang: &[usize],
        spares: &[usize],
        timeout: Duration,
        max_rounds: usize,
    ) -> anyhow::Result<(GangOutcome, Vec<usize>)> {
        anyhow::ensure!(!gang.is_empty(), "empty gang");
        anyhow::ensure!(
            gang.iter().chain(spares).all(|&w| w < self.workers.len()),
            "gang references unknown worker"
        );
        let started = Instant::now();
        let mut excluded: Vec<usize> = Vec::new();
        let mut current: Vec<usize> = gang.to_vec();
        for _ in 0..max_rounds.max(1) {
            let (mut results, failed) =
                self.try_dispatch(task_id, prompt, steps, model, tenant, &current, timeout);
            if failed.is_empty() {
                results.sort_by_key(|r| r.worker_id);
                let outcome = GangOutcome {
                    task_id,
                    results,
                    wall_seconds: started.elapsed().as_secs_f64(),
                };
                return Ok((outcome, excluded));
            }
            for w in failed {
                if !excluded.contains(&w) {
                    excluded.push(w);
                }
            }
            // Rebuild the gang: keep healthy members, refill from spares.
            let mut next: Vec<usize> = current
                .iter()
                .copied()
                .filter(|w| !excluded.contains(w))
                .collect();
            for &w in spares {
                if next.len() >= current.len() {
                    break;
                }
                if !excluded.contains(&w) && !next.contains(&w) {
                    next.push(w);
                }
            }
            anyhow::ensure!(
                next.len() == current.len(),
                "gang needs {} workers but only {} healthy candidates remain \
                 (excluded: {excluded:?})",
                current.len(),
                next.len()
            );
            current = next;
        }
        anyhow::bail!("gang dispatch still failing after {max_rounds} rounds (excluded: {excluded:?})")
    }

    /// `dispatch`, additionally feeding the streaming metrics collector:
    /// response latency (`waiting` + simulated gang execution), reload
    /// flag, and per-worker busy time. The caller advances the collector's
    /// clock (`advance_time`) according to its own notion of elapsed time.
    pub fn dispatch_collect(
        &self,
        task_id: u64,
        prompt: &str,
        steps: u32,
        model: u32,
        tenant: u32,
        gang: &[usize],
        waiting: f64,
        metrics: &mut MetricsCollector,
    ) -> anyhow::Result<GangOutcome> {
        let out = self.dispatch_tagged(task_id, prompt, steps, model, tenant, gang)?;
        metrics.observe_task(waiting + out.sim_exec_seconds(), waiting, out.any_reload());
        // Busy time is per worker: patches run in parallel and each worker
        // is free again after its own exec+load, not after the slowest
        // peer's (gang-max would inflate fast workers' utilization).
        for r in &out.results {
            metrics.observe_busy(r.worker_id, r.exec_time + r.load_time);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExecModelConfig;
    use crate::serving::worker::WorkerPool;

    #[test]
    fn gang_dispatch_collects_all_patches() {
        let pool = WorkerPool::spawn(4, ExecModelConfig::default(), 1e-4, 2).unwrap();
        let host = ServingHost::new(pool.addrs().to_vec());
        let out = host.dispatch(9, "gang test", 20, 0, &[0, 1, 2, 3]).unwrap();
        assert_eq!(out.results.len(), 4);
        assert!(out.any_reload());
        assert!(out.sim_exec_seconds() > 0.0);
        // Reuse on the second dispatch with same model + gang size.
        let out2 = host.dispatch(10, "again", 20, 0, &[0, 1, 2, 3]).unwrap();
        assert!(!out2.any_reload());
        assert!(out2.sim_exec_seconds() < out.sim_exec_seconds());
        pool.shutdown();
    }

    #[test]
    fn dispatch_validates_gang() {
        let host = ServingHost::new(vec![]);
        assert!(host.dispatch(0, "x", 10, 0, &[]).is_err());
        assert!(host.dispatch(0, "x", 10, 0, &[3]).is_err());
    }

    /// An address with nothing listening behind it (bind, read the port,
    /// drop the listener): connections are refused, like a crashed worker.
    fn dead_addr() -> std::net::SocketAddr {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap()
    }

    #[test]
    fn heartbeat_detects_live_and_dead_workers() {
        let pool = WorkerPool::spawn(2, ExecModelConfig::default(), 1e-4, 5).unwrap();
        let mut addrs = pool.addrs().to_vec();
        addrs.push(dead_addr());
        let host = ServingHost::new(addrs);
        let t = Duration::from_secs(2);
        assert!(host.heartbeat(0, t));
        assert!(host.heartbeat(1, t));
        assert!(!host.heartbeat(2, t), "dead worker must fail its heartbeat");
        assert!(!host.heartbeat(99, t), "unknown worker id is down by definition");
        pool.shutdown();
    }

    #[test]
    fn resilient_dispatch_excludes_failed_workers_and_retries() {
        let pool = WorkerPool::spawn(3, ExecModelConfig::default(), 1e-4, 6).unwrap();
        let mut addrs = pool.addrs().to_vec();
        addrs.push(dead_addr()); // worker 3 is dead
        let host = ServingHost::new(addrs);
        let timeout = Duration::from_secs(2);
        // Gang of 2 includes the dead worker; worker 2 is the spare.
        let (out, excluded) = host
            .dispatch_resilient(5, "p", 20, 0, 0, &[0, 3], &[2], timeout, 3)
            .unwrap();
        assert_eq!(excluded, vec![3]);
        assert_eq!(out.results.len(), 2);
        let ids: Vec<usize> = out.results.iter().map(|r| r.worker_id).collect();
        assert_eq!(ids, vec![0, 2]);
        // No healthy candidates left: the dispatch reports failure rather
        // than hanging.
        assert!(host
            .dispatch_resilient(6, "p", 20, 0, 0, &[3], &[], timeout, 2)
            .is_err());
        pool.shutdown();
    }

    #[test]
    fn dispatch_collect_feeds_metrics() {
        let pool = WorkerPool::spawn(2, ExecModelConfig::default(), 1e-4, 3).unwrap();
        let host = ServingHost::new(pool.addrs().to_vec());
        let mut m = MetricsCollector::new(2);
        let out = host
            .dispatch_collect(1, "p", 20, 0, 0, &[0, 1], 2.5, &mut m)
            .unwrap();
        m.advance_time(out.sim_exec_seconds());
        assert_eq!(m.completed(), 1);
        assert_eq!(m.reloads(), 1); // first dispatch always loads
        assert!(m.latency.p50() >= 2.5);
        assert!(m.avg_utilization() > 0.0);
        pool.shutdown();
    }
}
