//! Host side of the serving system: gang dispatch over sockets and
//! asynchronous result collection, mirroring the paper's host process that
//! "packages the task details into a JSON string and sends it via the
//! socket to the server responsible for execution ... then asynchronously
//! monitors the server's result port".

use super::protocol::{self, TaskRequest, TaskResult};
use crate::obs::trace::{DropReason, GangRef, SpanKind, TraceRecorder};
use crate::workload::MetricsCollector;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Per-worker socket timeout for the plain (non-resilient) dispatch path
/// when the caller does not supply one. Generous relative to any scaled
/// sleep the workers perform, but finite: a wedged worker surfaces as a
/// timeout error instead of hanging the serving loop forever.
pub const DEFAULT_DISPATCH_TIMEOUT: Duration = Duration::from_secs(300);

/// Outcome of one gang-scheduled task: per-worker results plus wall time.
#[derive(Clone, Debug)]
pub struct GangOutcome {
    pub task_id: u64,
    pub results: Vec<TaskResult>,
    /// Host-observed wall-clock seconds per gang member of the winning
    /// round (connect → parsed reply), aligned index-for-index with
    /// `results`. The per-member round trip that worker-reported span
    /// timings decompose against.
    pub rtts: Vec<f64>,
    /// Host-observed wall-clock seconds for the whole gang (max worker).
    pub wall_seconds: f64,
    /// Simulated seconds burnt in failed resilient-dispatch rounds before
    /// the successful one (max over each failed round's partial results —
    /// patches run in parallel). 0 for plain dispatch. Counts toward the
    /// task's latency and the caller's simulated clock: a killed gang's
    /// retry happens *later*, exactly as in the simulator.
    pub retry_seconds: f64,
}

impl GangOutcome {
    /// Simulated execution seconds (max over the gang — patches run in
    /// parallel and the task completes when the slowest patch does).
    pub fn sim_exec_seconds(&self) -> f64 {
        self.results
            .iter()
            .map(|r| r.exec_time + r.load_time)
            .fold(0.0, f64::max)
    }

    pub fn any_reload(&self) -> bool {
        self.results.iter().any(|r| !r.reused)
    }

    /// Total simulated patch-seconds burnt across the gang (the work-book
    /// currency: per-worker exec + load, summed).
    fn patch_seconds(&self) -> f64 {
        self.results.iter().map(|r| r.exec_time + r.load_time).sum()
    }
}

/// The host: knows every worker's address and dispatches gangs.
#[derive(Clone)]
pub struct ServingHost {
    workers: Vec<SocketAddr>,
}

impl ServingHost {
    pub fn new(workers: Vec<SocketAddr>) -> Self {
        ServingHost { workers }
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Dispatch one task to `gang` (worker indices), concurrently, and
    /// wait for every patch result (gang semantics: the task is complete
    /// only when all patches are). Single-tenant convenience wrapper.
    pub fn dispatch(
        &self,
        task_id: u64,
        prompt: &str,
        steps: u32,
        model: u32,
        gang: &[usize],
    ) -> anyhow::Result<GangOutcome> {
        self.dispatch_tagged(task_id, prompt, steps, model, None, gang)
    }

    /// `dispatch` with an explicit tenant class: every worker request on
    /// the wire carries the tenant tag, so container-side logs and billing
    /// can attribute GPU time per tenant. `None` (an untenanted workload)
    /// omits the tag entirely — it is not tenant 0.
    ///
    /// Built on [`try_dispatch`](Self::try_dispatch), so it shares the
    /// resilient path's per-worker timeouts and empty-reply guard; on
    /// failure the error names every worker that failed and why.
    pub fn dispatch_tagged(
        &self,
        task_id: u64,
        prompt: &str,
        steps: u32,
        model: u32,
        tenant: Option<u32>,
        gang: &[usize],
    ) -> anyhow::Result<GangOutcome> {
        self.dispatch_tagged_timeout(
            task_id,
            prompt,
            steps,
            model,
            tenant,
            None,
            gang,
            DEFAULT_DISPATCH_TIMEOUT,
        )
    }

    /// [`dispatch_tagged`](Self::dispatch_tagged) with an explicit
    /// per-worker socket timeout and an optional trace id: when set, the
    /// id rides every wire request and workers report their measured span
    /// timings in the replies ([`TaskResult::timings`]).
    #[allow(clippy::too_many_arguments)]
    pub fn dispatch_tagged_timeout(
        &self,
        task_id: u64,
        prompt: &str,
        steps: u32,
        model: u32,
        tenant: Option<u32>,
        trace_id: Option<u64>,
        gang: &[usize],
        timeout: Duration,
    ) -> anyhow::Result<GangOutcome> {
        anyhow::ensure!(!gang.is_empty(), "empty gang");
        anyhow::ensure!(
            gang.iter().all(|&w| w < self.workers.len()),
            "gang references unknown worker"
        );
        let started = Instant::now();
        let (mut results, failed) =
            self.try_dispatch(task_id, prompt, steps, model, tenant, trace_id, gang, timeout);
        if !failed.is_empty() {
            let detail: Vec<String> = failed
                .iter()
                .map(|(w, e)| format!("worker {w}: {e}"))
                .collect();
            anyhow::bail!(
                "task {task_id}: gang dispatch failed on {}/{} workers ({})",
                failed.len(),
                gang.len(),
                detail.join("; ")
            );
        }
        results.sort_by_key(|(r, _)| r.worker_id);
        let (results, rtts) = results.into_iter().unzip();
        Ok(GangOutcome {
            task_id,
            results,
            rtts,
            wall_seconds: started.elapsed().as_secs_f64(),
            retry_seconds: 0.0,
        })
    }

    /// Probe one worker with a heartbeat ping. `false` on connect
    /// failure, timeout, or a malformed reply — the caller should treat
    /// the worker as down and exclude it from gangs.
    pub fn heartbeat(&self, worker: usize, timeout: Duration) -> bool {
        let Some(addr) = self.workers.get(worker) else {
            return false;
        };
        let probe = || -> anyhow::Result<bool> {
            let mut stream = TcpStream::connect_timeout(addr, timeout)?;
            stream.set_read_timeout(Some(timeout))?;
            stream.set_write_timeout(Some(timeout))?;
            stream.write_all(protocol::ping_json().as_bytes())?;
            stream.write_all(b"\n")?;
            let mut line = String::new();
            BufReader::new(stream).read_line(&mut line)?;
            Ok(protocol::pong_worker(line.trim()).is_some())
        };
        probe().unwrap_or(false)
    }

    /// One gang round with per-worker connect/read/write timeouts.
    /// Returns the successful results — each paired with its host-observed
    /// round-trip wall seconds (connect → parsed reply) — plus, per failed
    /// worker, the error that felled it (connection refused, timeout, a
    /// clean close without a result, or a garbled reply). `trace_id`
    /// rides every request so workers report their span timings back.
    #[allow(clippy::too_many_arguments)]
    fn try_dispatch(
        &self,
        task_id: u64,
        prompt: &str,
        steps: u32,
        model: u32,
        tenant: Option<u32>,
        trace_id: Option<u64>,
        gang: &[usize],
        timeout: Duration,
    ) -> (Vec<(TaskResult, f64)>, Vec<(usize, anyhow::Error)>) {
        let (tx, rx) = mpsc::channel::<(usize, anyhow::Result<(TaskResult, f64)>)>();
        for (rank, &w) in gang.iter().enumerate() {
            let addr = self.workers[w];
            let req = TaskRequest {
                task_id,
                prompt: prompt.to_string(),
                steps,
                patches: gang.len(),
                model,
                rank,
                tenant,
                trace_id,
            };
            let tx = tx.clone();
            std::thread::spawn(move || {
                let send = || -> anyhow::Result<(TaskResult, f64)> {
                    let t0 = Instant::now();
                    let mut stream = TcpStream::connect_timeout(&addr, timeout)?;
                    stream.set_read_timeout(Some(timeout))?;
                    stream.set_write_timeout(Some(timeout))?;
                    stream.write_all(req.to_json().as_bytes())?;
                    stream.write_all(b"\n")?;
                    let mut line = String::new();
                    BufReader::new(stream).read_line(&mut line)?;
                    anyhow::ensure!(!line.trim().is_empty(), "worker closed without a result");
                    let res = TaskResult::from_json(line.trim())?;
                    Ok((res, t0.elapsed().as_secs_f64()))
                };
                tx.send((w, send())).ok();
            });
        }
        drop(tx);
        let mut results = Vec::with_capacity(gang.len());
        let mut failed = Vec::new();
        for (w, r) in rx {
            match r {
                Ok(res) => results.push(res),
                Err(e) => failed.push((w, e)),
            }
        }
        (results, failed)
    }

    /// Fault-tolerant gang dispatch: per-worker heartbeat timeouts, and on
    /// failure the whole gang retries on a server set that *excludes* every
    /// worker observed failing so far, refilled from `spares` (gang
    /// semantics: partial patch results are useless, but surviving members
    /// keep their loaded model, so the retry round reuses it). Returns the
    /// outcome plus the excluded worker ids, so the caller can mark them
    /// down and route around them (mirroring `EdgeEnv`'s health-aware
    /// dispatch).
    #[allow(clippy::too_many_arguments)]
    pub fn dispatch_resilient(
        &self,
        task_id: u64,
        prompt: &str,
        steps: u32,
        model: u32,
        tenant: Option<u32>,
        gang: &[usize],
        spares: &[usize],
        timeout: Duration,
        max_rounds: usize,
    ) -> anyhow::Result<(GangOutcome, Vec<usize>)> {
        self.dispatch_resilient_inner(
            task_id, prompt, steps, model, tenant, gang, spares, timeout, max_rounds, 0.0, 0.0,
            None, 0.0, None,
        )
    }

    /// [`dispatch_resilient`](Self::dispatch_resilient) feeding the
    /// streaming metrics collector, so retry rounds and excluded workers
    /// show up in the serving summary and the books balance like the
    /// simulator's: dispatched patch-seconds = completed + wasted. Records
    /// per round: each failed worker as a failure, the partial results of
    /// a failed round as a gang kill (their patches completed but the gang
    /// result is useless), each extra round as a retry, and — on success —
    /// response latency, reload flag, and per-worker busy time, exactly
    /// like [`dispatch_collect`](Self::dispatch_collect). A task that
    /// exhausts its rounds is recorded as a task failure.
    ///
    /// `time_scale` is the workers' sleep compression factor: it converts
    /// a failed round's wall time back into simulated seconds, so a round
    /// felled purely by timeouts (zero survivors — e.g. a wedged worker)
    /// still charges its stall to `retry_seconds`. Pass 0 when unknown
    /// (only the surviving partials' execution is charged then).
    #[allow(clippy::too_many_arguments)]
    pub fn dispatch_resilient_collect(
        &self,
        task_id: u64,
        prompt: &str,
        steps: u32,
        model: u32,
        tenant: Option<u32>,
        gang: &[usize],
        spares: &[usize],
        timeout: Duration,
        max_rounds: usize,
        time_scale: f64,
        waiting: f64,
        metrics: &mut MetricsCollector,
    ) -> anyhow::Result<(GangOutcome, Vec<usize>)> {
        self.dispatch_resilient_inner(
            task_id,
            prompt,
            steps,
            model,
            tenant,
            gang,
            spares,
            timeout,
            max_rounds,
            time_scale,
            waiting,
            Some(metrics),
            0.0,
            None,
        )
    }

    /// [`dispatch_resilient_collect`](Self::dispatch_resilient_collect)
    /// additionally emitting lifecycle span events (`dispatched` per
    /// round, `killed`/`retried` per failed round, `completed` or
    /// `dropped`) into `tracer`, all on the caller's simulated clock:
    /// `sim_now` is the simulated instant the first round starts. The
    /// serving trace then decomposes under `eat trace analyze` exactly
    /// like a simulator trace.
    #[allow(clippy::too_many_arguments)]
    pub fn dispatch_resilient_traced(
        &self,
        task_id: u64,
        prompt: &str,
        steps: u32,
        model: u32,
        tenant: Option<u32>,
        gang: &[usize],
        spares: &[usize],
        timeout: Duration,
        max_rounds: usize,
        time_scale: f64,
        waiting: f64,
        metrics: &mut MetricsCollector,
        sim_now: f64,
        tracer: &mut TraceRecorder,
    ) -> anyhow::Result<(GangOutcome, Vec<usize>)> {
        self.dispatch_resilient_inner(
            task_id,
            prompt,
            steps,
            model,
            tenant,
            gang,
            spares,
            timeout,
            max_rounds,
            time_scale,
            waiting,
            Some(metrics),
            sim_now,
            Some(tracer),
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn dispatch_resilient_inner(
        &self,
        task_id: u64,
        prompt: &str,
        steps: u32,
        model: u32,
        tenant: Option<u32>,
        gang: &[usize],
        spares: &[usize],
        timeout: Duration,
        max_rounds: usize,
        time_scale: f64,
        waiting: f64,
        mut metrics: Option<&mut MetricsCollector>,
        sim_now: f64,
        mut tracer: Option<&mut TraceRecorder>,
    ) -> anyhow::Result<(GangOutcome, Vec<usize>)> {
        anyhow::ensure!(!gang.is_empty(), "empty gang");
        anyhow::ensure!(
            gang.iter().chain(spares).all(|&w| w < self.workers.len()),
            "gang references unknown worker"
        );
        let started = Instant::now();
        let rounds = max_rounds.max(1);
        let mut excluded: Vec<usize> = Vec::new();
        let mut current: Vec<usize> = gang.to_vec();
        // Simulated seconds burnt by failed rounds: the retry can only
        // start once the slowest survivor finished (max over the partial
        // results — patches run in parallel) or, for timeout-felled
        // members with no survivors, once the timeout fired — recovered
        // from the round's wall time when time_scale is known.
        let mut lost_sim = 0.0f64;
        // Tracing wants worker-reported span timings in the replies;
        // propagate the task id as the trace id so workers know to
        // measure (untraced dispatches keep the lean wire format).
        let trace_id = tracer.as_ref().map(|_| task_id);
        for round in 0..rounds {
            let round_started = Instant::now();
            let (mut results, failed) = self.try_dispatch(
                task_id, prompt, steps, model, tenant, trace_id, &current, timeout,
            );
            if let Some(tr) = tracer.as_deref_mut() {
                // The round's dispatch instant on the simulated clock:
                // failed rounds pushed it forward by their charged time.
                // Cold/exec come from the round's critical member (the
                // gang completes when its slowest patch does), so the
                // analyzer's cold + exec reproduce `sim_exec_seconds`.
                let (cold, exec) = results
                    .iter()
                    .map(|(r, _)| (r.load_time, r.exec_time))
                    .max_by(|a, b| (a.0 + a.1).total_cmp(&(b.0 + b.1)))
                    .unwrap_or((0.0, 0.0));
                let gref = GangRef::capture(&current, |i| {
                    results.iter().any(|(r, _)| r.worker_id == current[i] && r.reused)
                });
                tr.record(
                    sim_now + lost_sim,
                    task_id,
                    tenant,
                    SpanKind::Dispatched {
                        gang: gref,
                        cold,
                        exec,
                        attempt: round as u32,
                        speculative: false,
                    },
                );
                if failed.is_empty() {
                    tr.record(sim_now + lost_sim, task_id, tenant, SpanKind::ExecStart);
                }
            }
            if failed.is_empty() {
                results.sort_by_key(|(r, _)| r.worker_id);
                let (results, rtts) = results.into_iter().unzip();
                let outcome = GangOutcome {
                    task_id,
                    results,
                    rtts,
                    wall_seconds: started.elapsed().as_secs_f64(),
                    retry_seconds: lost_sim,
                };
                if let Some(m) = metrics.as_deref_mut() {
                    let work = outcome.patch_seconds();
                    m.observe_dispatched_work(work);
                    m.observe_completed_work(work);
                    m.observe_task(
                        waiting + lost_sim + outcome.sim_exec_seconds(),
                        waiting,
                        outcome.any_reload(),
                    );
                    for r in &outcome.results {
                        m.observe_busy(r.worker_id, r.exec_time + r.load_time);
                    }
                }
                if let Some(tr) = tracer.as_deref_mut() {
                    // Worker span for the gang's critical member (largest
                    // host-observed round trip): the analyzer decomposes
                    // this wall RTT into network/queue/load/exec, with
                    // network the exact residual against the worker spans.
                    if let Some((i, &rtt)) = outcome
                        .rtts
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.total_cmp(b.1))
                    {
                        let t = outcome.results[i].timings.unwrap_or_default();
                        tr.record(
                            sim_now + lost_sim + outcome.sim_exec_seconds(),
                            task_id,
                            tenant,
                            SpanKind::WorkerSpan {
                                rtt,
                                recv: t.recv,
                                lock_wait: t.lock_wait,
                                load: t.load,
                                exec: t.exec,
                                reply: t.reply,
                            },
                        );
                    }
                    // Same response expression as the metrics book above,
                    // `start` bit-equal to the winning dispatch's instant.
                    tr.record(
                        sim_now + lost_sim + outcome.sim_exec_seconds(),
                        task_id,
                        tenant,
                        SpanKind::Completed {
                            response: waiting + lost_sim + outcome.sim_exec_seconds(),
                            start: sim_now + lost_sim,
                            speculative: false,
                        },
                    );
                }
                return Ok((outcome, excluded));
            }
            let partial_sim = results
                .iter()
                .map(|(r, _)| r.exec_time + r.load_time)
                .fold(0.0, f64::max);
            // Wall-derived charge only when a member actually hit its
            // timeout (the round lasted at least that long): an instantly
            // refused member costs just the surviving partials, and
            // timeout-free rounds stay free of host-speed noise.
            let round_wall = round_started.elapsed();
            let wall_sim = if time_scale > 0.0 && round_wall >= timeout {
                round_wall.as_secs_f64() / time_scale
            } else {
                0.0
            };
            lost_sim += partial_sim.max(wall_sim);
            if let Some(m) = metrics.as_deref_mut() {
                // The round's surviving patches did burn their workers'
                // time, but without the full gang the result is useless:
                // book the partial work as dispatched AND wasted. A round
                // with zero survivors killed nothing that ever executed,
                // so it is not a gang kill.
                if !results.is_empty() {
                    let burnt: f64 =
                        results.iter().map(|(r, _)| r.exec_time + r.load_time).sum();
                    m.observe_dispatched_work(burnt);
                    m.observe_gang_kill(burnt);
                    for (r, _) in &results {
                        m.observe_busy(r.worker_id, r.exec_time + r.load_time);
                    }
                }
                for _ in &failed {
                    m.observe_failure();
                }
            }
            if let Some(tr) = tracer.as_deref_mut() {
                tr.record(
                    sim_now + lost_sim,
                    task_id,
                    tenant,
                    SpanKind::Killed { attempt: round as u32 },
                );
            }
            for (w, _) in &failed {
                if !excluded.contains(w) {
                    excluded.push(*w);
                }
            }
            // Rebuild the gang: keep healthy members, refill from spares.
            let mut next: Vec<usize> = current
                .iter()
                .copied()
                .filter(|w| !excluded.contains(w))
                .collect();
            for &w in spares {
                if next.len() >= current.len() {
                    break;
                }
                if !excluded.contains(&w) && !next.contains(&w) {
                    next.push(w);
                }
            }
            if next.len() != current.len() {
                if let Some(m) = metrics.as_deref_mut() {
                    m.observe_task_failure();
                }
                if let Some(tr) = tracer.as_deref_mut() {
                    tr.record(
                        sim_now + lost_sim,
                        task_id,
                        tenant,
                        SpanKind::Dropped { reason: DropReason::RetriesExhausted },
                    );
                }
                anyhow::bail!(
                    "task {task_id}: gang needs {} workers but only {} healthy candidates remain \
                     (excluded: {excluded:?})",
                    current.len(),
                    next.len()
                );
            }
            if round + 1 < rounds {
                if let Some(m) = metrics.as_deref_mut() {
                    m.observe_retry();
                }
                if let Some(tr) = tracer.as_deref_mut() {
                    tr.record(
                        sim_now + lost_sim,
                        task_id,
                        tenant,
                        SpanKind::Retried { attempt: round as u32 + 1 },
                    );
                }
                current = next;
            }
        }
        if let Some(m) = metrics.as_deref_mut() {
            m.observe_task_failure();
        }
        if let Some(tr) = tracer.as_deref_mut() {
            tr.record(
                sim_now + lost_sim,
                task_id,
                tenant,
                SpanKind::Dropped { reason: DropReason::RetriesExhausted },
            );
        }
        anyhow::bail!(
            "task {task_id}: gang dispatch still failing after {rounds} rounds (excluded: {excluded:?})"
        )
    }

    /// `dispatch`, additionally feeding the streaming metrics collector:
    /// response latency (`waiting` + simulated gang execution), reload
    /// flag, and per-worker busy time. The caller advances the collector's
    /// clock (`advance_time`) according to its own notion of elapsed time,
    /// and supplies the per-worker socket timeout
    /// ([`DEFAULT_DISPATCH_TIMEOUT`] when in doubt).
    #[allow(clippy::too_many_arguments)]
    pub fn dispatch_collect(
        &self,
        task_id: u64,
        prompt: &str,
        steps: u32,
        model: u32,
        tenant: Option<u32>,
        trace_id: Option<u64>,
        gang: &[usize],
        waiting: f64,
        timeout: Duration,
        metrics: &mut MetricsCollector,
    ) -> anyhow::Result<GangOutcome> {
        let out = self
            .dispatch_tagged_timeout(task_id, prompt, steps, model, tenant, trace_id, gang, timeout)?;
        metrics.observe_task(waiting + out.sim_exec_seconds(), waiting, out.any_reload());
        // Busy time is per worker: patches run in parallel and each worker
        // is free again after its own exec+load, not after the slowest
        // peer's (gang-max would inflate fast workers' utilization).
        for r in &out.results {
            metrics.observe_busy(r.worker_id, r.exec_time + r.load_time);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExecModelConfig;
    use crate::serving::worker::WorkerPool;

    #[test]
    fn gang_dispatch_collects_all_patches() {
        let pool = WorkerPool::spawn(4, ExecModelConfig::default(), 1e-4, 2).unwrap();
        let host = ServingHost::new(pool.addrs().to_vec());
        let out = host.dispatch(9, "gang test", 20, 0, &[0, 1, 2, 3]).unwrap();
        assert_eq!(out.results.len(), 4);
        assert_eq!(out.rtts.len(), 4, "one round trip per gang member");
        assert!(out.rtts.iter().all(|&r| r > 0.0), "{:?}", out.rtts);
        assert!(out.any_reload());
        assert!(out.sim_exec_seconds() > 0.0);
        // Reuse on the second dispatch with same model + gang size.
        let out2 = host.dispatch(10, "again", 20, 0, &[0, 1, 2, 3]).unwrap();
        assert!(!out2.any_reload());
        assert!(out2.sim_exec_seconds() < out.sim_exec_seconds());
        pool.shutdown();
    }

    #[test]
    fn dispatch_validates_gang() {
        let host = ServingHost::new(vec![]);
        assert!(host.dispatch(0, "x", 10, 0, &[]).is_err());
        assert!(host.dispatch(0, "x", 10, 0, &[3]).is_err());
    }

    /// An address with nothing listening behind it (bind, read the port,
    /// drop the listener): connections are refused, like a crashed worker.
    fn dead_addr() -> std::net::SocketAddr {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap()
    }

    #[test]
    fn dispatch_error_names_the_failed_worker() {
        let pool = WorkerPool::spawn(1, ExecModelConfig::default(), 1e-4, 3).unwrap();
        let mut addrs = pool.addrs().to_vec();
        addrs.push(dead_addr()); // worker 1 is dead
        let host = ServingHost::new(addrs);
        let err = host.dispatch(4, "p", 20, 0, &[0, 1]).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("task 4"), "{msg}");
        assert!(msg.contains("worker 1"), "{msg}");
        assert!(!msg.contains("worker 0:"), "healthy worker blamed: {msg}");
        pool.shutdown();
    }

    #[test]
    fn clean_close_reports_empty_reply_not_a_parse_error() {
        // A worker that accepts and closes without replying used to
        // surface as a JSON parse error on ""; now both dispatch paths
        // share try_dispatch's empty-reply guard.
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let closer = std::thread::spawn(move || {
            if let Ok((stream, _)) = l.accept() {
                // Consume the request, then close cleanly without a reply.
                let mut line = String::new();
                BufReader::new(&stream).read_line(&mut line).ok();
                drop(stream);
            }
        });
        let host = ServingHost::new(vec![addr]);
        let err = host.dispatch(1, "p", 20, 0, &[0]).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("worker 0"), "{msg}");
        assert!(msg.contains("closed without a result"), "{msg}");
        closer.join().unwrap();
    }

    #[test]
    fn heartbeat_detects_live_and_dead_workers() {
        let pool = WorkerPool::spawn(2, ExecModelConfig::default(), 1e-4, 5).unwrap();
        let mut addrs = pool.addrs().to_vec();
        addrs.push(dead_addr());
        let host = ServingHost::new(addrs);
        let t = Duration::from_secs(2);
        assert!(host.heartbeat(0, t));
        assert!(host.heartbeat(1, t));
        assert!(!host.heartbeat(2, t), "dead worker must fail its heartbeat");
        assert!(!host.heartbeat(99, t), "unknown worker id is down by definition");
        pool.shutdown();
    }

    #[test]
    fn heartbeat_times_out_against_a_wedged_worker() {
        let pool = WorkerPool::spawn(1, ExecModelConfig::default(), 1e-4, 8).unwrap();
        let host = ServingHost::new(pool.addrs().to_vec());
        assert!(host.heartbeat(0, Duration::from_secs(2)));
        pool.wedge(0);
        let t0 = Instant::now();
        assert!(
            !host.heartbeat(0, Duration::from_millis(250)),
            "wedged worker accepts but never replies — the probe must fail"
        );
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "probe must fail within its timeout, not hang"
        );
        pool.unwedge(0);
        assert!(host.heartbeat(0, Duration::from_secs(2)), "unwedged worker revives");
        pool.shutdown();
    }

    #[test]
    fn resilient_dispatch_excludes_failed_workers_and_retries() {
        let pool = WorkerPool::spawn(3, ExecModelConfig::default(), 1e-4, 6).unwrap();
        let mut addrs = pool.addrs().to_vec();
        addrs.push(dead_addr()); // worker 3 is dead
        let host = ServingHost::new(addrs);
        let timeout = Duration::from_secs(2);
        // Gang of 2 includes the dead worker; worker 2 is the spare.
        let (out, excluded) = host
            .dispatch_resilient(5, "p", 20, 0, None, &[0, 3], &[2], timeout, 3)
            .unwrap();
        assert_eq!(excluded, vec![3]);
        assert_eq!(out.results.len(), 2);
        let ids: Vec<usize> = out.results.iter().map(|r| r.worker_id).collect();
        assert_eq!(ids, vec![0, 2]);
        // No healthy candidates left: the dispatch reports failure rather
        // than hanging.
        assert!(host
            .dispatch_resilient(6, "p", 20, 0, None, &[3], &[], timeout, 2)
            .is_err());
        pool.shutdown();
    }

    #[test]
    fn resilient_dispatch_refills_from_spares_after_a_mid_run_kill() {
        let mut pool = WorkerPool::spawn(3, ExecModelConfig::default(), 1e-4, 11).unwrap();
        let host = ServingHost::new(pool.addrs().to_vec());
        let timeout = Duration::from_secs(2);
        // Warm run: the gang [0, 1] completes with nothing excluded.
        let (_, ex) = host
            .dispatch_resilient(1, "p", 20, 0, None, &[0, 1], &[2], timeout, 3)
            .unwrap();
        assert!(ex.is_empty());
        // Kill a gang member mid-run: the next dispatch of the same gang
        // must exclude it and complete on the spare.
        pool.kill(1);
        let (out, ex) = host
            .dispatch_resilient(2, "p", 20, 0, None, &[0, 1], &[2], timeout, 3)
            .unwrap();
        assert_eq!(ex, vec![1]);
        let ids: Vec<usize> = out.results.iter().map(|r| r.worker_id).collect();
        assert_eq!(ids, vec![0, 2]);
        pool.shutdown();
    }

    #[test]
    fn resilient_collect_books_retries_failures_and_wasted_work() {
        let mut pool = WorkerPool::spawn(3, ExecModelConfig::default(), 1e-4, 12).unwrap();
        let host = ServingHost::new(pool.addrs().to_vec());
        let timeout = Duration::from_secs(2);
        pool.kill(1);
        let mut m = MetricsCollector::new(3);
        let (out, excluded) = host
            .dispatch_resilient_collect(
                7,
                "p",
                20,
                0,
                None,
                &[0, 1],
                &[2],
                timeout,
                3,
                1e-4,
                1.5,
                &mut m,
            )
            .unwrap();
        assert_eq!(excluded, vec![1]);
        assert_eq!(m.completed(), 1);
        assert_eq!(m.failures(), 1);
        assert_eq!(m.retries(), 1);
        assert_eq!(m.gang_kills(), 1);
        assert!(m.wasted_ps() > 0.0, "worker 0's first patch was burnt");
        assert!(
            out.retry_seconds > 0.0,
            "the failed round's simulated time must be charged to the task"
        );
        assert!(
            out.results.iter().all(|r| r.timings.is_none()),
            "untraced dispatch must keep the lean wire format"
        );
        // Serving books mirror the simulator's: dispatched = completed + wasted.
        assert!(
            (m.dispatched_ps() - m.completed_ps() - m.wasted_ps()).abs() < 1e-9,
            "books out of balance: {} != {} + {}",
            m.dispatched_ps(),
            m.completed_ps(),
            m.wasted_ps()
        );
        assert!(m.latency.p50() >= 1.5 + out.retry_seconds + out.sim_exec_seconds() - 1e-9);
        // Exhausting the gang (no spares left) books a task failure.
        assert!(host
            .dispatch_resilient_collect(
                8,
                "p",
                20,
                0,
                None,
                &[1],
                &[],
                timeout,
                2,
                1e-4,
                0.0,
                &mut m,
            )
            .is_err());
        assert_eq!(m.task_failures(), 1);
        assert_eq!(m.completed(), 1, "a failed task is not a completion");
        pool.shutdown();
    }

    #[test]
    fn resilient_traced_dispatch_decomposes_exactly() {
        use crate::obs::analyze::analyze;
        let mut pool = WorkerPool::spawn(3, ExecModelConfig::default(), 1e-4, 13).unwrap();
        let host = ServingHost::new(pool.addrs().to_vec());
        let timeout = Duration::from_secs(2);
        pool.kill(1);
        let mut m = MetricsCollector::new(3);
        let mut tr = TraceRecorder::new(256);
        let (sim_now, waiting) = (10.0, 1.5);
        tr.record(sim_now - waiting, 7, None, SpanKind::Admitted);
        let (out, _) = host
            .dispatch_resilient_traced(
                7,
                "p",
                20,
                0,
                None,
                &[0, 1],
                &[2],
                timeout,
                3,
                1e-4,
                waiting,
                &mut m,
                sim_now,
                &mut tr,
            )
            .unwrap();
        let names: Vec<&str> = tr.events().iter().map(|e| e.kind.name()).collect();
        assert!(names.contains(&"killed"), "{names:?}");
        assert!(names.contains(&"retried"), "{names:?}");
        assert!(names.contains(&"completed"), "{names:?}");
        let a = analyze(&tr.events());
        a.check_books().unwrap();
        assert_eq!(a.tasks.len(), 1);
        let d = &a.tasks[0];
        assert_eq!(d.attempts, 2);
        assert!(d.retry > 0.0, "failed round must book retry latency");
        assert!(
            (d.cold + d.exec - out.sim_exec_seconds()).abs() < 1e-9,
            "critical member's cold+exec {} + {} must equal sim exec {}",
            d.cold,
            d.exec,
            out.sim_exec_seconds()
        );
        // The traced dispatch propagated a trace id, so workers reported
        // span timings and the analyzer decomposed the live round trip:
        // network + lock_wait + load + exec must rebuild the host-measured
        // RTT bit-exactly (network is the ulp-walked residual).
        assert!(
            out.results.iter().all(|r| r.timings.is_some()),
            "traced dispatch must elicit worker timings"
        );
        assert_eq!(a.live.len(), 1, "one live decomposition per traced task");
        let live = &a.live[0];
        assert!(live.balanced(), "live decomposition out of balance: {live:?}");
        let max_rtt = out.rtts.iter().copied().fold(0.0, f64::max);
        assert_eq!(
            live.rtt.to_bits(),
            max_rtt.to_bits(),
            "live span must carry the critical member's round trip"
        );
        assert!(live.exec > 0.0, "{live:?}");
        // A task that exhausts its candidates books a drop.
        assert!(host
            .dispatch_resilient_traced(
                8, "p", 20, 0, None, &[1], &[], timeout, 2, 1e-4, 0.0, &mut m, 20.0, &mut tr,
            )
            .is_err());
        let a2 = analyze(&tr.events());
        assert_eq!(a2.dropped, 1);
        pool.shutdown();
    }

    #[test]
    fn dispatch_collect_feeds_metrics() {
        let pool = WorkerPool::spawn(2, ExecModelConfig::default(), 1e-4, 3).unwrap();
        let host = ServingHost::new(pool.addrs().to_vec());
        let mut m = MetricsCollector::new(2);
        let out = host
            .dispatch_collect(
                1,
                "p",
                20,
                0,
                None,
                None,
                &[0, 1],
                2.5,
                DEFAULT_DISPATCH_TIMEOUT,
                &mut m,
            )
            .unwrap();
        m.advance_time(out.sim_exec_seconds());
        assert_eq!(m.completed(), 1);
        assert_eq!(m.reloads(), 1); // first dispatch always loads
        assert!(m.latency.p50() >= 2.5);
        assert!(m.avg_utilization() > 0.0);
        pool.shutdown();
    }
}
