//! Lazy task streams: `EdgeEnv` can consume an [`ArrivalProcess`] directly
//! instead of a pre-materialised `Workload`, generating each task on
//! demand as simulated time reaches it. The draw order per task (arrival,
//! mix, prompt id) matches `workload::generate`, so a streamed episode and
//! a materialised one built from the same seeded RNG are identical.

use super::arrival::ArrivalProcess;
use super::mix::TaskMix;
use crate::sim::task::{Task, Workload};
use crate::util::rng::Pcg64;

/// On-demand task generator with a one-task lookahead.
#[derive(Clone)]
pub struct TaskStream {
    arrival: Box<dyn ArrivalProcess>,
    mix: TaskMix,
    rng: Pcg64,
    limit: usize,
    produced: usize,
    clock: f64,
    lookahead: Option<Task>,
}

impl TaskStream {
    pub fn new(
        arrival: Box<dyn ArrivalProcess>,
        mix: TaskMix,
        limit: usize,
        rng: Pcg64,
    ) -> TaskStream {
        TaskStream {
            arrival,
            mix,
            rng,
            limit,
            produced: 0,
            clock: 0.0,
            lookahead: None,
        }
    }

    /// Total number of tasks this stream will ever emit.
    pub fn limit(&self) -> usize {
        self.limit
    }

    /// Tasks generated so far (including a pending lookahead).
    pub fn produced(&self) -> usize {
        self.produced
    }

    fn refill(&mut self) {
        if self.lookahead.is_some() || self.produced >= self.limit {
            return;
        }
        let t = self.arrival.next_after(self.clock, &mut self.rng);
        self.clock = t;
        let s = self.mix.sample(t, &mut self.rng);
        let task = Task {
            id: self.produced as u64,
            prompt_id: self.rng.next_u64(),
            patches: s.patches,
            model: s.model,
            arrival: t,
            q_min: s.q_min,
            tenant: None,
            deadline: None,
        };
        self.produced += 1;
        self.lookahead = Some(task);
    }

    /// Arrival time of the next task, generating it if necessary.
    pub fn next_arrival(&mut self) -> Option<f64> {
        self.refill();
        self.lookahead.as_ref().map(|t| t.arrival)
    }

    /// Pop the next task iff it has arrived by `now`.
    pub fn pop_if_arrived(&mut self, now: f64) -> Option<Task> {
        self.refill();
        if self.lookahead.as_ref().map_or(false, |t| t.arrival <= now) {
            self.lookahead.take()
        } else {
            None
        }
    }
}

/// Where an environment's tasks come from: a pre-materialised workload
/// (common-random-number evaluation, trace replay) or a lazy stream.
#[derive(Clone)]
pub enum TaskSource {
    Fixed { workload: Workload, cursor: usize },
    Stream(TaskStream),
}

impl TaskSource {
    pub fn fixed(workload: Workload) -> TaskSource {
        TaskSource::Fixed {
            workload,
            cursor: 0,
        }
    }

    pub fn stream(stream: TaskStream) -> TaskSource {
        TaskSource::Stream(stream)
    }

    /// Total tasks this source will deliver over the episode.
    pub fn total(&self) -> usize {
        match self {
            TaskSource::Fixed { workload, .. } => workload.len(),
            TaskSource::Stream(s) => s.limit(),
        }
    }

    /// Pop the next task iff it has arrived by `now`. Tasks come out in
    /// arrival order; callers loop until `None`.
    pub fn pop_if_arrived(&mut self, now: f64) -> Option<Task> {
        match self {
            TaskSource::Fixed { workload, cursor } => {
                let task = workload.tasks.get(*cursor)?;
                if task.arrival <= now {
                    *cursor += 1;
                    Some(task.clone())
                } else {
                    None
                }
            }
            TaskSource::Stream(s) => s.pop_if_arrived(now),
        }
    }

    /// Arrival times of the whole workload for a fixed source. A stream
    /// retains no history (laziness is its point) and cannot report
    /// future arrivals without consuming randomness, so it yields an
    /// empty list.
    pub fn known_arrivals(&self) -> Vec<f64> {
        match self {
            TaskSource::Fixed { workload, .. } => {
                workload.tasks.iter().map(|t| t.arrival).collect()
            }
            TaskSource::Stream(_) => Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnvConfig;
    use crate::workload::{self, build_for_env};

    fn cfg() -> EnvConfig {
        let mut c = EnvConfig::default();
        c.tasks_per_episode = 24;
        c
    }

    #[test]
    fn stream_matches_materialised_generation() {
        let cfg = cfg();
        let (mut ap, mix) = build_for_env(&cfg);
        let w = workload::generate(ap.as_mut(), &mix, cfg.tasks_per_episode, &mut Pcg64::seeded(5));
        let (ap2, mix2) = build_for_env(&cfg);
        let mut stream = TaskStream::new(ap2, mix2, cfg.tasks_per_episode, Pcg64::seeded(5));
        let mut streamed = Vec::new();
        while let Some(t) = stream.pop_if_arrived(f64::INFINITY) {
            streamed.push(t);
        }
        assert_eq!(streamed.len(), w.len());
        for (a, b) in streamed.iter().zip(&w.tasks) {
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
            assert_eq!(a.prompt_id, b.prompt_id);
            assert_eq!(a.patches, b.patches);
            assert_eq!(a.model, b.model);
        }
    }

    #[test]
    fn stream_respects_arrival_gating() {
        let cfg = cfg();
        let (ap, mix) = build_for_env(&cfg);
        let mut stream = TaskStream::new(ap, mix, cfg.tasks_per_episode, Pcg64::seeded(6));
        let first = stream.next_arrival().unwrap();
        assert!(stream.pop_if_arrived(first - 1e-9).is_none());
        assert!(stream.pop_if_arrived(first).is_some());
    }

    #[test]
    fn stream_stops_at_limit() {
        let cfg = cfg();
        let (ap, mix) = build_for_env(&cfg);
        let mut stream = TaskStream::new(ap, mix, 5, Pcg64::seeded(7));
        let mut n = 0;
        while stream.pop_if_arrived(f64::INFINITY).is_some() {
            n += 1;
        }
        assert_eq!(n, 5);
        assert!(stream.next_arrival().is_none());
        assert_eq!(stream.produced(), 5);
    }

    #[test]
    fn fixed_source_walks_cursor() {
        let w = Workload::fixed(&[(0.0, 2, 0), (10.0, 2, 0), (20.0, 4, 1)]);
        let mut src = TaskSource::fixed(w);
        assert_eq!(src.total(), 3);
        assert_eq!(src.pop_if_arrived(0.0).unwrap().id, 0);
        assert!(src.pop_if_arrived(5.0).is_none());
        assert_eq!(src.pop_if_arrived(25.0).unwrap().id, 1);
        assert_eq!(src.pop_if_arrived(25.0).unwrap().id, 2);
        assert!(src.pop_if_arrived(1e9).is_none());
        assert_eq!(src.known_arrivals(), vec![0.0, 10.0, 20.0]);
    }

    #[test]
    fn cloned_stream_diverges_independently() {
        let cfg = cfg();
        let (ap, mix) = build_for_env(&cfg);
        let mut a = TaskStream::new(ap, mix, cfg.tasks_per_episode, Pcg64::seeded(8));
        let mut b = a.clone();
        let ta = a.pop_if_arrived(f64::INFINITY).unwrap();
        let tb = b.pop_if_arrived(f64::INFINITY).unwrap();
        assert_eq!(ta.arrival.to_bits(), tb.arrival.to_bits());
        assert_eq!(ta.prompt_id, tb.prompt_id);
    }
}
