//! JSONL workload traces: record any generated scenario to disk and replay
//! it bit-exactly later.
//!
//! Format: one JSON object per line. The first line is a header
//! (`{"format":"eat-trace","version":1,"tasks":N}`); each following line
//! is one task. `prompt_id` is a full 64-bit value and JSON numbers are
//! f64, so it is serialised as a decimal *string* — everything else
//! round-trips exactly through the shortest-roundtrip float writer in
//! `util::json`. Replaying a recorded trace through `EdgeEnv` with the
//! same policy and env seed reproduces the episode's numbers bit-for-bit
//! (common-random-number policy comparisons across machines and PRs).

use crate::faults::FaultEvent;
use crate::sim::task::{ModelType, Task, Workload};
use crate::util::json::{self, Value};

pub const FORMAT: &str = "eat-trace";
pub const VERSION: u64 = 1;

fn task_to_json(t: &Task) -> Value {
    let mut v = Value::obj();
    v.set("id", t.id)
        .set("prompt_id", format!("{}", t.prompt_id))
        .set("patches", t.patches)
        .set("model", t.model.0)
        .set("arrival", t.arrival);
    if let Some(q) = t.q_min {
        v.set("q_min", q);
    }
    if let Some(tenant) = t.tenant {
        v.set("tenant", tenant);
    }
    if let Some(d) = t.deadline {
        v.set("deadline", d);
    }
    v
}

fn task_from_json(v: &Value) -> anyhow::Result<Task> {
    let num = |key: &str| -> anyhow::Result<f64> {
        v.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("trace field '{key}' is not a number"))
    };
    let prompt_id: u64 = v
        .req("prompt_id")?
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("trace field 'prompt_id' must be a string"))?
        .parse()
        .map_err(|e| anyhow::anyhow!("bad prompt_id: {e}"))?;
    let arrival = num("arrival")?;
    anyhow::ensure!(
        arrival.is_finite() && arrival >= 0.0,
        "trace arrival {arrival} must be finite and non-negative"
    );
    // q_min is optional, but when present it must be a positive finite
    // number — silently dropping or accepting a floor that can never trip
    // (quality is clamped to [0, q_cap]) would replay with different QoS
    // accounting than the recording run.
    let q_min = match v.get("q_min") {
        None => None,
        Some(q) => {
            let q = q
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("trace field 'q_min' is not a number"))?;
            anyhow::ensure!(
                q.is_finite() && q > 0.0,
                "trace q_min {q} must be positive and finite"
            );
            Some(q)
        }
    };
    let tenant = match v.get("tenant") {
        None => None,
        Some(t) => {
            let t = t
                .as_f64()
                .filter(|x| x.is_finite() && *x >= 0.0)
                .ok_or_else(|| {
                    anyhow::anyhow!("trace field 'tenant' must be a non-negative number")
                })?;
            Some(t as u32)
        }
    };
    let deadline = match v.get("deadline") {
        None => None,
        Some(d) => {
            let d = d
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("trace field 'deadline' is not a number"))?;
            anyhow::ensure!(
                d.is_finite() && d >= 0.0,
                "trace deadline {d} must be finite and non-negative"
            );
            Some(d)
        }
    };
    Ok(Task {
        id: num("id")? as u64,
        prompt_id,
        patches: num("patches")? as usize,
        model: ModelType(num("model")? as u32),
        arrival,
        q_min,
        tenant,
        deadline,
    })
}

/// Serialise a workload as a JSONL trace string.
pub fn to_jsonl(w: &Workload) -> String {
    to_jsonl_with_faults(w, &[])
}

/// Serialise a workload plus its episode's fault events: replaying both
/// (workload via `EdgeEnv::with_workload`, events via
/// `EdgeEnv::script_faults`) reproduces a recorded churn episode
/// bit-exactly. Event lines are recognised by their `fault` field and
/// ignored by task-only readers of older tooling.
pub fn to_jsonl_with_faults(w: &Workload, events: &[FaultEvent]) -> String {
    let mut out = String::new();
    let mut header = Value::obj();
    header
        .set("format", FORMAT)
        .set("version", VERSION)
        .set("tasks", w.len());
    if !events.is_empty() {
        header.set("faults", events.len());
    }
    out.push_str(&header.to_json());
    out.push('\n');
    for t in &w.tasks {
        out.push_str(&task_to_json(t).to_json());
        out.push('\n');
    }
    for ev in events {
        out.push_str(&ev.to_json().to_json());
        out.push('\n');
    }
    out
}

/// Parse a JSONL trace, dropping any fault-event lines. The header line
/// is validated when present; task lines are recognised by their
/// `arrival` field. Out-of-order arrivals are normalised by a stable sort
/// (see `Workload::from_tasks`).
pub fn from_jsonl(text: &str) -> anyhow::Result<Workload> {
    Ok(from_jsonl_with_faults(text)?.0)
}

/// Parse a JSONL trace including its recorded fault events (empty for a
/// fault-free trace). Events come back sorted by timestamp.
pub fn from_jsonl_with_faults(text: &str) -> anyhow::Result<(Workload, Vec<FaultEvent>)> {
    let mut tasks = Vec::new();
    let mut events = Vec::new();
    let mut declared: Option<usize> = None;
    let mut declared_faults: Option<usize> = None;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let v = json::parse(line)
            .map_err(|e| anyhow::anyhow!("trace line {}: {e}", lineno + 1))?;
        if let Some(fmt) = v.get("format").and_then(Value::as_str) {
            anyhow::ensure!(fmt == FORMAT, "unknown trace format '{fmt}'");
            if let Some(ver) = v.get("version").and_then(Value::as_f64) {
                // Float compare: truncating would accept e.g. v1.5 as v1.
                anyhow::ensure!(
                    ver <= VERSION as f64,
                    "trace version {ver} is newer than supported version {VERSION}"
                );
            }
            if let Some(n) = v.get("tasks").and_then(Value::as_usize) {
                declared = Some(n);
            }
            if let Some(n) = v.get("faults").and_then(Value::as_usize) {
                declared_faults = Some(n);
            }
            continue;
        }
        if v.get("fault").is_some() {
            events.push(
                FaultEvent::from_json(&v)
                    .map_err(|e| anyhow::anyhow!("trace line {}: {e}", lineno + 1))?,
            );
            continue;
        }
        tasks.push(
            task_from_json(&v).map_err(|e| anyhow::anyhow!("trace line {}: {e}", lineno + 1))?,
        );
    }
    if let Some(n) = declared {
        anyhow::ensure!(
            n == tasks.len(),
            "trace header declares {n} tasks, found {}",
            tasks.len()
        );
    }
    if let Some(n) = declared_faults {
        anyhow::ensure!(
            n == events.len(),
            "trace header declares {n} fault events, found {}",
            events.len()
        );
    }
    events.sort_by(|a, b| a.t.partial_cmp(&b.t).expect("NaN fault time"));
    Ok((Workload::from_tasks(tasks), events))
}

/// Write a workload trace to a file.
pub fn write_file(w: &Workload, path: &str) -> anyhow::Result<()> {
    std::fs::write(path, to_jsonl(w))?;
    Ok(())
}

/// Read a workload trace from a file.
pub fn read_file(path: &str) -> anyhow::Result<Workload> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read trace '{path}': {e}"))?;
    from_jsonl(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnvConfig;
    use crate::util::rng::Pcg64;
    use crate::workload::WorkloadConfig;

    fn assert_bit_exact(a: &Workload, b: &Workload) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.prompt_id, y.prompt_id);
            assert_eq!(x.patches, y.patches);
            assert_eq!(x.model, y.model);
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
            assert_eq!(x.q_min.map(f64::to_bits), y.q_min.map(f64::to_bits));
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.deadline.map(f64::to_bits), y.deadline.map(f64::to_bits));
        }
    }

    #[test]
    fn roundtrip_is_bit_exact_for_every_scenario() {
        let mut cfg = EnvConfig::default();
        cfg.tasks_per_episode = 64;
        for (i, name) in WorkloadConfig::scenario_names().iter().enumerate() {
            cfg.workload = Some(WorkloadConfig::preset(name, 0.1).unwrap());
            let w = Workload::generate(&cfg, &mut Pcg64::seeded(100 + i as u64));
            let back = from_jsonl(&to_jsonl(&w)).unwrap();
            assert_bit_exact(&w, &back);
        }
    }

    #[test]
    fn header_mismatch_is_rejected() {
        let w = Workload::fixed(&[(0.0, 2, 0), (5.0, 4, 1)]);
        let text = to_jsonl(&w);
        let truncated: String = text.lines().take(2).map(|l| format!("{l}\n")).collect();
        assert!(from_jsonl(&truncated).is_err(), "declared 2 tasks, found 1");
        assert!(from_jsonl("{\"format\":\"something-else\"}\n").is_err());
        // Future trace versions must be rejected, not silently misread.
        assert!(from_jsonl("{\"format\":\"eat-trace\",\"version\":2,\"tasks\":0}\n").is_err());
    }

    #[test]
    fn bad_lines_carry_line_numbers() {
        let err = from_jsonl("{\"arrival\": 1.0}\n").unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
        let err = from_jsonl("not json\n").unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
    }

    #[test]
    fn malformed_q_min_is_an_error_not_a_silent_drop() {
        let line = "{\"id\":0,\"prompt_id\":\"1\",\"patches\":2,\"model\":0,\
                    \"arrival\":1.5,\"q_min\":\"0.25\"}\n";
        let err = from_jsonl(line).unwrap_err().to_string();
        assert!(err.contains("q_min"), "{err}");
    }

    #[test]
    fn tenant_workloads_roundtrip_bit_exactly() {
        use crate::qos::{generate_workload, TenantRegistry, TenantsConfig};
        let cfg = EnvConfig::default();
        let reg = TenantRegistry::new(&TenantsConfig::three_tier(0.3));
        let w = generate_workload(&cfg, &reg, 48, &mut Pcg64::seeded(7));
        assert!(w.tasks.iter().all(|t| t.tenant.is_some() && t.deadline.is_some()));
        let back = from_jsonl(&to_jsonl(&w)).unwrap();
        assert_bit_exact(&w, &back);
        // A malformed deadline must be an error, not a silent drop.
        let bad = "{\"id\":0,\"prompt_id\":\"1\",\"patches\":2,\"model\":0,\
                   \"arrival\":1.5,\"deadline\":-3.0}\n";
        assert!(from_jsonl(bad).unwrap_err().to_string().contains("deadline"));
    }

    #[test]
    fn fault_events_roundtrip_and_stay_invisible_to_task_readers() {
        use crate::faults::{FaultEvent, FaultKind};
        let w = Workload::fixed(&[(0.0, 2, 0), (5.0, 4, 1)]);
        let events = vec![
            FaultEvent { t: 3.0, server: 1, kind: FaultKind::Fail },
            FaultEvent { t: 9.0, server: 1, kind: FaultKind::Recover },
            FaultEvent {
                t: 4.5,
                server: 0,
                kind: FaultKind::SlowStart { factor: 2.5, duration: 20.0 },
            },
        ];
        let text = to_jsonl_with_faults(&w, &events);
        let (back_w, back_e) = from_jsonl_with_faults(&text).unwrap();
        assert_bit_exact(&w, &back_w);
        // Events come back sorted by time.
        assert_eq!(back_e.len(), 3);
        assert!(back_e.windows(2).all(|p| p[0].t <= p[1].t));
        assert!(back_e.contains(&events[0]) && back_e.contains(&events[2]));
        // A task-only reader sees the same workload and ignores events.
        let tasks_only = from_jsonl(&text).unwrap();
        assert_bit_exact(&w, &tasks_only);
        // A mismatched fault count in the header is an error.
        let broken: String = text
            .lines()
            .filter(|l| !l.contains("slow_start"))
            .map(|l| format!("{l}\n"))
            .collect();
        assert!(from_jsonl_with_faults(&broken).is_err());
    }

    #[test]
    fn unsorted_trace_is_normalised() {
        let w = Workload::fixed(&[(0.0, 2, 0), (5.0, 2, 0), (9.0, 2, 1)]);
        let mut text = to_jsonl(&w);
        // Swap the two task lines after the header.
        let mut lines: Vec<&str> = text.lines().collect();
        lines.swap(1, 3);
        text = lines.join("\n");
        let back = from_jsonl(&text).unwrap();
        assert!(back.is_sorted());
        assert_eq!(back.tasks[0].arrival, 0.0);
        assert_eq!(back.tasks[2].arrival, 9.0);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("eat_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        let path = path.to_str().unwrap();
        let mut cfg = EnvConfig::default();
        cfg.tasks_per_episode = 16;
        let w = Workload::generate(&cfg, &mut Pcg64::seeded(9));
        write_file(&w, path).unwrap();
        let back = read_file(path).unwrap();
        assert_bit_exact(&w, &back);
        std::fs::remove_file(path).ok();
    }
}
