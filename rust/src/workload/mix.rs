//! Task-mix distributions: *what* arrives, as opposed to *when*.
//!
//! A [`TaskMix`] bundles the three per-task draws — collaboration
//! requirement (patch count), AIGC model/service type, and optional
//! per-task quality demand — behind one `sample` call whose draw order is
//! fixed (patches, model, quality). The uniform mix reproduces the seed
//! generator's draw sequence bit-exactly; skewed (Zipf) and time-varying
//! (rotating hot model) mixes model real service popularity, where model
//! reuse either pays off massively or keeps thrashing.

use crate::config::EnvConfig;
use crate::sim::task::ModelType;
use crate::util::rng::Pcg64;

/// Distribution over model/service types.
#[derive(Clone, Debug, PartialEq)]
pub enum ModelMix {
    /// Every model type equally likely (the paper's setting).
    Uniform,
    /// Zipf popularity: weight of model i ∝ 1/(i+1)^exponent. Realistic
    /// for AIGC services, where a handful of checkpoints dominate.
    Zipf { exponent: f64 },
    /// A rotating "hot" model holds `hot_weight` of the traffic and hands
    /// over to the next model every `period` seconds — stresses the
    /// scheduler's reload behaviour under popularity drift.
    Rotating { hot_weight: f64, period: f64 },
}

/// Distribution over per-task minimum-quality demands (q_min). Tasks with
/// no demand fall back to the episode-wide `RewardConfig::q_min`.
#[derive(Clone, Debug, PartialEq)]
pub enum QualityDemand {
    /// No per-task demand (seed behaviour).
    Default,
    /// q_min ~ U[lo, hi).
    Uniform { lo: f64, hi: f64 },
    /// A `strict_frac` fraction of tasks demands `strict_q`; the rest are
    /// satisfied with `lax_q` (premium vs best-effort tenants).
    TwoTier {
        strict_frac: f64,
        strict_q: f64,
        lax_q: f64,
    },
}

/// One sampled task profile.
#[derive(Clone, Copy, Debug)]
pub struct MixSample {
    pub patches: usize,
    pub model: ModelType,
    pub q_min: Option<f64>,
}

/// Joint per-task distribution (patches × model × quality demand).
#[derive(Clone, Debug)]
pub struct TaskMix {
    pub patch_choices: Vec<usize>,
    pub patch_weights: Vec<f64>,
    pub num_models: usize,
    pub model_mix: ModelMix,
    pub quality_demand: QualityDemand,
    /// Precomputed unnormalised Zipf weights (empty unless `Zipf`).
    zipf_weights: Vec<f64>,
}

impl TaskMix {
    pub fn new(cfg: &EnvConfig, model_mix: ModelMix, quality_demand: QualityDemand) -> TaskMix {
        let zipf_weights = match &model_mix {
            ModelMix::Zipf { exponent } => (0..cfg.num_models)
                .map(|i| 1.0 / ((i + 1) as f64).powf(*exponent))
                .collect(),
            _ => Vec::new(),
        };
        TaskMix {
            patch_choices: cfg.patch_choices.clone(),
            patch_weights: cfg.patch_weights.clone(),
            num_models: cfg.num_models,
            model_mix,
            quality_demand,
            zipf_weights,
        }
    }

    /// The seed generator's mix: uniform models, no per-task demand.
    pub fn uniform(cfg: &EnvConfig) -> TaskMix {
        Self::new(cfg, ModelMix::Uniform, QualityDemand::Default)
    }

    /// Draw one task profile. Draw order is part of the replay contract:
    /// patches, then model, then quality demand.
    pub fn sample(&self, now: f64, rng: &mut Pcg64) -> MixSample {
        let patches = self.patch_choices[rng.categorical(&self.patch_weights)];
        let model = match &self.model_mix {
            ModelMix::Uniform => ModelType(rng.next_below(self.num_models as u64) as u32),
            ModelMix::Zipf { .. } => ModelType(rng.categorical(&self.zipf_weights) as u32),
            ModelMix::Rotating { hot_weight, period } => {
                if self.num_models <= 1 {
                    ModelType(0)
                } else {
                    // Allocation-free single draw (this sits on the 1M-task
                    // generation hot path): the first `hot_weight` of the
                    // unit interval selects the hot model, the rest maps
                    // uniformly onto the n-1 cold models.
                    let n = self.num_models;
                    let hot = ((now / period).floor() as u64 % n as u64) as usize;
                    let u = rng.next_f64();
                    let idx = if u < *hot_weight {
                        hot
                    } else {
                        let v = (u - hot_weight) / (1.0 - hot_weight);
                        let cold = ((v * (n - 1) as f64) as usize).min(n - 2);
                        if cold >= hot {
                            cold + 1
                        } else {
                            cold
                        }
                    };
                    ModelType(idx as u32)
                }
            }
        };
        let q_min = match &self.quality_demand {
            QualityDemand::Default => None,
            QualityDemand::Uniform { lo, hi } => Some(rng.uniform(*lo, *hi)),
            QualityDemand::TwoTier {
                strict_frac,
                strict_q,
                lax_q,
            } => Some(if rng.next_f64() < *strict_frac {
                *strict_q
            } else {
                *lax_q
            }),
        };
        MixSample {
            patches,
            model,
            q_min,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> EnvConfig {
        EnvConfig::default()
    }

    #[test]
    fn uniform_mix_covers_support() {
        let mix = TaskMix::uniform(&cfg());
        let mut rng = Pcg64::seeded(1);
        let mut seen_models = vec![false; mix.num_models];
        for _ in 0..1000 {
            let s = mix.sample(0.0, &mut rng);
            assert!(mix.patch_choices.contains(&s.patches));
            assert!((s.model.0 as usize) < mix.num_models);
            assert!(s.q_min.is_none());
            seen_models[s.model.0 as usize] = true;
        }
        assert!(seen_models.iter().all(|&b| b));
    }

    #[test]
    fn zipf_mix_skews_to_model_zero() {
        let mix = TaskMix::new(&cfg(), ModelMix::Zipf { exponent: 1.5 }, QualityDemand::Default);
        let mut rng = Pcg64::seeded(2);
        let mut counts = vec![0usize; mix.num_models];
        for _ in 0..10_000 {
            counts[mix.sample(0.0, &mut rng).model.0 as usize] += 1;
        }
        assert!(counts[0] > counts[1] && counts[1] > counts[2], "{counts:?}");
        // Model 0 weight 1 vs 1/2^1.5 vs 1/3^1.5 → >50% of traffic.
        assert!(counts[0] > 5_000, "{counts:?}");
    }

    #[test]
    fn rotating_mix_moves_the_hot_model() {
        let mix = TaskMix::new(
            &cfg(),
            ModelMix::Rotating {
                hot_weight: 0.9,
                period: 100.0,
            },
            QualityDemand::Default,
        );
        let mut rng = Pcg64::seeded(3);
        let hot_at = |t: f64, rng: &mut Pcg64| {
            let mut counts = vec![0usize; mix.num_models];
            for _ in 0..2_000 {
                counts[mix.sample(t, rng).model.0 as usize] += 1;
            }
            counts
                .iter()
                .enumerate()
                .max_by_key(|&(_, &c)| c)
                .map(|(i, _)| i)
                .unwrap()
        };
        assert_eq!(hot_at(10.0, &mut rng), 0);
        assert_eq!(hot_at(110.0, &mut rng), 1);
        assert_eq!(hot_at(210.0, &mut rng), 2);
        // Wraps around num_models (default 3).
        assert_eq!(hot_at(310.0, &mut rng), 0);
    }

    #[test]
    fn two_tier_demand_hits_fraction() {
        let mix = TaskMix::new(
            &cfg(),
            ModelMix::Uniform,
            QualityDemand::TwoTier {
                strict_frac: 0.25,
                strict_q: 0.26,
                lax_q: 0.18,
            },
        );
        let mut rng = Pcg64::seeded(4);
        let n = 20_000;
        let strict = (0..n)
            .filter(|_| mix.sample(0.0, &mut rng).q_min == Some(0.26))
            .count();
        let frac = strict as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.02, "strict frac {frac}");
    }

    #[test]
    fn uniform_demand_stays_in_range() {
        let mix = TaskMix::new(
            &cfg(),
            ModelMix::Uniform,
            QualityDemand::Uniform { lo: 0.2, hi: 0.26 },
        );
        let mut rng = Pcg64::seeded(5);
        for _ in 0..1_000 {
            let q = mix.sample(0.0, &mut rng).q_min.unwrap();
            assert!((0.2..0.26).contains(&q));
        }
    }
}
