//! CSV → JSONL trace importer: map real request logs onto workload trace
//! records (`eat trace import <csv> <out.jsonl>`).
//!
//! The first non-empty line is a header naming the columns (case
//! insensitive, common aliases accepted); fields are comma separated and
//! trimmed (no quoting — request logs exported for the simulator carry
//! only numeric/identifier columns). Recognised columns:
//!
//! | column | aliases | default |
//! |---|---|---|
//! | `arrival` (required) | `arrival_time`, `timestamp`, `time`, `t` | — |
//! | `patches` | `gang`, `workers`, `cooperate` | 1 |
//! | `model` | `model_id`, `service`, `checkpoint` | 0 |
//! | `q_min` | `qmin`, `quality_min` | none |
//! | `tenant` | `tenant_id`, `class` | none |
//! | `deadline` | `deadline_at` | none (absolute instant) |
//! | `slo` | `latency_slo`, `deadline_rel` | none (budget: deadline = arrival + slo) |
//! | `id` | `task_id` | row order |
//! | `prompt_id` | — | = id |
//! | `prompt` | — | hashed (FNV-1a) into `prompt_id` |
//!
//! Rows may arrive out of order; the importer normalises them through
//! `Workload::from_tasks` (stable sort by arrival), after which a written
//! trace round-trips bit-exactly through `workload::trace`.

use crate::sim::task::{ModelType, Task, Workload};

/// FNV-1a over the prompt text: deterministic prompt ids for logs that
/// carry free-text prompts instead of numeric ids.
fn fnv1a(text: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in text.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

struct Columns {
    arrival: usize,
    patches: Option<usize>,
    model: Option<usize>,
    q_min: Option<usize>,
    tenant: Option<usize>,
    deadline: Option<usize>,
    slo: Option<usize>,
    id: Option<usize>,
    prompt_id: Option<usize>,
    prompt: Option<usize>,
}

impl Columns {
    fn from_header(header: &str) -> anyhow::Result<Columns> {
        let cols: Vec<String> = header
            .split(',')
            .map(|c| c.trim().to_ascii_lowercase())
            .collect();
        let find = |names: &[&str]| cols.iter().position(|c| names.contains(&c.as_str()));
        let arrival = find(&["arrival", "arrival_time", "timestamp", "time", "t"])
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "csv header has no arrival column (looked for arrival/arrival_time/\
                     timestamp/time/t in: {header})"
                )
            })?;
        Ok(Columns {
            arrival,
            patches: find(&["patches", "gang", "workers", "cooperate"]),
            model: find(&["model", "model_id", "service", "checkpoint"]),
            q_min: find(&["q_min", "qmin", "quality_min"]),
            tenant: find(&["tenant", "tenant_id", "class"]),
            deadline: find(&["deadline", "deadline_at"]),
            slo: find(&["slo", "latency_slo", "deadline_rel"]),
            id: find(&["id", "task_id"]),
            prompt_id: find(&["prompt_id"]),
            prompt: find(&["prompt"]),
        })
    }
}

/// Non-empty field at `col`, if any.
fn field<'a>(fields: &[&'a str], col: Option<usize>) -> Option<&'a str> {
    fields
        .get(col?)
        .copied()
        .map(str::trim)
        .filter(|s| !s.is_empty())
}

/// Required numeric field with line context in errors.
fn req_num(fields: &[&str], col: usize, what: &str, lineno: usize) -> anyhow::Result<f64> {
    let s = field(fields, Some(col))
        .ok_or_else(|| anyhow::anyhow!("csv line {lineno}: missing '{what}' field"))?;
    s.parse::<f64>()
        .map_err(|e| anyhow::anyhow!("csv line {lineno}: bad '{what}': {e}"))
}

/// Optional numeric field with line context in errors.
fn opt_num(
    fields: &[&str],
    col: Option<usize>,
    what: &str,
    lineno: usize,
) -> anyhow::Result<Option<f64>> {
    match field(fields, col) {
        None => Ok(None),
        Some(s) => s
            .parse::<f64>()
            .map(Some)
            .map_err(|e| anyhow::anyhow!("csv line {lineno}: bad '{what}': {e}")),
    }
}

/// Parse a CSV request log into a workload (sorted by arrival).
pub fn parse_csv(text: &str) -> anyhow::Result<Workload> {
    let mut rows = text
        .lines()
        .enumerate()
        .filter(|(_, l)| !l.trim().is_empty());
    let (_, header) = rows
        .next()
        .ok_or_else(|| anyhow::anyhow!("csv is empty"))?;
    let cols = Columns::from_header(header)?;

    let mut tasks = Vec::new();
    for (idx, line) in rows {
        let lineno = idx + 1;
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();

        let arrival = req_num(&fields, cols.arrival, "arrival", lineno)?;
        anyhow::ensure!(
            arrival.is_finite() && arrival >= 0.0,
            "csv line {lineno}: arrival {arrival} must be finite and non-negative"
        );
        let patches = match opt_num(&fields, cols.patches, "patches", lineno)? {
            Some(p) => p as usize,
            None => 1,
        };
        anyhow::ensure!(
            matches!(patches, 1 | 2 | 4 | 8),
            "csv line {lineno}: patches must be one of 1/2/4/8, got {patches}"
        );
        let model = opt_num(&fields, cols.model, "model", lineno)?.map_or(0, |m| m as u32);
        let q_min = match opt_num(&fields, cols.q_min, "q_min", lineno)? {
            Some(q) => {
                anyhow::ensure!(
                    q.is_finite() && q > 0.0,
                    "csv line {lineno}: q_min {q} must be positive"
                );
                Some(q)
            }
            None => None,
        };
        let tenant = match field(&fields, cols.tenant) {
            Some(s) => Some(
                s.parse::<u32>()
                    .map_err(|e| anyhow::anyhow!("csv line {lineno}: bad 'tenant': {e}"))?,
            ),
            None => None,
        };
        // Absolute deadline wins over a relative SLO budget.
        let deadline = match (
            opt_num(&fields, cols.deadline, "deadline", lineno)?,
            opt_num(&fields, cols.slo, "slo", lineno)?,
        ) {
            (Some(d), _) => {
                anyhow::ensure!(
                    d.is_finite() && d >= arrival,
                    "csv line {lineno}: deadline {d} precedes arrival {arrival}"
                );
                Some(d)
            }
            (None, Some(slo)) => {
                anyhow::ensure!(
                    slo.is_finite() && slo > 0.0,
                    "csv line {lineno}: slo {slo} must be positive"
                );
                Some(arrival + slo)
            }
            (None, None) => None,
        };
        let id = match opt_num(&fields, cols.id, "id", lineno)? {
            Some(i) => i as u64,
            None => tasks.len() as u64,
        };
        let prompt_id = match (field(&fields, cols.prompt_id), field(&fields, cols.prompt)) {
            (Some(s), _) => s
                .parse::<u64>()
                .map_err(|e| anyhow::anyhow!("csv line {lineno}: bad 'prompt_id': {e}"))?,
            (None, Some(p)) => fnv1a(p),
            (None, None) => id,
        };
        tasks.push(Task {
            id,
            prompt_id,
            patches,
            model: ModelType(model),
            arrival,
            q_min,
            tenant,
            deadline,
        });
    }
    anyhow::ensure!(!tasks.is_empty(), "csv contains a header but no task rows");
    Ok(Workload::from_tasks(tasks))
}

/// Import a CSV request log and write it as a JSONL workload trace.
/// Returns the number of imported tasks.
pub fn import_file(csv_path: &str, out_path: &str) -> anyhow::Result<usize> {
    let text = std::fs::read_to_string(csv_path)
        .map_err(|e| anyhow::anyhow!("read csv '{csv_path}': {e}"))?;
    let w = parse_csv(&text)?;
    super::trace::write_file(&w, out_path)?;
    Ok(w.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace;

    const SAMPLE: &str = "\
arrival,patches,model,tenant,slo,q_min,prompt
0.5,2,1,0,60,0.24,a lighthouse at dawn
12.25,4,0,1,120,0.2,red panda portrait
3.0,1,2,,,,plain prompt
";

    #[test]
    fn csv_imports_sorts_and_maps_columns() {
        let w = parse_csv(SAMPLE).unwrap();
        assert_eq!(w.len(), 3);
        assert!(w.is_sorted());
        // Row at t=3.0 sorted between the others.
        assert_eq!(w.tasks[0].arrival, 0.5);
        assert_eq!(w.tasks[1].arrival, 3.0);
        assert_eq!(w.tasks[2].arrival, 12.25);
        let first = &w.tasks[0];
        assert_eq!(first.patches, 2);
        assert_eq!(first.model.0, 1);
        assert_eq!(first.tenant, Some(0));
        assert_eq!(first.deadline, Some(60.5));
        assert_eq!(first.q_min, Some(0.24));
        assert_eq!(first.prompt_id, fnv1a("a lighthouse at dawn"));
        let bare = &w.tasks[1];
        assert_eq!(bare.tenant, None);
        assert_eq!(bare.deadline, None);
        assert_eq!(bare.q_min, None);
    }

    #[test]
    fn csv_roundtrips_through_jsonl_trace() {
        let w = parse_csv(SAMPLE).unwrap();
        let back = trace::from_jsonl(&trace::to_jsonl(&w)).unwrap();
        assert_eq!(w.len(), back.len());
        for (a, b) in w.tasks.iter().zip(&back.tasks) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.prompt_id, b.prompt_id);
            assert_eq!(a.patches, b.patches);
            assert_eq!(a.model, b.model);
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
            assert_eq!(a.q_min.map(f64::to_bits), b.q_min.map(f64::to_bits));
            assert_eq!(a.tenant, b.tenant);
            assert_eq!(a.deadline.map(f64::to_bits), b.deadline.map(f64::to_bits));
        }
    }

    #[test]
    fn file_import_roundtrip() {
        let dir = std::env::temp_dir().join("eat_import_test");
        std::fs::create_dir_all(&dir).unwrap();
        let csv = dir.join("log.csv");
        let out = dir.join("log.jsonl");
        std::fs::write(&csv, SAMPLE).unwrap();
        let n = import_file(csv.to_str().unwrap(), out.to_str().unwrap()).unwrap();
        assert_eq!(n, 3);
        let replayed = trace::read_file(out.to_str().unwrap()).unwrap();
        assert_eq!(replayed.len(), 3);
        assert!(replayed.is_sorted());
        std::fs::remove_file(&csv).ok();
        std::fs::remove_file(&out).ok();
    }

    #[test]
    fn header_aliases_and_defaults() {
        let w = parse_csv("timestamp\n1.0\n2.0\n").unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(w.tasks[0].patches, 1);
        assert_eq!(w.tasks[0].model.0, 0);
        assert_eq!(w.tasks[0].prompt_id, w.tasks[0].id);
    }

    #[test]
    fn bad_rows_carry_line_numbers() {
        let err = parse_csv("arrival\nnot-a-number\n").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        let err = parse_csv("arrival,patches\n1.0,3\n").unwrap_err().to_string();
        assert!(err.contains("patches"), "{err}");
        let err = parse_csv("arrival,deadline\n5.0,1.0\n").unwrap_err().to_string();
        assert!(err.contains("precedes"), "{err}");
        assert!(parse_csv("nope\n1.0\n").is_err());
        assert!(parse_csv("").is_err());
        assert!(parse_csv("arrival\n").is_err());
    }
}
