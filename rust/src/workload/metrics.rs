//! Streaming per-episode metrics: a fixed-bucket latency histogram with
//! percentile queries, per-server busy-time utilization, and reload
//! counters.
//!
//! The seed reported only per-episode *means*, which hide exactly the tail
//! behaviour that QoS scheduling is about — a policy can improve the mean
//! while its p99 explodes under a flash crowd. Everything here is O(1) per
//! observation and mergeable across episodes, so `evaluate` can aggregate
//! percentile-grade numbers without storing every sample.

/// Fixed-width-bucket histogram over non-negative values.
///
/// `observe` clamps negatives to 0 and drops non-finite values; samples
/// beyond the last bucket land in an overflow bucket whose percentile
/// estimate is censored at the observed maximum. Percentiles interpolate
/// linearly inside a bucket and are clamped to the observed [min, max],
/// which makes the single-sample case exact.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    bucket_width: f64,
    counts: Vec<u64>,
    overflow: u64,
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl LatencyHistogram {
    pub fn new(bucket_width: f64, num_buckets: usize) -> Self {
        assert!(bucket_width > 0.0, "bucket width must be > 0");
        assert!(num_buckets >= 1, "need at least one bucket");
        LatencyHistogram {
            bucket_width,
            counts: vec![0; num_buckets],
            overflow: 0,
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Default for response latencies in seconds: 0.5 s resolution out to
    /// 2048 s, past the longest episode the presets can produce.
    pub fn default_latency() -> Self {
        Self::new(0.5, 4096)
    }

    pub fn observe(&mut self, x: f64) {
        if !x.is_finite() {
            return;
        }
        let x = x.max(0.0);
        self.total += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        let idx = (x / self.bucket_width) as usize;
        if idx < self.counts.len() {
            self.counts[idx] += 1;
        } else {
            self.overflow += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.sum / self.total as f64
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Quantile estimate for q ∈ [0, 1]; `None` when no samples recorded.
    pub fn percentile(&self, q: f64) -> Option<f64> {
        assert!((0.0..=1.0).contains(&q), "quantile {q} outside [0,1]");
        if self.total == 0 {
            return None;
        }
        // Rank of the q-th sample, 1-based; q = 0 maps to the first.
        let target = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let prev = cum;
            cum += c;
            if cum >= target {
                let lo = i as f64 * self.bucket_width;
                let frac = (target - prev) as f64 / c as f64;
                let est = lo + frac * self.bucket_width;
                return Some(est.clamp(self.min, self.max));
            }
        }
        // Rank fell into the overflow bucket: censor at the observed max.
        Some(self.max)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(0.5).unwrap_or(f64::NAN)
    }

    pub fn p90(&self) -> f64 {
        self.percentile(0.9).unwrap_or(f64::NAN)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(0.99).unwrap_or(f64::NAN)
    }

    pub fn bucket_width(&self) -> f64 {
        self.bucket_width
    }

    /// Per-bucket counts (bucket `i` covers `[i*w, (i+1)*w)`); overflow
    /// samples beyond the last bucket are in [`overflow`](Self::overflow).
    pub fn bucket_counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    /// Sum of all observed values (the Prometheus `_sum` series).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Merge another histogram with identical bucket configuration.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        assert_eq!(self.bucket_width, other.bucket_width, "bucket width mismatch");
        assert_eq!(self.counts.len(), other.counts.len(), "bucket count mismatch");
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.overflow += other.overflow;
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Per-tenant streaming statistics: a latency histogram plus the QoS
/// counters SLO attainment and drop rate derive from.
#[derive(Clone, Debug)]
pub struct TenantStats {
    pub name: String,
    pub tier: u8,
    pub weight: f64,
    pub latency: LatencyHistogram,
    /// Tasks that arrived (admitted or not).
    pub offered: u64,
    /// Tasks rejected by admission control.
    pub dropped: u64,
    /// Tasks scheduled to completion.
    pub completed: u64,
    /// Completed tasks whose response met their deadline.
    pub slo_met: u64,
}

impl TenantStats {
    fn new(name: &str, tier: u8, weight: f64) -> Self {
        TenantStats {
            name: name.to_string(),
            tier,
            weight,
            latency: LatencyHistogram::default_latency(),
            offered: 0,
            dropped: 0,
            completed: 0,
            slo_met: 0,
        }
    }

    fn merge(&mut self, other: &TenantStats) {
        self.latency.merge(&other.latency);
        self.offered += other.offered;
        self.dropped += other.dropped;
        self.completed += other.completed;
        self.slo_met += other.slo_met;
    }
}

/// Derived per-tenant QoS summary (per episode and pooled across
/// episodes): SLO attainment counts dropped and never-scheduled tasks as
/// misses, so shedding a tenant's load cannot inflate its attainment.
#[derive(Clone, Debug)]
pub struct TenantReport {
    pub name: String,
    pub tier: u8,
    pub weight: f64,
    pub offered: u64,
    pub completed: u64,
    pub dropped: u64,
    pub slo_met: u64,
    /// slo_met / offered (0 when nothing was offered).
    pub slo_attainment: f64,
    /// dropped / offered (0 when nothing was offered).
    pub drop_rate: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

/// Streaming collector fed by the simulator (`EdgeEnv`) and the serving
/// host: response/waiting latency histograms, per-server busy time,
/// model-reload counters, admission-drop/deferral counters, and (when a
/// tenant registry is configured) per-tenant QoS statistics.
#[derive(Clone, Debug)]
pub struct MetricsCollector {
    pub latency: LatencyHistogram,
    pub waiting: LatencyHistogram,
    busy: Vec<f64>,
    sim_time: f64,
    reloads: u64,
    completed: u64,
    offered: u64,
    admission_dropped: u64,
    deferred: u64,
    tenants: Vec<TenantStats>,
    // --- fault-subsystem counters (all zero when faults are disabled) ---
    failures: u64,
    gang_kills: u64,
    retries: u64,
    /// down→up worker transitions observed by the serving health registry
    /// (always 0 in the simulator, which books recovery via MTTR instead).
    recoveries: u64,
    task_failures: u64,
    spec_launches: u64,
    spec_wins: u64,
    /// Patch-second accounting: nominal work dispatched / completed /
    /// wasted (killed gangs and speculative losers).
    dispatched_ps: f64,
    completed_ps: f64,
    wasted_ps: f64,
}

impl MetricsCollector {
    pub fn new(num_servers: usize) -> Self {
        MetricsCollector {
            latency: LatencyHistogram::default_latency(),
            waiting: LatencyHistogram::default_latency(),
            busy: vec![0.0; num_servers],
            sim_time: 0.0,
            reloads: 0,
            completed: 0,
            offered: 0,
            admission_dropped: 0,
            deferred: 0,
            tenants: Vec::new(),
            failures: 0,
            gang_kills: 0,
            retries: 0,
            recoveries: 0,
            task_failures: 0,
            spec_launches: 0,
            spec_wins: 0,
            dispatched_ps: 0.0,
            completed_ps: 0.0,
            wasted_ps: 0.0,
        }
    }

    /// A collector with per-tenant statistics enabled for every tenant in
    /// the registry. Collectors merge only with same-shaped collectors.
    pub fn with_tenants(num_servers: usize, registry: &crate::qos::TenantRegistry) -> Self {
        let mut m = Self::new(num_servers);
        m.tenants = (0..registry.num_tenants())
            .map(|i| {
                let t = registry.tenant(i);
                TenantStats::new(&t.name, t.tier, t.weight)
            })
            .collect();
        m
    }

    /// Record one completed (scheduled) task.
    pub fn observe_task(&mut self, response: f64, waiting: f64, reloaded: bool) {
        self.latency.observe(response);
        self.waiting.observe(waiting);
        self.completed += 1;
        if reloaded {
            self.reloads += 1;
        }
    }

    fn tenant_mut(&mut self, tenant: Option<u32>) -> Option<&mut TenantStats> {
        self.tenants.get_mut(tenant? as usize)
    }

    /// Record one arrival (before the admission decision).
    pub fn observe_offered(&mut self, tenant: Option<u32>) {
        self.offered += 1;
        if let Some(t) = self.tenant_mut(tenant) {
            t.offered += 1;
        }
    }

    /// Record one arrival rejected by admission control.
    pub fn observe_drop(&mut self, tenant: Option<u32>) {
        self.admission_dropped += 1;
        if let Some(t) = self.tenant_mut(tenant) {
            t.dropped += 1;
        }
    }

    /// Record one dispatch skipped as infeasible (deferred, not vanished).
    pub fn observe_deferred(&mut self) {
        self.deferred += 1;
    }

    // --- fault subsystem -------------------------------------------------

    /// One server failure event (independent churn or zone shock).
    pub fn observe_failure(&mut self) {
        self.failures += 1;
    }

    /// One in-flight gang killed; its nominal work is wasted.
    pub fn observe_gang_kill(&mut self, wasted_patch_s: f64) {
        self.gang_kills += 1;
        self.wasted_ps += wasted_patch_s;
    }

    /// Wasted work without a kill (a speculative loser's attempt).
    pub fn observe_wasted_work(&mut self, wasted_patch_s: f64) {
        self.wasted_ps += wasted_patch_s;
    }

    /// A killed task re-queued for another attempt.
    pub fn observe_retry(&mut self) {
        self.retries += 1;
    }

    /// Workers observed coming back up (serving health registry).
    pub fn observe_recoveries(&mut self, n: u64) {
        self.recoveries += n;
    }

    /// A task dropped after exhausting its retry budget.
    pub fn observe_task_failure(&mut self) {
        self.task_failures += 1;
    }

    pub fn observe_spec_launch(&mut self) {
        self.spec_launches += 1;
    }

    pub fn observe_spec_win(&mut self) {
        self.spec_wins += 1;
    }

    /// Nominal patch-seconds handed to servers at dispatch.
    pub fn observe_dispatched_work(&mut self, patch_s: f64) {
        self.dispatched_ps += patch_s;
    }

    /// Nominal patch-seconds credited on actual completion.
    pub fn observe_completed_work(&mut self, patch_s: f64) {
        self.completed_ps += patch_s;
    }

    pub fn failures(&self) -> u64 {
        self.failures
    }

    pub fn gang_kills(&self) -> u64 {
        self.gang_kills
    }

    pub fn retries(&self) -> u64 {
        self.retries
    }

    pub fn recoveries(&self) -> u64 {
        self.recoveries
    }

    pub fn task_failures(&self) -> u64 {
        self.task_failures
    }

    pub fn spec_launches(&self) -> u64 {
        self.spec_launches
    }

    pub fn spec_wins(&self) -> u64 {
        self.spec_wins
    }

    pub fn dispatched_ps(&self) -> f64 {
        self.dispatched_ps
    }

    pub fn completed_ps(&self) -> f64 {
        self.completed_ps
    }

    pub fn wasted_ps(&self) -> f64 {
        self.wasted_ps
    }

    /// Wasted / dispatched patch-seconds (0 before any dispatch).
    pub fn wasted_frac(&self) -> f64 {
        if self.dispatched_ps > 0.0 {
            self.wasted_ps / self.dispatched_ps
        } else {
            0.0
        }
    }

    /// Record a completed task against its tenant's SLO. `deadline_met` is
    /// `None` for tasks without a deadline (counted as met).
    pub fn observe_tenant_task(
        &mut self,
        tenant: Option<u32>,
        response: f64,
        deadline_met: Option<bool>,
    ) {
        if let Some(t) = self.tenant_mut(tenant) {
            t.completed += 1;
            t.latency.observe(response);
            if deadline_met.unwrap_or(true) {
                t.slo_met += 1;
            }
        }
    }

    pub fn offered(&self) -> u64 {
        self.offered
    }

    pub fn admission_dropped(&self) -> u64 {
        self.admission_dropped
    }

    pub fn deferred(&self) -> u64 {
        self.deferred
    }

    pub fn tenant_stats(&self) -> &[TenantStats] {
        &self.tenants
    }

    /// Derived per-tenant QoS reports (empty unless tenants are enabled).
    pub fn tenant_reports(&self) -> Vec<TenantReport> {
        self.tenants
            .iter()
            .map(|t| {
                let offered = t.offered.max(1) as f64;
                TenantReport {
                    name: t.name.clone(),
                    tier: t.tier,
                    weight: t.weight,
                    offered: t.offered,
                    completed: t.completed,
                    dropped: t.dropped,
                    slo_met: t.slo_met,
                    slo_attainment: t.slo_met as f64 / offered,
                    drop_rate: t.dropped as f64 / offered,
                    p50: t.latency.p50(),
                    p90: t.latency.p90(),
                    p99: t.latency.p99(),
                }
            })
            .collect()
    }

    /// Credit `dt` seconds of busy time to one server.
    pub fn observe_busy(&mut self, server: usize, dt: f64) {
        if let Some(b) = self.busy.get_mut(server) {
            *b += dt;
        }
    }

    /// Advance the utilization denominator.
    pub fn advance_time(&mut self, dt: f64) {
        self.sim_time += dt;
    }

    pub fn sim_time(&self) -> f64 {
        self.sim_time
    }

    pub fn completed(&self) -> u64 {
        self.completed
    }

    pub fn reloads(&self) -> u64 {
        self.reloads
    }

    /// Per-server utilization in [0, 1] (0 before any time has passed).
    pub fn utilization(&self) -> Vec<f64> {
        if self.sim_time <= 0.0 {
            return vec![0.0; self.busy.len()];
        }
        self.busy
            .iter()
            .map(|b| (b / self.sim_time).clamp(0.0, 1.0))
            .collect()
    }

    pub fn avg_utilization(&self) -> f64 {
        let u = self.utilization();
        if u.is_empty() {
            0.0
        } else {
            u.iter().sum::<f64>() / u.len() as f64
        }
    }

    /// Merge a same-shape collector (cross-episode aggregation).
    pub fn merge(&mut self, other: &MetricsCollector) {
        assert_eq!(self.busy.len(), other.busy.len(), "server count mismatch");
        assert_eq!(self.tenants.len(), other.tenants.len(), "tenant shape mismatch");
        self.latency.merge(&other.latency);
        self.waiting.merge(&other.waiting);
        for (a, b) in self.busy.iter_mut().zip(&other.busy) {
            *a += b;
        }
        self.sim_time += other.sim_time;
        self.reloads += other.reloads;
        self.completed += other.completed;
        self.offered += other.offered;
        self.admission_dropped += other.admission_dropped;
        self.deferred += other.deferred;
        self.failures += other.failures;
        self.gang_kills += other.gang_kills;
        self.retries += other.retries;
        self.recoveries += other.recoveries;
        self.task_failures += other.task_failures;
        self.spec_launches += other.spec_launches;
        self.spec_wins += other.spec_wins;
        self.dispatched_ps += other.dispatched_ps;
        self.completed_ps += other.completed_ps;
        self.wasted_ps += other.wasted_ps;
        for (a, b) in self.tenants.iter_mut().zip(&other.tenants) {
            a.merge(b);
        }
    }

    /// One-line human summary (serving CLI and scenario sweep footer).
    pub fn summary_line(&self) -> String {
        let mut line = format!(
            "completed {}  p50 {:.1}s  p90 {:.1}s  p99 {:.1}s  util {:.3}  reloads {}  \
             dropped {}  deferred {}",
            self.completed,
            self.latency.p50(),
            self.latency.p90(),
            self.latency.p99(),
            self.avg_utilization(),
            self.reloads,
            self.admission_dropped,
            self.deferred
        );
        if self.failures > 0 || self.recoveries > 0 || self.wasted_ps > 0.0 {
            line.push_str(&format!(
                "  failures {}  retries {}  recoveries {}  wasted {:.1}%",
                self.failures,
                self.retries,
                self.recoveries,
                self.wasted_frac() * 100.0
            ));
        }
        line
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_has_no_percentiles() {
        let h = LatencyHistogram::new(1.0, 16);
        assert!(h.percentile(0.5).is_none());
        assert!(h.p50().is_nan());
        assert!(h.mean().is_nan());
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn single_sample_is_exact() {
        let mut h = LatencyHistogram::new(0.5, 64);
        h.observe(3.2);
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(h.percentile(q), Some(3.2));
        }
        assert_eq!(h.mean(), 3.2);
    }

    #[test]
    fn overflow_censors_at_max() {
        let mut h = LatencyHistogram::new(1.0, 4); // covers [0, 4)
        h.observe(1.5);
        h.observe(100.0);
        h.observe(250.0);
        assert_eq!(h.percentile(1.0), Some(250.0));
        assert_eq!(h.percentile(0.99), Some(250.0));
        // p0 must still resolve inside the real buckets.
        let p0 = h.percentile(0.0).unwrap();
        assert!((1.0..=2.0).contains(&p0), "p0 {p0}");
    }

    #[test]
    fn percentiles_are_monotone_and_bracketed() {
        let mut h = LatencyHistogram::new(0.25, 1024);
        // Two full sweeps over [0, 180): near-uniform coverage.
        for i in 0..5_000 {
            h.observe((i as f64 * 0.072) % 180.0);
        }
        let (p50, p90, p99) = (h.p50(), h.p90(), h.p99());
        assert!(p50 <= p90 && p90 <= p99, "{p50} {p90} {p99}");
        assert!(p50 >= h.min() && p99 <= h.max());
        // Near-uniform over [0, 180): p50 ≈ 90, p90 ≈ 162.
        assert!((p50 - 90.0).abs() < 2.0, "p50 {p50}");
        assert!((p90 - 162.0).abs() < 2.0, "p90 {p90}");
    }

    #[test]
    fn negative_and_nonfinite_inputs_are_sanitised() {
        let mut h = LatencyHistogram::new(1.0, 8);
        h.observe(-3.0); // clamped to 0
        h.observe(f64::NAN); // dropped
        h.observe(f64::INFINITY); // dropped
        assert_eq!(h.count(), 1);
        assert_eq!(h.percentile(0.5), Some(0.0));
    }

    #[test]
    fn merge_matches_sequential_observation() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64 * 7.31) % 50.0).collect();
        let mut all = LatencyHistogram::new(0.5, 128);
        let mut a = LatencyHistogram::new(0.5, 128);
        let mut b = LatencyHistogram::new(0.5, 128);
        for (i, &x) in xs.iter().enumerate() {
            all.observe(x);
            if i % 2 == 0 {
                a.observe(x);
            } else {
                b.observe(x);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(a.percentile(q), all.percentile(q));
        }
    }

    #[test]
    fn sharded_merge_is_bit_identical_to_concatenated_stream() {
        use crate::util::rng::Pcg64;
        // Percentiles derive from bucket counts (u64, additive) plus
        // min/max (associative), so a merge of unevenly-sized shards must
        // reproduce the concatenated-stream collector bit-for-bit — the
        // invariant that lets `evaluate` and the sharded sweeps pool
        // per-episode histograms without storing samples.
        let mut rng = Pcg64::new(19, 0x5EED);
        let sizes = [311usize, 7, 1024, 95];
        let mut whole = MetricsCollector::new(3);
        let mut merged = MetricsCollector::new(3);
        for &n in &sizes {
            let mut shard = MetricsCollector::new(3);
            for _ in 0..n {
                // Spread into the overflow bucket too (>2048 s).
                let resp = rng.next_f64() * 2500.0;
                let wait = rng.next_f64() * 50.0;
                let reload = rng.next_f64() < 0.3;
                whole.observe_task(resp, wait, reload);
                shard.observe_task(resp, wait, reload);
            }
            merged.merge(&shard);
        }
        assert_eq!(merged.completed(), whole.completed());
        assert_eq!(merged.reloads(), whole.reloads());
        assert_eq!(merged.latency.overflow(), whole.latency.overflow());
        let pairs = [(&merged.latency, &whole.latency), (&merged.waiting, &whole.waiting)];
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 0.999, 1.0] {
            for (hm, hw) in pairs {
                let a = hm.percentile(q);
                let b = hw.percentile(q);
                assert_eq!(
                    a.map(f64::to_bits),
                    b.map(f64::to_bits),
                    "q={q}: merged {a:?} vs concatenated {b:?}"
                );
            }
        }
    }

    #[test]
    fn fault_counters_are_additive_under_sharded_sweeps() {
        use crate::util::par;
        // Shard collectors are built on `par::map_cells` worker threads,
        // exactly as `faults::sweep_threaded` farms out cells; the pooled
        // counters must equal the per-shard sums regardless of threading.
        let shards = par::map_cells(vec![3u64, 5, 7, 11], 3, |n| {
            let mut m = MetricsCollector::new(2);
            for i in 0..n {
                m.observe_failure();
                m.observe_retry();
                m.observe_dispatched_work(2.0 * i as f64);
                if i % 2 == 0 {
                    m.observe_gang_kill(i as f64);
                }
            }
            m
        });
        let mut pooled = MetricsCollector::new(2);
        for s in &shards {
            pooled.merge(s);
        }
        assert_eq!(pooled.failures(), 26);
        assert_eq!(pooled.retries(), 26);
        assert_eq!(pooled.gang_kills(), 2 + 3 + 4 + 6);
        // Small integers: exactly representable, so sums are exact.
        assert_eq!(pooled.dispatched_ps(), 6.0 + 20.0 + 42.0 + 110.0);
        assert_eq!(pooled.wasted_ps(), 2.0 + 6.0 + 12.0 + 30.0);
    }

    #[test]
    fn collector_utilization_and_reloads() {
        let mut m = MetricsCollector::new(2);
        m.advance_time(10.0);
        m.observe_busy(0, 5.0);
        m.observe_busy(1, 10.0);
        m.observe_busy(7, 99.0); // out of range: ignored
        let u = m.utilization();
        assert_eq!(u, vec![0.5, 1.0]);
        assert!((m.avg_utilization() - 0.75).abs() < 1e-12);
        m.observe_task(12.0, 2.0, true);
        m.observe_task(8.0, 0.0, false);
        assert_eq!(m.completed(), 2);
        assert_eq!(m.reloads(), 1);
        assert!(m.summary_line().contains("completed 2"));
    }

    #[test]
    fn tenant_stats_attainment_and_drop_rate() {
        use crate::qos::{TenantRegistry, TenantsConfig};
        let reg = TenantRegistry::new(&TenantsConfig::three_tier(0.3));
        let mut m = MetricsCollector::with_tenants(2, &reg);
        // Premium: 3 offered, 2 completed in-SLO, 1 dropped.
        for _ in 0..3 {
            m.observe_offered(Some(0));
        }
        m.observe_drop(Some(0));
        m.observe_tenant_task(Some(0), 10.0, Some(true));
        m.observe_tenant_task(Some(0), 50.0, Some(true));
        // Batch: 2 offered, 1 completed late.
        m.observe_offered(Some(2));
        m.observe_offered(Some(2));
        m.observe_tenant_task(Some(2), 400.0, Some(false));
        // Untenanted observations only touch the global counters.
        m.observe_offered(None);
        m.observe_drop(None);
        m.observe_deferred();
        let reports = m.tenant_reports();
        assert_eq!(reports.len(), 3);
        let premium = &reports[0];
        assert_eq!(premium.name, "premium");
        assert_eq!(premium.offered, 3);
        assert!((premium.slo_attainment - 2.0 / 3.0).abs() < 1e-12);
        assert!((premium.drop_rate - 1.0 / 3.0).abs() < 1e-12);
        let batch = &reports[2];
        assert_eq!(batch.completed, 1);
        assert_eq!(batch.slo_met, 0);
        assert_eq!(batch.slo_attainment, 0.0);
        assert_eq!(m.offered(), 6);
        assert_eq!(m.admission_dropped(), 2);
        assert_eq!(m.deferred(), 1);
        assert!(m.summary_line().contains("deferred 1"));

        // Merging doubles every tenant counter.
        let other = m.clone();
        m.merge(&other);
        let reports = m.tenant_reports();
        assert_eq!(reports[0].offered, 6);
        assert_eq!(reports[0].slo_met, 4);
        assert!((reports[0].slo_attainment - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn fault_counters_accumulate_and_merge() {
        let mut m = MetricsCollector::new(2);
        m.observe_dispatched_work(100.0);
        m.observe_failure();
        m.observe_gang_kill(40.0);
        m.observe_retry();
        m.observe_dispatched_work(60.0);
        m.observe_completed_work(60.0);
        m.observe_spec_launch();
        m.observe_spec_win();
        m.observe_wasted_work(10.0);
        m.observe_task_failure();
        m.observe_recoveries(2);
        assert_eq!(m.failures(), 1);
        assert_eq!(m.gang_kills(), 1);
        assert_eq!(m.retries(), 1);
        assert_eq!(m.recoveries(), 2);
        assert_eq!(m.task_failures(), 1);
        assert_eq!(m.spec_launches(), 1);
        assert_eq!(m.spec_wins(), 1);
        assert_eq!(m.dispatched_ps(), 160.0);
        assert_eq!(m.completed_ps(), 60.0);
        assert_eq!(m.wasted_ps(), 50.0);
        assert!((m.wasted_frac() - 50.0 / 160.0).abs() < 1e-12);
        let line = m.summary_line();
        assert!(line.contains("failures 1"), "{line}");
        assert!(line.contains("recoveries 2"), "{line}");
        assert!(line.contains("wasted 31.2%") || line.contains("wasted 31.3%"), "{line}");
        // Merging doubles everything; a fault-free collector stays silent.
        let other = m.clone();
        m.merge(&other);
        assert_eq!(m.failures(), 2);
        assert_eq!(m.recoveries(), 4);
        assert_eq!(m.dispatched_ps(), 320.0);
        assert!((m.wasted_frac() - 100.0 / 320.0).abs() < 1e-12);
        let clean = MetricsCollector::new(2);
        assert!(!clean.summary_line().contains("failures"));
        assert_eq!(clean.wasted_frac(), 0.0);
    }

    #[test]
    #[should_panic]
    fn merge_rejects_tenant_shape_mismatch() {
        use crate::qos::{TenantRegistry, TenantsConfig};
        let reg = TenantRegistry::new(&TenantsConfig::three_tier(0.3));
        let mut a = MetricsCollector::with_tenants(2, &reg);
        let b = MetricsCollector::new(2);
        a.merge(&b);
    }

    #[test]
    fn collector_merge_adds_busy_time() {
        let mut a = MetricsCollector::new(2);
        a.advance_time(10.0);
        a.observe_busy(0, 4.0);
        let mut b = MetricsCollector::new(2);
        b.advance_time(10.0);
        b.observe_busy(0, 6.0);
        b.observe_task(3.0, 1.0, true);
        a.merge(&b);
        assert_eq!(a.utilization()[0], 0.5);
        assert_eq!(a.reloads(), 1);
        assert_eq!(a.latency.count(), 1);
    }
}
