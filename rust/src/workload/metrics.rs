//! Streaming per-episode metrics: a fixed-bucket latency histogram with
//! percentile queries, per-server busy-time utilization, and reload
//! counters.
//!
//! The seed reported only per-episode *means*, which hide exactly the tail
//! behaviour that QoS scheduling is about — a policy can improve the mean
//! while its p99 explodes under a flash crowd. Everything here is O(1) per
//! observation and mergeable across episodes, so `evaluate` can aggregate
//! percentile-grade numbers without storing every sample.

/// Fixed-width-bucket histogram over non-negative values.
///
/// `observe` clamps negatives to 0 and drops non-finite values; samples
/// beyond the last bucket land in an overflow bucket whose percentile
/// estimate is censored at the observed maximum. Percentiles interpolate
/// linearly inside a bucket and are clamped to the observed [min, max],
/// which makes the single-sample case exact.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    bucket_width: f64,
    counts: Vec<u64>,
    overflow: u64,
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl LatencyHistogram {
    pub fn new(bucket_width: f64, num_buckets: usize) -> Self {
        assert!(bucket_width > 0.0, "bucket width must be > 0");
        assert!(num_buckets >= 1, "need at least one bucket");
        LatencyHistogram {
            bucket_width,
            counts: vec![0; num_buckets],
            overflow: 0,
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Default for response latencies in seconds: 0.5 s resolution out to
    /// 2048 s, past the longest episode the presets can produce.
    pub fn default_latency() -> Self {
        Self::new(0.5, 4096)
    }

    pub fn observe(&mut self, x: f64) {
        if !x.is_finite() {
            return;
        }
        let x = x.max(0.0);
        self.total += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        let idx = (x / self.bucket_width) as usize;
        if idx < self.counts.len() {
            self.counts[idx] += 1;
        } else {
            self.overflow += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.sum / self.total as f64
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Quantile estimate for q ∈ [0, 1]; `None` when no samples recorded.
    pub fn percentile(&self, q: f64) -> Option<f64> {
        assert!((0.0..=1.0).contains(&q), "quantile {q} outside [0,1]");
        if self.total == 0 {
            return None;
        }
        // Rank of the q-th sample, 1-based; q = 0 maps to the first.
        let target = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let prev = cum;
            cum += c;
            if cum >= target {
                let lo = i as f64 * self.bucket_width;
                let frac = (target - prev) as f64 / c as f64;
                let est = lo + frac * self.bucket_width;
                return Some(est.clamp(self.min, self.max));
            }
        }
        // Rank fell into the overflow bucket: censor at the observed max.
        Some(self.max)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(0.5).unwrap_or(f64::NAN)
    }

    pub fn p90(&self) -> f64 {
        self.percentile(0.9).unwrap_or(f64::NAN)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(0.99).unwrap_or(f64::NAN)
    }

    /// Merge another histogram with identical bucket configuration.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        assert_eq!(self.bucket_width, other.bucket_width, "bucket width mismatch");
        assert_eq!(self.counts.len(), other.counts.len(), "bucket count mismatch");
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.overflow += other.overflow;
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Streaming collector fed by the simulator (`EdgeEnv`) and the serving
/// host: response/waiting latency histograms, per-server busy time, and
/// model-reload counters.
#[derive(Clone, Debug)]
pub struct MetricsCollector {
    pub latency: LatencyHistogram,
    pub waiting: LatencyHistogram,
    busy: Vec<f64>,
    sim_time: f64,
    reloads: u64,
    completed: u64,
}

impl MetricsCollector {
    pub fn new(num_servers: usize) -> Self {
        MetricsCollector {
            latency: LatencyHistogram::default_latency(),
            waiting: LatencyHistogram::default_latency(),
            busy: vec![0.0; num_servers],
            sim_time: 0.0,
            reloads: 0,
            completed: 0,
        }
    }

    /// Record one completed (scheduled) task.
    pub fn observe_task(&mut self, response: f64, waiting: f64, reloaded: bool) {
        self.latency.observe(response);
        self.waiting.observe(waiting);
        self.completed += 1;
        if reloaded {
            self.reloads += 1;
        }
    }

    /// Credit `dt` seconds of busy time to one server.
    pub fn observe_busy(&mut self, server: usize, dt: f64) {
        if let Some(b) = self.busy.get_mut(server) {
            *b += dt;
        }
    }

    /// Advance the utilization denominator.
    pub fn advance_time(&mut self, dt: f64) {
        self.sim_time += dt;
    }

    pub fn sim_time(&self) -> f64 {
        self.sim_time
    }

    pub fn completed(&self) -> u64 {
        self.completed
    }

    pub fn reloads(&self) -> u64 {
        self.reloads
    }

    /// Per-server utilization in [0, 1] (0 before any time has passed).
    pub fn utilization(&self) -> Vec<f64> {
        if self.sim_time <= 0.0 {
            return vec![0.0; self.busy.len()];
        }
        self.busy
            .iter()
            .map(|b| (b / self.sim_time).clamp(0.0, 1.0))
            .collect()
    }

    pub fn avg_utilization(&self) -> f64 {
        let u = self.utilization();
        if u.is_empty() {
            0.0
        } else {
            u.iter().sum::<f64>() / u.len() as f64
        }
    }

    /// Merge a same-shape collector (cross-episode aggregation).
    pub fn merge(&mut self, other: &MetricsCollector) {
        assert_eq!(self.busy.len(), other.busy.len(), "server count mismatch");
        self.latency.merge(&other.latency);
        self.waiting.merge(&other.waiting);
        for (a, b) in self.busy.iter_mut().zip(&other.busy) {
            *a += b;
        }
        self.sim_time += other.sim_time;
        self.reloads += other.reloads;
        self.completed += other.completed;
    }

    /// One-line human summary (serving CLI and scenario sweep footer).
    pub fn summary_line(&self) -> String {
        format!(
            "completed {}  p50 {:.1}s  p90 {:.1}s  p99 {:.1}s  util {:.3}  reloads {}",
            self.completed,
            self.latency.p50(),
            self.latency.p90(),
            self.latency.p99(),
            self.avg_utilization(),
            self.reloads
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_has_no_percentiles() {
        let h = LatencyHistogram::new(1.0, 16);
        assert!(h.percentile(0.5).is_none());
        assert!(h.p50().is_nan());
        assert!(h.mean().is_nan());
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn single_sample_is_exact() {
        let mut h = LatencyHistogram::new(0.5, 64);
        h.observe(3.2);
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(h.percentile(q), Some(3.2));
        }
        assert_eq!(h.mean(), 3.2);
    }

    #[test]
    fn overflow_censors_at_max() {
        let mut h = LatencyHistogram::new(1.0, 4); // covers [0, 4)
        h.observe(1.5);
        h.observe(100.0);
        h.observe(250.0);
        assert_eq!(h.percentile(1.0), Some(250.0));
        assert_eq!(h.percentile(0.99), Some(250.0));
        // p0 must still resolve inside the real buckets.
        let p0 = h.percentile(0.0).unwrap();
        assert!((1.0..=2.0).contains(&p0), "p0 {p0}");
    }

    #[test]
    fn percentiles_are_monotone_and_bracketed() {
        let mut h = LatencyHistogram::new(0.25, 1024);
        // Two full sweeps over [0, 180): near-uniform coverage.
        for i in 0..5_000 {
            h.observe((i as f64 * 0.072) % 180.0);
        }
        let (p50, p90, p99) = (h.p50(), h.p90(), h.p99());
        assert!(p50 <= p90 && p90 <= p99, "{p50} {p90} {p99}");
        assert!(p50 >= h.min() && p99 <= h.max());
        // Near-uniform over [0, 180): p50 ≈ 90, p90 ≈ 162.
        assert!((p50 - 90.0).abs() < 2.0, "p50 {p50}");
        assert!((p90 - 162.0).abs() < 2.0, "p90 {p90}");
    }

    #[test]
    fn negative_and_nonfinite_inputs_are_sanitised() {
        let mut h = LatencyHistogram::new(1.0, 8);
        h.observe(-3.0); // clamped to 0
        h.observe(f64::NAN); // dropped
        h.observe(f64::INFINITY); // dropped
        assert_eq!(h.count(), 1);
        assert_eq!(h.percentile(0.5), Some(0.0));
    }

    #[test]
    fn merge_matches_sequential_observation() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64 * 7.31) % 50.0).collect();
        let mut all = LatencyHistogram::new(0.5, 128);
        let mut a = LatencyHistogram::new(0.5, 128);
        let mut b = LatencyHistogram::new(0.5, 128);
        for (i, &x) in xs.iter().enumerate() {
            all.observe(x);
            if i % 2 == 0 {
                a.observe(x);
            } else {
                b.observe(x);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(a.percentile(q), all.percentile(q));
        }
    }

    #[test]
    fn collector_utilization_and_reloads() {
        let mut m = MetricsCollector::new(2);
        m.advance_time(10.0);
        m.observe_busy(0, 5.0);
        m.observe_busy(1, 10.0);
        m.observe_busy(7, 99.0); // out of range: ignored
        let u = m.utilization();
        assert_eq!(u, vec![0.5, 1.0]);
        assert!((m.avg_utilization() - 0.75).abs() < 1e-12);
        m.observe_task(12.0, 2.0, true);
        m.observe_task(8.0, 0.0, false);
        assert_eq!(m.completed(), 2);
        assert_eq!(m.reloads(), 1);
        assert!(m.summary_line().contains("completed 2"));
    }

    #[test]
    fn collector_merge_adds_busy_time() {
        let mut a = MetricsCollector::new(2);
        a.advance_time(10.0);
        a.observe_busy(0, 4.0);
        let mut b = MetricsCollector::new(2);
        b.advance_time(10.0);
        b.observe_busy(0, 6.0);
        b.observe_task(3.0, 1.0, true);
        a.merge(&b);
        assert_eq!(a.utilization()[0], 0.5);
        assert_eq!(a.reloads(), 1);
        assert_eq!(a.latency.count(), 1);
    }
}
