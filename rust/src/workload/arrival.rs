//! Arrival processes: stochastic models of *when* tasks reach the cluster.
//!
//! The paper evaluates only stationary Poisson arrivals (λ ∈ {0.01..0.19});
//! production AIGC traffic is bursty, diurnal, and spiky. Each process here
//! answers one question — "given the last arrival at `now`, when is the
//! next?" — so generators and the streaming [`crate::workload::TaskStream`]
//! can drive any of them interchangeably. Non-homogeneous processes use
//! Lewis–Shedler thinning against their peak rate, which is exact (not a
//! discretisation) and keeps every draw on the seeded [`Pcg64`] stream so
//! scenarios replay bit-identically.

use crate::util::rng::Pcg64;

/// A point process generating task arrival instants.
///
/// Implementations are stateful (e.g. the MMPP's modulating chain) but
/// cheap to clone; `next_after` must be called with non-decreasing `now`
/// values (the generator/stream guarantees this).
pub trait ArrivalProcess {
    /// Scenario-family name (used in tables and trace headers).
    fn name(&self) -> &'static str;

    /// Absolute time of the next arrival strictly after `now`.
    fn next_after(&mut self, now: f64, rng: &mut Pcg64) -> f64;

    /// Long-run average arrival rate (tasks/s), for diagnostics and the
    /// mean-rate convergence property tests. For [`FlashCrowd`] this is
    /// the off-spike base rate (the spike is a transient, not a regime).
    fn mean_rate(&self) -> f64;

    /// Clone into a boxed trait object (lets env/stream state be `Clone`).
    fn clone_box(&self) -> Box<dyn ArrivalProcess>;
}

impl Clone for Box<dyn ArrivalProcess> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

/// Stationary Poisson arrivals: i.i.d. Exp(rate) inter-arrival gaps.
/// The paper's process and the backwards-compatible default — its draw
/// sequence is identical to the seed's `Workload::generate`.
#[derive(Clone, Debug)]
pub struct Poisson {
    pub rate: f64,
}

impl ArrivalProcess for Poisson {
    fn name(&self) -> &'static str {
        "poisson"
    }

    fn next_after(&mut self, now: f64, rng: &mut Pcg64) -> f64 {
        now + rng.exponential(self.rate)
    }

    fn mean_rate(&self) -> f64 {
        self.rate
    }

    fn clone_box(&self) -> Box<dyn ArrivalProcess> {
        Box::new(self.clone())
    }
}

/// Deterministic constant-rate arrivals: one task every 1/rate seconds.
/// The zero-variance control case — separates queueing effects caused by
/// arrival burstiness from those caused by service-time variance.
#[derive(Clone, Debug)]
pub struct ConstantRate {
    pub rate: f64,
}

impl ArrivalProcess for ConstantRate {
    fn name(&self) -> &'static str {
        "constant"
    }

    fn next_after(&mut self, now: f64, _rng: &mut Pcg64) -> f64 {
        now + 1.0 / self.rate
    }

    fn mean_rate(&self) -> f64 {
        self.rate
    }

    fn clone_box(&self) -> Box<dyn ArrivalProcess> {
        Box::new(self.clone())
    }
}

/// Two-state Markov-modulated Poisson process (bursty on-off traffic):
/// exponential dwell times in an ON state (rate_on) and an OFF state
/// (rate_off), Poisson arrivals at the state's rate while it holds.
/// Standard model for bursty request streams; the competing-exponentials
/// simulation below is exact thanks to memorylessness.
#[derive(Clone, Debug)]
pub struct MmppOnOff {
    pub rate_on: f64,
    pub rate_off: f64,
    pub mean_on: f64,
    pub mean_off: f64,
    on: bool,
    switch_at: f64,
    started: bool,
}

impl MmppOnOff {
    pub fn new(rate_on: f64, rate_off: f64, mean_on: f64, mean_off: f64) -> Self {
        MmppOnOff {
            rate_on,
            rate_off,
            mean_on,
            mean_off,
            on: true,
            switch_at: 0.0,
            started: false,
        }
    }
}

impl ArrivalProcess for MmppOnOff {
    fn name(&self) -> &'static str {
        "bursty"
    }

    fn next_after(&mut self, now: f64, rng: &mut Pcg64) -> f64 {
        if !self.started {
            self.started = true;
            self.switch_at = now + rng.exponential(1.0 / self.mean_on);
        }
        let mut t = now;
        loop {
            let rate = if self.on { self.rate_on } else { self.rate_off };
            let gap = rng.exponential(rate);
            if t + gap <= self.switch_at {
                return t + gap;
            }
            // The candidate arrival falls past the state switch: jump to the
            // switch and resample (valid by memorylessness of Exp).
            t = self.switch_at;
            self.on = !self.on;
            let mean_dwell = if self.on { self.mean_on } else { self.mean_off };
            self.switch_at = t + rng.exponential(1.0 / mean_dwell);
        }
    }

    fn mean_rate(&self) -> f64 {
        (self.rate_on * self.mean_on + self.rate_off * self.mean_off)
            / (self.mean_on + self.mean_off)
    }

    fn clone_box(&self) -> Box<dyn ArrivalProcess> {
        Box::new(self.clone())
    }
}

/// Sinusoidal diurnal cycle: rate(t) = base·(1 + amplitude·sin(2πt/period)),
/// sampled exactly by thinning against the peak rate base·(1+amplitude).
/// Long-run mean rate is exactly `base` (the sine integrates to zero).
#[derive(Clone, Debug)]
pub struct Diurnal {
    pub base_rate: f64,
    /// Relative swing in [0, 1]: 0 = stationary, 1 = rate touches zero.
    pub amplitude: f64,
    pub period: f64,
}

impl ArrivalProcess for Diurnal {
    fn name(&self) -> &'static str {
        "diurnal"
    }

    fn next_after(&mut self, now: f64, rng: &mut Pcg64) -> f64 {
        let peak = self.base_rate * (1.0 + self.amplitude);
        let mut t = now;
        loop {
            t += rng.exponential(peak);
            let phase = std::f64::consts::TAU * t / self.period;
            let rate = self.base_rate * (1.0 + self.amplitude * phase.sin());
            if rng.next_f64() * peak <= rate {
                return t;
            }
        }
    }

    fn mean_rate(&self) -> f64 {
        self.base_rate
    }

    fn clone_box(&self) -> Box<dyn ArrivalProcess> {
        Box::new(self.clone())
    }
}

/// Flash crowd: base-rate Poisson traffic with one rectangular spike window
/// during which the rate jumps to `spike_rate` (a release announcement, a
/// viral prompt). Thinning against max(base, spike) keeps it exact.
#[derive(Clone, Debug)]
pub struct FlashCrowd {
    pub base_rate: f64,
    pub spike_rate: f64,
    pub spike_start: f64,
    pub spike_len: f64,
}

impl FlashCrowd {
    fn rate_at(&self, t: f64) -> f64 {
        if t >= self.spike_start && t < self.spike_start + self.spike_len {
            self.spike_rate
        } else {
            self.base_rate
        }
    }
}

impl ArrivalProcess for FlashCrowd {
    fn name(&self) -> &'static str {
        "flash"
    }

    fn next_after(&mut self, now: f64, rng: &mut Pcg64) -> f64 {
        let peak = self.base_rate.max(self.spike_rate);
        let mut t = now;
        loop {
            t += rng.exponential(peak);
            if rng.next_f64() * peak <= self.rate_at(t) {
                return t;
            }
        }
    }

    fn mean_rate(&self) -> f64 {
        self.base_rate
    }

    fn clone_box(&self) -> Box<dyn ArrivalProcess> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drive(p: &mut dyn ArrivalProcess, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg64::seeded(seed);
        let mut t = 0.0;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            t = p.next_after(t, &mut rng);
            out.push(t);
        }
        out
    }

    fn all_processes() -> Vec<Box<dyn ArrivalProcess>> {
        vec![
            Box::new(Poisson { rate: 0.1 }),
            Box::new(ConstantRate { rate: 0.1 }),
            Box::new(MmppOnOff::new(0.4, 0.025, 60.0, 180.0)),
            Box::new(Diurnal {
                base_rate: 0.1,
                amplitude: 0.8,
                period: 600.0,
            }),
            Box::new(FlashCrowd {
                base_rate: 0.1,
                spike_rate: 0.6,
                spike_start: 200.0,
                spike_len: 120.0,
            }),
        ]
    }

    #[test]
    fn arrivals_strictly_increase() {
        for mut p in all_processes() {
            let ts = drive(p.as_mut(), 2_000, 7);
            let mut prev = 0.0;
            for &t in &ts {
                assert!(t > prev, "{}: {t} after {prev}", p.name());
                assert!(t.is_finite());
                prev = t;
            }
        }
    }

    #[test]
    fn clone_box_replays_identically() {
        for p in all_processes() {
            let mut a = p.clone_box();
            let mut b = p.clone_box();
            assert_eq!(drive(a.as_mut(), 200, 3), drive(b.as_mut(), 200, 3));
        }
    }

    #[test]
    fn poisson_and_constant_hit_mean_rate() {
        for mut p in [
            Box::new(Poisson { rate: 0.2 }) as Box<dyn ArrivalProcess>,
            Box::new(ConstantRate { rate: 0.2 }),
        ] {
            let n = 20_000;
            let ts = drive(p.as_mut(), n, 11);
            let empirical = n as f64 / ts[n - 1];
            let expect = p.mean_rate();
            assert!(
                (empirical - expect).abs() / expect < 0.05,
                "{}: empirical {empirical} vs {expect}",
                p.name()
            );
        }
    }

    #[test]
    fn mmpp_is_burstier_than_poisson() {
        // Index of dispersion of counts over windows: ≈1 for Poisson,
        // substantially >1 for the on-off MMPP with these dwell times.
        let window = 100.0;
        let dispersion = |ts: &[f64]| {
            let horizon = ts.last().copied().unwrap_or(0.0);
            let bins = (horizon / window) as usize;
            let mut counts = vec![0.0f64; bins];
            for &t in ts {
                let b = (t / window) as usize;
                if b < bins {
                    counts[b] += 1.0;
                }
            }
            let mean = counts.iter().sum::<f64>() / bins as f64;
            let var = counts.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>()
                / (bins - 1) as f64;
            var / mean
        };
        let mut mmpp = MmppOnOff::new(0.4, 0.025, 60.0, 180.0);
        let mut poisson = Poisson {
            rate: mmpp.mean_rate(),
        };
        let d_mmpp = dispersion(&drive(&mut mmpp, 30_000, 5));
        let d_poisson = dispersion(&drive(&mut poisson, 30_000, 5));
        assert!(
            d_mmpp > d_poisson * 2.0,
            "mmpp dispersion {d_mmpp} vs poisson {d_poisson}"
        );
    }

    #[test]
    fn flash_crowd_spike_window_is_denser() {
        let mut p = FlashCrowd {
            base_rate: 0.1,
            spike_rate: 1.0,
            spike_start: 500.0,
            spike_len: 200.0,
        };
        let ts = drive(&mut p, 5_000, 13);
        let in_spike = ts.iter().filter(|&&t| (500.0..700.0).contains(&t)).count();
        // 200 s at rate 1.0 → ~200 arrivals; the same 200 s at base rate
        // would hold ~20. Require a clear multiple.
        assert!(in_spike > 100, "only {in_spike} arrivals inside the spike");
    }

    #[test]
    fn diurnal_trough_is_sparser_than_crest() {
        let mut p = Diurnal {
            base_rate: 0.2,
            amplitude: 0.9,
            period: 1000.0,
        };
        let ts = drive(&mut p, 20_000, 17);
        // Crest = rising half of each period (sin ≥ 0), trough = the rest.
        let (mut crest, mut trough) = (0usize, 0usize);
        for &t in &ts {
            let phase = (t / 1000.0).fract();
            if phase < 0.5 {
                crest += 1;
            } else {
                trough += 1;
            }
        }
        assert!(
            crest as f64 > trough as f64 * 1.5,
            "crest {crest} vs trough {trough}"
        );
    }
}
