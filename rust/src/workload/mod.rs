//! Workload & scenario subsystem: the single source of task streams for
//! the simulator and the serving emulation.
//!
//! The paper evaluates EAT under stationary Poisson arrivals with a
//! uniform model mix — one point in a large space of operating regimes.
//! This module opens the rest of that space:
//!
//! - [`arrival`] — an [`ArrivalProcess`] trait with five implementations:
//!   stationary Poisson (the backwards-compatible default), constant-rate,
//!   bursty on-off MMPP, sinusoidal diurnal, and flash-crowd spike.
//! - [`mix`] — [`TaskMix`]: patch-count, model-popularity (uniform /
//!   Zipf / rotating-hot), and per-task quality-demand distributions.
//! - [`stream`] — [`TaskStream`] / [`TaskSource`]: lazy generation so
//!   `EdgeEnv` can consume an arrival process directly.
//! - [`trace`] — JSONL record/replay: any generated scenario can be saved
//!   and re-run bit-exactly for common-random-number policy comparisons.
//! - [`metrics`] — [`MetricsCollector`]: streaming latency histograms
//!   (p50/p90/p99), per-server utilization, and reload counters.
//!
//! [`WorkloadConfig`] ties it together: a serialisable description of a
//! scenario, with named presets (`WorkloadConfig::preset`) used by the
//! `eat scenarios` sweep. `EnvConfig::workload = None` reproduces the
//! seed generator draw-for-draw.

pub mod arrival;
pub mod import;
pub mod metrics;
pub mod mix;
pub mod stream;
pub mod trace;

pub use arrival::ArrivalProcess;
pub use metrics::{LatencyHistogram, MetricsCollector, TenantReport, TenantStats};
pub use mix::{MixSample, ModelMix, QualityDemand, TaskMix};
pub use stream::{TaskSource, TaskStream};

use crate::config::EnvConfig;
use crate::qos::AdmissionConfig;
use crate::sim::task::{Task, Workload};
use crate::util::json::Value;
use crate::util::rng::Pcg64;

/// Generate `n` tasks by driving an arrival process and a task mix.
///
/// Draw order per task — arrival draw(s), mix draws, prompt id — is the
/// replay contract shared with [`TaskStream`]; with a Poisson process and
/// uniform mix it is bit-identical to the seed's `Workload::generate`.
pub fn generate(
    arrival: &mut dyn ArrivalProcess,
    mix: &TaskMix,
    n: usize,
    rng: &mut Pcg64,
) -> Workload {
    let mut tasks = Vec::with_capacity(n);
    let mut t = 0.0;
    for id in 0..n as u64 {
        t = arrival.next_after(t, rng);
        let s = mix.sample(t, rng);
        tasks.push(Task {
            id,
            prompt_id: rng.next_u64(),
            patches: s.patches,
            model: s.model,
            arrival: t,
            q_min: s.q_min,
            tenant: None,
            deadline: None,
        });
    }
    Workload { tasks }
}

/// Build the arrival process + mix for an env config: its scenario when
/// one is set, else the legacy stationary Poisson + uniform mix.
pub fn build_for_env(cfg: &EnvConfig) -> (Box<dyn ArrivalProcess>, TaskMix) {
    match &cfg.workload {
        Some(w) => w.build(cfg),
        None => (
            Box::new(arrival::Poisson {
                rate: cfg.arrival_rate,
            }),
            TaskMix::uniform(cfg),
        ),
    }
}

/// Serialisable description of an arrival process.
#[derive(Clone, Debug, PartialEq)]
pub enum ArrivalConfig {
    Poisson {
        rate: f64,
    },
    Constant {
        rate: f64,
    },
    Mmpp {
        rate_on: f64,
        rate_off: f64,
        mean_on: f64,
        mean_off: f64,
    },
    Diurnal {
        base_rate: f64,
        amplitude: f64,
        period: f64,
    },
    FlashCrowd {
        base_rate: f64,
        spike_rate: f64,
        spike_start: f64,
        spike_len: f64,
    },
}

impl ArrivalConfig {
    /// The same process with every rate multiplied by `factor` (overload
    /// sweeps); dwell times, periods and spike windows are unchanged.
    pub fn scaled(&self, factor: f64) -> ArrivalConfig {
        let mut out = self.clone();
        match &mut out {
            ArrivalConfig::Poisson { rate } | ArrivalConfig::Constant { rate } => {
                *rate *= factor;
            }
            ArrivalConfig::Mmpp {
                rate_on, rate_off, ..
            } => {
                *rate_on *= factor;
                *rate_off *= factor;
            }
            ArrivalConfig::Diurnal { base_rate, .. } => {
                *base_rate *= factor;
            }
            ArrivalConfig::FlashCrowd {
                base_rate,
                spike_rate,
                ..
            } => {
                *base_rate *= factor;
                *spike_rate *= factor;
            }
        }
        out
    }

    pub fn build(&self) -> Box<dyn ArrivalProcess> {
        match *self {
            ArrivalConfig::Poisson { rate } => Box::new(arrival::Poisson { rate }),
            ArrivalConfig::Constant { rate } => Box::new(arrival::ConstantRate { rate }),
            ArrivalConfig::Mmpp {
                rate_on,
                rate_off,
                mean_on,
                mean_off,
            } => Box::new(arrival::MmppOnOff::new(rate_on, rate_off, mean_on, mean_off)),
            ArrivalConfig::Diurnal {
                base_rate,
                amplitude,
                period,
            } => Box::new(arrival::Diurnal {
                base_rate,
                amplitude,
                period,
            }),
            ArrivalConfig::FlashCrowd {
                base_rate,
                spike_rate,
                spike_start,
                spike_len,
            } => Box::new(arrival::FlashCrowd {
                base_rate,
                spike_rate,
                spike_start,
                spike_len,
            }),
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        let pos = |name: &str, x: f64| -> anyhow::Result<()> {
            anyhow::ensure!(x > 0.0 && x.is_finite(), "{name} must be > 0, got {x}");
            Ok(())
        };
        match *self {
            ArrivalConfig::Poisson { rate } | ArrivalConfig::Constant { rate } => {
                pos("rate", rate)
            }
            ArrivalConfig::Mmpp {
                rate_on,
                rate_off,
                mean_on,
                mean_off,
            } => {
                pos("rate_on", rate_on)?;
                pos("rate_off", rate_off)?;
                pos("mean_on", mean_on)?;
                pos("mean_off", mean_off)
            }
            ArrivalConfig::Diurnal {
                base_rate,
                amplitude,
                period,
            } => {
                pos("base_rate", base_rate)?;
                pos("period", period)?;
                anyhow::ensure!(
                    (0.0..=1.0).contains(&amplitude),
                    "amplitude must be in [0,1], got {amplitude}"
                );
                Ok(())
            }
            ArrivalConfig::FlashCrowd {
                base_rate,
                spike_rate,
                spike_start,
                spike_len,
            } => {
                pos("base_rate", base_rate)?;
                pos("spike_rate", spike_rate)?;
                pos("spike_len", spike_len)?;
                anyhow::ensure!(
                    spike_start >= 0.0 && spike_start.is_finite(),
                    "spike_start must be >= 0"
                );
                Ok(())
            }
        }
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        match *self {
            ArrivalConfig::Poisson { rate } => {
                v.set("kind", "poisson").set("rate", rate);
            }
            ArrivalConfig::Constant { rate } => {
                v.set("kind", "constant").set("rate", rate);
            }
            ArrivalConfig::Mmpp {
                rate_on,
                rate_off,
                mean_on,
                mean_off,
            } => {
                v.set("kind", "mmpp")
                    .set("rate_on", rate_on)
                    .set("rate_off", rate_off)
                    .set("mean_on", mean_on)
                    .set("mean_off", mean_off);
            }
            ArrivalConfig::Diurnal {
                base_rate,
                amplitude,
                period,
            } => {
                v.set("kind", "diurnal")
                    .set("base_rate", base_rate)
                    .set("amplitude", amplitude)
                    .set("period", period);
            }
            ArrivalConfig::FlashCrowd {
                base_rate,
                spike_rate,
                spike_start,
                spike_len,
            } => {
                v.set("kind", "flash_crowd")
                    .set("base_rate", base_rate)
                    .set("spike_rate", spike_rate)
                    .set("spike_start", spike_start)
                    .set("spike_len", spike_len);
            }
        }
        v
    }

    pub fn from_json(v: &Value) -> anyhow::Result<ArrivalConfig> {
        let num = |key: &str| -> anyhow::Result<f64> {
            v.req(key)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("arrival field '{key}' is not a number"))
        };
        let kind = v
            .req("kind")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("arrival 'kind' must be a string"))?;
        let cfg = match kind {
            "poisson" => ArrivalConfig::Poisson { rate: num("rate")? },
            "constant" => ArrivalConfig::Constant { rate: num("rate")? },
            "mmpp" => ArrivalConfig::Mmpp {
                rate_on: num("rate_on")?,
                rate_off: num("rate_off")?,
                mean_on: num("mean_on")?,
                mean_off: num("mean_off")?,
            },
            "diurnal" => ArrivalConfig::Diurnal {
                base_rate: num("base_rate")?,
                amplitude: num("amplitude")?,
                period: num("period")?,
            },
            "flash_crowd" => ArrivalConfig::FlashCrowd {
                base_rate: num("base_rate")?,
                spike_rate: num("spike_rate")?,
                spike_start: num("spike_start")?,
                spike_len: num("spike_len")?,
            },
            other => anyhow::bail!("unknown arrival kind '{other}'"),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

pub(crate) fn model_mix_to_json(m: &ModelMix) -> Value {
    let mut v = Value::obj();
    match m {
        ModelMix::Uniform => {
            v.set("kind", "uniform");
        }
        ModelMix::Zipf { exponent } => {
            v.set("kind", "zipf").set("exponent", *exponent);
        }
        ModelMix::Rotating { hot_weight, period } => {
            v.set("kind", "rotating")
                .set("hot_weight", *hot_weight)
                .set("period", *period);
        }
    }
    v
}

pub(crate) fn model_mix_from_json(v: &Value) -> anyhow::Result<ModelMix> {
    let kind = v
        .req("kind")?
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("model_mix 'kind' must be a string"))?;
    Ok(match kind {
        "uniform" => ModelMix::Uniform,
        "zipf" => ModelMix::Zipf {
            exponent: v
                .req("exponent")?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("zipf exponent must be a number"))?,
        },
        "rotating" => ModelMix::Rotating {
            hot_weight: v
                .req("hot_weight")?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("hot_weight must be a number"))?,
            period: v
                .req("period")?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("period must be a number"))?,
        },
        other => anyhow::bail!("unknown model mix '{other}'"),
    })
}

fn quality_demand_to_json(q: &QualityDemand) -> Value {
    let mut v = Value::obj();
    match q {
        QualityDemand::Default => {
            v.set("kind", "default");
        }
        QualityDemand::Uniform { lo, hi } => {
            v.set("kind", "uniform").set("lo", *lo).set("hi", *hi);
        }
        QualityDemand::TwoTier {
            strict_frac,
            strict_q,
            lax_q,
        } => {
            v.set("kind", "two_tier")
                .set("strict_frac", *strict_frac)
                .set("strict_q", *strict_q)
                .set("lax_q", *lax_q);
        }
    }
    v
}

fn quality_demand_from_json(v: &Value) -> anyhow::Result<QualityDemand> {
    let num = |key: &str| -> anyhow::Result<f64> {
        v.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("quality_demand field '{key}' is not a number"))
    };
    let kind = v
        .req("kind")?
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("quality_demand 'kind' must be a string"))?;
    Ok(match kind {
        "default" => QualityDemand::Default,
        "uniform" => QualityDemand::Uniform {
            lo: num("lo")?,
            hi: num("hi")?,
        },
        "two_tier" => QualityDemand::TwoTier {
            strict_frac: num("strict_frac")?,
            strict_q: num("strict_q")?,
            lax_q: num("lax_q")?,
        },
        other => anyhow::bail!("unknown quality demand '{other}'"),
    })
}

/// A complete scenario description: when tasks arrive and what they are.
/// Lives in `EnvConfig::workload`; `None` there means the legacy
/// stationary Poisson + uniform mix at `EnvConfig::arrival_rate`.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadConfig {
    pub arrival: ArrivalConfig,
    pub model_mix: ModelMix,
    pub quality_demand: QualityDemand,
    /// Admission control for the pending queue (`AdmitAll` = the seed's
    /// unbounded queue). The `flash` preset defaults to a bounded queue so
    /// overload spikes shed load instead of backlogging forever.
    pub admission: AdmissionConfig,
}

/// Scenario-family preset names accepted by [`WorkloadConfig::preset`].
pub const SCENARIO_NAMES: [&str; 7] = [
    "poisson",
    "constant",
    "bursty",
    "diurnal",
    "flash",
    "zipf-hot",
    "rotating",
];

impl WorkloadConfig {
    /// Stationary Poisson with a uniform mix — the paper's regime as an
    /// explicit scenario.
    pub fn poisson(rate: f64) -> WorkloadConfig {
        WorkloadConfig {
            arrival: ArrivalConfig::Poisson { rate },
            model_mix: ModelMix::Uniform,
            quality_demand: QualityDemand::Default,
            admission: AdmissionConfig::AdmitAll,
        }
    }

    pub fn scenario_names() -> &'static [&'static str] {
        &SCENARIO_NAMES
    }

    /// Named scenario family, parameterised by the base arrival rate λ so
    /// presets line up with the paper's per-cluster rate columns.
    pub fn preset(name: &str, base_rate: f64) -> anyhow::Result<WorkloadConfig> {
        let uniform = (ModelMix::Uniform, QualityDemand::Default);
        let (arrival, (model_mix, quality_demand)) = match name {
            "poisson" => (ArrivalConfig::Poisson { rate: base_rate }, uniform),
            "constant" => (ArrivalConfig::Constant { rate: base_rate }, uniform),
            // ~20% duty cycle bursts at 4λ with quiet λ/4 valleys; the
            // time-averaged rate stays near λ.
            "bursty" => (
                ArrivalConfig::Mmpp {
                    rate_on: base_rate * 4.0,
                    rate_off: base_rate * 0.25,
                    mean_on: 60.0,
                    mean_off: 180.0,
                },
                uniform,
            ),
            // One full day compressed into 600 s of simulated time.
            "diurnal" => (
                ArrivalConfig::Diurnal {
                    base_rate,
                    amplitude: 0.8,
                    period: 600.0,
                },
                uniform,
            ),
            // 6x overload spike in the middle of the episode. The queue is
            // bounded (drop-tail) so reports reflect shed load rather than
            // an unbounded backlog inflating every percentile.
            "flash" => (
                ArrivalConfig::FlashCrowd {
                    base_rate,
                    spike_rate: base_rate * 6.0,
                    spike_start: 200.0,
                    spike_len: 120.0,
                },
                uniform,
            ),
            // Stationary arrivals, heavily skewed model popularity:
            // maximises the payoff of reuse-aware placement.
            "zipf-hot" => (
                ArrivalConfig::Poisson { rate: base_rate },
                (
                    ModelMix::Zipf { exponent: 1.1 },
                    QualityDemand::Default,
                ),
            ),
            // Popularity drift + premium/best-effort quality tiers.
            "rotating" => (
                ArrivalConfig::Diurnal {
                    base_rate,
                    amplitude: 0.5,
                    period: 600.0,
                },
                (
                    ModelMix::Rotating {
                        hot_weight: 0.7,
                        period: 300.0,
                    },
                    QualityDemand::TwoTier {
                        strict_frac: 0.3,
                        strict_q: 0.26,
                        lax_q: 0.2,
                    },
                ),
            ),
            other => anyhow::bail!(
                "unknown scenario '{other}' (known: {})",
                SCENARIO_NAMES.join(", ")
            ),
        };
        let admission = if name == "flash" {
            AdmissionConfig::DropTail { max_queue: 16 }
        } else {
            AdmissionConfig::AdmitAll
        };
        let cfg = WorkloadConfig {
            arrival,
            model_mix,
            quality_demand,
            admission,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Instantiate the arrival process and task mix for an env config.
    pub fn build(&self, cfg: &EnvConfig) -> (Box<dyn ArrivalProcess>, TaskMix) {
        (
            self.arrival.build(),
            TaskMix::new(cfg, self.model_mix.clone(), self.quality_demand.clone()),
        )
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        self.arrival.validate()?;
        if let ModelMix::Zipf { exponent } = self.model_mix {
            anyhow::ensure!(exponent > 0.0, "zipf exponent must be > 0");
        }
        if let ModelMix::Rotating { hot_weight, period } = self.model_mix {
            anyhow::ensure!(
                (0.0..=1.0).contains(&hot_weight),
                "hot_weight must be in [0,1]"
            );
            anyhow::ensure!(period > 0.0, "rotation period must be > 0");
        }
        // Quality floors must be positive and finite: sampled quality is
        // clamped to [0, q_cap], so a non-positive floor can never trip
        // and would silently disable QoS accounting.
        if let QualityDemand::Uniform { lo, hi } = self.quality_demand {
            anyhow::ensure!(
                lo > 0.0 && hi.is_finite() && lo < hi,
                "quality demand must satisfy 0 < lo < hi (finite), got [{lo}, {hi})"
            );
        }
        if let QualityDemand::TwoTier {
            strict_frac,
            strict_q,
            lax_q,
        } = self.quality_demand
        {
            anyhow::ensure!(
                (0.0..=1.0).contains(&strict_frac),
                "strict_frac must be in [0,1]"
            );
            anyhow::ensure!(
                strict_q > 0.0 && strict_q.is_finite() && lax_q > 0.0 && lax_q.is_finite(),
                "quality tiers must be positive and finite, got strict {strict_q} lax {lax_q}"
            );
        }
        self.admission.validate()
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("arrival", self.arrival.to_json())
            .set("model_mix", model_mix_to_json(&self.model_mix))
            .set("quality_demand", quality_demand_to_json(&self.quality_demand));
        if self.admission != AdmissionConfig::AdmitAll {
            v.set("admission", self.admission.to_json());
        }
        v
    }

    pub fn from_json(v: &Value) -> anyhow::Result<WorkloadConfig> {
        let cfg = WorkloadConfig {
            arrival: ArrivalConfig::from_json(v.req("arrival")?)?,
            model_mix: match v.get("model_mix") {
                Some(m) => model_mix_from_json(m)?,
                None => ModelMix::Uniform,
            },
            quality_demand: match v.get("quality_demand") {
                Some(q) => quality_demand_from_json(q)?,
                None => QualityDemand::Default,
            },
            admission: match v.get("admission") {
                Some(a) => AdmissionConfig::from_json(a)?,
                None => AdmissionConfig::AdmitAll,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_preset_builds_and_validates() {
        for name in WorkloadConfig::scenario_names() {
            let w = WorkloadConfig::preset(name, 0.1).unwrap();
            w.validate().unwrap();
            let cfg = EnvConfig::default();
            let (mut ap, mix) = w.build(&cfg);
            let wl = generate(ap.as_mut(), &mix, 100, &mut Pcg64::seeded(1));
            assert_eq!(wl.len(), 100);
            assert!(wl.is_sorted(), "{name} produced unsorted arrivals");
        }
        assert!(WorkloadConfig::preset("no-such-scenario", 0.1).is_err());
    }

    #[test]
    fn legacy_generate_path_is_unchanged() {
        // build_for_env with workload=None must replay the seed generator's
        // exact draw sequence (Poisson + uniform mix).
        let cfg = EnvConfig::default();
        let (mut ap, mix) = build_for_env(&cfg);
        let a = generate(ap.as_mut(), &mix, cfg.tasks_per_episode, &mut Pcg64::seeded(5));
        let b = Workload::generate(&cfg, &mut Pcg64::seeded(5));
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
            assert_eq!(x.prompt_id, y.prompt_id);
            assert_eq!(x.patches, y.patches);
            assert_eq!(x.model, y.model);
        }
    }

    #[test]
    fn workload_config_json_roundtrip() {
        for name in WorkloadConfig::scenario_names() {
            let w = WorkloadConfig::preset(name, 0.07).unwrap();
            let back = WorkloadConfig::from_json(&w.to_json()).unwrap();
            assert_eq!(back, w, "roundtrip failed for {name}");
        }
    }

    #[test]
    fn json_rejects_bad_configs() {
        let mut v = Value::obj();
        let mut a = Value::obj();
        a.set("kind", "poisson").set("rate", -1.0);
        v.set("arrival", a);
        assert!(WorkloadConfig::from_json(&v).is_err());
        let mut v = Value::obj();
        let mut a = Value::obj();
        a.set("kind", "martian");
        v.set("arrival", a);
        assert!(WorkloadConfig::from_json(&v).is_err());
        // Non-positive quality floors can never trip (quality >= 0) and
        // must be rejected rather than silently disabling QoS accounting.
        let mut w = WorkloadConfig::poisson(0.1);
        w.quality_demand = QualityDemand::Uniform { lo: -1.0, hi: -0.5 };
        assert!(w.validate().is_err());
        w.quality_demand = QualityDemand::TwoTier {
            strict_frac: 0.5,
            strict_q: 0.0,
            lax_q: 0.2,
        };
        assert!(w.validate().is_err());
    }

    #[test]
    fn missing_mix_fields_default() {
        let w = WorkloadConfig::poisson(0.1);
        let mut v = Value::obj();
        v.set("arrival", w.arrival.to_json());
        let back = WorkloadConfig::from_json(&v).unwrap();
        assert_eq!(back.model_mix, ModelMix::Uniform);
        assert_eq!(back.quality_demand, QualityDemand::Default);
    }
}
