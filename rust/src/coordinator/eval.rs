//! Multi-episode evaluation with common random numbers: every algorithm in
//! a comparison sees exactly the same workload realisations (same seeds),
//! so Table IX–XI differences reflect policy quality, not workload luck.

use super::{run_episode, DecisionTiming};
use crate::config::ExperimentConfig;
use crate::policy::Policy;
use crate::qos::TenantRegistry;
use crate::sim::env::EdgeEnv;
use crate::sim::task::Workload;
use crate::util::rng::Pcg64;
use crate::util::stats::Welford;
use crate::workload::{MetricsCollector, TenantReport};

/// Aggregated metrics over an evaluation run: means over episodes, plus
/// latency percentiles over the *pooled* per-task latency histogram of
/// all episodes (a mean of per-episode percentiles is not a percentile).
#[derive(Clone, Debug, Default)]
pub struct EvalSummary {
    pub algorithm: String,
    pub episodes: usize,
    pub avg_quality: f64,
    pub avg_response_latency: f64,
    pub p50_latency: f64,
    pub p90_latency: f64,
    pub p99_latency: f64,
    pub avg_utilization: f64,
    pub reload_rate: f64,
    pub avg_reward: f64,
    pub avg_episode_len: f64,
    pub avg_steps_chosen: f64,
    pub efficiency: f64,
    pub below_quality_min_frac: f64,
    pub decision_latency_s: f64,
    /// Fraction of offered tasks shed by admission control.
    pub dropped_frac: f64,
    /// Pooled per-tenant QoS reports (empty without a tenants config).
    pub tenants: Vec<TenantReport>,
}

/// Evaluate `policy` over `episodes` seeded episodes of `cfg`'s env.
pub fn evaluate(
    cfg: &ExperimentConfig,
    policy: &mut dyn Policy,
    episodes: usize,
) -> EvalSummary {
    let mut quality = Welford::new();
    let mut latency = Welford::new();
    let mut reload = Welford::new();
    let mut reward = Welford::new();
    let mut ep_len = Welford::new();
    let mut steps = Welford::new();
    let mut eff = Welford::new();
    let mut below = Welford::new();
    // Pooled collector shape must match the per-episode collectors, which
    // enable per-tenant stats when a tenants section is configured.
    let registry = cfg.env.tenants.as_ref().map(TenantRegistry::new);
    let mut pooled = match &registry {
        Some(reg) => MetricsCollector::with_tenants(cfg.env.num_servers, reg),
        None => MetricsCollector::new(cfg.env.num_servers),
    };
    let mut timing = DecisionTiming::default();
    for ep in 0..episodes {
        // Common random numbers: workload seed depends only on (cfg.seed,
        // ep), never on the algorithm. Scenario configs flow through
        // Workload::generate, so the whole grid works per scenario too.
        let mut wl_rng = Pcg64::new(cfg.seed.wrapping_add(ep as u64), 0xC0FFEE);
        let workload = Workload::generate(&cfg.env, &mut wl_rng);
        let mut env = EdgeEnv::with_workload(
            cfg.env.clone(),
            workload,
            Pcg64::new(cfg.seed.wrapping_add(ep as u64), 0xE21),
        );
        let rep = run_episode(&mut env, policy, Some(&mut timing));
        quality.push(rep.avg_quality);
        latency.push(rep.avg_response_latency);
        reload.push(rep.reload_rate);
        reward.push(rep.total_reward);
        ep_len.push(rep.decision_steps as f64);
        steps.push(rep.avg_steps_chosen);
        eff.push(rep.efficiency);
        below.push(rep.below_quality_min as f64 / rep.completed_tasks.max(1) as f64);
        pooled.merge(env.metrics());
        if rep.completed_tasks == 0 {
            // Mirror EpisodeReport's censoring inside the pooled histogram
            // too: a do-nothing episode contributes one sample censored at
            // its simulated time, so it degrades the percentile columns
            // instead of silently vanishing from them.
            pooled.latency.observe(rep.sim_time);
        }
    }
    // Pooled over all episodes: percentiles from the merged histogram
    // (a mean of per-episode percentiles is not a percentile).
    let pct = |q: f64| pooled.latency.percentile(q).unwrap_or(f64::NAN);
    EvalSummary {
        algorithm: policy.name(),
        episodes,
        avg_quality: quality.mean(),
        avg_response_latency: latency.mean(),
        p50_latency: pct(0.5),
        p90_latency: pct(0.9),
        p99_latency: pct(0.99),
        avg_utilization: pooled.avg_utilization(),
        reload_rate: reload.mean(),
        avg_reward: reward.mean(),
        avg_episode_len: ep_len.mean(),
        avg_steps_chosen: steps.mean(),
        efficiency: eff.mean(),
        below_quality_min_frac: below.mean(),
        decision_latency_s: timing.mean_seconds(),
        dropped_frac: pooled.admission_dropped() as f64 / pooled.offered().max(1) as f64,
        tenants: pooled.tenant_reports(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::policy::{GreedyPolicy, RandomPolicy};

    #[test]
    fn greedy_beats_random_on_quality() {
        let cfg = ExperimentConfig::preset_4node(0.05);
        let mut greedy = GreedyPolicy::new(cfg.env.clone());
        let mut random = RandomPolicy::new(cfg.env.clone(), cfg.seed);
        let g = evaluate(&cfg, &mut greedy, 3);
        let r = evaluate(&cfg, &mut random, 3);
        assert!(g.avg_quality > r.avg_quality, "{} vs {}", g.avg_quality, r.avg_quality);
        // Greedy max-steps => higher response latency (Table X shape).
        assert!(g.avg_response_latency > r.avg_response_latency * 0.8);
    }

    #[test]
    fn evaluation_is_reproducible() {
        let cfg = ExperimentConfig::preset_4node(0.05);
        let a = evaluate(&cfg, &mut GreedyPolicy::new(cfg.env.clone()), 2);
        let b = evaluate(&cfg, &mut GreedyPolicy::new(cfg.env.clone()), 2);
        assert_eq!(a.avg_quality, b.avg_quality);
        assert_eq!(a.avg_response_latency, b.avg_response_latency);
        assert_eq!(a.p99_latency, b.p99_latency);
    }

    #[test]
    fn summary_percentiles_are_ordered() {
        let cfg = ExperimentConfig::preset_4node(0.05);
        let s = evaluate(&cfg, &mut GreedyPolicy::new(cfg.env.clone()), 2);
        assert!(s.p50_latency <= s.p90_latency && s.p90_latency <= s.p99_latency);
        assert!(s.p50_latency > 0.0);
        assert!(s.avg_utilization > 0.0 && s.avg_utilization <= 1.0);
    }

    #[test]
    fn pooled_percentiles_match_manually_merged_episodes() {
        // EvalSummary's percentile columns come from the merged histogram;
        // re-running the same CRN episodes by hand and merging their
        // per-episode collectors must land on the same bits.
        let cfg = ExperimentConfig::preset_4node(0.05);
        let episodes = 3;
        let s = evaluate(&cfg, &mut GreedyPolicy::new(cfg.env.clone()), episodes);
        let mut policy = GreedyPolicy::new(cfg.env.clone());
        let mut pooled = MetricsCollector::new(cfg.env.num_servers);
        for ep in 0..episodes {
            let mut wl_rng = Pcg64::new(cfg.seed.wrapping_add(ep as u64), 0xC0FFEE);
            let workload = Workload::generate(&cfg.env, &mut wl_rng);
            let mut env = EdgeEnv::with_workload(
                cfg.env.clone(),
                workload,
                Pcg64::new(cfg.seed.wrapping_add(ep as u64), 0xE21),
            );
            let rep = run_episode(&mut env, &mut policy, None);
            pooled.merge(env.metrics());
            if rep.completed_tasks == 0 {
                pooled.latency.observe(rep.sim_time);
            }
        }
        for (q, got) in [(0.5, s.p50_latency), (0.9, s.p90_latency), (0.99, s.p99_latency)] {
            let want = pooled.latency.percentile(q).unwrap();
            assert_eq!(want.to_bits(), got.to_bits(), "q={q}: {want} vs {got}");
        }
    }

    #[test]
    fn tenant_config_flows_through_evaluate() {
        use crate::qos::TenantsConfig;
        let mut cfg = ExperimentConfig::preset_8node(0.1);
        cfg.env.tenants = Some(TenantsConfig::three_tier(0.3));
        cfg.env.tasks_per_episode = 24;
        let s = evaluate(&cfg, &mut GreedyPolicy::new(cfg.env.clone()), 2);
        assert_eq!(s.tenants.len(), 3);
        let offered: u64 = s.tenants.iter().map(|t| t.offered).sum();
        assert!(offered > 0, "pooled tenant stats must accumulate");
        assert!((0.0..=1.0).contains(&s.dropped_frac));
        // CRN reproducibility holds for tenant workloads too.
        let s2 = evaluate(&cfg, &mut GreedyPolicy::new(cfg.env.clone()), 2);
        assert_eq!(s.avg_response_latency, s2.avg_response_latency);
        assert_eq!(s.tenants[0].slo_met, s2.tenants[0].slo_met);
    }

    #[test]
    fn scenario_config_flows_through_evaluate() {
        use crate::workload::WorkloadConfig;
        let mut cfg = ExperimentConfig::preset_4node(0.05);
        let base = evaluate(&cfg, &mut GreedyPolicy::new(cfg.env.clone()), 2);
        cfg.env.workload = Some(WorkloadConfig::preset("flash", 0.05).unwrap());
        let flash = evaluate(&cfg, &mut GreedyPolicy::new(cfg.env.clone()), 2);
        // Different arrival regime → different realised numbers.
        assert_ne!(base.avg_response_latency, flash.avg_response_latency);
    }
}
