//! The "Traditional" scheduler from the paper's motivating example
//! (§II, Tables II–IV): FIFO task order, a fixed 20 inference steps for
//! every task, and first-fit (lowest-id idle servers) placement with no
//! model-reuse awareness — reuse happens only by accident. Compared against
//! EAT in `experiments::motivation`.

use crate::sim::env::{EdgeEnv, Scheduled};

/// Fixed inference steps used by the traditional algorithm (paper: 20).
pub const TRADITIONAL_STEPS: u32 = 20;

/// Drive one decision tick: schedule the queue head on the lowest-id idle
/// servers if it fits. Returns the schedule record if one happened.
pub fn traditional_tick(env: &mut EdgeEnv) -> Option<Scheduled> {
    let task = env.queue().front()?.clone();
    let idle: Vec<usize> = env
        .cluster
        .servers
        .iter()
        .filter(|s| s.is_idle())
        .map(|s| s.id)
        .collect();
    if idle.len() < task.patches {
        return None;
    }
    let chosen: Vec<usize> = idle.into_iter().take(task.patches).collect();
    env.schedule_task_on(0, TRADITIONAL_STEPS, &chosen)
}

/// Run a whole episode under the traditional scheduler.
pub fn run_traditional(env: &mut EdgeEnv) -> crate::sim::env::EpisodeReport {
    use crate::sim::env::Action;
    let l = env.cfg.queue_window;
    loop {
        traditional_tick(env);
        // Advance time via a no-op action (the scheduling above already
        // happened through the direct API).
        let out = env.step(&Action::noop(l));
        if out.done {
            break;
        }
    }
    env.report()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::sim::env::EdgeEnv;
    use crate::sim::task::Workload;
    use crate::util::rng::Pcg64;

    fn four_task_env() -> EdgeEnv {
        // The paper's motivating trace: tasks every 10 s on 4 GPUs,
        // patches 2/2/4/2, same model/service type.
        let mut cfg = ExperimentConfig::preset_4node(0.05).env;
        cfg.num_models = 1;
        cfg.tasks_per_episode = 4;
        let wl = Workload::fixed(&[(0.0, 2, 0), (10.0, 2, 0), (20.0, 4, 0), (30.0, 2, 0)]);
        EdgeEnv::with_workload(cfg, wl, Pcg64::seeded(7))
    }

    #[test]
    fn traditional_uses_fixed_steps_and_first_fit() {
        let mut env = four_task_env();
        let rep = run_traditional(&mut env);
        assert_eq!(rep.completed_tasks, 4);
        for sch in env.trace() {
            assert_eq!(sch.steps, TRADITIONAL_STEPS);
        }
        // Task 1 goes to the two lowest ids.
        assert_eq!(env.trace()[0].servers, vec![0, 1]);
    }

    #[test]
    fn traditional_reloads_more_than_reuse_aware() {
        // With one model type and alternating gang sizes, first-fit breaks
        // gangs and pays reinitialisation that EAT's selector avoids.
        let mut env = four_task_env();
        let rep = run_traditional(&mut env);
        assert!(rep.reload_rate >= 0.5, "reload={}", rep.reload_rate);
    }
}
