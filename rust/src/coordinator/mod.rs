//! The L3 coordinator: drives policies against environments (Algorithm 1's
//! outer loop), aggregates evaluation grids with common random numbers,
//! and implements the fixed-step "Traditional" scheduler used by the
//! paper's motivating example (Tables II–IV).

pub mod eval;
pub mod traditional;

pub use eval::{evaluate, EvalSummary};

use crate::policy::Policy;
use crate::sim::env::{EdgeEnv, EpisodeReport};
// eat-lint: allow(determinism, "wall-clock decision-latency telemetry; never reaches episode state")
use std::time::{Duration, Instant};

/// Decision-latency statistics for one episode (Table XII).
#[derive(Clone, Copy, Debug, Default)]
pub struct DecisionTiming {
    pub decisions: usize,
    pub total: Duration,
}

impl DecisionTiming {
    pub fn mean_seconds(&self) -> f64 {
        if self.decisions == 0 {
            0.0
        } else {
            self.total.as_secs_f64() / self.decisions as f64
        }
    }
}

/// Run one full episode of `policy` against `env` (Algorithm 1).
/// `timing` optionally collects per-decision wall-clock latency.
pub fn run_episode(
    env: &mut EdgeEnv,
    policy: &mut dyn Policy,
    mut timing: Option<&mut DecisionTiming>,
) -> EpisodeReport {
    policy.reset(env);
    loop {
        // eat-lint: allow(determinism, "times the policy for Table XII; result feeds telemetry only")
        let t0 = Instant::now();
        let action = match policy.decide(env) {
            Ok(a) => a,
            Err(e) => panic!("policy '{}' failed to decide: {e}", policy.name()),
        };
        if let Some(t) = timing.as_deref_mut() {
            t.total += t0.elapsed();
            t.decisions += 1;
        }
        let out = env.step(&action);
        if out.done {
            break;
        }
    }
    env.report()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::policy::{GreedyPolicy, RandomPolicy};

    #[test]
    fn run_episode_reports_and_times() {
        let cfg = ExperimentConfig::preset_4node(0.05);
        let mut env = EdgeEnv::new(cfg.env.clone(), 11);
        let mut p = GreedyPolicy::new(cfg.env.clone());
        let mut timing = DecisionTiming::default();
        let rep = run_episode(&mut env, &mut p, Some(&mut timing));
        assert!(rep.completed_tasks > 0);
        assert_eq!(timing.decisions, rep.decision_steps);
        assert!(timing.mean_seconds() >= 0.0);
    }

    #[test]
    fn random_policy_episode_terminates() {
        let cfg = ExperimentConfig::preset_4node(0.05);
        let mut env = EdgeEnv::new(cfg.env.clone(), 12);
        let mut p = RandomPolicy::new(cfg.env.clone(), 12);
        let rep = run_episode(&mut env, &mut p, None);
        assert!(rep.decision_steps > 0);
    }
}
