//! # EAT — QoS-Aware Edge-Collaborative AIGC Task Scheduling
//!
//! A production-quality, three-layer (Rust + JAX + Pallas, AOT via
//! xla/PJRT) reproduction of *"EAT: QoS-Aware Edge-Collaborative AIGC Task
//! Scheduling via Attention-Guided Diffusion Reinforcement Learning"*.
//!
//! Layer map:
//! - **L3 (this crate)** — the coordinator: an edge-cluster simulator, a gang
//!   scheduler with model-reuse-aware server selection, RL training drivers
//!   (SAC-family + PPO), baseline policies (Random / Greedy / Harmony /
//!   Genetic), a socket-based serving emulation, and the experiment harness
//!   that regenerates every table and figure in the paper.
//! - **L2 (python/compile/model.py)** — JAX networks (attention encoder,
//!   diffusion policy, double critics) and whole train-steps with in-graph
//!   Adam, AOT-lowered to HLO text.
//! - **L1 (python/compile/kernels/)** — Pallas kernels (interpret mode) for
//!   the attention feature extraction and the diffusion denoiser MLP.
//!
//! Python never runs on the request path: `runtime` loads `artifacts/*.hlo.txt`
//! with the PJRT CPU client and executes them directly.
//!
//! Quickstart (after `make artifacts && cargo build --release`):
//!
//! ```no_run
//! use eat::config::ExperimentConfig;
//! use eat::sim::env::EdgeEnv;
//! use eat::policy::{Policy, greedy::GreedyPolicy};
//!
//! let cfg = ExperimentConfig::preset_4node(0.05);
//! let mut env = EdgeEnv::new(cfg.env.clone(), 42);
//! let mut policy = GreedyPolicy::new(cfg.env.clone());
//! let report = eat::coordinator::run_episode(&mut env, &mut policy, None);
//! println!("avg latency {:.1}s quality {:.3}", report.avg_response_latency, report.avg_quality);
//! ```

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod faults;
pub mod obs;
pub mod policy;
pub mod qos;
pub mod rl;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod testing;
pub mod util;
pub mod workload;
