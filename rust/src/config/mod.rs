//! Typed configuration for environments, algorithms, training, and
//! experiments, with JSON (de)serialisation and the paper's presets.
//!
//! The paper evaluates 4-node (real testbed), 8-node, and 12-node
//! (simulated) clusters at arrival rates {0.01..0.09}, {0.06..0.14},
//! {0.11..0.19} respectively (Tables IX–XI); presets here mirror those.

use crate::faults::FaultsConfig;
use crate::qos::TenantsConfig;
use crate::util::json::{self, Value};
use crate::workload::WorkloadConfig;

/// Reward / objective coefficients (Problem 1 + §V.A.4).
#[derive(Clone, Debug, PartialEq)]
pub struct RewardConfig {
    /// Quality weight α_q.
    pub alpha_q: f64,
    /// Response-time weight β_t (inside the reciprocal term).
    pub beta_t: f64,
    /// Quality-penalty weight λ_q.
    pub lambda_q: f64,
    /// Queue-wait weight μ_t (inside the reciprocal term).
    pub mu_t: f64,
    /// Minimum acceptable CLIP-proxy quality q_min.
    pub q_min: f64,
    /// Penalty p_quality applied when q_k < q_min.
    pub p_quality: f64,
    /// Penalty per missed deadline, scaled by the tenant's weight. Only
    /// tasks carrying a deadline (multi-tenant workloads) can trip it, so
    /// legacy episodes are bit-identical regardless of its value.
    pub p_deadline: f64,
}

impl Default for RewardConfig {
    fn default() -> Self {
        RewardConfig {
            alpha_q: 10.0,
            beta_t: 0.05,
            lambda_q: 5.0,
            mu_t: 0.02,
            q_min: 0.2,
            p_quality: 1.0,
            p_deadline: 1.0,
        }
    }
}

/// Calibrated execution-time model (Tables I & VI, Fig 6, §VII).
#[derive(Clone, Debug, PartialEq)]
pub struct ExecModelConfig {
    /// Model initialisation base time (s) per patch count, for counts
    /// 1, 2, 4, 8 (paper: 33.5 / 31.9 / 35.0 / extrapolated 36.0).
    pub init_base: [f64; 4],
    /// Lognormal jitter sigma on init time; grows mildly with patch count
    /// (Fig 6 shows wider spread at higher cooperate counts).
    pub init_jitter_sigma: f64,
    /// Per-inference-step time (s) per patch count 1/2/4/8
    /// (paper: 0.53 / 0.29 / 0.20 / 0.14).
    pub step_time: [f64; 4],
    /// Relative Gaussian jitter on execution time.
    pub exec_jitter_rel: f64,
    /// One-way image transfer latency between servers (s), §VII: 0.175 s
    /// between physical servers; hidden by the async design but modelled.
    pub comm_latency: f64,
    /// Fixed per-task overhead (s): process-group setup, dispatch.
    pub dispatch_overhead: f64,
    /// §VII future-work extension — partial model-cache reuse: when a
    /// server already holds the right model weights but the gang shape
    /// changed, only the NCCL process group must be rebuilt, costing this
    /// fraction of a full initialisation. 1.0 (default) = paper's
    /// DistriFusion behaviour (full unload+reload); the paper suggests
    /// ~0.2-0.4 is achievable.
    pub group_rebuild_frac: f64,
}

impl Default for ExecModelConfig {
    fn default() -> Self {
        ExecModelConfig {
            init_base: [33.5, 31.9, 35.0, 36.0],
            init_jitter_sigma: 0.08,
            step_time: [0.53, 0.29, 0.20, 0.14],
            exec_jitter_rel: 0.03,
            comm_latency: 0.175,
            dispatch_overhead: 0.1,
            group_rebuild_frac: 1.0,
        }
    }
}

impl ExecModelConfig {
    /// Index into the per-patch tables for c ∈ {1,2,4,8}.
    pub fn patch_index(c: usize) -> usize {
        match c {
            1 => 0,
            2 => 1,
            4 => 2,
            8 => 3,
            _ => panic!("unsupported patch count {c}"),
        }
    }
}

/// CLIP-score proxy q(s) (Eq. 2), calibrated to the paper's measured points
/// (17, 0.240), (20, 0.251), (25, 0.270) — these are exactly collinear with
/// slope 0.00375/step — plus a steep power-law drop below `knee` steps
/// (CLIP collapses quickly for very few denoising steps; this reproduces
/// Random's ≈0.19 mean quality over uniform steps in Table IX).
#[derive(Clone, Debug, PartialEq)]
pub struct QualityConfig {
    /// Quality at the knee-matching line: q(s) = line_q17 + slope·(s−17).
    pub line_q17: f64,
    pub slope: f64,
    /// Below `knee` steps quality falls as q(knee)·(s/knee)^drop_pow.
    pub knee: f64,
    pub drop_pow: f64,
    /// Hard cap (never exceeded even with noise).
    pub q_cap: f64,
    /// Per-task Gaussian jitter sigma (prompt-dependent variation).
    pub noise_sigma: f64,
}

impl Default for QualityConfig {
    fn default() -> Self {
        QualityConfig {
            line_q17: 0.240,
            slope: 0.00375,
            knee: 12.0,
            drop_pow: 0.6,
            q_cap: 0.272,
            noise_sigma: 0.004,
        }
    }
}

/// Optional extra rows of the policy state matrix (Eq. 6 ships three).
/// Both default to off, keeping `state_len` — and with it every trained
/// checkpoint and AOT artifact shape — exactly as before.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StateFeatures {
    /// One extra server row: health = 1/slowdown for up servers, 0 for
    /// down ones (queue columns zero). Lets policies route around churn.
    pub health: bool,
    /// Two extra queue rows: per-task deadline slack and tenant service
    /// weight (server columns zero). Lets trained policies see the
    /// tenancy axis the QoS subsystem introduced.
    pub tenancy: bool,
}

impl StateFeatures {
    pub fn extra_rows(&self) -> usize {
        (self.health as usize) + if self.tenancy { 2 } else { 0 }
    }
}

/// Fault-aware serving-loop configuration (`eat serve --resilient`):
/// heartbeat cadence, down-detection threshold, and the resilient-dispatch
/// retry budget. Times are real (wall-clock) seconds — the serving system
/// runs against live sockets, not the simulation clock.
#[derive(Clone, Debug, PartialEq)]
pub struct ServingConfig {
    /// Seconds between heartbeat sweeps over the worker set.
    pub hb_interval: f64,
    /// Per-probe socket timeout (connect, read, write) in seconds.
    pub hb_timeout: f64,
    /// Consecutive missed probes before a worker is marked down.
    pub down_after: u32,
    /// Per-worker socket timeout during resilient gang dispatch (s).
    pub dispatch_timeout: f64,
    /// Maximum dispatch rounds per task (1 initial + retries).
    pub max_rounds: usize,
    /// Seconds an infeasible task waits for workers to recover before it
    /// is deferred (the serving twin of "infeasible tasks wait, not drop").
    pub defer_timeout: f64,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            hb_interval: 0.5,
            hb_timeout: 0.25,
            down_after: 2,
            dispatch_timeout: 5.0,
            max_rounds: 3,
            defer_timeout: 30.0,
        }
    }
}

impl ServingConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.hb_interval > 0.0, "hb_interval must be > 0");
        anyhow::ensure!(self.hb_timeout > 0.0, "hb_timeout must be > 0");
        anyhow::ensure!(self.down_after >= 1, "down_after must be >= 1");
        anyhow::ensure!(self.dispatch_timeout > 0.0, "dispatch_timeout must be > 0");
        anyhow::ensure!(self.max_rounds >= 1, "max_rounds must be >= 1");
        anyhow::ensure!(self.defer_timeout >= 0.0, "defer_timeout must be >= 0");
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("hb_interval", self.hb_interval)
            .set("hb_timeout", self.hb_timeout)
            .set("down_after", self.down_after as usize)
            .set("dispatch_timeout", self.dispatch_timeout)
            .set("max_rounds", self.max_rounds)
            .set("defer_timeout", self.defer_timeout);
        v
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        let mut cfg = ServingConfig::default();
        macro_rules! num {
            ($key:literal, $field:expr, $ty:ty) => {
                if let Some(x) = v.get($key).and_then(Value::as_f64) {
                    $field = x as $ty;
                }
            };
        }
        num!("hb_interval", cfg.hb_interval, f64);
        num!("hb_timeout", cfg.hb_timeout, f64);
        num!("down_after", cfg.down_after, u32);
        num!("dispatch_timeout", cfg.dispatch_timeout, f64);
        num!("max_rounds", cfg.max_rounds, usize);
        num!("defer_timeout", cfg.defer_timeout, f64);
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Environment (cluster + workload + episode) configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct EnvConfig {
    /// |E|: number of edge servers (GPU workers).
    pub num_servers: usize,
    /// l: number of queue slots visible to the scheduler.
    pub queue_window: usize,
    /// Task arrival rate λ; inter-arrival t^g ~ Exp(λ).
    pub arrival_rate: f64,
    /// Support of D_c (collaboration requirement), e.g. [1,2,4,8].
    pub patch_choices: Vec<usize>,
    /// Weights of D_c (uniform if all equal).
    pub patch_weights: Vec<f64>,
    /// Number of distinct AIGC model/service types (model reuse matters
    /// only when tasks share a type).
    pub num_models: usize,
    /// S_min / S_max inference-step bounds (4d).
    pub s_min: u32,
    pub s_max: u32,
    /// Episode termination: wall-clock limit (s), decision-step limit,
    /// and number of tasks submitted per episode.
    pub time_limit: f64,
    pub step_limit: usize,
    pub tasks_per_episode: usize,
    /// Simulated decision tick Δt (s).
    pub decision_dt: f64,
    /// Workload scenario (arrival process + task mix). `None` keeps the
    /// paper's stationary Poisson at `arrival_rate` with a uniform mix,
    /// bit-identical to the seed generator.
    pub workload: Option<WorkloadConfig>,
    /// Multi-tenant QoS section: per-tenant SLO classes with their own
    /// arrival processes, plus the admission policy and queue discipline.
    /// When set it supersedes `workload`/`arrival_rate` as the task
    /// source; `None` keeps the single-tenant behaviour exactly.
    pub tenants: Option<TenantsConfig>,
    /// Server-health dynamics (failures / zone shocks / stragglers) plus
    /// recovery, retry, and speculation policy. `None` — or an inert
    /// section ([`FaultsConfig::is_active`] false) — keeps the seed's
    /// fault-free behaviour bit-identically.
    pub faults: Option<FaultsConfig>,
    /// Optional extra state-matrix rows (health / tenancy features).
    pub state_features: StateFeatures,
    pub reward: RewardConfig,
    pub exec: ExecModelConfig,
    pub quality: QualityConfig,
}

impl Default for EnvConfig {
    fn default() -> Self {
        EnvConfig {
            num_servers: 8,
            queue_window: 8,
            arrival_rate: 0.1,
            patch_choices: vec![1, 2, 4, 8],
            patch_weights: vec![1.0, 1.0, 1.0, 1.0],
            num_models: 3,
            s_min: 1,
            s_max: 25,
            time_limit: 1024.0,
            step_limit: 1024,
            tasks_per_episode: 32,
            decision_dt: 1.0,
            workload: None,
            tenants: None,
            faults: None,
            state_features: StateFeatures::default(),
            reward: RewardConfig::default(),
            exec: ExecModelConfig::default(),
            quality: QualityConfig::default(),
        }
    }
}

impl EnvConfig {
    /// State matrix dimensions (Eq. 6): 3 × (|E| + l), plus any opt-in
    /// feature rows (health / tenancy) behind `state_features`.
    pub fn state_rows(&self) -> usize {
        3 + self.state_features.extra_rows()
    }
    pub fn state_cols(&self) -> usize {
        self.num_servers + self.queue_window
    }
    pub fn state_len(&self) -> usize {
        self.state_rows() * self.state_cols()
    }
    /// Action vector length (Eq. 8): [a_c, a_s, a_k1..a_kl].
    pub fn action_len(&self) -> usize {
        2 + self.queue_window
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.num_servers >= 1, "need at least one server");
        anyhow::ensure!(self.queue_window >= 1, "queue window must be >= 1");
        anyhow::ensure!(self.arrival_rate > 0.0, "arrival rate must be > 0");
        anyhow::ensure!(
            self.patch_choices.len() == self.patch_weights.len(),
            "patch choices/weights length mismatch"
        );
        anyhow::ensure!(
            self.patch_choices.iter().all(|&c| matches!(c, 1 | 2 | 4 | 8)),
            "patch counts must be in {{1,2,4,8}}"
        );
        anyhow::ensure!(
            self.patch_choices.iter().all(|&c| c <= self.num_servers),
            "a patch count exceeds the cluster size"
        );
        anyhow::ensure!(self.s_min >= 1 && self.s_min < self.s_max, "bad step bounds");
        anyhow::ensure!(self.num_models >= 1, "need at least one model type");
        if let Some(w) = &self.workload {
            w.validate()?;
        }
        if let Some(t) = &self.tenants {
            t.validate()?;
        }
        if let Some(f) = &self.faults {
            f.validate()?;
        }
        Ok(())
    }
}

/// Which scheduling algorithm drives decisions.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// Full EAT: attention + diffusion SAC.
    Eat,
    /// EAT-A: diffusion SAC, no attention (≈ D2SAC).
    EatA,
    /// EAT-D: attention SAC, no diffusion.
    EatD,
    /// EAT-DA: plain SAC (no attention, no diffusion).
    EatDa,
    /// PPO baseline.
    Ppo,
    /// Harmony Search meta-heuristic.
    Harmony,
    /// Genetic Algorithm meta-heuristic.
    Genetic,
    Random,
    Greedy,
}

impl Algorithm {
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Eat => "EAT",
            Algorithm::EatA => "EAT-A",
            Algorithm::EatD => "EAT-D",
            Algorithm::EatDa => "EAT-DA",
            Algorithm::Ppo => "PPO",
            Algorithm::Harmony => "Harmony",
            Algorithm::Genetic => "Genetic",
            Algorithm::Random => "Random",
            Algorithm::Greedy => "Greedy",
        }
    }

    /// Artifact key used by aot.py / the manifest (RL algorithms only).
    pub fn artifact_key(&self) -> Option<&'static str> {
        match self {
            Algorithm::Eat => Some("eat"),
            Algorithm::EatA => Some("eat_a"),
            Algorithm::EatD => Some("eat_d"),
            Algorithm::EatDa => Some("eat_da"),
            Algorithm::Ppo => Some("ppo"),
            _ => None,
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Algorithm> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "eat" => Algorithm::Eat,
            "eat-a" | "eat_a" | "eata" | "d2sac" => Algorithm::EatA,
            "eat-d" | "eat_d" | "eatd" => Algorithm::EatD,
            "eat-da" | "eat_da" | "eatda" | "sac" => Algorithm::EatDa,
            "ppo" => Algorithm::Ppo,
            "harmony" => Algorithm::Harmony,
            "genetic" => Algorithm::Genetic,
            "random" => Algorithm::Random,
            "greedy" => Algorithm::Greedy,
            other => anyhow::bail!("unknown algorithm '{other}'"),
        })
    }

    pub fn all() -> [Algorithm; 9] {
        [
            Algorithm::Eat,
            Algorithm::EatA,
            Algorithm::EatD,
            Algorithm::EatDa,
            Algorithm::Ppo,
            Algorithm::Genetic,
            Algorithm::Harmony,
            Algorithm::Random,
            Algorithm::Greedy,
        ]
    }
}

/// Training hyperparameters (paper Table VIII).
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    /// Actor / critic learning rates η_a, η_c.
    pub lr_actor: f64,
    pub lr_critic: f64,
    /// Entropy temperature α.
    pub entropy_alpha: f64,
    /// Target soft-update rate τ.
    pub soft_tau: f64,
    /// Batch size b (paper 512; default reduced for CPU PJRT).
    pub batch_size: usize,
    /// Discount γ.
    pub gamma: f64,
    /// Diffusion denoise steps T.
    pub denoise_steps: usize,
    /// Replay capacity D.
    pub replay_capacity: usize,
    /// Environment steps collected before updates start.
    pub warmup_steps: usize,
    /// Gradient updates per environment step.
    pub updates_per_step: usize,
    /// Training episodes E.
    pub episodes: usize,
    /// PPO-specific: rollout horizon, epochs, clip, GAE λ, value/entropy coef.
    pub ppo_horizon: usize,
    pub ppo_epochs: usize,
    pub ppo_clip: f64,
    pub ppo_gae_lambda: f64,
    pub ppo_value_coef: f64,
    pub ppo_entropy_coef: f64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            lr_actor: 3e-4,
            lr_critic: 3e-4,
            entropy_alpha: 0.05,
            soft_tau: 0.005,
            batch_size: 128,
            gamma: 0.95,
            denoise_steps: 10,
            replay_capacity: 200_000,
            warmup_steps: 256,
            updates_per_step: 1,
            episodes: 50,
            ppo_horizon: 256,
            ppo_epochs: 4,
            ppo_clip: 0.2,
            ppo_gae_lambda: 0.95,
            ppo_value_coef: 0.5,
            ppo_entropy_coef: 0.01,
        }
    }
}

/// Top-level experiment config.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    pub env: EnvConfig,
    pub train: TrainConfig,
    pub algorithm: Algorithm,
    pub seed: u64,
    /// Directory with AOT artifacts + manifest.json.
    pub artifacts_dir: String,
    /// Fault-aware serving-loop settings (`eat serve --resilient`);
    /// `None` uses the built-in defaults.
    pub serving: Option<ServingConfig>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            env: EnvConfig::default(),
            train: TrainConfig::default(),
            algorithm: Algorithm::Eat,
            seed: 42,
            artifacts_dir: "artifacts".to_string(),
            serving: None,
        }
    }
}

impl ExperimentConfig {
    /// Paper's 4-node real testbed: patches limited to {1,2,4}.
    pub fn preset_4node(arrival_rate: f64) -> Self {
        let mut cfg = ExperimentConfig::default();
        cfg.env.num_servers = 4;
        cfg.env.queue_window = 6;
        cfg.env.arrival_rate = arrival_rate;
        cfg.env.patch_choices = vec![1, 2, 4];
        cfg.env.patch_weights = vec![1.0, 1.0, 1.0];
        cfg
    }

    /// Paper's 8-node simulated cluster.
    pub fn preset_8node(arrival_rate: f64) -> Self {
        let mut cfg = ExperimentConfig::default();
        cfg.env.num_servers = 8;
        cfg.env.queue_window = 8;
        cfg.env.arrival_rate = arrival_rate;
        cfg
    }

    /// Paper's 12-node simulated cluster.
    pub fn preset_12node(arrival_rate: f64) -> Self {
        let mut cfg = ExperimentConfig::default();
        cfg.env.num_servers = 12;
        cfg.env.queue_window = 8;
        cfg.env.arrival_rate = arrival_rate;
        cfg
    }

    /// Preset by node count with the paper's default (middle) arrival rate.
    pub fn preset(nodes: usize) -> Self {
        match nodes {
            4 => Self::preset_4node(0.05),
            8 => Self::preset_8node(0.1),
            12 => Self::preset_12node(0.15),
            other => {
                let mut cfg = ExperimentConfig::default();
                cfg.env.num_servers = other;
                cfg
            }
        }
    }

    /// Config key used in artifact names: "n{servers}l{window}".
    pub fn topology_key(&self) -> String {
        format!("n{}l{}", self.env.num_servers, self.env.queue_window)
    }

    // --- JSON round trip -------------------------------------------------

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("algorithm", self.algorithm.name().to_ascii_lowercase().replace('-', "_"));
        v.set("seed", self.seed);
        v.set("artifacts_dir", self.artifacts_dir.as_str());
        if let Some(s) = &self.serving {
            v.set("serving", s.to_json());
        }
        let e = &self.env;
        let mut env = Value::obj();
        env.set("num_servers", e.num_servers)
            .set("queue_window", e.queue_window)
            .set("arrival_rate", e.arrival_rate)
            .set("patch_choices", e.patch_choices.clone())
            .set("patch_weights", e.patch_weights.clone())
            .set("num_models", e.num_models)
            .set("s_min", e.s_min as usize)
            .set("s_max", e.s_max as usize)
            .set("time_limit", e.time_limit)
            .set("step_limit", e.step_limit)
            .set("tasks_per_episode", e.tasks_per_episode)
            .set("decision_dt", e.decision_dt);
        if let Some(w) = &e.workload {
            env.set("workload", w.to_json());
        }
        if let Some(t) = &e.tenants {
            env.set("tenants", t.to_json());
        }
        if let Some(f) = &e.faults {
            env.set("faults", f.to_json());
        }
        if e.state_features != StateFeatures::default() {
            let mut sf = Value::obj();
            sf.set("health", e.state_features.health)
                .set("tenancy", e.state_features.tenancy);
            env.set("state_features", sf);
        }
        let r = &e.reward;
        let mut rew = Value::obj();
        rew.set("alpha_q", r.alpha_q)
            .set("beta_t", r.beta_t)
            .set("lambda_q", r.lambda_q)
            .set("mu_t", r.mu_t)
            .set("q_min", r.q_min)
            .set("p_quality", r.p_quality)
            .set("p_deadline", r.p_deadline);
        env.set("reward", rew);
        let x = &e.exec;
        let mut exec = Value::obj();
        exec.set("init_base", x.init_base.to_vec())
            .set("init_jitter_sigma", x.init_jitter_sigma)
            .set("step_time", x.step_time.to_vec())
            .set("exec_jitter_rel", x.exec_jitter_rel)
            .set("comm_latency", x.comm_latency)
            .set("dispatch_overhead", x.dispatch_overhead);
        env.set("exec", exec);
        let q = &e.quality;
        let mut qual = Value::obj();
        qual.set("line_q17", q.line_q17)
            .set("slope", q.slope)
            .set("knee", q.knee)
            .set("drop_pow", q.drop_pow)
            .set("q_cap", q.q_cap)
            .set("noise_sigma", q.noise_sigma);
        env.set("quality", qual);
        v.set("env", env);
        let t = &self.train;
        let mut tr = Value::obj();
        tr.set("lr_actor", t.lr_actor)
            .set("lr_critic", t.lr_critic)
            .set("entropy_alpha", t.entropy_alpha)
            .set("soft_tau", t.soft_tau)
            .set("batch_size", t.batch_size)
            .set("gamma", t.gamma)
            .set("denoise_steps", t.denoise_steps)
            .set("replay_capacity", t.replay_capacity)
            .set("warmup_steps", t.warmup_steps)
            .set("updates_per_step", t.updates_per_step)
            .set("episodes", t.episodes)
            .set("ppo_horizon", t.ppo_horizon)
            .set("ppo_epochs", t.ppo_epochs)
            .set("ppo_clip", t.ppo_clip)
            .set("ppo_gae_lambda", t.ppo_gae_lambda)
            .set("ppo_value_coef", t.ppo_value_coef)
            .set("ppo_entropy_coef", t.ppo_entropy_coef);
        v.set("train", tr);
        v
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        let mut cfg = ExperimentConfig::default();
        if let Some(alg) = v.get("algorithm").and_then(Value::as_str) {
            cfg.algorithm = Algorithm::parse(alg)?;
        }
        if let Some(s) = v.get("seed").and_then(Value::as_f64) {
            cfg.seed = s as u64;
        }
        if let Some(d) = v.get("artifacts_dir").and_then(Value::as_str) {
            cfg.artifacts_dir = d.to_string();
        }
        if let Some(s) = v.get("serving") {
            cfg.serving = Some(ServingConfig::from_json(s)?);
        }
        if let Some(env) = v.get("env") {
            let e = &mut cfg.env;
            macro_rules! num {
                ($key:literal, $field:expr, $ty:ty) => {
                    if let Some(x) = env.get($key).and_then(Value::as_f64) {
                        $field = x as $ty;
                    }
                };
            }
            num!("num_servers", e.num_servers, usize);
            num!("queue_window", e.queue_window, usize);
            num!("arrival_rate", e.arrival_rate, f64);
            num!("num_models", e.num_models, usize);
            num!("s_min", e.s_min, u32);
            num!("s_max", e.s_max, u32);
            num!("time_limit", e.time_limit, f64);
            num!("step_limit", e.step_limit, usize);
            num!("tasks_per_episode", e.tasks_per_episode, usize);
            num!("decision_dt", e.decision_dt, f64);
            if let Some(pc) = env.get("patch_choices").and_then(Value::as_usize_vec) {
                e.patch_choices = pc;
            }
            if let Some(pw) = env.get("patch_weights").and_then(Value::as_arr) {
                e.patch_weights = pw.iter().filter_map(Value::as_f64).collect();
            }
            if let Some(w) = env.get("workload") {
                e.workload = Some(WorkloadConfig::from_json(w)?);
            }
            if let Some(t) = env.get("tenants") {
                e.tenants = Some(TenantsConfig::from_json(t)?);
            }
            if let Some(f) = env.get("faults") {
                e.faults = Some(FaultsConfig::from_json(f)?);
            }
            if let Some(sf) = env.get("state_features") {
                e.state_features.health =
                    sf.get("health").and_then(Value::as_bool).unwrap_or(false);
                e.state_features.tenancy =
                    sf.get("tenancy").and_then(Value::as_bool).unwrap_or(false);
            }
            if let Some(r) = env.get("reward") {
                let rc = &mut e.reward;
                macro_rules! rnum {
                    ($key:literal, $field:expr) => {
                        if let Some(x) = r.get($key).and_then(Value::as_f64) {
                            $field = x;
                        }
                    };
                }
                rnum!("alpha_q", rc.alpha_q);
                rnum!("beta_t", rc.beta_t);
                rnum!("lambda_q", rc.lambda_q);
                rnum!("mu_t", rc.mu_t);
                rnum!("q_min", rc.q_min);
                rnum!("p_quality", rc.p_quality);
                rnum!("p_deadline", rc.p_deadline);
            }
        }
        if let Some(t) = v.get("train") {
            let tc = &mut cfg.train;
            macro_rules! tnum {
                ($key:literal, $field:expr, $ty:ty) => {
                    if let Some(x) = t.get($key).and_then(Value::as_f64) {
                        $field = x as $ty;
                    }
                };
            }
            tnum!("lr_actor", tc.lr_actor, f64);
            tnum!("lr_critic", tc.lr_critic, f64);
            tnum!("entropy_alpha", tc.entropy_alpha, f64);
            tnum!("soft_tau", tc.soft_tau, f64);
            tnum!("batch_size", tc.batch_size, usize);
            tnum!("gamma", tc.gamma, f64);
            tnum!("denoise_steps", tc.denoise_steps, usize);
            tnum!("replay_capacity", tc.replay_capacity, usize);
            tnum!("warmup_steps", tc.warmup_steps, usize);
            tnum!("updates_per_step", tc.updates_per_step, usize);
            tnum!("episodes", tc.episodes, usize);
            tnum!("ppo_horizon", tc.ppo_horizon, usize);
            tnum!("ppo_epochs", tc.ppo_epochs, usize);
            tnum!("ppo_clip", tc.ppo_clip, f64);
            tnum!("ppo_gae_lambda", tc.ppo_gae_lambda, f64);
            tnum!("ppo_value_coef", tc.ppo_value_coef, f64);
            tnum!("ppo_entropy_coef", tc.ppo_entropy_coef, f64);
        }
        cfg.env.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &str) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&json::parse(&text)?)
    }

    pub fn save(&self, path: &str) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_json_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ExperimentConfig::default().env.validate().unwrap();
        ExperimentConfig::preset_4node(0.05).env.validate().unwrap();
        ExperimentConfig::preset_8node(0.1).env.validate().unwrap();
        ExperimentConfig::preset_12node(0.15).env.validate().unwrap();
    }

    #[test]
    fn json_roundtrip_preserves_fields() {
        let mut cfg = ExperimentConfig::preset_8node(0.12);
        cfg.algorithm = Algorithm::Ppo;
        cfg.seed = 1234;
        cfg.train.batch_size = 64;
        let v = cfg.to_json();
        let back = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(back.algorithm, Algorithm::Ppo);
        assert_eq!(back.seed, 1234);
        assert_eq!(back.train.batch_size, 64);
        assert_eq!(back.env.num_servers, 8);
        assert!((back.env.arrival_rate - 0.12).abs() < 1e-12);
        assert_eq!(back.env.workload, None);
    }

    #[test]
    fn serving_config_roundtrips_and_validates() {
        let cfg = ServingConfig {
            hb_interval: 0.2,
            hb_timeout: 0.1,
            down_after: 1,
            dispatch_timeout: 2.0,
            max_rounds: 4,
            defer_timeout: 12.0,
        };
        let back = ServingConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);
        // The section rides the experiment-config file round trip.
        let mut exp = ExperimentConfig::preset_4node(0.05);
        exp.serving = Some(cfg.clone());
        let exp_back = ExperimentConfig::from_json(&exp.to_json()).unwrap();
        assert_eq!(exp_back.serving, Some(cfg));
        assert_eq!(ExperimentConfig::default().serving, None);
        // Defaults fill absent keys.
        let sparse =
            ServingConfig::from_json(&json::parse("{\"hb_interval\":1.5}").unwrap()).unwrap();
        assert!((sparse.hb_interval - 1.5).abs() < 1e-12);
        assert_eq!(sparse.down_after, ServingConfig::default().down_after);
        // Invalid values fail at parse time.
        assert!(ServingConfig::from_json(&json::parse("{\"max_rounds\":0}").unwrap()).is_err());
        assert!(ServingConfig::from_json(&json::parse("{\"hb_interval\":0}").unwrap()).is_err());
    }

    #[test]
    fn json_roundtrip_preserves_workload_scenario() {
        let mut cfg = ExperimentConfig::preset_8node(0.1);
        cfg.env.workload = Some(WorkloadConfig::preset("rotating", 0.1).unwrap());
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.env.workload, cfg.env.workload);
        // A bad scenario must fail validation at parse time.
        let mut bad = cfg.env.workload.clone().unwrap();
        if let crate::workload::ArrivalConfig::Diurnal { amplitude, .. } = &mut bad.arrival {
            *amplitude = 7.0;
        }
        cfg.env.workload = Some(bad);
        assert!(ExperimentConfig::from_json(&cfg.to_json()).is_err());
    }

    #[test]
    fn json_roundtrip_preserves_tenants_section() {
        use crate::qos::{AdmissionConfig, QueueDiscipline, TenantsConfig};
        let mut cfg = ExperimentConfig::preset_8node(0.1);
        let mut tenants = TenantsConfig::three_tier(0.3);
        tenants.admission = AdmissionConfig::DropTail { max_queue: 24 };
        tenants.queue = QueueDiscipline::EdfWfq;
        cfg.env.tenants = Some(tenants);
        cfg.env.reward.p_deadline = 2.5;
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.env.tenants, cfg.env.tenants);
        assert!((back.env.reward.p_deadline - 2.5).abs() < 1e-12);
        // An invalid tenant must fail validation at parse time.
        let mut bad = cfg.env.tenants.clone().unwrap();
        bad.tenants[0].weight = -1.0;
        cfg.env.tenants = Some(bad);
        assert!(ExperimentConfig::from_json(&cfg.to_json()).is_err());
    }

    #[test]
    fn json_roundtrip_preserves_faults_section() {
        let mut cfg = ExperimentConfig::preset_8node(0.1);
        cfg.env.faults = Some(FaultsConfig {
            mtbf: 240.0,
            zones: 2,
            health_aware: false,
            ..FaultsConfig::default()
        });
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.env.faults, cfg.env.faults);
        // A config without the section parses to None (old configs load).
        cfg.env.faults = None;
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.env.faults, None);
        // Invalid sections fail at parse time.
        cfg.env.faults = Some(FaultsConfig { mttr: -1.0, ..FaultsConfig::default() });
        assert!(ExperimentConfig::from_json(&cfg.to_json()).is_err());
    }

    #[test]
    fn state_features_extend_dims_and_roundtrip() {
        let mut cfg = ExperimentConfig::preset_8node(0.1);
        assert_eq!(cfg.env.state_rows(), 3);
        cfg.env.state_features.health = true;
        assert_eq!(cfg.env.state_rows(), 4);
        assert_eq!(cfg.env.state_len(), 64);
        cfg.env.state_features.tenancy = true;
        assert_eq!(cfg.env.state_rows(), 6);
        assert_eq!(cfg.env.state_len(), 96);
        // Action length is untouched by state features.
        assert_eq!(cfg.env.action_len(), 10);
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.env.state_features, cfg.env.state_features);
        assert_eq!(back.env.state_len(), 96);
    }

    #[test]
    fn four_node_limits_patches() {
        let cfg = ExperimentConfig::preset_4node(0.05);
        assert_eq!(cfg.env.patch_choices, vec![1, 2, 4]);
        assert!(cfg.env.validate().is_ok());
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut cfg = EnvConfig::default();
        cfg.patch_choices = vec![16];
        assert!(cfg.validate().is_err());
        let mut cfg = EnvConfig::default();
        cfg.num_servers = 4;
        // 8-patch tasks cannot fit a 4-server cluster.
        assert!(cfg.validate().is_err());
        let mut cfg = EnvConfig::default();
        cfg.s_min = 30;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn algorithm_parse_roundtrip() {
        for alg in Algorithm::all() {
            let name = alg.name().to_ascii_lowercase();
            assert_eq!(Algorithm::parse(&name).unwrap(), alg);
        }
    }

    #[test]
    fn state_and_action_dims() {
        let cfg = ExperimentConfig::preset_8node(0.1);
        assert_eq!(cfg.env.state_cols(), 16);
        assert_eq!(cfg.env.state_len(), 48);
        assert_eq!(cfg.env.action_len(), 10);
        assert_eq!(cfg.topology_key(), "n8l8");
    }
}
