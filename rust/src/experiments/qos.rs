//! Multi-tenant QoS sweep (`eat qos`): overload factor × admission policy
//! × queue discipline, reported per tenant — p50/p90/p99 response
//! latency, SLO attainment %, and drop rate.
//!
//! Common random numbers hold per overload factor: the tenant workload is
//! a function of (tenants' arrival configs, seed, episode) only, so every
//! admission × discipline cell replays exactly the same arrivals and the
//! table isolates the controller, not workload luck.
//!
//! The dispatcher is a deterministic work-conserving head-first loop: each
//! decision tick it schedules every queue-feasible task in queue order
//! (the discipline's order — FIFO or EDF/WFQ), so the table measures the
//! queue discipline and admission policy rather than a learned policy's
//! idiosyncrasies.

use crate::config::ExperimentConfig;
use crate::qos::{AdmissionConfig, QueueDiscipline, TenantRegistry, TenantsConfig};
use crate::sim::env::{Action, EdgeEnv};
use crate::sim::task::Workload;
use crate::util::cli::Args;
use crate::util::par;
use crate::util::rng::Pcg64;
use crate::util::table::{f, Table};
use crate::workload::{MetricsCollector, TenantReport};

/// One sweep cell: a (overload, admission, discipline) combination with
/// pooled per-tenant reports over its episodes.
#[derive(Clone, Debug)]
pub struct QosCell {
    pub overload: f64,
    pub admission: AdmissionConfig,
    pub discipline: QueueDiscipline,
    pub total_tasks: usize,
    pub completed: usize,
    pub dropped: usize,
    pub tenants: Vec<TenantReport>,
}

impl QosCell {
    pub fn tenant(&self, name: &str) -> &TenantReport {
        self.tenants
            .iter()
            .find(|t| t.name == name)
            .unwrap_or_else(|| panic!("no tenant '{name}' in cell"))
    }
}

/// Run one cell's episodes with the head-first dispatcher at fixed steps.
fn run_cell(cfg: &ExperimentConfig, episodes: usize, steps: u32) -> QosCell {
    let tenants_cfg = cfg.env.tenants.as_ref().expect("qos cell needs tenants");
    let registry = TenantRegistry::new(tenants_cfg);
    let mut pooled = MetricsCollector::with_tenants(cfg.env.num_servers, &registry);
    let (mut total, mut completed, mut dropped) = (0usize, 0usize, 0usize);
    for ep in 0..episodes {
        // Mirror `evaluate`'s CRN seeding: same (seed, ep) → same workload
        // for every admission × discipline cell at this overload.
        let mut wl_rng = Pcg64::new(cfg.seed.wrapping_add(ep as u64), 0xC0FFEE);
        let workload = Workload::generate(&cfg.env, &mut wl_rng);
        let mut env = EdgeEnv::with_workload(
            cfg.env.clone(),
            workload,
            Pcg64::new(cfg.seed.wrapping_add(ep as u64), 0xE21),
        );
        let noop = Action::noop(cfg.env.queue_window);
        loop {
            while let Some(idx) = env.first_feasible() {
                if env.schedule_task_at(idx, steps).is_none() {
                    break;
                }
            }
            if env.step(&noop).done {
                break;
            }
        }
        let rep = env.report();
        total += rep.total_tasks;
        completed += rep.completed_tasks;
        dropped += rep.dropped_tasks;
        pooled.merge(env.metrics());
    }
    QosCell {
        overload: 0.0, // caller fills in
        admission: tenants_cfg.admission.clone(),
        discipline: tenants_cfg.queue,
        total_tasks: total,
        completed,
        dropped,
        tenants: pooled.tenant_reports(),
    }
}

/// Run the full sweep; one `QosCell` per combination, in sweep order.
/// `template` carries the cluster/env shape (nodes, patch mix, task count,
/// seed); `tenants_base` the unscaled tenant classes.
pub fn sweep(
    template: &ExperimentConfig,
    tenants_base: &TenantsConfig,
    episodes: usize,
    overloads: &[f64],
    admissions: &[AdmissionConfig],
    disciplines: &[QueueDiscipline],
) -> anyhow::Result<Vec<QosCell>> {
    sweep_threaded(
        template,
        tenants_base,
        episodes,
        overloads,
        admissions,
        disciplines,
        1,
    )
}

/// [`sweep`] with the cells farmed out to `threads` workers. Each cell
/// seeds its own RNG streams from `(cfg.seed, episode)` alone, so cells
/// share no state and the result vector is identical for any thread
/// count (pinned by `sweep_output_independent_of_thread_count`).
#[allow(clippy::too_many_arguments)]
pub fn sweep_threaded(
    template: &ExperimentConfig,
    tenants_base: &TenantsConfig,
    episodes: usize,
    overloads: &[f64],
    admissions: &[AdmissionConfig],
    disciplines: &[QueueDiscipline],
    threads: usize,
) -> anyhow::Result<Vec<QosCell>> {
    // Build the cell configs in sweep order first (validation stays on
    // the caller's thread), then map them in parallel.
    let mut jobs: Vec<(f64, ExperimentConfig)> = Vec::new();
    for &overload in overloads {
        anyhow::ensure!(overload > 0.0, "overload factor must be > 0");
        for admission in admissions {
            for &discipline in disciplines {
                let mut tenants = tenants_base.scaled(overload);
                tenants.admission = admission.clone();
                tenants.queue = discipline;
                let mut cfg = template.clone();
                cfg.env.tenants = Some(tenants);
                cfg.env.validate()?;
                jobs.push((overload, cfg));
            }
        }
    }
    Ok(par::map_cells(jobs, threads, |(overload, cfg)| {
        let mut cell = run_cell(&cfg, episodes, 20);
        cell.overload = overload;
        cell
    }))
}

/// Re-run episode 0 of `cfg` with lifecycle tracing on and return the
/// recorder. Recording never perturbs the episode (no RNG draws, no
/// scheduling feedback — pinned by `tracing_on_or_off_is_bit_identical`
/// in `sim::env`), so the trace describes exactly what the sweep measured.
pub fn traced_episode(cfg: &ExperimentConfig, steps: u32) -> crate::obs::trace::TraceRecorder {
    let mut wl_rng = Pcg64::new(cfg.seed, 0xC0FFEE);
    let workload = Workload::generate(&cfg.env, &mut wl_rng);
    let mut env = EdgeEnv::with_workload(cfg.env.clone(), workload, Pcg64::new(cfg.seed, 0xE21));
    env.enable_tracing(crate::obs::trace::TraceRecorder::default_capacity());
    let noop = Action::noop(cfg.env.queue_window);
    loop {
        while let Some(idx) = env.first_feasible() {
            if env.schedule_task_at(idx, steps).is_none() {
                break;
            }
        }
        if env.step(&noop).done {
            break;
        }
    }
    env.take_tracer().expect("tracing was enabled")
}

/// Re-run one episode of `cfg` with fleet sampling on and return its
/// series shard. Like tracing, sampling never perturbs the episode
/// (pinned by `sampling_on_or_off_is_bit_identical` in `sim::env`), and
/// each episode's shard is a function of `(cfg.seed, ep)` alone, so
/// shards can be computed on any thread layout and pooled bit-exactly
/// with [`crate::obs::FleetSeries::merge`].
pub fn sampled_episode(
    cfg: &ExperimentConfig,
    ep: u64,
    steps: u32,
    cadence: f64,
) -> crate::obs::FleetSeries {
    let mut wl_rng = Pcg64::new(cfg.seed.wrapping_add(ep), 0xC0FFEE);
    let workload = Workload::generate(&cfg.env, &mut wl_rng);
    let mut env = EdgeEnv::with_workload(
        cfg.env.clone(),
        workload,
        Pcg64::new(cfg.seed.wrapping_add(ep), 0xE21),
    );
    env.enable_sampling(cadence, crate::obs::FleetSeries::default_capacity());
    let noop = Action::noop(cfg.env.queue_window);
    loop {
        while let Some(idx) = env.first_feasible() {
            if env.schedule_task_at(idx, steps).is_none() {
                break;
            }
        }
        if env.step(&noop).done {
            break;
        }
    }
    env.take_series().expect("sampling was enabled")
}

fn parse_f64_list(s: &str) -> anyhow::Result<Vec<f64>> {
    s.split(',')
        .map(|x| {
            x.trim()
                .parse::<f64>()
                .map_err(|e| anyhow::anyhow!("bad number '{x}': {e}"))
        })
        .collect()
}

pub fn run(args: &Args) -> anyhow::Result<String> {
    let nodes = args.get_usize("nodes", 8);
    let tasks = args.get_usize("tasks", 120);
    let episodes = args.get_usize("episodes", 1);
    let seed = args.get_u64("seed", 42);
    let default_rate = match nodes {
        4 => 0.05,
        12 => 0.15,
        _ => 0.1,
    };
    let base_rate = args.get_f64("rate", default_rate);
    let overloads = parse_f64_list(&args.get_or("overloads", "1.0,3.0"))?;
    let max_queue = args.get_usize("max-queue", nodes * 4);
    let bucket_rate = args.get_f64("bucket-rate", base_rate);
    let bucket_burst = args.get_f64("bucket-burst", 8.0);
    let admissions: Vec<AdmissionConfig> = args
        .get_or("admissions", "admit-all,drop-tail,token-bucket")
        .split(',')
        .map(|s| match s.trim() {
            "admit-all" | "admitall" | "all" => Ok(AdmissionConfig::AdmitAll),
            "drop-tail" | "droptail" | "bounded" => {
                Ok(AdmissionConfig::DropTail { max_queue })
            }
            "token-bucket" | "tokenbucket" | "bucket" => Ok(AdmissionConfig::TokenBucket {
                rate: bucket_rate,
                burst: bucket_burst,
            }),
            other => Err(anyhow::anyhow!(
                "unknown admission '{other}' (admit-all, drop-tail, token-bucket)"
            )),
        })
        .collect::<anyhow::Result<_>>()?;
    let disciplines: Vec<QueueDiscipline> = args
        .get_or("queues", "fifo,edf")
        .split(',')
        .map(|s| QueueDiscipline::parse(s.trim()))
        .collect::<anyhow::Result<_>>()?;

    let threads = args.get_usize("threads", par::default_threads());
    let mut template = ExperimentConfig::preset(nodes);
    template.seed = seed;
    template.env.tasks_per_episode = tasks;
    let tenants_base = TenantsConfig::three_tier(base_rate);
    let cells = sweep_threaded(
        &template,
        &tenants_base,
        episodes,
        &overloads,
        &admissions,
        &disciplines,
        threads,
    )?;

    let mut table = Table::new(
        &format!(
            "Multi-tenant QoS sweep ({nodes} nodes, base rate {base_rate}, {tasks} tasks, \
             {episodes} episode(s))"
        ),
        &[
            "load", "admission", "queue", "tenant", "offered", "done", "drop%", "SLO%", "p50",
            "p90", "p99",
        ],
    );
    for cell in &cells {
        for t in &cell.tenants {
            table.row(vec![
                format!("{:.1}x", cell.overload),
                cell.admission.name().to_string(),
                cell.discipline.name().to_string(),
                t.name.clone(),
                format!("{}", t.offered),
                format!("{}", t.completed),
                f(t.drop_rate * 100.0, 1),
                f(t.slo_attainment * 100.0, 1),
                f(t.p50, 1),
                f(t.p90, 1),
                f(t.p99, 1),
            ]);
        }
    }
    let out = table.render();
    // eat-lint: allow(logging, "sweep table is the command's stdout contract")
    println!("{out}");
    super::save_csv(&format!("qos_n{nodes}"), &table.to_csv())?;
    if let Some(path) = args.get("trace") {
        // Trace the first sweep cell's episode 0 — the same config the
        // sweep just measured — and export it for `eat trace analyze` /
        // `eat slo report`. A single episode is inherently serial, so its
        // wall time is reported on its own line, never folded into the
        // sweep's.
        let mut tenants = tenants_base
            .scaled(overloads.first().copied().unwrap_or(1.0));
        tenants.admission = admissions.first().cloned().unwrap_or(AdmissionConfig::AdmitAll);
        tenants.queue = disciplines.first().copied().unwrap_or(QueueDiscipline::Fifo);
        let mut cfg = template.clone();
        cfg.env.tenants = Some(tenants);
        cfg.env.validate()?;
        crate::log_info!(
            "tracing cell load={:.1}x admission={} queue={} episode 0 (serial re-run)",
            overloads.first().copied().unwrap_or(1.0),
            cfg.env.tenants.as_ref().unwrap().admission.name(),
            cfg.env.tenants.as_ref().unwrap().queue.name(),
        );
        // eat-lint: allow(determinism, "wall-time progress telemetry; the re-run is CRN-seeded")
        let t0 = std::time::Instant::now();
        let tr = traced_episode(&cfg, 20);
        crate::log_info!("traced re-run: {:.2}s wall", t0.elapsed().as_secs_f64());
        tr.write_jsonl(path)?;
        crate::log_info!("wrote trace {path} ({} events, {} evicted)", tr.len(), tr.evicted());
    }
    if let Some(path) = args.get("timeseries") {
        // Sample the first sweep cell's episodes at a fixed cadence and
        // pool the per-episode shards — across `--threads`, since each
        // shard is a function of (seed, episode) alone and the merge is
        // bit-exact. Feeds `eat slo report` and dashboard plotting.
        let cadence = args.get_f64("cadence", 25.0);
        anyhow::ensure!(
            cadence > 0.0 && cadence.is_finite(),
            "--cadence must be a positive number of simulated seconds"
        );
        let mut tenants = tenants_base.scaled(overloads.first().copied().unwrap_or(1.0));
        tenants.admission = admissions.first().cloned().unwrap_or(AdmissionConfig::AdmitAll);
        tenants.queue = disciplines.first().copied().unwrap_or(QueueDiscipline::Fifo);
        let mut cfg = template.clone();
        cfg.env.tenants = Some(tenants);
        cfg.env.validate()?;
        let eps: Vec<u64> = (0..episodes.max(1) as u64).collect();
        let shards = par::map_cells(eps, threads, |ep| sampled_episode(&cfg, ep, 20, cadence));
        let mut merged = shards.first().cloned().expect("at least one episode");
        for s in &shards[1..] {
            merged.merge(s);
        }
        merged.write_jsonl(path)?;
        crate::log_info!(
            "wrote time series {path} ({} windows, cadence {cadence}s, {} episode(s) pooled)",
            merged.len(),
            shards.len()
        );
    }
    if let Some(path) = args.get("decisions") {
        // Record every dispatch decision of the first sweep cell's
        // episodes (same CRN pairing as the sweep; recording is
        // bit-inert) into an `eat-decisions-v1` ledger for
        // `eat decisions analyze` / `--export-experience`.
        let mut tenants = tenants_base.scaled(overloads.first().copied().unwrap_or(1.0));
        tenants.admission = admissions.first().cloned().unwrap_or(AdmissionConfig::AdmitAll);
        tenants.queue = disciplines.first().copied().unwrap_or(QueueDiscipline::Fifo);
        let mut cfg = template.clone();
        cfg.env.tenants = Some(tenants);
        cfg.env.validate()?;
        // eat-lint: allow(determinism, "wall-time progress telemetry; the re-run is CRN-seeded")
        let t0 = std::time::Instant::now();
        let ledger = super::faults::recorded_cell(&cfg, episodes, 20, threads);
        crate::log_info!("recorded re-run: {:.2}s wall", t0.elapsed().as_secs_f64());
        ledger.write_jsonl(path)?;
        crate::log_info!(
            "wrote decision ledger {path} ({} decisions, {} evicted, {} episode(s) pooled)",
            ledger.len(),
            ledger.evicted(),
            episodes.max(1)
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 8-node template with light gangs (1-2 patches). Large gangs stall
    /// on feasibility (an 8-patch task needs the whole cluster idle), which
    /// masks the queue discipline behind each tenant's random patch draw;
    /// light gangs keep the cluster work-conserving so SLO attainment is a
    /// clean function of the service share the queue grants each tier.
    fn light_gang_template(tasks: usize, seed: u64) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::preset(8);
        cfg.seed = seed;
        cfg.env.tasks_per_episode = tasks;
        cfg.env.patch_choices = vec![1, 2];
        cfg.env.patch_weights = vec![1.0, 1.0];
        cfg
    }

    /// The PR's acceptance criterion: under the overload scenario with the
    /// deadline-aware weighted queue, higher-weight tenants achieve
    /// strictly better SLO attainment than lower-weight tenants, for every
    /// admission policy.
    #[test]
    fn overload_attainment_orders_by_tenant_weight() {
        // 2 episodes × 150 tasks pooled (~100 offered per tenant) at 3x
        // overload: the weight-ordered attainment gaps (premium ≫ standard
        // ≫ batch) dwarf Poisson noise.
        let cells = sweep(
            &light_gang_template(150, 42),
            &TenantsConfig::three_tier(0.1),
            2,
            &[3.0],
            &[
                AdmissionConfig::AdmitAll,
                AdmissionConfig::DropTail { max_queue: 32 },
            ],
            &[QueueDiscipline::EdfWfq],
        )
        .unwrap();
        assert_eq!(cells.len(), 2);
        for cell in &cells {
            let premium = cell.tenant("premium").slo_attainment;
            let standard = cell.tenant("standard").slo_attainment;
            let batch = cell.tenant("batch").slo_attainment;
            assert!(
                premium > standard && standard > batch,
                "{}: attainment not ordered by weight: premium {premium:.3} \
                 standard {standard:.3} batch {batch:.3}",
                cell.admission.name()
            );
        }
        // The bounded-queue cell actually shed load at 3x overload.
        assert!(cells[1].dropped > 0, "drop-tail cell must shed under overload");
    }

    #[test]
    fn drop_tail_sheds_and_bucket_drops_by_entitlement() {
        let cells = sweep(
            &light_gang_template(80, 7),
            &TenantsConfig::three_tier(0.1),
            1,
            &[3.0],
            &[
                AdmissionConfig::DropTail { max_queue: 12 },
                AdmissionConfig::TokenBucket { rate: 0.1, burst: 6.0 },
            ],
            &[QueueDiscipline::EdfWfq],
        )
        .unwrap();
        let drop_tail = &cells[0];
        assert!(drop_tail.dropped > 0, "3x overload with a 12-slot queue must shed");
        let bucket = &cells[1];
        // Token buckets shed the lower-entitlement tenant harder: batch's
        // bucket refills at a tenth of the aggregate admit rate while its
        // demand equals the others'.
        let premium = bucket.tenant("premium").drop_rate;
        let batch = bucket.tenant("batch").drop_rate;
        assert!(
            batch > premium,
            "token bucket should drop batch ({batch:.3}) harder than premium ({premium:.3})"
        );
    }

    #[test]
    fn crn_holds_across_admission_and_discipline() {
        // Same overload and seed → identical offered counts per tenant in
        // every cell (admission/discipline cannot change the arrivals).
        let cells = sweep(
            &light_gang_template(40, 11),
            &TenantsConfig::three_tier(0.1),
            1,
            &[2.0],
            &[AdmissionConfig::AdmitAll, AdmissionConfig::DropTail { max_queue: 8 }],
            &[QueueDiscipline::Fifo, QueueDiscipline::EdfWfq],
        )
        .unwrap();
        assert_eq!(cells.len(), 4);
        for name in ["premium", "standard", "batch"] {
            let offered: Vec<u64> = cells.iter().map(|c| c.tenant(name).offered).collect();
            assert!(
                offered.windows(2).all(|w| w[0] == w[1]),
                "{name}: offered diverged across cells: {offered:?}"
            );
        }
    }

    #[test]
    fn sweep_output_independent_of_thread_count() {
        // nproc may be 1 here, so force worker counts above it: the claim
        // is about the fork-join plumbing, not about real parallel timing.
        let run_with = |threads: usize| {
            sweep_threaded(
                &light_gang_template(40, 5),
                &TenantsConfig::three_tier(0.1),
                1,
                &[1.0, 2.0],
                &[AdmissionConfig::AdmitAll, AdmissionConfig::DropTail { max_queue: 8 }],
                &[QueueDiscipline::Fifo, QueueDiscipline::EdfWfq],
                threads,
            )
            .unwrap()
        };
        let sequential = run_with(1);
        assert_eq!(sequential.len(), 8);
        for threads in [3, 4] {
            let parallel = run_with(threads);
            // Debug formatting of f64 prints the shortest uniquely
            // round-tripping string, so equal strings ⇒ equal bits.
            assert_eq!(
                format!("{sequential:?}"),
                format!("{parallel:?}"),
                "sweep diverged at {threads} threads"
            );
        }
    }

    #[test]
    fn traced_episode_books_balance_and_feed_slo_report() {
        let mut cfg = light_gang_template(40, 5);
        cfg.env.tenants = Some(TenantsConfig::three_tier(0.1).scaled(2.0));
        cfg.env.validate().unwrap();
        let tr = traced_episode(&cfg, 20);
        assert!(!tr.is_empty());
        let a = crate::obs::analyze::analyze_jsonl(&tr.to_jsonl()).unwrap();
        a.check_books().unwrap();
        // The trace drives the burn-rate path end to end: every tenant
        // class appears in the report with a non-empty outcome stream.
        let classes = crate::obs::slo::SloClass::from_config(&TenantsConfig::three_tier(0.1));
        let report = crate::obs::slo::report_from_trace(
            &tr.events(),
            &classes,
            crate::obs::slo::SloOptions::default(),
        );
        for t in &report.tenants {
            assert!(t.outcomes > 0, "{}: no outcomes in traced episode", t.name);
        }
    }

    #[test]
    fn sampled_episodes_pool_into_a_series_the_slo_report_reads() {
        let mut cfg = light_gang_template(30, 5);
        cfg.env.tenants = Some(TenantsConfig::three_tier(0.1).scaled(2.0));
        cfg.env.validate().unwrap();
        let mut merged = sampled_episode(&cfg, 0, 20, 25.0);
        merged.merge(&sampled_episode(&cfg, 1, 20, 25.0));
        assert!(!merged.is_empty());
        let classes = crate::obs::slo::SloClass::from_config(&TenantsConfig::three_tier(0.1));
        let report = crate::obs::slo::report_from_series(
            &merged,
            &classes,
            crate::obs::slo::SloOptions::default(),
        );
        assert!(
            report.tenants.iter().any(|t| t.outcomes > 0),
            "pooled series carried no outcomes into the burn-rate report"
        );
    }

    #[test]
    fn cli_run_renders_table() {
        let args = Args::parse(
            [
                "--nodes",
                "8",
                "--tasks",
                "30",
                "--overloads",
                "1.5",
                "--admissions",
                "admit-all",
                "--queues",
                "edf",
            ]
            .map(String::from),
        );
        let out = run(&args).unwrap();
        for needle in ["premium", "standard", "batch", "SLO%", "admit-all", "edf", "1.5x"] {
            assert!(out.contains(needle), "missing {needle} in:\n{out}");
        }
    }
}
