//! Fig 5: training metrics (episode reward, actor/critic loss, episode
//! length) for the DRL algorithms in the 8-server environment. Emits one
//! curve per algorithm as CSV and a summary table comparing the first-k
//! vs last-k episode averages (the paper's qualitative claims: EAT's
//! reward trends up and its episode length converges to ~450, while
//! EAT-DA and PPO often blow through the step limit).

use crate::config::{Algorithm, ExperimentConfig};
use crate::rl::{EpisodePoint, PpoDriver, SacDriver};
use crate::runtime::Runtime;
use crate::util::cli::Args;
use crate::util::stats::mean;
use crate::util::table::{f, Table};

fn curve_csv(points: &[EpisodePoint]) -> String {
    let mut s = String::from("episode,env_steps,reward,episode_len,actor_loss,critic_loss\n");
    for p in points {
        s.push_str(&format!(
            "{},{},{:.3},{},{:.4},{:.4}\n",
            p.episode, p.env_steps, p.reward, p.episode_len, p.actor_loss, p.critic_loss
        ));
    }
    s
}

pub fn run(args: &Args) -> anyhow::Result<String> {
    let nodes = args.get_usize("nodes", 8);
    let episodes = args.get_usize("episodes", 5);
    let seed = args.get_u64("seed", 42);
    let verbose = args.has_flag("verbose");
    let algorithms = match args.get("algs") {
        None => vec![
            Algorithm::Eat,
            Algorithm::EatA,
            Algorithm::EatD,
            Algorithm::EatDa,
            Algorithm::Ppo,
        ],
        Some(list) => list
            .split(',')
            .map(|s| Algorithm::parse(s.trim()))
            .collect::<Result<Vec<_>, _>>()?,
    };
    let rt = Runtime::new(args.get("artifacts").unwrap_or("artifacts"))?;
    let mut t = Table::new(
        &format!("Fig 5: Training metrics ({nodes} servers, {episodes} episodes)"),
        &[
            "Algorithm",
            "reward first",
            "reward last",
            "ep-len first",
            "ep-len last",
            "final critic loss",
        ],
    );
    for alg in &algorithms {
        let mut cfg = ExperimentConfig::preset(nodes);
        cfg.algorithm = *alg;
        cfg.seed = seed;
        let on_ep = |p: &EpisodePoint| {
            if verbose {
                crate::log_debug!(
                    "  [{} ep {}] reward {:.1} len {}",
                    alg.name(),
                    p.episode,
                    p.reward,
                    p.episode_len
                );
            }
        };
        let curve = if *alg == Algorithm::Ppo {
            let mut d = PpoDriver::new(&rt, &cfg)?;
            d.train_loop(&cfg, episodes, on_ep)?
        } else {
            let mut d = SacDriver::new(&rt, &cfg)?;
            d.train_loop(&cfg, episodes, on_ep)?
        };
        let k = (episodes / 3).max(1);
        let rewards: Vec<f64> = curve.iter().map(|p| p.reward).collect();
        let lens: Vec<f64> = curve.iter().map(|p| p.episode_len as f64).collect();
        t.row(vec![
            alg.name().to_string(),
            f(mean(&rewards[..k]), 1),
            f(mean(&rewards[rewards.len() - k..]), 1),
            f(mean(&lens[..k]), 0),
            f(mean(&lens[lens.len() - k..]), 0),
            f(curve.last().map(|p| p.critic_loss).unwrap_or(0.0), 3),
        ]);
        super::save_csv(
            &format!("fig5_curve_{}", alg.artifact_key().unwrap_or("x")),
            &curve_csv(&curve),
        )?;
    }
    let out = t.render();
    // eat-lint: allow(logging, "paper table is the command's stdout contract")
    println!("{out}");
    Ok(out)
}
