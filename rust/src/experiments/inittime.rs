//! Fig 6: initialisation-time variability vs cooperate (patch) count —
//! samples of the measured model-load time distribution per gang size,
//! reported as mean / std / p10 / p90 series.

use crate::config::ExecModelConfig;
use crate::sim::exec_model::ExecModel;
use crate::util::cli::Args;
use crate::util::rng::Pcg64;
use crate::util::stats::{percentile, Welford};
use crate::util::table::{f, Table};

pub fn run(args: &Args) -> anyhow::Result<String> {
    let samples = args.get_usize("samples", 400);
    let em = ExecModel::new(ExecModelConfig::default());
    // eat-lint: allow(rng, "stream 0 is the published paper-figure stream; nothing to pair with")
    let mut rng = Pcg64::seeded(args.get_u64("seed", 42));
    let mut t = Table::new(
        "Fig 6: Initialization Time with Different Cooperate Number",
        &["Cooperate #", "mean (s)", "std (s)", "p10 (s)", "p90 (s)"],
    );
    for &patches in &[1usize, 2, 4, 8] {
        let mut w = Welford::new();
        let mut xs = Vec::with_capacity(samples);
        for _ in 0..samples {
            let v = em.sample_init(patches, &mut rng);
            w.push(v);
            xs.push(v);
        }
        t.row(vec![
            patches.to_string(),
            f(w.mean(), 1),
            f(w.std(), 2),
            f(percentile(&xs, 0.1), 1),
            f(percentile(&xs, 0.9), 1),
        ]);
    }
    let out = t.render();
    // eat-lint: allow(logging, "paper table is the command's stdout contract")
    println!("{out}");
    super::save_csv("fig6_init_time", &t.to_csv())?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spread_grows_with_cooperate_count() {
        let args = Args::parse(std::iter::empty());
        let out = run(&args).unwrap();
        // 4 patch-count rows + header/rule/title.
        assert_eq!(out.lines().count(), 7);
    }
}
