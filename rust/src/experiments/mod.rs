//! Experiment harness: one module per table/figure of the paper's
//! evaluation (§VI). Each regenerates the same rows/series the paper
//! reports, printed as text tables and dumped as CSV under `results/`.
//!
//! | paper artifact | module | CLI |
//! |---|---|---|
//! | Table I (patch acceleration) | `tables` | `eat experiment table1` |
//! | Tables II–IV (EAT vs Traditional trace) | `motivation` | `eat experiment table2_4` |
//! | Table VI (time prediction constants) | `tables` | `eat experiment table6` |
//! | Fig 4 (serving-system speedups) | `fig4` | `eat experiment fig4` |
//! | Fig 5 (training curves) | `training` | `eat experiment fig5` |
//! | Tables IX/X/XI + Fig 8 (grids) | `grid` | `eat experiment table9 ...` |
//! | Table XII (decision latency) | `latency` | `eat experiment table12` |
//! | Fig 6 (init-time variability) | `inittime` | `eat experiment fig6` |
//! | Fig 7 (time prediction scatter) | `timepred` | `eat experiment fig7` |
//! | Scenario sweep (beyond the paper) | `scenarios` | `eat scenarios` |
//! | Multi-tenant QoS sweep (beyond the paper) | `qos` | `eat qos` |
//! | Fault & straggler sweep (beyond the paper) | `faults` | `eat faults` |

pub mod bench;
pub mod faults;
pub mod fig4;
pub mod grid;
pub mod inittime;
pub mod latency;
pub mod motivation;
pub mod qos;
pub mod scenarios;
pub mod tables;
pub mod timepred;
pub mod training;

use crate::config::{Algorithm, ExperimentConfig};
use crate::policy::{self, Policy};
use crate::rl::{PpoDriver, SacDriver};
use crate::runtime::Runtime;
use crate::util::cli::Args;

/// Run an experiment by id; returns the rendered report (also printed).
pub fn run(name: &str, args: &Args) -> anyhow::Result<String> {
    let out = match name {
        "table1" => tables::table1(args)?,
        "table6" => tables::table6(args)?,
        "table2_4" | "motivation" => motivation::run(args)?,
        "fig4" => fig4::run(args)?,
        "fig5" | "training" => training::run(args)?,
        "table9" | "table10" | "table11" | "fig8" | "grid" => grid::run(args)?,
        "table12" | "latency" => latency::run(args)?,
        "fig6" => inittime::run(args)?,
        "fig7" => timepred::run(args)?,
        "scenarios" => scenarios::run(args)?,
        "qos" => qos::run(args)?,
        "faults" => faults::run(args)?,
        "bench" => bench::run(args)?,
        "all" => {
            let mut all = String::new();
            for id in [
                "table1", "table6", "table2_4", "fig6", "fig7", "fig4", "table12", "grid",
            ] {
                all.push_str(&run(id, args)?);
                all.push('\n');
            }
            all
        }
        other => anyhow::bail!(
            "unknown experiment '{other}' (try table1, table2_4, table6, table9, \
             table10, table11, table12, fig4, fig5, fig6, fig7, fig8, grid, scenarios, qos, \
             faults, all)"
        ),
    };
    Ok(out)
}

/// Write an experiment's CSV dump under `results/`.
pub fn save_csv(name: &str, csv: &str) -> anyhow::Result<()> {
    std::fs::create_dir_all("results")?;
    std::fs::write(format!("results/{name}.csv"), csv)?;
    Ok(())
}

/// Default checkpoint path for a trained actor.
pub fn checkpoint_path(cfg: &ExperimentConfig) -> String {
    format!(
        "{}/checkpoints/{}_{}.actor.f32",
        cfg.artifacts_dir,
        cfg.algorithm.artifact_key().unwrap_or("none"),
        cfg.topology_key()
    )
}

/// Build a policy ready for evaluation: heuristics as-is; RL policies are
/// loaded from a checkpoint if present, otherwise trained for
/// `train_episodes` fresh episodes first (and checkpointed).
pub fn trained_policy(
    cfg: &ExperimentConfig,
    rt: Option<&Runtime>,
    train_episodes: usize,
    verbose: bool,
) -> anyhow::Result<Box<dyn Policy>> {
    match cfg.algorithm {
        Algorithm::Random | Algorithm::Greedy | Algorithm::Harmony | Algorithm::Genetic => {
            policy::build_policy(cfg, rt)
        }
        Algorithm::Ppo => {
            let rt = rt.ok_or_else(|| anyhow::anyhow!("PPO needs artifacts runtime"))?;
            let mut driver = PpoDriver::new(rt, cfg)?;
            let ckpt = checkpoint_path(cfg);
            if std::path::Path::new(&ckpt).exists() {
                driver.load_actor(&ckpt)?;
                if verbose {
                    crate::log_debug!("loaded checkpoint {ckpt}");
                }
            } else if train_episodes > 0 {
                driver.train_loop(cfg, train_episodes, |p| {
                    if verbose {
                        crate::log_debug!(
                            "  [PPO ep {}] reward {:.1} len {}",
                            p.episode, p.reward, p.episode_len
                        );
                    }
                })?;
                std::fs::create_dir_all(format!("{}/checkpoints", cfg.artifacts_dir)).ok();
                driver.save_actor(&ckpt).ok();
            }
            Ok(Box::new(policy::PpoPolicy::from_driver(driver, false)))
        }
        _ => {
            let rt = rt.ok_or_else(|| anyhow::anyhow!("{} needs artifacts runtime", cfg.algorithm.name()))?;
            let mut driver = SacDriver::new(rt, cfg)?;
            let ckpt = checkpoint_path(cfg);
            if std::path::Path::new(&ckpt).exists() {
                driver.load_actor(&ckpt)?;
                if verbose {
                    crate::log_debug!("loaded checkpoint {ckpt}");
                }
            } else if train_episodes > 0 {
                driver.train_loop(cfg, train_episodes, |p| {
                    if verbose {
                        crate::log_debug!(
                            "  [{} ep {}] reward {:.1} len {} critic {:.3}",
                            cfg.algorithm.name(),
                            p.episode,
                            p.reward,
                            p.episode_len,
                            p.critic_loss
                        );
                    }
                })?;
                std::fs::create_dir_all(format!("{}/checkpoints", cfg.artifacts_dir)).ok();
                driver.save_actor(&ckpt).ok();
            }
            Ok(Box::new(policy::SacPolicy::from_driver(driver, false)))
        }
    }
}
