//! Scenario sweep: every workload scenario family × every requested policy,
//! reported with percentile-grade latency (p50/p90/p99), utilization, and
//! reload counts — the evaluation axis the paper's stationary-Poisson grid
//! cannot reach.
//!
//! Common random numbers hold *per scenario*: every policy sees the same
//! workload realisations for a given (scenario, episode), so rows differ
//! only by policy. `--record <dir>` writes each realisation as a JSONL
//! trace; `--replay <file>` re-runs policies on a recorded trace and — with
//! the same `--seed`/`--ep` (plus `--scenario`/`--rate` for policies that
//! plan or train on the env config) as the recording run — reproduces the
//! original episode numbers bit-exactly.

use crate::config::{Algorithm, ExperimentConfig};
use crate::coordinator::{evaluate, run_episode};
use crate::runtime::Runtime;
use crate::sim::env::EdgeEnv;
use crate::sim::task::Workload;
use crate::util::cli::Args;
use crate::util::par;
use crate::util::rng::Pcg64;
use crate::util::table::{f, Table};
use crate::workload::{trace, WorkloadConfig};

/// Paper-aligned default rate for a cluster size (the middle rate column).
fn default_rate(nodes: usize) -> f64 {
    match nodes {
        4 => 0.05,
        12 => 0.15,
        _ => 0.1,
    }
}

fn parse_algorithms(args: &Args) -> anyhow::Result<Vec<Algorithm>> {
    args.get_or("algs", "greedy,random,harmony")
        .split(',')
        .map(|s| Algorithm::parse(s.trim()))
        .collect()
}

fn parse_scenarios(args: &Args) -> Vec<String> {
    match args.get("scenarios") {
        Some(list) => list.split(',').map(|s| s.trim().to_string()).collect(),
        None => WorkloadConfig::scenario_names()
            .iter()
            .map(|s| s.to_string())
            .collect(),
    }
}

pub fn run(args: &Args) -> anyhow::Result<String> {
    if let Some(path) = args.get("replay") {
        return replay(args, path);
    }
    let nodes = args.get_usize("nodes", 8);
    let episodes = args.get_usize("episodes", 2);
    let seed = args.get_u64("seed", 42);
    let rate = args.get_f64("rate", default_rate(nodes));
    let train_episodes = args.get_usize("train-episodes", 2);
    let verbose = args.has_flag("verbose");
    let algorithms = parse_algorithms(args)?;
    let scenarios = parse_scenarios(args);
    let needs_rt = algorithms.iter().any(|a| a.artifact_key().is_some());
    let rt = if needs_rt {
        Some(Runtime::new(args.get("artifacts").unwrap_or("artifacts"))?)
    } else {
        None
    };

    let threads = args.get_usize("threads", par::default_threads());

    let mut table = Table::new(
        &format!("Scenario sweep ({nodes} nodes, base rate {rate}, {episodes} episodes)"),
        &[
            "Scenario", "Algorithm", "p50", "p90", "p99", "mean", "util", "reload", "quality",
        ],
    );

    // Sequential pre-pass: validate configs, record traces, and lay the
    // (scenario × algorithm) cells out in sweep order.
    let mut jobs: Vec<(String, ExperimentConfig)> = Vec::new();
    for scenario in &scenarios {
        let wcfg = WorkloadConfig::preset(scenario, rate)?;
        let mut cfg = ExperimentConfig::preset(nodes);
        cfg.seed = seed;
        cfg.env.arrival_rate = rate;
        cfg.env.workload = Some(wcfg);

        if let Some(dir) = args.get("record") {
            std::fs::create_dir_all(dir)?;
            for ep in 0..episodes {
                // Must mirror `evaluate`'s common-random-number seeding so
                // the recorded trace is exactly what the policies saw.
                let mut wl_rng = Pcg64::new(seed.wrapping_add(ep as u64), 0xC0FFEE);
                let w = Workload::generate(&cfg.env, &mut wl_rng);
                let path = format!("{dir}/{scenario}_ep{ep}.jsonl");
                trace::write_file(&w, &path)?;
                if verbose {
                    crate::log_debug!("recorded {path} ({} tasks)", w.len());
                }
            }
        }

        for alg in &algorithms {
            cfg.algorithm = *alg;
            jobs.push((scenario.clone(), cfg.clone()));
        }
    }

    // Heuristic policies are self-contained, so their cells run on the
    // thread pool; artifact-backed policies hold a `Runtime` handle and
    // stay sequential. Every cell seeds its RNG streams from (seed, ep)
    // alone, so the rows are identical for any thread count.
    fn run_row(
        scenario: &str,
        cfg: &ExperimentConfig,
        rt: Option<&Runtime>,
        train_episodes: usize,
        episodes: usize,
        verbose: bool,
    ) -> anyhow::Result<Vec<String>> {
        if verbose {
            crate::log_debug!("scenario {scenario}: running {}...", cfg.algorithm.name());
        }
        let mut policy = super::trained_policy(cfg, rt, train_episodes, verbose)?;
        let s = evaluate(cfg, policy.as_mut(), episodes);
        Ok(vec![
            scenario.to_string(),
            cfg.algorithm.name().to_string(),
            f(s.p50_latency, 1),
            f(s.p90_latency, 1),
            f(s.p99_latency, 1),
            f(s.avg_response_latency, 1),
            f(s.avg_utilization, 3),
            f(s.reload_rate, 3),
            f(s.avg_quality, 3),
        ])
    }
    // eat-lint: allow(determinism, "wall-time progress telemetry; the sweep itself is CRN-seeded")
    let t_sweep = std::time::Instant::now();
    let rows: Vec<Vec<String>> = if let Some(rt) = &rt {
        let mut rows = Vec::with_capacity(jobs.len());
        for (scenario, cfg) in &jobs {
            rows.push(run_row(scenario, cfg, Some(rt), train_episodes, episodes, verbose)?);
        }
        rows
    } else {
        par::map_cells(jobs, threads, |(scenario, cfg)| {
            run_row(&scenario, &cfg, None, train_episodes, episodes, verbose)
        })
        .into_iter()
        .collect::<anyhow::Result<_>>()?
    };
    crate::log_info!(
        "sweep: {} cells x {episodes} episode(s) in {:.2}s wall on {}",
        rows.len(),
        t_sweep.elapsed().as_secs_f64(),
        if rt.is_some() {
            "1 thread (artifact-backed policies stay sequential)".to_string()
        } else {
            format!("{threads} thread(s)")
        },
    );
    for row in rows {
        table.row(row);
    }

    let out = table.render();
    // eat-lint: allow(logging, "sweep table is the command's stdout contract")
    println!("{out}");
    super::save_csv(&format!("scenarios_n{nodes}"), &table.to_csv())?;
    if let Some(path) = args.get("trace") {
        // Trace the first (scenario × algorithm) cell's episode 0 — the
        // same CRN streams the sweep used, with the same policy driving
        // dispatch — and export it for `eat trace analyze`. A single
        // episode is inherently serial, so its wall time is logged on its
        // own line, never folded into the sweep's.
        let scenario = scenarios.first().map(String::as_str).unwrap_or("poisson");
        let mut cfg = ExperimentConfig::preset(nodes);
        cfg.seed = seed;
        cfg.env.arrival_rate = rate;
        cfg.env.workload = Some(WorkloadConfig::preset(scenario, rate)?);
        cfg.algorithm = *algorithms.first().unwrap_or(&Algorithm::Greedy);
        crate::log_info!(
            "tracing cell scenario={scenario} algorithm={} episode 0 (serial re-run)",
            cfg.algorithm.name(),
        );
        // eat-lint: allow(determinism, "wall-time progress telemetry; the re-run is CRN-seeded")
        let t0 = std::time::Instant::now();
        let mut policy = super::trained_policy(&cfg, rt.as_ref(), train_episodes, verbose)?;
        let mut wl_rng = Pcg64::new(seed, 0xC0FFEE);
        let workload = Workload::generate(&cfg.env, &mut wl_rng);
        let mut env = EdgeEnv::with_workload(cfg.env.clone(), workload, Pcg64::new(seed, 0xE21));
        env.enable_tracing(crate::obs::trace::TraceRecorder::default_capacity());
        run_episode(&mut env, policy.as_mut(), None);
        let tr = env.take_tracer().expect("tracing was enabled");
        crate::log_info!("traced re-run: {:.2}s wall", t0.elapsed().as_secs_f64());
        tr.write_jsonl(path)?;
        crate::log_info!("wrote trace {path} ({} events, {} evicted)", tr.len(), tr.evicted());
    }
    if let Some(path) = args.get("decisions") {
        // Record the first (scenario × algorithm) cell's episode 0 into a
        // decision ledger — the same CRN streams the sweep used, labelled
        // with the policy that drove dispatch, so `eat decisions analyze`
        // can compare regret across algorithms.
        let scenario = scenarios.first().map(String::as_str).unwrap_or("poisson");
        let mut cfg = ExperimentConfig::preset(nodes);
        cfg.seed = seed;
        cfg.env.arrival_rate = rate;
        cfg.env.workload = Some(WorkloadConfig::preset(scenario, rate)?);
        cfg.algorithm = *algorithms.first().unwrap_or(&Algorithm::Greedy);
        crate::log_info!(
            "recording decisions for cell scenario={scenario} algorithm={} episode 0 (serial re-run)",
            cfg.algorithm.name(),
        );
        // eat-lint: allow(determinism, "wall-time progress telemetry; the re-run is CRN-seeded")
        let t0 = std::time::Instant::now();
        let mut policy = super::trained_policy(&cfg, rt.as_ref(), train_episodes, verbose)?;
        let mut wl_rng = Pcg64::new(seed, 0xC0FFEE);
        let workload = Workload::generate(&cfg.env, &mut wl_rng);
        let mut env = EdgeEnv::with_workload(cfg.env.clone(), workload, Pcg64::new(seed, 0xE21));
        env.enable_decisions(
            cfg.algorithm.name(),
            crate::obs::decisions::DecisionLedger::default_capacity(),
        );
        run_episode(&mut env, policy.as_mut(), None);
        let ledger = env.take_decisions().expect("recording was enabled");
        crate::log_info!("recorded re-run: {:.2}s wall", t0.elapsed().as_secs_f64());
        ledger.write_jsonl(path)?;
        crate::log_info!(
            "wrote decision ledger {path} ({} decisions, {} evicted)",
            ledger.len(),
            ledger.evicted()
        );
    }
    Ok(out)
}

/// Replay a recorded JSONL trace through every requested policy. With the
/// `--seed`/`--ep` of the recording run, a memoryless policy's
/// `EpisodeReport` matches the original episode number-for-number. For
/// policies whose decisions also depend on the env *config* — the
/// meta-heuristics plan and RL policies train on workloads generated from
/// it — pass the recording run's `--scenario` and `--rate` too, so the
/// reconstructed config (and hence planning/training) matches as well.
fn replay(args: &Args, path: &str) -> anyhow::Result<String> {
    let nodes = args.get_usize("nodes", 8);
    let seed = args.get_u64("seed", 42);
    let ep = args.get_u64("ep", 0);
    let rate = args.get_f64("rate", default_rate(nodes));
    let train_episodes = args.get_usize("train-episodes", 2);
    let verbose = args.has_flag("verbose");
    let algorithms = parse_algorithms(args)?;
    let workload = trace::read_file(path)?;
    let scenario = match args.get("scenario") {
        Some(name) => Some(WorkloadConfig::preset(name, rate)?),
        None => None,
    };
    let needs_rt = algorithms.iter().any(|a| a.artifact_key().is_some());
    let rt = if needs_rt {
        Some(Runtime::new(args.get("artifacts").unwrap_or("artifacts"))?)
    } else {
        None
    };

    let mut table = Table::new(
        &format!("Trace replay: {path} ({} tasks, {nodes} nodes)", workload.len()),
        &[
            "Algorithm", "p50", "p90", "p99", "mean", "util", "reloads", "quality", "reward",
        ],
    );
    for alg in &algorithms {
        let mut cfg = ExperimentConfig::preset(nodes);
        cfg.seed = seed;
        cfg.algorithm = *alg;
        cfg.env.arrival_rate = rate;
        cfg.env.workload = scenario.clone();
        let mut policy = super::trained_policy(&cfg, rt.as_ref(), train_episodes, verbose)?;
        // Same env-rng stream as `evaluate` episode `ep` of the recording
        // run: identical jitter draws → identical EpisodeReport.
        let mut env = EdgeEnv::with_workload(
            cfg.env.clone(),
            workload.clone(),
            Pcg64::new(seed.wrapping_add(ep), 0xE21),
        );
        let rep = run_episode(&mut env, policy.as_mut(), None);
        table.row(vec![
            alg.name().to_string(),
            f(rep.p50_latency, 1),
            f(rep.p90_latency, 1),
            f(rep.p99_latency, 1),
            f(rep.avg_response_latency, 1),
            f(rep.avg_utilization, 3),
            format!("{}", rep.reloads),
            f(rep.avg_quality, 3),
            f(rep.total_reward, 1),
        ]);
    }
    let out = table.render();
    // eat-lint: allow(logging, "replay summary table is the command's stdout contract")
    println!("{out}");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::GreedyPolicy;

    #[test]
    fn sweep_covers_scenarios_and_policies() {
        let args = Args::parse(
            [
                "--nodes",
                "4",
                "--episodes",
                "1",
                "--algs",
                "greedy,random",
                "--scenarios",
                "poisson,bursty,flash",
            ]
            .map(String::from),
        );
        let out = run(&args).unwrap();
        for needle in ["poisson", "bursty", "flash", "Greedy", "Random", "p99"] {
            assert!(out.contains(needle), "missing {needle} in:\n{out}");
        }
    }

    #[test]
    fn sweep_output_independent_of_thread_count() {
        // nproc may be 1 here, so force a worker count above it: the
        // rendered table (formatted from the cells' f64s) must not move.
        let run_with = |threads: &str| {
            let args = Args::parse(
                [
                    "--nodes",
                    "4",
                    "--episodes",
                    "1",
                    "--algs",
                    "greedy,random",
                    "--scenarios",
                    "poisson,flash",
                    "--threads",
                    threads,
                ]
                .map(String::from),
            );
            run(&args).unwrap()
        };
        assert_eq!(run_with("1"), run_with("3"));
    }

    #[test]
    fn recorded_trace_replays_bit_exactly() {
        // The acceptance check: record a scenario realisation, replay it
        // through EdgeEnv with the recording run's seeds, and require an
        // identical EpisodeReport.
        let seed = 42u64;
        let ep = 0u64;
        let mut cfg = ExperimentConfig::preset_4node(0.05);
        cfg.seed = seed;
        cfg.env.workload = Some(WorkloadConfig::preset("bursty", 0.05).unwrap());

        // What `evaluate` episode 0 runs:
        let mut wl_rng = Pcg64::new(seed.wrapping_add(ep), 0xC0FFEE);
        let workload = Workload::generate(&cfg.env, &mut wl_rng);
        let run_one = |w: Workload| {
            let mut env = EdgeEnv::with_workload(
                cfg.env.clone(),
                w,
                Pcg64::new(seed.wrapping_add(ep), 0xE21),
            );
            let mut p = GreedyPolicy::new(cfg.env.clone());
            run_episode(&mut env, &mut p, None)
        };
        let original = run_one(workload.clone());

        // Round-trip through the JSONL trace format.
        let replayed = run_one(trace::from_jsonl(&trace::to_jsonl(&workload)).unwrap());

        assert_eq!(original.completed_tasks, replayed.completed_tasks);
        assert_eq!(original.total_reward.to_bits(), replayed.total_reward.to_bits());
        assert_eq!(
            original.avg_response_latency.to_bits(),
            replayed.avg_response_latency.to_bits()
        );
        assert_eq!(original.avg_quality.to_bits(), replayed.avg_quality.to_bits());
        assert_eq!(original.p50_latency.to_bits(), replayed.p50_latency.to_bits());
        assert_eq!(original.p99_latency.to_bits(), replayed.p99_latency.to_bits());
        assert_eq!(original.reloads, replayed.reloads);
        assert_eq!(original.avg_utilization.to_bits(), replayed.avg_utilization.to_bits());
    }
}
