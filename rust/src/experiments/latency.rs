//! Table XII: per-decision inference latency of each scheduling algorithm
//! (wall-clock cost of `decide()` — the policy's own compute, not the
//! simulated task time).

use crate::config::{Algorithm, ExperimentConfig};
use crate::coordinator::{run_episode, DecisionTiming};
use crate::runtime::Runtime;
use crate::sim::env::EdgeEnv;
use crate::util::cli::Args;
use crate::util::table::Table;

pub fn run(args: &Args) -> anyhow::Result<String> {
    let nodes = args.get_usize("nodes", 4);
    let seed = args.get_u64("seed", 42);
    let algorithms = match args.get("algs") {
        None => Algorithm::all().to_vec(),
        Some(list) => list
            .split(',')
            .map(|s| Algorithm::parse(s.trim()))
            .collect::<Result<Vec<_>, _>>()?,
    };
    let needs_rt = algorithms.iter().any(|a| a.artifact_key().is_some());
    let rt = if needs_rt {
        Some(Runtime::new(args.get("artifacts").unwrap_or("artifacts"))?)
    } else {
        None
    };
    let mut t = Table::new(
        &format!("Table XII: Inference (decision) Latency ({nodes} nodes)"),
        &["Algorithm", "Time (s)"],
    );
    let mut out_rows: Vec<(String, f64)> = Vec::new();
    for alg in &algorithms {
        let mut cfg = ExperimentConfig::preset(nodes);
        cfg.algorithm = *alg;
        cfg.seed = seed;
        // No training needed: Table XII measures compute cost per decision,
        // which is architecture- not weights-dependent.
        let mut policy = super::trained_policy(&cfg, rt.as_ref(), 0, false)?;
        let mut env = EdgeEnv::new(cfg.env.clone(), seed);
        let mut timing = DecisionTiming::default();
        run_episode(&mut env, policy.as_mut(), Some(&mut timing));
        out_rows.push((alg.name().to_string(), timing.mean_seconds()));
    }
    // Paper presents slowest first.
    out_rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    for (name, secs) in &out_rows {
        t.row(vec![name.clone(), format!("{secs:.2e}")]);
    }
    let out = t.render();
    // eat-lint: allow(logging, "paper table is the command's stdout contract")
    println!("{out}");
    super::save_csv("table12_decision_latency", &t.to_csv())?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_table_for_heuristics() {
        let args = Args::parse(
            ["--algs".to_string(), "random,greedy".into(), "--nodes".into(), "4".into()]
                .into_iter(),
        );
        let out = run(&args).unwrap();
        assert!(out.contains("Random") && out.contains("Greedy"));
    }
}
