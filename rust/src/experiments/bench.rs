//! `eat bench` — simulator-core benchmark (`BENCH_sim.json`).
//!
//! Runs a servers × tasks grid through the head-first dispatcher, once on
//! the event-driven core (incremental busy set, residency index,
//! infeasibility memo) and once on the seed's tick-scan core
//! (`set_legacy_scan(true)`), and reports stepped throughput (completed
//! tasks per wall second), per-tick decision latency percentiles, and
//! peak RSS. Both cores consume identical RNG streams, so a cell's
//! completed counts must agree exactly — the benchmark doubles as a
//! scale-level cross-check of the bit-exactness property tests.
//!
//! The emitted JSON is the perf trajectory's unit of record: CI runs
//! `eat bench --quick --min-speedup 10` and then
//! `eat bench compare BENCH_sim.json BENCH_quick.json` — the comparator
//! matches cells on (servers, tasks), computes new/old event-core
//! throughput ratios, emits an `eat-bench-compare-v1` verdict document,
//! and exits non-zero when any cell falls below `--min-ratio` (default
//! 0.8). The in-process `--check` flag remains for one-shot local gating
//! against a baseline file without a second invocation.

use crate::config::ExperimentConfig;
use crate::obs::schema;
use crate::sim::env::{Action, EdgeEnv};
use crate::util::cli::Args;
use crate::util::json::{self, Value};
use crate::workload::WorkloadConfig;

/// Steps requested per task, matching the `eat qos`/`eat faults` drivers.
const BENCH_STEPS: u32 = 20;

/// One (servers, tasks, mode) measurement.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub servers: usize,
    pub tasks: usize,
    /// "event" or "tick".
    pub mode: &'static str,
    pub wall_s: f64,
    pub ticks: usize,
    pub completed: usize,
    pub tasks_per_s: f64,
    pub decision_p50_us: f64,
    pub decision_p99_us: f64,
}

/// The benchmark grid: (servers, tasks, run the tick core too?). The
/// tick core is skipped at metro scale — that cell exists to show the
/// event core completing 100k servers / 1M tasks inside a CI budget,
/// which the tick core cannot.
fn grid(quick: bool) -> Vec<(usize, usize, bool)> {
    let mut g = vec![(8, 2_000, true), (1_000, 20_000, true), (10_000, 50_000, true)];
    if !quick {
        g.push((100_000, 1_000_000, false));
    }
    g
}

/// Arrival rate scaling: the 8-node preset's 0.1 tasks/s, held per-server
/// so every fleet runs at the same utilisation regime.
fn rate_for(servers: usize) -> f64 {
    servers as f64 / 80.0
}

fn bench_env(servers: usize, tasks: usize, seed: u64) -> anyhow::Result<EdgeEnv> {
    let mut cfg = ExperimentConfig::preset(8).env;
    cfg.num_servers = servers;
    cfg.tasks_per_episode = tasks;
    let rate = rate_for(servers);
    cfg.arrival_rate = rate;
    // A streamed Poisson source keeps workload memory O(1) regardless of
    // task count (1M materialised tasks would dominate peak RSS).
    cfg.workload = Some(WorkloadConfig::preset("poisson", rate)?);
    // Budget: 1.5x the nominal arrival horizon plus drain headroom, so a
    // cell ends at `done` (source drained, cluster idle) or at the cap.
    let horizon = (tasks as f64 / rate * 1.5 / cfg.decision_dt).ceil() as usize + 400;
    cfg.step_limit = horizon;
    cfg.time_limit = horizon as f64 * cfg.decision_dt;
    cfg.validate()?;
    Ok(EdgeEnv::new(cfg, seed))
}

/// Run one cell with the head-first dispatcher; `legacy` selects the core.
pub fn run_cell(
    servers: usize,
    tasks: usize,
    seed: u64,
    legacy: bool,
) -> anyhow::Result<CellResult> {
    let mut env = bench_env(servers, tasks, seed)?;
    env.set_legacy_scan(legacy);
    let noop = Action::noop(env.cfg.queue_window);
    let mut decision_ns: Vec<u64> = Vec::new();
    // eat-lint: allow(determinism, "the bench harness measures wall time by design")
    let t0 = std::time::Instant::now();
    let mut ticks = 0usize;
    loop {
        // eat-lint: allow(determinism, "the bench harness measures wall time by design")
        let d0 = std::time::Instant::now();
        while let Some(idx) = env.first_feasible() {
            if env.schedule_task_at(idx, BENCH_STEPS).is_none() {
                break;
            }
        }
        decision_ns.push(d0.elapsed().as_nanos() as u64);
        ticks += 1;
        if env.step(&noop).done {
            break;
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let completed = env.report().completed_tasks;
    decision_ns.sort_unstable();
    let pct = |p: f64| -> f64 {
        if decision_ns.is_empty() {
            return 0.0;
        }
        let idx = ((decision_ns.len() - 1) as f64 * p).round() as usize;
        decision_ns[idx] as f64 / 1_000.0
    };
    Ok(CellResult {
        servers,
        tasks,
        mode: if legacy { "tick" } else { "event" },
        wall_s,
        ticks,
        completed,
        tasks_per_s: if wall_s > 0.0 {
            completed as f64 / wall_s
        } else {
            0.0
        },
        decision_p50_us: pct(0.50),
        decision_p99_us: pct(0.99),
    })
}

/// Peak resident set size in MiB from /proc/self/status. `None` where the
/// probe has no source (non-Linux, or an unparsable VmHWM line) — reported
/// as JSON `null` rather than a fake 0, so downstream tooling can tell
/// "no data" from "no memory".
pub fn peak_rss_mib() -> Option<f64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: f64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb / 1024.0);
        }
    }
    None
}

fn cell_json(c: &CellResult) -> Value {
    let mut v = Value::obj();
    v.set("mode", c.mode)
        .set("wall_s", c.wall_s)
        .set("ticks", c.ticks)
        .set("completed", c.completed)
        .set("tasks_per_s", c.tasks_per_s)
        .set("decision_p50_us", c.decision_p50_us)
        .set("decision_p99_us", c.decision_p99_us);
    v
}

/// Assemble the BENCH_sim.json document from measured cells.
pub fn report_json(quick: bool, seed: u64, cells: &[(usize, usize, Vec<CellResult>)]) -> Value {
    let mut grid_rows: Vec<Value> = Vec::new();
    for (servers, tasks, results) in cells {
        let mut row = Value::obj();
        row.set("servers", *servers).set("tasks", *tasks);
        let event = results.iter().find(|c| c.mode == "event");
        let tick = results.iter().find(|c| c.mode == "tick");
        if let Some(c) = event {
            row.set("event", cell_json(c));
        }
        if let Some(c) = tick {
            row.set("tick", cell_json(c));
        }
        if let (Some(e), Some(t)) = (event, tick) {
            if t.tasks_per_s > 0.0 {
                row.set("speedup", e.tasks_per_s / t.tasks_per_s);
            }
        }
        grid_rows.push(row);
    }
    let mut doc = Value::obj();
    doc.set("schema", schema::BENCH)
        .set("bench", "sim")
        .set("quick", quick)
        .set("seed", seed)
        .set("steps_per_task", BENCH_STEPS as usize)
        .set("peak_rss_mib", peak_rss_mib().map_or(Value::Null, Value::Num))
        .set("grid", grid_rows);
    doc
}

/// Regression gate: every event-mode cell present in both documents must
/// reach ≥ `floor_frac` of the baseline's tasks/sec.
pub fn check_against_baseline(
    current: &Value,
    baseline: &Value,
    floor_frac: f64,
) -> anyhow::Result<()> {
    let base_rows = baseline.req("grid")?.as_arr().unwrap_or(&[]);
    let cur_rows = current.req("grid")?.as_arr().unwrap_or(&[]);
    let mut compared = 0usize;
    for base in base_rows {
        let (bs, bt) = (
            base.req("servers")?.as_usize().unwrap_or(0),
            base.req("tasks")?.as_usize().unwrap_or(0),
        );
        let Some(base_tps) = base
            .get("event")
            .and_then(|e| e.get("tasks_per_s"))
            .and_then(Value::as_f64)
        else {
            continue;
        };
        let Some(cur) = cur_rows.iter().find(|r| {
            r.get("servers").and_then(Value::as_usize) == Some(bs)
                && r.get("tasks").and_then(Value::as_usize) == Some(bt)
        }) else {
            continue;
        };
        let cur_tps = cur
            .req("event")?
            .req("tasks_per_s")?
            .as_f64()
            .unwrap_or(0.0);
        anyhow::ensure!(
            cur_tps >= floor_frac * base_tps,
            "throughput regression at {bs} servers / {bt} tasks: \
             {cur_tps:.0} tasks/s < {floor_frac} x baseline {base_tps:.0}"
        );
        compared += 1;
    }
    anyhow::ensure!(
        compared > 0,
        "baseline check matched no grid cells (schema or grid mismatch)"
    );
    Ok(())
}

/// Speedup gate: every ≥10k-server cell that ran both cores must show the
/// event core at ≥ `min_speedup` x the tick core's tasks/sec.
pub fn check_speedup(cells: &[(usize, usize, Vec<CellResult>)], min_speedup: f64) -> anyhow::Result<()> {
    let mut checked = 0usize;
    for (servers, tasks, results) in cells {
        if *servers < 10_000 {
            continue;
        }
        let (Some(e), Some(t)) = (
            results.iter().find(|c| c.mode == "event"),
            results.iter().find(|c| c.mode == "tick"),
        ) else {
            continue;
        };
        let speedup = if t.tasks_per_s > 0.0 {
            e.tasks_per_s / t.tasks_per_s
        } else {
            f64::INFINITY
        };
        anyhow::ensure!(
            speedup >= min_speedup,
            "event core only {speedup:.1}x the tick core at {servers} servers / {tasks} tasks \
             (floor {min_speedup}x)"
        );
        checked += 1;
    }
    anyhow::ensure!(checked > 0, "--min-speedup given but no >=10k-server cell ran both cores");
    Ok(())
}

/// Compare two `eat-bench-v1` documents cell-by-cell. Cells are matched
/// on (servers, tasks); each matched cell's event-core throughput ratio
/// (new/old) is checked against `min_ratio`. Returns the verdict document
/// (`eat-bench-compare-v1`) — the caller decides how to exit on `pass`.
/// Cells present in only one document are skipped, not failed: grids
/// legitimately differ between `--quick` and full runs.
pub fn compare_docs(old: &Value, new: &Value, min_ratio: f64) -> anyhow::Result<Value> {
    for (label, doc) in [("old", old), ("new", new)] {
        let schema = doc.get("schema").and_then(Value::as_str).unwrap_or("?");
        anyhow::ensure!(
            schema == self::schema::BENCH,
            "{label} document has schema {schema:?}, expected {:?}",
            self::schema::BENCH
        );
    }
    let event_tps = |row: &Value| -> Option<f64> {
        row.get("event").and_then(|e| e.get("tasks_per_s")).and_then(Value::as_f64)
    };
    let old_rows = old.req("grid")?.as_arr().unwrap_or(&[]);
    let new_rows = new.req("grid")?.as_arr().unwrap_or(&[]);
    let mut cells: Vec<Value> = Vec::new();
    let mut pass = true;
    let mut skipped = 0usize;
    for old_row in old_rows {
        let (servers, tasks) = (
            old_row.req("servers")?.as_usize().unwrap_or(0),
            old_row.req("tasks")?.as_usize().unwrap_or(0),
        );
        let Some(old_tps) = event_tps(old_row) else {
            skipped += 1;
            continue;
        };
        let Some(new_row) = new_rows.iter().find(|r| {
            r.get("servers").and_then(Value::as_usize) == Some(servers)
                && r.get("tasks").and_then(Value::as_usize) == Some(tasks)
        }) else {
            skipped += 1;
            continue;
        };
        let Some(new_tps) = event_tps(new_row) else {
            skipped += 1;
            continue;
        };
        let ratio = if old_tps > 0.0 { new_tps / old_tps } else { f64::INFINITY };
        let ok = ratio >= min_ratio;
        pass &= ok;
        let mut cell = Value::obj();
        cell.set("servers", servers)
            .set("tasks", tasks)
            .set("old_tps", old_tps)
            .set("new_tps", new_tps)
            .set("ratio", ratio)
            .set("verdict", if ok { "ok" } else { "regression" });
        cells.push(cell);
    }
    // Cells only the new document ran are unmatched in the other
    // direction; fold them into the same skip count.
    for new_row in new_rows {
        let (servers, tasks) = (
            new_row.get("servers").and_then(Value::as_usize),
            new_row.get("tasks").and_then(Value::as_usize),
        );
        if !old_rows.iter().any(|r| {
            r.get("servers").and_then(Value::as_usize) == servers
                && r.get("tasks").and_then(Value::as_usize) == tasks
        }) {
            skipped += 1;
        }
    }
    if skipped > 0 {
        crate::log_warn!(
            "bench compare: skipped {skipped} unmatched cell(s) — grids differ \
             (e.g. --quick vs full) or a cell ran only one core"
        );
    }
    anyhow::ensure!(
        !cells.is_empty(),
        "bench compare matched no grid cells (disjoint grids or schema drift)"
    );
    let mut doc = Value::obj();
    doc.set("schema", schema::BENCH_COMPARE)
        .set("min_ratio", min_ratio)
        .set("cells", cells)
        .set("skipped", skipped)
        .set("pass", pass);
    Ok(doc)
}

/// Render a compare verdict document as a terminal table.
pub fn render_compare(doc: &Value) -> String {
    let skipped = doc.get("skipped").and_then(Value::as_usize).unwrap_or(0);
    let title = if skipped > 0 {
        format!("bench compare (event-core tasks/s, new vs old; {skipped} unmatched skipped)")
    } else {
        "bench compare (event-core tasks/s, new vs old)".to_string()
    };
    let mut table = crate::util::table::Table::new(
        &title,
        &["servers", "tasks", "old", "new", "ratio", "verdict"],
    );
    for cell in doc.get("cells").and_then(Value::as_arr).unwrap_or(&[]) {
        let g = |k: &str| cell.get(k).and_then(Value::as_f64).unwrap_or(0.0);
        let verdict = cell.get("verdict").and_then(Value::as_str).unwrap_or("?");
        table.row(vec![
            format!("{}", g("servers") as usize),
            format!("{}", g("tasks") as usize),
            crate::util::table::f(g("old_tps"), 0),
            crate::util::table::f(g("new_tps"), 0),
            crate::util::table::f(g("ratio"), 3),
            verdict.to_string(),
        ]);
    }
    table.render()
}

/// `eat bench compare OLD.json NEW.json [--min-ratio 0.8] [--out v.json]`.
fn run_compare(args: &Args) -> anyhow::Result<String> {
    let (Some(old_path), Some(new_path)) = (args.positional.get(2), args.positional.get(3))
    else {
        anyhow::bail!("usage: eat bench compare OLD.json NEW.json [--min-ratio 0.8] [--out v.json]");
    };
    let min_ratio = args.get_f64("min-ratio", 0.8);
    anyhow::ensure!(min_ratio > 0.0, "--min-ratio must be positive, got {min_ratio}");
    let old = json::parse(&std::fs::read_to_string(old_path)?)?;
    let new = json::parse(&std::fs::read_to_string(new_path)?)?;
    let mut doc = compare_docs(&old, &new, min_ratio)?;
    doc.set("old", old_path.as_str()).set("new", new_path.as_str());
    let rendered = render_compare(&doc);
    // eat-lint: allow(logging, "verdict table is the command's stdout contract")
    println!("{rendered}");
    if let Some(out_path) = args.get("out") {
        std::fs::write(out_path, format!("{}\n", doc.to_json_pretty()))?;
        crate::log_info!("wrote {out_path}");
    }
    let pass = doc.get("pass").and_then(Value::as_bool) == Some(true);
    anyhow::ensure!(
        pass,
        "bench compare: at least one cell fell below {min_ratio}x of {old_path}"
    );
    crate::log_info!("bench compare: all cells >= {min_ratio}x of {old_path}");
    Ok(rendered)
}

pub fn run(args: &Args) -> anyhow::Result<String> {
    if args.positional.get(1).map(String::as_str) == Some("compare") {
        return run_compare(args);
    }
    let quick = args.has_flag("quick");
    let seed = args.get_u64("seed", 42);
    let out_path = args.get_or("out", "BENCH_sim.json");
    let mut cells: Vec<(usize, usize, Vec<CellResult>)> = Vec::new();
    for (servers, tasks, with_tick) in grid(quick) {
        let mut results = Vec::new();
        crate::log_info!("bench: {servers} servers / {tasks} tasks (event core)...");
        let event = run_cell(servers, tasks, seed, false)?;
        crate::log_info!(
            "  event: {:.0} tasks/s ({} completed, {:.2}s wall, p99 decision {:.0}us)",
            event.tasks_per_s, event.completed, event.wall_s, event.decision_p99_us
        );
        results.push(event);
        if with_tick {
            crate::log_info!("bench: {servers} servers / {tasks} tasks (tick core)...");
            let tick = run_cell(servers, tasks, seed, true)?;
            crate::log_info!(
                "  tick:  {:.0} tasks/s ({} completed, {:.2}s wall, p99 decision {:.0}us)",
                tick.tasks_per_s, tick.completed, tick.wall_s, tick.decision_p99_us
            );
            // Both cores ran the same seeds: the episodes must agree.
            anyhow::ensure!(
                results[0].completed == tick.completed,
                "core divergence at {servers} servers: event completed {} vs tick {}",
                results[0].completed,
                tick.completed
            );
            results.push(tick);
        }
        cells.push((servers, tasks, results));
    }

    let doc = report_json(quick, seed, &cells);
    if let Some(min_speedup) = args.get("min-speedup") {
        check_speedup(&cells, min_speedup.parse()?)?;
    }
    if let Some(baseline_path) = args.get("check") {
        let baseline = json::parse(&std::fs::read_to_string(baseline_path)?)?;
        check_against_baseline(&doc, &baseline, 0.8)?;
        crate::log_info!("baseline check vs {baseline_path}: ok");
    }
    let rendered = doc.to_json_pretty();
    std::fs::write(&out_path, format!("{rendered}\n"))?;
    // eat-lint: allow(logging, "bench results document is the command's stdout contract")
    println!("{rendered}");
    crate::log_info!("wrote {out_path}");
    Ok(rendered)
}

/// Deterministic smoke used by unit tests: tiny grid, both cores.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_cell_runs_both_cores_and_agrees() {
        let event = run_cell(8, 40, 7, false).unwrap();
        let tick = run_cell(8, 40, 7, true).unwrap();
        assert!(event.completed > 0, "no tasks completed: {event:?}");
        assert_eq!(event.completed, tick.completed);
        assert_eq!(event.ticks, tick.ticks);
        assert!(event.tasks_per_s > 0.0);
    }

    #[test]
    fn report_json_carries_grid_and_speedup() {
        let cells = vec![(
            10_000usize,
            100usize,
            vec![
                CellResult {
                    servers: 10_000,
                    tasks: 100,
                    mode: "event",
                    wall_s: 1.0,
                    ticks: 10,
                    completed: 100,
                    tasks_per_s: 100.0,
                    decision_p50_us: 1.0,
                    decision_p99_us: 2.0,
                },
                CellResult {
                    servers: 10_000,
                    tasks: 100,
                    mode: "tick",
                    wall_s: 12.0,
                    ticks: 10,
                    completed: 100,
                    tasks_per_s: 100.0 / 12.0,
                    decision_p50_us: 100.0,
                    decision_p99_us: 200.0,
                },
            ],
        )];
        let doc = report_json(true, 42, &cells);
        let row = &doc.req("grid").unwrap().as_arr().unwrap()[0];
        assert_eq!(row.req("servers").unwrap().as_usize(), Some(10_000));
        let speedup = row.req("speedup").unwrap().as_f64().unwrap();
        assert!((speedup - 12.0).abs() < 1e-9);
        // The speedup gate passes at 10x and fails at 13x.
        check_speedup(&cells, 10.0).unwrap();
        assert!(check_speedup(&cells, 13.0).is_err());
    }

    #[test]
    fn peak_rss_probe_is_positive_or_null() {
        let doc = report_json(true, 1, &[]);
        let field = doc.req("peak_rss_mib").unwrap();
        match peak_rss_mib() {
            // Linux: VmHWM exists and a running process has touched memory.
            Some(mib) => {
                assert!(mib > 0.0, "VmHWM parsed but non-positive: {mib}");
                assert!(field.as_f64().is_some_and(|x| x > 0.0));
            }
            // Elsewhere the report must say null, never a fake 0.
            None => assert!(matches!(field, Value::Null)),
        }
    }

    #[test]
    fn compare_verdicts_flag_only_regressed_cells() {
        let doc = |cells: &[(usize, usize, f64)]| {
            let cells: Vec<_> = cells
                .iter()
                .map(|&(servers, tasks, tps)| {
                    (
                        servers,
                        tasks,
                        vec![CellResult {
                            servers,
                            tasks,
                            mode: "event",
                            wall_s: 1.0,
                            ticks: 5,
                            completed: 10,
                            tasks_per_s: tps,
                            decision_p50_us: 1.0,
                            decision_p99_us: 2.0,
                        }],
                    )
                })
                .collect();
            report_json(true, 1, &cells)
        };
        // One healthy cell, one regressed cell, one cell only in `old`
        // (skipped, not failed).
        let old = doc(&[(8, 100, 1000.0), (1_000, 500, 2000.0), (9, 9, 1.0)]);
        let new = doc(&[(8, 100, 950.0), (1_000, 500, 1000.0)]);
        let verdict = compare_docs(&old, &new, 0.8).unwrap();
        assert_eq!(verdict.req("schema").unwrap().as_str(), Some("eat-bench-compare-v1"));
        assert_eq!(verdict.req("pass").unwrap().as_bool(), Some(false));
        let cells = verdict.req("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 2, "unmatched cell must be skipped: {verdict:?}");
        assert_eq!(
            verdict.req("skipped").unwrap().as_usize(),
            Some(1),
            "the old-only (9, 9) cell must be counted, not failed: {verdict:?}"
        );
        assert_eq!(cells[0].req("verdict").unwrap().as_str(), Some("ok"));
        assert_eq!(cells[1].req("verdict").unwrap().as_str(), Some("regression"));
        let ratio = cells[1].req("ratio").unwrap().as_f64().unwrap();
        assert!((ratio - 0.5).abs() < 1e-12, "ratio {ratio}");
        // The same pair passes under a floor below the worst ratio.
        let lax = compare_docs(&old, &new, 0.4).unwrap();
        assert_eq!(lax.req("pass").unwrap().as_bool(), Some(true));
        // The rendered table carries every matched cell, its verdict,
        // and the skip count in the header.
        let table = render_compare(&verdict);
        assert!(table.contains("regression"), "{table}");
        assert!(table.contains("0.500"), "{table}");
        assert!(table.contains("1 unmatched skipped"), "{table}");
        // A new-only cell also counts as skipped (one each way here).
        let widened = doc(&[(8, 100, 950.0), (1_000, 500, 1000.0), (77, 7, 5.0)]);
        let v2 = compare_docs(&old, &widened, 0.4).unwrap();
        assert_eq!(v2.req("skipped").unwrap().as_usize(), Some(2));
        assert_eq!(v2.req("pass").unwrap().as_bool(), Some(true));
        // Disjoint grids are an error, not a silent pass.
        assert!(compare_docs(&doc(&[(5, 5, 1.0)]), &new, 0.8).is_err());
        // Wrong schema is rejected before any cell math.
        let mut bogus = Value::obj();
        bogus.set("schema", "something-else").set("grid", Vec::<Value>::new());
        assert!(compare_docs(&bogus, &new, 0.8).is_err());
    }

    #[test]
    fn baseline_check_flags_regressions() {
        let fast = |tps: f64| {
            let cells = vec![(
                8usize,
                10usize,
                vec![CellResult {
                    servers: 8,
                    tasks: 10,
                    mode: "event",
                    wall_s: 1.0,
                    ticks: 5,
                    completed: 10,
                    tasks_per_s: tps,
                    decision_p50_us: 1.0,
                    decision_p99_us: 2.0,
                }],
            )];
            report_json(true, 1, &cells)
        };
        let baseline = fast(1000.0);
        assert!(check_against_baseline(&fast(900.0), &baseline, 0.8).is_ok());
        assert!(check_against_baseline(&fast(700.0), &baseline, 0.8).is_err());
    }
}
