//! Fig 4: the serving-system demonstration — five prompts submitted to the
//! socket-based host/worker system at 1, 2 and 4 patches; reports average
//! execution time, speedup vs single-patch, and quality (paper: x1.63 at
//! 2 patches, x2.07 at 4 including the non-compute overheads).

use crate::config::{ExecModelConfig, QualityConfig};
use crate::serving::{ServingHost, WorkerPool};
use crate::sim::quality::QualityModel;
use crate::util::cli::Args;
use crate::util::stats::Welford;
use crate::util::table::{f, Table};

pub const PROMPTS: [&str; 5] = [
    "a lighthouse on a cliff at dawn",
    "cyberpunk street market in the rain",
    "watercolor fox in a snowy forest",
    "isometric floating island with waterfalls",
    "portrait of an astronaut, studio light",
];

pub fn run(args: &Args) -> anyhow::Result<String> {
    // Compress simulated seconds so the demo finishes quickly (1 simulated
    // second sleeps time_scale real seconds).
    let time_scale = args.get_f64("time-scale", 2e-3);
    let steps = args.get_usize("steps", 20) as u32;
    let seed = args.get_u64("seed", 42);
    let pool = WorkerPool::spawn(4, ExecModelConfig::default(), time_scale, seed)?;
    let host = ServingHost::new(pool.addrs().to_vec());
    let quality = QualityModel::new(QualityConfig::default());

    let mut t = Table::new(
        "Fig 4: Serving-system execution (5 prompts, Stable-Diffusion-style)",
        &["Patches", "Avg exec (sim s)", "Speedup", "Avg quality", "Reloads"],
    );
    let mut base = 0.0;
    let mut out_csv_rows = Vec::new();
    for &patches in &[1usize, 2, 4] {
        let gang: Vec<usize> = (0..patches).collect();
        let mut w = Welford::new();
        let mut q = Welford::new();
        let mut reloads = 0usize;
        for (i, prompt) in PROMPTS.iter().enumerate() {
            let outcome = host.dispatch(
                (patches * 10 + i) as u64,
                prompt,
                steps,
                0,
                &gang,
            )?;
            // Execution time excludes the (one-off) model load, matching
            // the paper's per-image execution-time comparison.
            let exec = outcome
                .results
                .iter()
                .map(|r| r.exec_time)
                .fold(0.0, f64::max);
            if outcome.any_reload() {
                reloads += 1;
            }
            w.push(exec);
            q.push(quality.sample_quality(steps, i as u64 ^ 0xF16));
        }
        if patches == 1 {
            base = w.mean();
        }
        let speedup = base / w.mean();
        out_csv_rows.push(format!(
            "{patches},{:.2},{:.2},{:.3},{reloads}",
            w.mean(),
            speedup,
            q.mean()
        ));
        t.row(vec![
            patches.to_string(),
            f(w.mean(), 2),
            format!("x{speedup:.2}"),
            f(q.mean(), 3),
            reloads.to_string(),
        ]);
    }
    pool.shutdown();
    let out = t.render();
    // eat-lint: allow(logging, "paper table is the command's stdout contract")
    println!("{out}");
    super::save_csv(
        "fig4_serving",
        &format!(
            "patches,avg_exec_s,speedup,avg_quality,reloads\n{}\n",
            out_csv_rows.join("\n")
        ),
    )?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serving_demo_shows_parallel_speedup() {
        let args = Args::parse(
            ["--time-scale".to_string(), "1e-4".into()].into_iter(),
        );
        let out = run(&args).unwrap();
        assert!(out.contains("x1.00"));
        // 2- and 4-patch speedups should be > 1.
        let sp: Vec<f64> = out
            .lines()
            .filter_map(|l| {
                l.split_whitespace()
                    .find(|w| w.starts_with('x'))
                    .and_then(|w| w[1..].parse().ok())
            })
            .collect();
        assert_eq!(sp.len(), 3);
        assert!(sp[1] > 1.3 && sp[2] > sp[1], "speedups {sp:?}");
    }
}
