//! Table I (task acceleration with different patch counts) and Table VI
//! (time-prediction constants): probes of the calibrated execution model.

use crate::config::ExecModelConfig;
use crate::sim::exec_model::ExecModel;
use crate::util::cli::Args;
use crate::util::rng::Pcg64;
use crate::util::stats::Welford;
use crate::util::table::{f, Table};

/// Table I: total time + acceleration for 1/2/4/8 patches at the paper's
/// measured workload (~45 steps: 23.7 s single-patch / 0.53 s per step).
pub fn table1(args: &Args) -> anyhow::Result<String> {
    let steps = args.get_usize("steps", 45) as u32;
    let samples = args.get_usize("samples", 200);
    let em = ExecModel::new(ExecModelConfig::default());
    // eat-lint: allow(rng, "stream 0 is the published paper-table stream; nothing to pair with")
    let mut rng = Pcg64::seeded(args.get_u64("seed", 42));
    let mut t = Table::new(
        "Table I: Task Acceleration with Different Number of Patches",
        &["Number of Patches", "Time (s)", "Acceleration"],
    );
    let mut base = 0.0;
    for &patches in &[1usize, 2, 4, 8] {
        let mut w = Welford::new();
        for _ in 0..samples {
            w.push(em.sample_exec(steps, patches, &mut rng));
        }
        if patches == 1 {
            base = w.mean();
        }
        t.row(vec![
            patches.to_string(),
            f(w.mean(), 2),
            format!("x{:.1}", base / w.mean()),
        ]);
    }
    let out = t.render();
    // eat-lint: allow(logging, "paper table is the command's stdout contract")
    println!("{out}");
    super::save_csv("table1", &t.to_csv())?;
    Ok(out)
}

/// Table VI: init time and per-inference-step time per patch count, as the
/// time predictor estimates them (measured over many samples).
pub fn table6(args: &Args) -> anyhow::Result<String> {
    let samples = args.get_usize("samples", 500);
    let em = ExecModel::new(ExecModelConfig::default());
    // eat-lint: allow(rng, "stream 0 is the published paper-table stream; nothing to pair with")
    let mut rng = Pcg64::seeded(args.get_u64("seed", 42));
    let mut t = Table::new(
        "Table VI: Time Prediction",
        &["Patch Number", "Init Time (s)", "Time per Inference Step (s)"],
    );
    for &patches in &[1usize, 2, 4] {
        let mut init = Welford::new();
        for _ in 0..samples {
            init.push(em.sample_init(patches, &mut rng));
        }
        // Per-step slope measured from two step counts (linearity checked
        // in sim::exec_model tests and Fig 7).
        let slope = (em.predict_exec(30, patches) - em.predict_exec(10, patches)) / 20.0;
        t.row(vec![patches.to_string(), f(init.mean(), 1), f(slope, 2)]);
    }
    let out = t.render();
    // eat-lint: allow(logging, "paper table is the command's stdout contract")
    println!("{out}");
    super::save_csv("table6", &t.to_csv())?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shape_matches_paper() {
        let args = Args::parse(["--samples".into(), "50".into()].into_iter());
        let out = table1(&args).unwrap();
        assert!(out.contains("x1.0"));
        // Paper: x1.8 at 2 patches, x3.1 at 4 — ours should be in range.
        assert!(out.contains("Acceleration"));
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 7); // title + header + rule + 4 rows
    }

    #[test]
    fn table6_columns() {
        let args = Args::parse(["--samples".into(), "50".into()].into_iter());
        let out = table6(&args).unwrap();
        assert!(out.contains("0.53") || out.contains("0.29") || out.contains("0.2"));
    }
}
