//! Fault & straggler sweep (`eat faults`): MTBF × zone-shock rate ×
//! straggler rate × dispatch mode (health-aware vs fault-blind), reported
//! as goodput, wasted-work fraction, retries/kills, latency percentiles,
//! and per-tenant SLO attainment under churn.
//!
//! Common random numbers hold twice over: the tenant workload is a
//! function of (seed, episode) only, and the fault timeline is a function
//! of (seed, episode, fault rates) only — the health process draws from
//! its own stream and never consumes scheduling randomness — so the
//! aware/blind pair of every fault cell replays the *same* arrivals under
//! the *same* failure storm, isolating the dispatch mode.
//!
//! The dispatcher is the same deterministic work-conserving head-first
//! loop as `eat qos`: each tick it schedules every queue-feasible task in
//! queue order at fixed steps, so the table measures the resilience
//! machinery, not a learned policy.

use crate::config::ExperimentConfig;
use crate::faults::FaultsConfig;
use crate::obs::decisions::DecisionLedger;
use crate::obs::trace::TraceRecorder;
use crate::qos::{TenantRegistry, TenantsConfig};
use crate::sim::env::{Action, EdgeEnv};
use crate::sim::task::Workload;
use crate::util::cli::Args;
use crate::util::par;
use crate::util::rng::Pcg64;
use crate::util::table::{f, Table};
use crate::workload::{MetricsCollector, TenantReport};

/// One sweep cell: a fault configuration × dispatch mode with pooled
/// metrics over its episodes.
#[derive(Clone, Debug)]
pub struct FaultCell {
    pub mtbf: f64,
    pub zone_shock_rate: f64,
    pub straggler_rate: f64,
    pub health_aware: bool,
    pub total_tasks: usize,
    pub completed: usize,
    pub failed_tasks: usize,
    pub failures: usize,
    pub gang_kills: usize,
    pub retries: usize,
    pub spec_launches: usize,
    pub spec_wins: usize,
    pub wasted_frac: f64,
    /// Pooled completed tasks per simulated second.
    pub goodput: f64,
    pub p50: f64,
    pub p99: f64,
    /// Patch-second books pooled over episodes (balance check:
    /// dispatched = completed + wasted + inflight).
    pub dispatched_patch_s: f64,
    pub completed_patch_s: f64,
    pub wasted_patch_s: f64,
    pub inflight_patch_s: f64,
    pub tenants: Vec<TenantReport>,
}

impl FaultCell {
    pub fn mode_name(&self) -> &'static str {
        if self.health_aware {
            "aware"
        } else {
            "blind"
        }
    }

    pub fn tenant(&self, name: &str) -> &TenantReport {
        self.tenants
            .iter()
            .find(|t| t.name == name)
            .unwrap_or_else(|| panic!("no tenant '{name}' in cell"))
    }
}

/// Run one cell's episodes with the head-first dispatcher at fixed steps.
fn run_cell(cfg: &ExperimentConfig, episodes: usize, steps: u32) -> FaultCell {
    let tenants_cfg = cfg.env.tenants.as_ref().expect("fault cell needs tenants");
    let faults_cfg = cfg.env.faults.clone().unwrap_or_else(FaultsConfig::off);
    let registry = TenantRegistry::new(tenants_cfg);
    let mut pooled = MetricsCollector::with_tenants(cfg.env.num_servers, &registry);
    let (mut total, mut completed, mut failed) = (0usize, 0usize, 0usize);
    let mut sim_time = 0.0f64;
    let mut inflight_ps = 0.0f64;
    for ep in 0..episodes {
        // Mirror `evaluate`'s CRN seeding: same (seed, ep) → same workload
        // and same fault timeline for every dispatch mode in this cell.
        let mut wl_rng = Pcg64::new(cfg.seed.wrapping_add(ep as u64), 0xC0FFEE);
        let workload = Workload::generate(&cfg.env, &mut wl_rng);
        let mut env = EdgeEnv::with_workload(
            cfg.env.clone(),
            workload,
            Pcg64::new(cfg.seed.wrapping_add(ep as u64), 0xE21),
        );
        let noop = Action::noop(cfg.env.queue_window);
        loop {
            while let Some(idx) = env.first_feasible() {
                if env.schedule_task_at(idx, steps).is_none() {
                    break;
                }
            }
            if env.step(&noop).done {
                break;
            }
        }
        let rep = env.report();
        total += rep.total_tasks;
        completed += rep.completed_tasks;
        failed += rep.failed_tasks;
        sim_time += rep.sim_time;
        inflight_ps += rep.inflight_patch_s;
        pooled.merge(env.metrics());
    }
    FaultCell {
        mtbf: faults_cfg.mtbf,
        zone_shock_rate: faults_cfg.zone_shock_rate,
        straggler_rate: faults_cfg.straggler_rate,
        health_aware: faults_cfg.health_aware,
        total_tasks: total,
        completed,
        failed_tasks: failed,
        failures: pooled.failures() as usize,
        gang_kills: pooled.gang_kills() as usize,
        retries: pooled.retries() as usize,
        spec_launches: pooled.spec_launches() as usize,
        spec_wins: pooled.spec_wins() as usize,
        wasted_frac: pooled.wasted_frac(),
        goodput: if sim_time > 0.0 {
            completed as f64 / sim_time
        } else {
            0.0
        },
        p50: pooled.latency.p50(),
        p99: pooled.latency.p99(),
        dispatched_patch_s: pooled.dispatched_ps(),
        completed_patch_s: pooled.completed_ps(),
        wasted_patch_s: pooled.wasted_ps(),
        inflight_patch_s: inflight_ps,
        tenants: pooled.tenant_reports(),
    }
}

/// Re-run episode 0 of `cfg` with lifecycle tracing on and return the
/// recorder. Recording never perturbs the episode (no RNG draws, no
/// scheduling feedback — pinned by `tracing_on_or_off_is_bit_identical`
/// in `sim::env`), so the trace describes exactly what the sweep measured.
pub fn traced_episode(cfg: &ExperimentConfig, steps: u32) -> TraceRecorder {
    let mut wl_rng = Pcg64::new(cfg.seed, 0xC0FFEE);
    let workload = Workload::generate(&cfg.env, &mut wl_rng);
    let mut env = EdgeEnv::with_workload(cfg.env.clone(), workload, Pcg64::new(cfg.seed, 0xE21));
    env.enable_tracing(TraceRecorder::default_capacity());
    let noop = Action::noop(cfg.env.queue_window);
    loop {
        while let Some(idx) = env.first_feasible() {
            if env.schedule_task_at(idx, steps).is_none() {
                break;
            }
        }
        if env.step(&noop).done {
            break;
        }
    }
    env.take_tracer().expect("tracing was enabled")
}

/// Record every dispatch decision across a cell's episodes, CRN-seeded
/// exactly like [`run_cell`] so the ledger describes the very episodes
/// the sweep measured (recording is bit-inert — pinned by
/// `decision_recording_on_or_off_is_bit_identical` in `sim::env`).
/// Episodes fan out across `threads` and merge in episode order, so the
/// pooled ledger is byte-identical for any thread count.
pub fn recorded_cell(
    cfg: &ExperimentConfig,
    episodes: usize,
    steps: u32,
    threads: usize,
) -> DecisionLedger {
    let policy = match cfg.env.faults.as_ref() {
        Some(f) if f.health_aware => "aware",
        Some(_) => "blind",
        None => "head-first",
    };
    let shards = par::map_cells((0..episodes.max(1) as u64).collect(), threads, |ep| {
        let mut wl_rng = Pcg64::new(cfg.seed.wrapping_add(ep), 0xC0FFEE);
        let workload = Workload::generate(&cfg.env, &mut wl_rng);
        let mut env = EdgeEnv::with_workload(
            cfg.env.clone(),
            workload,
            Pcg64::new(cfg.seed.wrapping_add(ep), 0xE21),
        );
        env.enable_decisions(policy, DecisionLedger::default_capacity());
        let noop = Action::noop(cfg.env.queue_window);
        loop {
            while let Some(idx) = env.first_feasible() {
                if env.schedule_task_at(idx, steps).is_none() {
                    break;
                }
            }
            if env.step(&noop).done {
                break;
            }
        }
        let mut led = env.take_decisions().expect("recording was enabled");
        led.tag_episode(ep);
        led
    });
    let mut pooled: Option<DecisionLedger> = None;
    for s in &shards {
        match pooled.as_mut() {
            Some(p) => p.merge(s),
            None => pooled = Some(s.clone()),
        }
    }
    pooled.expect("at least one episode")
}

/// Run the full sweep; one `FaultCell` per combination, in sweep order.
#[allow(clippy::too_many_arguments)]
pub fn sweep(
    template: &ExperimentConfig,
    tenants_base: &TenantsConfig,
    faults_base: &FaultsConfig,
    episodes: usize,
    mtbfs: &[f64],
    zone_rates: &[f64],
    straggler_rates: &[f64],
    modes: &[bool],
) -> anyhow::Result<Vec<FaultCell>> {
    sweep_threaded(
        template,
        tenants_base,
        faults_base,
        episodes,
        mtbfs,
        zone_rates,
        straggler_rates,
        modes,
        1,
    )
}

/// [`sweep`] with the cells farmed out to `threads` workers. Both RNG
/// streams of a cell (workload and fault timeline) are functions of
/// `(cfg.seed, episode)` alone, so cells share no state and the result
/// vector is identical for any thread count (pinned by
/// `sweep_output_independent_of_thread_count`).
#[allow(clippy::too_many_arguments)]
pub fn sweep_threaded(
    template: &ExperimentConfig,
    tenants_base: &TenantsConfig,
    faults_base: &FaultsConfig,
    episodes: usize,
    mtbfs: &[f64],
    zone_rates: &[f64],
    straggler_rates: &[f64],
    modes: &[bool],
    threads: usize,
) -> anyhow::Result<Vec<FaultCell>> {
    // Build the cell configs in sweep order first (validation stays on
    // the caller's thread), then map them in parallel.
    let mut jobs: Vec<ExperimentConfig> = Vec::new();
    for &mtbf in mtbfs {
        for &zone_rate in zone_rates {
            for &straggler_rate in straggler_rates {
                for &health_aware in modes {
                    let mut faults = faults_base.clone();
                    faults.mtbf = mtbf;
                    faults.zone_shock_rate = zone_rate;
                    faults.straggler_rate = straggler_rate;
                    faults.health_aware = health_aware;
                    let mut cfg = template.clone();
                    cfg.env.tenants = Some(tenants_base.clone());
                    cfg.env.faults = Some(faults);
                    cfg.env.validate()?;
                    jobs.push(cfg);
                }
            }
        }
    }
    Ok(par::map_cells(jobs, threads, |cfg| run_cell(&cfg, episodes, 20)))
}

fn parse_f64_list(s: &str) -> anyhow::Result<Vec<f64>> {
    s.split(',')
        .map(|x| {
            x.trim()
                .parse::<f64>()
                .map_err(|e| anyhow::anyhow!("bad number '{x}': {e}"))
        })
        .collect()
}

pub fn run(args: &Args) -> anyhow::Result<String> {
    let nodes = args.get_usize("nodes", 8);
    let tasks = args.get_usize("tasks", 120);
    let episodes = args.get_usize("episodes", 1);
    let seed = args.get_u64("seed", 42);
    let default_rate = match nodes {
        4 => 0.05,
        12 => 0.15,
        _ => 0.1,
    };
    let base_rate = args.get_f64("rate", default_rate);
    let mtbfs = parse_f64_list(&args.get_or("mtbfs", "0,600,200"))?;
    let zone_rates = parse_f64_list(&args.get_or("zone-rates", "0.002"))?;
    let straggler_rates = parse_f64_list(&args.get_or("straggler-rates", "0.005"))?;
    let modes: Vec<bool> = args
        .get_or("modes", "aware,blind")
        .split(',')
        .map(|s| match s.trim() {
            "aware" | "health-aware" => Ok(true),
            "blind" | "fault-blind" => Ok(false),
            other => Err(anyhow::anyhow!("unknown mode '{other}' (aware, blind)")),
        })
        .collect::<anyhow::Result<_>>()?;
    let defaults = FaultsConfig::default();
    let faults_base = FaultsConfig {
        mttr: args.get_f64("mttr", defaults.mttr),
        zones: args.get_usize("zones", defaults.zones),
        spec_beta: args.get_f64("spec-beta", defaults.spec_beta),
        max_retries: args.get_usize("max-retries", defaults.max_retries as usize) as u32,
        ..defaults
    };

    let threads = args.get_usize("threads", par::default_threads());
    let mut template = ExperimentConfig::preset(nodes);
    template.seed = seed;
    template.env.tasks_per_episode = tasks;
    let tenants_base = TenantsConfig::three_tier(base_rate);
    // eat-lint: allow(determinism, "wall-time progress telemetry; the sweep itself is CRN-seeded")
    let t_sweep = std::time::Instant::now();
    let cells = sweep_threaded(
        &template,
        &tenants_base,
        &faults_base,
        episodes,
        &mtbfs,
        &zone_rates,
        &straggler_rates,
        &modes,
        threads,
    )?;
    crate::log_info!(
        "sweep: {} cells x {episodes} episode(s) in {:.2}s wall on {threads} thread(s)",
        cells.len(),
        t_sweep.elapsed().as_secs_f64()
    );

    let mut header: Vec<String> = [
        "mtbf", "zshock", "slow", "mode", "done", "fail", "retry", "kills", "spec", "wasted%",
        "goodput", "p50", "p99",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    for t in &tenants_base.tenants {
        header.push(format!("SLO% {}", t.name));
    }
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut table = Table::new(
        &format!(
            "Fault & straggler sweep ({nodes} nodes, base rate {base_rate}, {tasks} tasks, \
             {episodes} episode(s), mttr {}, {} zones)",
            faults_base.mttr, faults_base.zones
        ),
        &header_refs,
    );
    for cell in &cells {
        let mut row = vec![
            if cell.mtbf > 0.0 { f(cell.mtbf, 0) } else { "off".to_string() },
            f(cell.zone_shock_rate, 4),
            f(cell.straggler_rate, 4),
            cell.mode_name().to_string(),
            format!("{}/{}", cell.completed, cell.total_tasks),
            format!("{}", cell.failed_tasks),
            format!("{}", cell.retries),
            format!("{}", cell.gang_kills),
            format!("{}/{}", cell.spec_wins, cell.spec_launches),
            f(cell.wasted_frac * 100.0, 1),
            f(cell.goodput * 1000.0, 2), // tasks per 1000 simulated seconds
            f(cell.p50, 1),
            f(cell.p99, 1),
        ];
        for t in &cell.tenants {
            row.push(f(t.slo_attainment * 100.0, 1));
        }
        table.row(row);
    }
    let out = table.render();
    // eat-lint: allow(logging, "sweep table is the command's stdout contract")
    println!("{out}");
    crate::log_info!("goodput column is completed tasks per 1000 simulated seconds");
    super::save_csv(&format!("faults_n{nodes}"), &table.to_csv())?;
    if let Some(path) = args.get("trace") {
        // Trace the first sweep cell's episode 0 — the same config the
        // sweep just measured — and export it for `eat trace analyze`.
        // A single episode is inherently serial, so its wall time is
        // logged on its own line, never folded into the sweep's.
        let mut faults = faults_base.clone();
        faults.mtbf = mtbfs.first().copied().unwrap_or(0.0);
        faults.zone_shock_rate = zone_rates.first().copied().unwrap_or(0.0);
        faults.straggler_rate = straggler_rates.first().copied().unwrap_or(0.0);
        faults.health_aware = modes.first().copied().unwrap_or(true);
        crate::log_info!(
            "tracing cell mtbf={} zshock={} slow={} mode={} episode 0 (serial re-run)",
            faults.mtbf,
            faults.zone_shock_rate,
            faults.straggler_rate,
            if faults.health_aware { "aware" } else { "blind" },
        );
        let mut cfg = template.clone();
        cfg.env.tenants = Some(tenants_base.clone());
        cfg.env.faults = Some(faults);
        cfg.env.validate()?;
        // eat-lint: allow(determinism, "wall-time progress telemetry; the re-run is CRN-seeded")
        let t0 = std::time::Instant::now();
        let tr = traced_episode(&cfg, 20);
        crate::log_info!("traced re-run: {:.2}s wall", t0.elapsed().as_secs_f64());
        tr.write_jsonl(path)?;
        crate::log_info!("wrote trace {path} ({} events, {} evicted)", tr.len(), tr.evicted());
    }
    if let Some(path) = args.get("decisions") {
        // Record the first sweep cell's episodes — the same CRN-paired
        // episodes the sweep pooled — into a decision ledger for
        // `eat decisions analyze`.
        let mut faults = faults_base.clone();
        faults.mtbf = mtbfs.first().copied().unwrap_or(0.0);
        faults.zone_shock_rate = zone_rates.first().copied().unwrap_or(0.0);
        faults.straggler_rate = straggler_rates.first().copied().unwrap_or(0.0);
        faults.health_aware = modes.first().copied().unwrap_or(true);
        crate::log_info!(
            "recording decisions for cell mtbf={} zshock={} slow={} mode={} x {episodes} episode(s)",
            faults.mtbf,
            faults.zone_shock_rate,
            faults.straggler_rate,
            if faults.health_aware { "aware" } else { "blind" },
        );
        let mut cfg = template.clone();
        cfg.env.tenants = Some(tenants_base.clone());
        cfg.env.faults = Some(faults);
        cfg.env.validate()?;
        // eat-lint: allow(determinism, "wall-time progress telemetry; the re-run is CRN-seeded")
        let t0 = std::time::Instant::now();
        let ledger = recorded_cell(&cfg, episodes, 20, threads);
        crate::log_info!("recorded re-run: {:.2}s wall", t0.elapsed().as_secs_f64());
        ledger.write_jsonl(path)?;
        crate::log_info!(
            "wrote decision ledger {path} ({} decisions, {} evicted)",
            ledger.len(),
            ledger.evicted()
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 8-node template with light gangs, like the QoS tests: large gangs
    /// stall on feasibility under churn (an 8-patch task needs the whole
    /// cluster up and idle), which would measure gang-size luck instead of
    /// the dispatch mode.
    fn light_gang_template(tasks: usize, seed: u64) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::preset(8);
        cfg.seed = seed;
        cfg.env.tasks_per_episode = tasks;
        cfg.env.patch_choices = vec![1, 2];
        cfg.env.patch_weights = vec![1.0, 1.0];
        cfg
    }

    /// Heavy churn, no stragglers/speculation: isolates health-aware
    /// dispatch. mtbf 150 s on 8 servers ≈ dozens of failures per episode.
    fn churn_base() -> FaultsConfig {
        FaultsConfig {
            mtbf: 150.0,
            mttr: 60.0,
            zones: 4,
            zone_shock_rate: 0.002,
            straggler_rate: 0.0,
            spec_beta: 0.0,
            max_retries: 3,
            ..FaultsConfig::default()
        }
    }

    /// The PR's acceptance criterion: under ≥1 failure-per-episode churn,
    /// health-aware dispatch beats the fault-blind baseline on goodput and
    /// p99 latency, and the patch-second books balance in every cell.
    #[test]
    fn health_aware_beats_fault_blind_under_churn() {
        let cells = sweep(
            &light_gang_template(120, 42),
            &TenantsConfig::three_tier(0.1),
            &churn_base(),
            2,
            &[150.0],
            &[0.002],
            &[0.0],
            &[true, false],
        )
        .unwrap();
        assert_eq!(cells.len(), 2);
        let (aware, blind) = (&cells[0], &cells[1]);
        assert!(aware.health_aware && !blind.health_aware);
        // The churn regime actually bites: at least one failure per
        // episode (we expect dozens), in both cells identically (CRN).
        assert!(aware.failures >= 2, "only {} failures pooled", aware.failures);
        assert_eq!(aware.failures, blind.failures, "fault timeline must be CRN-paired");
        assert!(
            aware.goodput > blind.goodput,
            "health-aware goodput {} must beat fault-blind {}",
            aware.goodput,
            blind.goodput
        );
        assert!(
            aware.p99 < blind.p99,
            "health-aware p99 {} must beat fault-blind {}",
            aware.p99,
            blind.p99
        );
        // Blind dispatch onto down servers manufactures kills and wasted
        // work that health masking avoids.
        assert!(blind.gang_kills > aware.gang_kills);
        assert!(blind.wasted_frac > aware.wasted_frac);
        // Wasted-work accounting balances in every cell.
        for cell in &cells {
            let sum = cell.completed_patch_s + cell.wasted_patch_s + cell.inflight_patch_s;
            assert!(
                (sum - cell.dispatched_patch_s).abs()
                    <= 1e-6 * cell.dispatched_patch_s.max(1.0),
                "{}: dispatched {} != completed {} + wasted {} + inflight {}",
                cell.mode_name(),
                cell.dispatched_patch_s,
                cell.completed_patch_s,
                cell.wasted_patch_s,
                cell.inflight_patch_s
            );
        }
    }

    #[test]
    fn arrivals_stay_crn_paired_across_fault_cells() {
        // Offered counts per tenant must be identical across every fault
        // configuration — churn cannot change the arrival process.
        let cells = sweep(
            &light_gang_template(40, 11),
            &TenantsConfig::three_tier(0.1),
            &churn_base(),
            1,
            &[0.0, 300.0],
            &[0.0],
            &[0.0],
            &[true],
        )
        .unwrap();
        assert_eq!(cells.len(), 2);
        for name in ["premium", "standard", "batch"] {
            let offered: Vec<u64> = cells.iter().map(|c| c.tenant(name).offered).collect();
            assert!(
                offered.windows(2).all(|w| w[0] == w[1]),
                "{name}: offered diverged across cells: {offered:?}"
            );
        }
        // The fault-free cell reports no churn at all.
        assert_eq!(cells[0].failures, 0);
        assert_eq!(cells[0].wasted_frac, 0.0);
        assert!(cells[1].failures > 0);
    }

    #[test]
    fn stragglers_trigger_speculation_in_the_sweep() {
        let mut base = churn_base();
        base.mtbf = 0.0;
        base.zone_shock_rate = 0.0;
        base.spec_beta = 1.5;
        base.straggler_mu = 1.6; // median ~5x slowdowns: clearly past beta
        base.straggler_mean_duration = 120.0;
        let cells = sweep(
            &light_gang_template(80, 9),
            &TenantsConfig::three_tier(0.1),
            &base,
            1,
            &[0.0],
            &[0.0],
            &[0.02],
            &[true],
        )
        .unwrap();
        let cell = &cells[0];
        assert!(
            cell.spec_launches > 0,
            "heavy stragglers must trigger speculative backups"
        );
        assert!(cell.spec_wins <= cell.spec_launches);
        assert!(cell.completed > 0);
    }

    #[test]
    fn sweep_output_independent_of_thread_count() {
        // nproc may be 1 here, so force worker counts above it: the claim
        // is about the fork-join plumbing, not about real parallel timing.
        let run_with = |threads: usize| {
            sweep_threaded(
                &light_gang_template(30, 13),
                &TenantsConfig::three_tier(0.1),
                &churn_base(),
                1,
                &[0.0, 200.0],
                &[0.0, 0.002],
                &[0.0],
                &[true, false],
                threads,
            )
            .unwrap()
        };
        let sequential = run_with(1);
        assert_eq!(sequential.len(), 8);
        for threads in [3, 4] {
            let parallel = run_with(threads);
            // Debug formatting of f64 prints the shortest uniquely
            // round-tripping string, so equal strings ⇒ equal bits.
            assert_eq!(
                format!("{sequential:?}"),
                format!("{parallel:?}"),
                "sweep diverged at {threads} threads"
            );
        }
    }

    #[test]
    fn traced_episode_books_balance() {
        let mut cfg = light_gang_template(30, 5);
        cfg.env.tenants = Some(TenantsConfig::three_tier(0.1));
        cfg.env.faults = Some(churn_base());
        cfg.env.validate().unwrap();
        let tr = traced_episode(&cfg, 20);
        assert!(!tr.is_empty());
        let a = crate::obs::analyze::analyze_jsonl(&tr.to_jsonl()).unwrap();
        a.check_books().unwrap();
        assert!(!a.tasks.is_empty());
    }

    #[test]
    fn recorded_cell_ledger_is_thread_count_independent_and_balances() {
        let mut cfg = light_gang_template(30, 13);
        cfg.env.tenants = Some(TenantsConfig::three_tier(0.1));
        cfg.env.faults = Some(churn_base());
        cfg.env.validate().unwrap();
        let single = recorded_cell(&cfg, 3, 20, 1).to_jsonl();
        for threads in [3, 4] {
            assert_eq!(
                single,
                recorded_cell(&cfg, 3, 20, threads).to_jsonl(),
                "pooled ledger diverged at {threads} threads"
            );
        }
        let ledger = DecisionLedger::parse_jsonl(&single).unwrap();
        assert!(!ledger.is_empty(), "churn cell recorded no decisions");
        crate::obs::decisions::analyze(&ledger).check_books().unwrap();
    }

    #[test]
    fn aware_median_regret_does_not_exceed_blind_on_the_crn_paired_cell() {
        // The CI smoke's gate, pinned here as a test too: on the same
        // CRN-paired churn cell, health-aware dispatch should not regret
        // its choices more than fault-blind dispatch does at the median.
        let make = |aware: bool| {
            let mut cfg = light_gang_template(120, 42);
            cfg.env.tenants = Some(TenantsConfig::three_tier(0.1));
            cfg.env.faults = Some(FaultsConfig { health_aware: aware, ..churn_base() });
            cfg.env.validate().unwrap();
            crate::obs::decisions::analyze(&recorded_cell(&cfg, 2, 20, 1))
        };
        let (aware, blind) = (make(true), make(false));
        aware.check_books().unwrap();
        blind.check_books().unwrap();
        assert!(
            aware.median_regret() <= blind.median_regret() + 1e-9,
            "aware median regret {} exceeds blind {}",
            aware.median_regret(),
            blind.median_regret()
        );
    }

    #[test]
    fn cli_run_renders_table() {
        let args = Args::parse(
            [
                "--nodes",
                "8",
                "--tasks",
                "20",
                "--mtbfs",
                "200",
                "--zone-rates",
                "0.002",
                "--straggler-rates",
                "0.01",
                "--modes",
                "aware,blind",
            ]
            .map(String::from),
        );
        let out = run(&args).unwrap();
        for needle in ["aware", "blind", "wasted%", "goodput", "SLO% premium", "200"] {
            assert!(out.contains(needle), "missing {needle} in:\n{out}");
        }
    }
}
