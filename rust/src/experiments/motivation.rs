//! Tables II–IV: the motivating example (§II). Four tasks arrive 10 s
//! apart on a 4-GPU box (patches 2/2/4/2, same AIGC service). The
//! Traditional scheduler runs FIFO with fixed 20 steps and first-fit
//! placement; the EAT-style scheduler reuses loaded gangs and adapts step
//! counts to queue pressure. We report the per-task trace (steps, exec
//! time, inference latency, quality) and the Table IV summary.

use crate::config::ExperimentConfig;
use crate::coordinator::traditional::{run_traditional, TRADITIONAL_STEPS};
use crate::sim::cluster::Selection;
use crate::sim::env::{Action, EdgeEnv};
use crate::sim::task::Workload;
use crate::util::cli::Args;
use crate::util::rng::Pcg64;
use crate::util::table::{f, Table};

fn motivation_env(seed: u64) -> EdgeEnv {
    let mut cfg = ExperimentConfig::preset_4node(0.05).env;
    cfg.num_models = 1; // one AIGC service in the example
    cfg.tasks_per_episode = 4;
    cfg.time_limit = 400.0;
    cfg.step_limit = 400;
    // Tasks 1-4: patches 2, 2, 4, 2 arriving 10 s apart (paper trace).
    let wl = Workload::fixed(&[(0.0, 2, 0), (10.0, 2, 0), (20.0, 4, 0), (30.0, 2, 0)]);
    // eat-lint: allow(rng, "stream 0 is the published paper-trace stream; nothing to pair with")
    EdgeEnv::with_workload(cfg, wl, Pcg64::seeded(seed))
}

/// EAT-style heuristic used for the motivating trace, mirroring what the
/// trained EAT does in Table II: when a task must pay a cold start it gets
/// ~17 steps (the init delay is recovered by cheaper inference); when a
/// loaded gang can be reused, the task can afford the full 25 steps.
fn run_eat_style(env: &mut EdgeEnv) {
    let l = env.cfg.queue_window;
    loop {
        if !env.queue().is_empty() {
            // Prefer a task whose gang can be reused right now.
            let reuse_idx = (0..env.queue().len().min(l)).find(|&i| {
                let t = &env.queue()[i];
                matches!(env.cluster.select(t.model, t.patches), Selection::Reuse(_))
            });
            let (idx, steps) = match reuse_idx {
                Some(i) => (i, 25),
                None => (0, 17),
            };
            env.schedule_task_at(idx, steps);
        }
        if env.step(&Action::noop(l)).done {
            break;
        }
    }
}

fn trace_table(title: &str, env: &EdgeEnv) -> Table {
    let mut t = Table::new(
        title,
        &["Task", "Patch", "GPU", "Step", "Time", "Inference (s)", "Quality"],
    );
    for sch in env.trace() {
        let gpus: Vec<String> = sch.servers.iter().map(|s| (s + 1).to_string()).collect();
        let init_note = if sch.reused_model { "" } else { " (+init)" };
        t.row(vec![
            format!("Task {}", sch.task_id + 1),
            sch.servers.len().to_string(),
            gpus.join(" "),
            format!("{}{}", sch.steps, init_note),
            f(sch.duration, 1),
            f(sch.response, 1),
            f(sch.quality * 10.0, 2), // paper's example scales CLIP x10
        ]);
    }
    t
}

pub fn run(args: &Args) -> anyhow::Result<String> {
    let seed = args.get_u64("seed", 42);
    let mut out = String::new();

    let mut eat_env = motivation_env(seed);
    run_eat_style(&mut eat_env);
    let eat_rep = eat_env.report();
    let t2 = trace_table("Table II: EAT Algorithm Example", &eat_env);
    out.push_str(&t2.render());
    out.push('\n');

    let mut trad_env = motivation_env(seed);
    run_traditional(&mut trad_env);
    let trad_rep = trad_env.report();
    let t3 = trace_table(
        &format!("Table III: Traditional Algorithm Example (fixed {TRADITIONAL_STEPS} steps)"),
        &trad_env,
    );
    out.push_str(&t3.render());
    out.push('\n');

    let mut t4 = Table::new(
        "Table IV: Algorithm Performance Comparison",
        &["Metric", "EAT", "Traditional"],
    );
    t4.row(vec![
        "Quality".into(),
        f(eat_rep.avg_quality * 10.0, 2),
        f(trad_rep.avg_quality * 10.0, 2),
    ]);
    t4.row(vec![
        "Inference Latency (s)".into(),
        f(eat_rep.avg_response_latency, 2),
        f(trad_rep.avg_response_latency, 2),
    ]);
    t4.row(vec![
        "Reload Rate".into(),
        f(eat_rep.reload_rate, 2),
        f(trad_rep.reload_rate, 2),
    ]);
    out.push_str(&t4.render());
    // eat-lint: allow(logging, "paper tables are the command's stdout contract")
    println!("{out}");
    super::save_csv("table2_eat_trace", &t2.to_csv())?;
    super::save_csv("table3_traditional_trace", &t3.to_csv())?;
    super::save_csv("table4_summary", &t4.to_csv())?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eat_style_beats_traditional_on_latency() {
        let mut eat_env = motivation_env(7);
        run_eat_style(&mut eat_env);
        let mut trad_env = motivation_env(7);
        run_traditional(&mut trad_env);
        let eat = eat_env.report();
        let trad = trad_env.report();
        assert_eq!(eat.completed_tasks, 4);
        assert_eq!(trad.completed_tasks, 4);
        // Table IV shape: EAT halves latency at a small quality cost.
        assert!(
            eat.avg_response_latency < trad.avg_response_latency * 0.8,
            "eat {} vs trad {}",
            eat.avg_response_latency,
            trad.avg_response_latency
        );
        assert!(trad.avg_quality >= eat.avg_quality - 1e-9);
        // EAT reuses the 2-gang at least once; traditional reloads more.
        assert!(eat.reload_rate < trad.reload_rate + 1e-9);
    }
}
