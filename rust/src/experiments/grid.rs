//! Tables IX (quality), X (response latency), XI (reload rate) and Fig 8
//! (efficiency = quality / latency): the paper's main comparison grid of
//! nine algorithms across {4, 8, 12}-node clusters and five arrival rates
//! each.
//!
//! Every algorithm sees identical workload realisations per (nodes, rate,
//! episode) via common random numbers, so the rows differ only by policy.
//! RL rows load checkpoints from `artifacts/checkpoints/` when present
//! (produced by `eat train`), else do a short on-the-fly training run.

use crate::config::{Algorithm, ExperimentConfig};
use crate::coordinator::evaluate;
use crate::runtime::Runtime;
use crate::util::cli::Args;
use crate::util::table::{f, Table};

/// Paper Table IX arrival-rate columns per node count.
pub fn paper_rates(nodes: usize) -> Vec<f64> {
    match nodes {
        4 => vec![0.01, 0.03, 0.05, 0.07, 0.09],
        8 => vec![0.06, 0.08, 0.1, 0.12, 0.14],
        12 => vec![0.11, 0.13, 0.15, 0.17, 0.19],
        _ => vec![0.05, 0.1, 0.15],
    }
}

fn parse_algorithms(args: &Args) -> anyhow::Result<Vec<Algorithm>> {
    match args.get("algs") {
        None => Ok(Algorithm::all().to_vec()),
        Some(list) => list
            .split(',')
            .map(|s| Algorithm::parse(s.trim()))
            .collect(),
    }
}

pub fn run(args: &Args) -> anyhow::Result<String> {
    let nodes = args.get_usize("nodes", 4);
    let episodes = args.get_usize("episodes", 3);
    let train_episodes = args.get_usize("train-episodes", 2);
    let seed = args.get_u64("seed", 42);
    let verbose = args.has_flag("verbose");
    let rates = match args.get("rates") {
        Some(r) => r
            .split(',')
            .map(|x| x.trim().parse::<f64>())
            .collect::<Result<Vec<_>, _>>()?,
        None => paper_rates(nodes),
    };
    let algorithms = parse_algorithms(args)?;
    let needs_rt = algorithms.iter().any(|a| a.artifact_key().is_some());
    let rt = if needs_rt {
        Some(Runtime::new(
            args.get("artifacts").unwrap_or("artifacts"),
        )?)
    } else {
        None
    };

    let header: Vec<String> = std::iter::once("Algorithm".to_string())
        .chain(rates.iter().map(|r| format!("{r}")))
        .collect();
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut t_quality = Table::new(
        &format!("Table IX: Quality ({nodes} nodes, arrival rates)"),
        &header_refs,
    );
    let mut t_latency = Table::new(
        &format!("Table X: Response Latency ({nodes} nodes)"),
        &header_refs,
    );
    let mut t_reload = Table::new(
        &format!("Table XI: Reload Rate ({nodes} nodes)"),
        &header_refs,
    );
    let mut t_eff = Table::new(
        &format!("Fig 8: Generation Efficiency = quality/latency ({nodes} nodes)"),
        &header_refs,
    );

    for alg in &algorithms {
        // Train once per (alg, nodes) at the middle rate; evaluate across
        // all rates with the same policy (as the paper does).
        let mid_rate = rates[rates.len() / 2];
        let mut cfg = ExperimentConfig::preset(nodes);
        cfg.env.arrival_rate = mid_rate;
        cfg.algorithm = *alg;
        cfg.seed = seed;
        if verbose {
            crate::log_debug!("preparing {} ({} nodes)...", alg.name(), nodes);
        }
        let mut policy = super::trained_policy(&cfg, rt.as_ref(), train_episodes, verbose)?;
        let mut q_row = vec![alg.name().to_string()];
        let mut l_row = vec![alg.name().to_string()];
        let mut r_row = vec![alg.name().to_string()];
        let mut e_row = vec![alg.name().to_string()];
        for &rate in &rates {
            let mut ecfg = cfg.clone();
            ecfg.env.arrival_rate = rate;
            let summary = evaluate(&ecfg, policy.as_mut(), episodes);
            if verbose {
                crate::log_debug!(
                    "  {} rate {rate}: q={:.3} lat={:.1} reload={:.3}",
                    alg.name(),
                    summary.avg_quality,
                    summary.avg_response_latency,
                    summary.reload_rate
                );
            }
            q_row.push(f(summary.avg_quality, 3));
            l_row.push(f(summary.avg_response_latency, 1));
            r_row.push(f(summary.reload_rate, 3));
            e_row.push(f(summary.efficiency * 1000.0, 2)); // x1e-3 units
        }
        t_quality.row(q_row);
        t_latency.row(l_row);
        t_reload.row(r_row);
        t_eff.row(e_row);
    }

    let mut out = String::new();
    out.push_str(&t_quality.render());
    out.push('\n');
    out.push_str(&t_latency.render());
    out.push('\n');
    out.push_str(&t_reload.render());
    out.push('\n');
    out.push_str(&t_eff.render());
    // eat-lint: allow(logging, "paper tables are the command's stdout contract")
    println!("{out}");
    super::save_csv(&format!("table9_quality_n{nodes}"), &t_quality.to_csv())?;
    super::save_csv(&format!("table10_latency_n{nodes}"), &t_latency.to_csv())?;
    super::save_csv(&format!("table11_reload_n{nodes}"), &t_reload.to_csv())?;
    super::save_csv(&format!("fig8_efficiency_n{nodes}"), &t_eff.to_csv())?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates_match_paper_columns() {
        assert_eq!(paper_rates(4), vec![0.01, 0.03, 0.05, 0.07, 0.09]);
        assert_eq!(paper_rates(8)[2], 0.1);
        assert_eq!(paper_rates(12)[4], 0.19);
    }

    #[test]
    fn heuristic_only_grid_runs_without_runtime() {
        let args = Args::parse(
            [
                "--nodes".to_string(),
                "4".into(),
                "--episodes".into(),
                "1".into(),
                "--algs".into(),
                "greedy,random".into(),
            ]
            .into_iter(),
        );
        let out = run(&args).unwrap();
        assert!(out.contains("Greedy") && out.contains("Random"));
        assert!(out.contains("Table IX") && out.contains("Table XI"));
    }
}
