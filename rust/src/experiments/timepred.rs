//! Fig 7: time-prediction accuracy — predicted vs realised execution time
//! with and without model reloading, per cooperate count. Reports the
//! regression slope/R² for the no-reload case (the paper's "execution
//! time grows linearly with draw steps") and MAE for the reload case.

use crate::config::ExecModelConfig;
use crate::sim::exec_model::ExecModel;
use crate::util::cli::Args;
use crate::util::rng::Pcg64;
use crate::util::stats::linreg;
use crate::util::table::{f, Table};

pub fn run(args: &Args) -> anyhow::Result<String> {
    let em = ExecModel::new(ExecModelConfig::default());
    // eat-lint: allow(rng, "stream 0 is the published paper-figure stream; nothing to pair with")
    let mut rng = Pcg64::seeded(args.get_u64("seed", 42));
    let samples = args.get_usize("samples", 200);
    let mut t = Table::new(
        "Fig 7: Time Prediction with Different Cooperate Number",
        &[
            "Cooperate #",
            "slope actual (s/step)",
            "slope predicted",
            "R2 (no reload)",
            "MAE no-reload (s)",
            "MAE with-reload (s)",
        ],
    );
    for &patches in &[1usize, 2, 4] {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        let mut mae_plain = 0.0;
        let mut mae_reload = 0.0;
        for i in 0..samples {
            let steps = 1 + (i % 25) as u32;
            let actual = em.sample_exec(steps, patches, &mut rng);
            let pred = em.predict_exec(steps, patches);
            xs.push(steps as f64);
            ys.push(actual);
            mae_plain += (actual - pred).abs();
            let actual_r = actual + em.sample_init(patches, &mut rng);
            let pred_r = pred + em.predict_init(patches);
            mae_reload += (actual_r - pred_r).abs();
        }
        mae_plain /= samples as f64;
        mae_reload /= samples as f64;
        let (_, slope, r2) = linreg(&xs, &ys);
        let pred_slope = (em.predict_exec(30, patches) - em.predict_exec(10, patches)) / 20.0;
        t.row(vec![
            patches.to_string(),
            f(slope, 3),
            f(pred_slope, 3),
            f(r2, 3),
            f(mae_plain, 2),
            f(mae_reload, 2),
        ]);
    }
    let out = t.render();
    // eat-lint: allow(logging, "paper table is the command's stdout contract")
    println!("{out}");
    super::save_csv("fig7_time_prediction", &t.to_csv())?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_reload_is_nearly_linear_and_reload_is_noisier() {
        let args = Args::parse(std::iter::empty());
        let out = run(&args).unwrap();
        // R2 close to 1 for the no-reload series appears in each row.
        assert!(out.contains("0.9"));
    }
}
