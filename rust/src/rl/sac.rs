//! SAC-family training driver (Algorithm 2) for EAT and its ablations.
//!
//! Owns the five flat parameter vectors (actor, double critics, double
//! targets), the Adam moments, and the replay buffer; each call to
//! `update` samples a batch, draws the diffusion-chain and exploration
//! noise tensors, and executes the single-HLO train step (critic update →
//! actor update → soft target update fused in one module).

use super::replay::ReplayBuffer;
use super::{EpisodePoint, TrainMetrics};
use crate::config::{Algorithm, ExperimentConfig};
use crate::runtime::{Executable, ParamSpec, Runtime};
use crate::sim::env::{Action, EdgeEnv};
use crate::sim::task::Workload;
use crate::util::rng::Pcg64;
use std::rc::Rc;

/// All mutable training state of one SAC agent.
pub struct SacDriver {
    pub alg: Algorithm,
    pub key: String,
    spec: ParamSpec,
    act_exe: Rc<Executable>,
    train_exe: Rc<Executable>,
    // Flat parameter + optimiser state (kept host-side between steps).
    actor: Vec<f32>,
    critic1: Vec<f32>,
    critic2: Vec<f32>,
    critic1_t: Vec<f32>,
    critic2_t: Vec<f32>,
    m_actor: Vec<f32>,
    v_actor: Vec<f32>,
    m_c1: Vec<f32>,
    v_c1: Vec<f32>,
    m_c2: Vec<f32>,
    v_c2: Vec<f32>,
    t: f32,
    pub replay: ReplayBuffer,
    rng: Pcg64,
    // Scratch noise buffers reused across steps (no hot-loop allocation).
    chain_s: Vec<f32>,
    chain_s2: Vec<f32>,
    expl_s: Vec<f32>,
    expl_s2: Vec<f32>,
    act_chain: Vec<f32>,
    act_expl: Vec<f32>,
    /// Device-resident copy of the actor params, refreshed lazily after
    /// each update (§Perf: one 320 KB upload per gradient step instead of
    /// one per decision).
    actor_buf: Option<xla::PjRtBuffer>,
}

impl SacDriver {
    /// Load executables + initial parameters for `alg` on the config's
    /// topology (`{alg}_{topology}` manifest key).
    pub fn new(rt: &Runtime, cfg: &ExperimentConfig) -> anyhow::Result<SacDriver> {
        let alg_key = cfg
            .algorithm
            .artifact_key()
            .ok_or_else(|| anyhow::anyhow!("{} is not an RL algorithm", cfg.algorithm.name()))?;
        anyhow::ensure!(cfg.algorithm != Algorithm::Ppo, "use PpoDriver for PPO");
        let key = format!("{}_{}", alg_key, cfg.topology_key());
        let spec = rt.manifest.param(&key)?.clone();
        anyhow::ensure!(
            spec.state_dim == cfg.env.state_len(),
            "artifact state dim {} != env {} (topology mismatch)",
            spec.state_dim,
            cfg.env.state_len()
        );
        let act_exe = rt.load(&format!("{key}_act"))?;
        let train_exe = rt.load(&format!("{key}_train"))?;
        let actor = rt.manifest.load_init(&key, "actor")?;
        let critic1 = rt.manifest.load_init(&key, "critic1")?;
        let critic2 = rt.manifest.load_init(&key, "critic2")?;
        let b = spec.batch_size;
        let chain_len = b * spec.chain_steps * spec.action_dim;
        let expl_len = b * spec.action_dim;
        Ok(SacDriver {
            alg: cfg.algorithm,
            key,
            act_exe,
            train_exe,
            critic1_t: critic1.clone(),
            critic2_t: critic2.clone(),
            m_actor: vec![0.0; actor.len()],
            v_actor: vec![0.0; actor.len()],
            m_c1: vec![0.0; critic1.len()],
            v_c1: vec![0.0; critic1.len()],
            m_c2: vec![0.0; critic2.len()],
            v_c2: vec![0.0; critic2.len()],
            t: 0.0,
            replay: ReplayBuffer::new(
                spec.state_dim,
                spec.action_dim,
                cfg.train.replay_capacity,
            ),
            rng: Pcg64::new(cfg.seed, 0x5AC),
            chain_s: vec![0.0; chain_len],
            chain_s2: vec![0.0; chain_len],
            expl_s: vec![0.0; expl_len],
            expl_s2: vec![0.0; expl_len],
            act_chain: vec![0.0; spec.chain_steps.max(1) * spec.action_dim],
            act_expl: vec![0.0; spec.action_dim],
            actor_buf: None,
            actor,
            critic1,
            critic2,
            spec,
        })
    }

    pub fn spec(&self) -> &ParamSpec {
        &self.spec
    }

    pub fn grad_steps(&self) -> f32 {
        self.t
    }

    /// Sample an action for `state` (Algorithm 1 lines 4-12).
    /// `deterministic` zeroes the exploration noise (evaluation mode); the
    /// diffusion chain noise is always drawn — it *is* the policy's
    /// generative process.
    pub fn act(&mut self, state: &[f32], deterministic: bool) -> anyhow::Result<Vec<f32>> {
        self.rng.fill_normal_f32(&mut self.act_chain);
        if deterministic {
            self.act_expl.fill(0.0);
        } else {
            self.rng.fill_normal_f32(&mut self.act_expl);
        }
        // Device-resident actor params: upload only when stale.
        if self.actor_buf.is_none() {
            self.actor_buf = Some(self.act_exe.to_device(&self.actor, 0)?);
        }
        let actor_buf = self.actor_buf.as_ref().unwrap();
        // Small per-decision tensors still come from the host each call.
        let state_idx = 1;
        let state_buf = self.act_exe.to_device(state, state_idx)?;
        // Non-diffusion variants (chain_steps == 0) have no chain input.
        let out = if self.spec.chain_steps > 0 {
            let chain_buf = self.act_exe.to_device(&self.act_chain, 2)?;
            let expl_buf = self.act_exe.to_device(&self.act_expl, 3)?;
            self.act_exe
                .run_b(&[actor_buf, &state_buf, &chain_buf, &expl_buf])?
        } else {
            let expl_buf = self.act_exe.to_device(&self.act_expl, 2)?;
            self.act_exe.run_b(&[actor_buf, &state_buf, &expl_buf])?
        };
        Ok(out.into_iter().next().unwrap())
    }

    /// Legacy full-upload act path (kept for the §Perf before/after bench).
    pub fn act_upload_all(&mut self, state: &[f32]) -> anyhow::Result<Vec<f32>> {
        self.rng.fill_normal_f32(&mut self.act_chain);
        self.act_expl.fill(0.0);
        let out = if self.spec.chain_steps > 0 {
            self.act_exe
                .run(&[&self.actor, state, &self.act_chain, &self.act_expl])?
        } else {
            self.act_exe.run(&[&self.actor, state, &self.act_expl])?
        };
        Ok(out.into_iter().next().unwrap())
    }

    /// One gradient update (Algorithm 2 lines 19-22).
    pub fn update(&mut self, batch_size: usize) -> anyhow::Result<TrainMetrics> {
        anyhow::ensure!(
            batch_size == self.spec.batch_size,
            "batch {} != artifact batch {} (re-lower with --batch)",
            batch_size,
            self.spec.batch_size
        );
        let batch = self.replay.sample(batch_size, &mut self.rng);
        self.rng.fill_normal_f32(&mut self.chain_s);
        self.rng.fill_normal_f32(&mut self.chain_s2);
        self.rng.fill_normal_f32(&mut self.expl_s);
        self.rng.fill_normal_f32(&mut self.expl_s2);
        let t_in = [self.t];
        let mut inputs: Vec<&[f32]> = vec![
            &self.actor,
            &self.critic1,
            &self.critic2,
            &self.critic1_t,
            &self.critic2_t,
            &self.m_actor,
            &self.v_actor,
            &self.m_c1,
            &self.v_c1,
            &self.m_c2,
            &self.v_c2,
            &t_in,
            &batch.s,
            &batch.a,
            &batch.r,
            &batch.s2,
            &batch.done,
        ];
        if self.spec.chain_steps > 0 {
            inputs.push(&self.chain_s);
            inputs.push(&self.chain_s2);
        }
        inputs.push(&self.expl_s);
        inputs.push(&self.expl_s2);
        let outs = self.train_exe.run(&inputs)?;
        let mut it = outs.into_iter();
        self.actor = it.next().unwrap();
        self.critic1 = it.next().unwrap();
        self.critic2 = it.next().unwrap();
        self.critic1_t = it.next().unwrap();
        self.critic2_t = it.next().unwrap();
        self.m_actor = it.next().unwrap();
        self.v_actor = it.next().unwrap();
        self.m_c1 = it.next().unwrap();
        self.v_c1 = it.next().unwrap();
        self.m_c2 = it.next().unwrap();
        self.v_c2 = it.next().unwrap();
        self.t = it.next().unwrap()[0];
        // Actor moved: the device-resident copy used by act() is stale.
        self.actor_buf = None;
        let metrics = TrainMetrics {
            actor_loss: it.next().unwrap()[0] as f64,
            critic_loss: it.next().unwrap()[0] as f64,
            mean_q: it.next().unwrap()[0] as f64,
            entropy: it.next().unwrap()[0] as f64,
        };
        Ok(metrics)
    }

    /// Save / restore the policy parameters (raw little-endian f32).
    pub fn save_actor(&self, path: &str) -> anyhow::Result<()> {
        let bytes: Vec<u8> = self.actor.iter().flat_map(|x| x.to_le_bytes()).collect();
        std::fs::write(path, bytes)?;
        Ok(())
    }

    pub fn load_actor(&mut self, path: &str) -> anyhow::Result<()> {
        let bytes = std::fs::read(path)?;
        anyhow::ensure!(bytes.len() == self.actor.len() * 4, "actor size mismatch");
        for (i, chunk) in bytes.chunks_exact(4).enumerate() {
            self.actor[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        self.actor_buf = None;
        Ok(())
    }

    /// Full training run (Algorithm 2): interact with fresh episodes,
    /// store transitions, update after warmup. Returns the training curve.
    pub fn train_loop(
        &mut self,
        cfg: &ExperimentConfig,
        episodes: usize,
        mut on_episode: impl FnMut(&EpisodePoint),
    ) -> anyhow::Result<Vec<EpisodePoint>> {
        let mut curve = Vec::with_capacity(episodes);
        let mut env_steps = 0usize;
        let mut wl_rng = Pcg64::new(cfg.seed, 0xE9);
        for ep in 0..episodes {
            let workload = Workload::generate(&cfg.env, &mut wl_rng);
            let mut env =
                EdgeEnv::with_workload(cfg.env.clone(), workload, wl_rng.fork(ep as u64));
            let mut state = env.state();
            let mut ep_reward = 0.0;
            let mut ep_len = 0usize;
            let mut last = TrainMetrics::default();
            loop {
                let action_vec = self.act(&state, false)?;
                let action = Action::from_vec(&action_vec);
                let outcome = env.step(&action);
                let next_state = env.state();
                self.replay
                    .push(&state, &action_vec, outcome.reward as f32, &next_state, outcome.done);
                state = next_state;
                ep_reward += outcome.reward;
                ep_len += 1;
                env_steps += 1;
                if self.replay.len() >= cfg.train.warmup_steps.max(cfg.train.batch_size) {
                    for _ in 0..cfg.train.updates_per_step {
                        last = self.update(cfg.train.batch_size)?;
                    }
                }
                if outcome.done {
                    break;
                }
            }
            let point = EpisodePoint {
                episode: ep,
                env_steps,
                reward: ep_reward,
                episode_len: ep_len,
                actor_loss: last.actor_loss,
                critic_loss: last.critic_loss,
            };
            on_episode(&point);
            curve.push(point);
        }
        Ok(curve)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn runtime() -> Option<Runtime> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts");
            return None;
        }
        Some(Runtime::new(dir.to_str().unwrap()).unwrap())
    }

    #[test]
    fn act_produces_bounded_actions() {
        let Some(rt) = runtime() else { return };
        let cfg = ExperimentConfig::preset_8node(0.1);
        let mut drv = SacDriver::new(&rt, &cfg).unwrap();
        let state = vec![0.3f32; cfg.env.state_len()];
        let a = drv.act(&state, true).unwrap();
        assert_eq!(a.len(), cfg.env.action_len());
        assert!(a.iter().all(|x| x.abs() <= 1.0 && x.is_finite()));
        // Deterministic act is repeatable only if chain noise repeats;
        // different calls draw fresh chains, so just check both valid.
        let b = drv.act(&state, true).unwrap();
        assert!(b.iter().all(|x| x.abs() <= 1.0));
    }

    #[test]
    fn update_changes_parameters_and_reports_finite_losses() {
        let Some(rt) = runtime() else { return };
        let mut cfg = ExperimentConfig::preset_8node(0.1);
        cfg.train.batch_size = rt.manifest.batch_size;
        let mut drv = SacDriver::new(&rt, &cfg).unwrap();
        let s_dim = cfg.env.state_len();
        let a_dim = cfg.env.action_len();
        let mut rng = Pcg64::seeded(3);
        for _ in 0..cfg.train.batch_size {
            let mut s = vec![0.0f32; s_dim];
            let mut a = vec![0.0f32; a_dim];
            rng.fill_uniform_f32(&mut s);
            rng.fill_normal_f32(&mut a);
            drv.replay.push(&s, &a, rng.next_f32(), &s, false);
        }
        let before = drv.actor.clone();
        let m = drv.update(cfg.train.batch_size).unwrap();
        assert!(m.actor_loss.is_finite() && m.critic_loss.is_finite());
        assert!(m.critic_loss >= 0.0);
        assert_ne!(before, drv.actor, "actor params should move");
        assert_eq!(drv.grad_steps(), 1.0);
    }

    #[test]
    fn save_load_actor_roundtrip() {
        let Some(rt) = runtime() else { return };
        let cfg = ExperimentConfig::preset_8node(0.1);
        let mut drv = SacDriver::new(&rt, &cfg).unwrap();
        let path = std::env::temp_dir().join(format!("eat_actor_{}.f32", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        drv.save_actor(&path).unwrap();
        let orig = drv.actor.clone();
        drv.actor.iter_mut().for_each(|x| *x = 0.0);
        drv.load_actor(&path).unwrap();
        assert_eq!(drv.actor, orig);
        std::fs::remove_file(&path).ok();
    }
}
