//! Reinforcement-learning drivers (Algorithm 2): the experience replay
//! buffer, the SAC-family trainer (EAT / EAT-A / EAT-D / EAT-DA) and the
//! PPO baseline trainer. The network math lives in AOT-compiled HLO
//! (python/compile/model.py); these drivers own the buffers, the noise
//! generation, GAE, and the environment interaction loop.

pub mod ppo;
pub mod replay;
pub mod sac;

pub use ppo::PpoDriver;
pub use replay::ReplayBuffer;
pub use sac::SacDriver;

/// Scalar metrics emitted by one gradient update.
#[derive(Clone, Copy, Debug, Default)]
pub struct TrainMetrics {
    pub actor_loss: f64,
    pub critic_loss: f64,
    pub mean_q: f64,
    pub entropy: f64,
}

/// One point of a training curve (Fig 5).
#[derive(Clone, Copy, Debug)]
pub struct EpisodePoint {
    pub episode: usize,
    pub env_steps: usize,
    pub reward: f64,
    pub episode_len: usize,
    pub actor_loss: f64,
    pub critic_loss: f64,
}
