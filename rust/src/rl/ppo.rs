//! PPO baseline driver (Table VIII PPO block): on-policy rollouts, GAE(λ)
//! advantages computed host-side, clipped-objective updates through the
//! AOT train step.

use super::{EpisodePoint, TrainMetrics};
use crate::config::{Algorithm, ExperimentConfig};
use crate::runtime::{Executable, ParamSpec, Runtime};
use crate::sim::env::{Action, EdgeEnv};
use crate::sim::task::Workload;
use crate::util::rng::Pcg64;
use std::rc::Rc;

/// One on-policy rollout transition.
#[derive(Clone, Debug)]
struct Step {
    state: Vec<f32>,
    action: Vec<f32>,
    logp: f32,
    value: f32,
    reward: f32,
    done: bool,
}

pub struct PpoDriver {
    pub key: String,
    spec: ParamSpec,
    act_exe: Rc<Executable>,
    train_exe: Rc<Executable>,
    actor: Vec<f32>,
    critic: Vec<f32>,
    m_actor: Vec<f32>,
    v_actor: Vec<f32>,
    m_critic: Vec<f32>,
    v_critic: Vec<f32>,
    t: f32,
    rollout: Vec<Step>,
    rng: Pcg64,
    gamma: f32,
    lambda: f32,
    expl: Vec<f32>,
}

impl PpoDriver {
    pub fn new(rt: &Runtime, cfg: &ExperimentConfig) -> anyhow::Result<PpoDriver> {
        anyhow::ensure!(cfg.algorithm == Algorithm::Ppo, "PpoDriver needs algorithm=ppo");
        let key = format!("ppo_{}", cfg.topology_key());
        let spec = rt.manifest.param(&key)?.clone();
        anyhow::ensure!(
            spec.state_dim == cfg.env.state_len(),
            "artifact/env topology mismatch"
        );
        let act_exe = rt.load(&format!("{key}_act"))?;
        let train_exe = rt.load(&format!("{key}_train"))?;
        let actor = rt.manifest.load_init(&key, "actor")?;
        let critic = rt.manifest.load_init(&key, "critic")?;
        Ok(PpoDriver {
            key,
            act_exe,
            train_exe,
            m_actor: vec![0.0; actor.len()],
            v_actor: vec![0.0; actor.len()],
            m_critic: vec![0.0; critic.len()],
            v_critic: vec![0.0; critic.len()],
            t: 0.0,
            rollout: Vec::new(),
            rng: Pcg64::new(cfg.seed, 0x990),
            gamma: cfg.train.gamma as f32,
            lambda: cfg.train.ppo_gae_lambda as f32,
            expl: vec![0.0; spec.action_dim],
            actor,
            critic,
            spec,
        })
    }

    /// Sample action + bookkeeping (logp, value) and stash pending step.
    pub fn act(&mut self, state: &[f32], deterministic: bool) -> anyhow::Result<(Vec<f32>, f32, f32)> {
        if deterministic {
            self.expl.fill(0.0);
        } else {
            self.rng.fill_normal_f32(&mut self.expl);
        }
        let out = self.act_exe.run(&[&self.actor, &self.critic, state, &self.expl])?;
        let mut it = out.into_iter();
        let action = it.next().unwrap();
        let logp = it.next().unwrap()[0];
        let value = it.next().unwrap()[0];
        Ok((action, logp, value))
    }

    pub fn record(
        &mut self,
        state: &[f32],
        action: &[f32],
        logp: f32,
        value: f32,
        reward: f32,
        done: bool,
    ) {
        self.rollout.push(Step {
            state: state.to_vec(),
            action: action.to_vec(),
            logp,
            value,
            reward,
            done,
        });
    }

    pub fn rollout_len(&self) -> usize {
        self.rollout.len()
    }

    /// GAE(λ): returns (advantages, returns) for the current rollout.
    fn gae(&self, last_value: f32) -> (Vec<f32>, Vec<f32>) {
        let n = self.rollout.len();
        let mut adv = vec![0.0f32; n];
        let mut ret = vec![0.0f32; n];
        let mut next_adv = 0.0f32;
        let mut next_value = last_value;
        for i in (0..n).rev() {
            let s = &self.rollout[i];
            let nonterminal = if s.done { 0.0 } else { 1.0 };
            let delta = s.reward + self.gamma * next_value * nonterminal - s.value;
            next_adv = delta + self.gamma * self.lambda * nonterminal * next_adv;
            adv[i] = next_adv;
            ret[i] = adv[i] + s.value;
            next_value = s.value;
        }
        (adv, ret)
    }

    /// Run `epochs` PPO updates over the rollout in artifact-sized
    /// minibatches (padding the tail by re-sampling), then clear it.
    pub fn update(&mut self, epochs: usize, last_value: f32) -> anyhow::Result<TrainMetrics> {
        let n = self.rollout.len();
        anyhow::ensure!(n > 0, "ppo update with empty rollout");
        let (adv, ret) = self.gae(last_value);
        let b = self.spec.batch_size;
        let s_dim = self.spec.state_dim;
        let a_dim = self.spec.action_dim;
        let mut metrics = TrainMetrics::default();
        let mut order: Vec<usize> = (0..n).collect();
        for _ in 0..epochs {
            self.rng.shuffle(&mut order);
            let num_batches = n.div_ceil(b);
            for mb in 0..num_batches {
                let mut s = Vec::with_capacity(b * s_dim);
                let mut a = Vec::with_capacity(b * a_dim);
                let mut lp = Vec::with_capacity(b);
                let mut ad = Vec::with_capacity(b);
                let mut rt_ = Vec::with_capacity(b);
                for j in 0..b {
                    // Wrap around so every minibatch is exactly b rows.
                    let idx = order[(mb * b + j) % n];
                    let st = &self.rollout[idx];
                    s.extend_from_slice(&st.state);
                    a.extend_from_slice(&st.action);
                    lp.push(st.logp);
                    ad.push(adv[idx]);
                    rt_.push(ret[idx]);
                }
                let t_in = [self.t];
                let outs = self.train_exe.run(&[
                    &self.actor,
                    &self.critic,
                    &self.m_actor,
                    &self.v_actor,
                    &self.m_critic,
                    &self.v_critic,
                    &t_in,
                    &s,
                    &a,
                    &lp,
                    &ad,
                    &rt_,
                ])?;
                let mut it = outs.into_iter();
                self.actor = it.next().unwrap();
                self.critic = it.next().unwrap();
                self.m_actor = it.next().unwrap();
                self.v_actor = it.next().unwrap();
                self.m_critic = it.next().unwrap();
                self.v_critic = it.next().unwrap();
                self.t = it.next().unwrap()[0];
                metrics.actor_loss = it.next().unwrap()[0] as f64;
                metrics.critic_loss = it.next().unwrap()[0] as f64;
                metrics.entropy = it.next().unwrap()[0] as f64;
                metrics.mean_q = it.next().unwrap()[0] as f64; // approx_kl slot
            }
        }
        self.rollout.clear();
        Ok(metrics)
    }

    /// Save / restore the policy parameters (raw little-endian f32).
    pub fn save_actor(&self, path: &str) -> anyhow::Result<()> {
        let bytes: Vec<u8> = self.actor.iter().flat_map(|x| x.to_le_bytes()).collect();
        std::fs::write(path, bytes)?;
        Ok(())
    }

    pub fn load_actor(&mut self, path: &str) -> anyhow::Result<()> {
        let bytes = std::fs::read(path)?;
        anyhow::ensure!(bytes.len() == self.actor.len() * 4, "actor size mismatch");
        for (i, chunk) in bytes.chunks_exact(4).enumerate() {
            self.actor[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        Ok(())
    }

    /// Full on-policy training loop.
    pub fn train_loop(
        &mut self,
        cfg: &ExperimentConfig,
        episodes: usize,
        mut on_episode: impl FnMut(&EpisodePoint),
    ) -> anyhow::Result<Vec<EpisodePoint>> {
        let mut curve = Vec::new();
        let mut env_steps = 0usize;
        let mut wl_rng = Pcg64::new(cfg.seed, 0xE9);
        for ep in 0..episodes {
            let workload = Workload::generate(&cfg.env, &mut wl_rng);
            let mut env =
                EdgeEnv::with_workload(cfg.env.clone(), workload, wl_rng.fork(ep as u64));
            let mut state = env.state();
            let mut ep_reward = 0.0;
            let mut ep_len = 0usize;
            let mut last = TrainMetrics::default();
            loop {
                let (action_vec, logp, value) = self.act(&state, false)?;
                let action = Action::from_vec(&action_vec);
                let outcome = env.step(&action);
                let next_state = env.state();
                self.record(&state, &action_vec, logp, value, outcome.reward as f32, outcome.done);
                state = next_state;
                ep_reward += outcome.reward;
                ep_len += 1;
                env_steps += 1;
                if self.rollout.len() >= cfg.train.ppo_horizon {
                    let (_, _, last_v) = self.act(&state, true)?;
                    last = self.update(cfg.train.ppo_epochs, last_v)?;
                }
                if outcome.done {
                    break;
                }
            }
            if !self.rollout.is_empty() {
                last = self.update(cfg.train.ppo_epochs, 0.0)?;
            }
            let point = EpisodePoint {
                episode: ep,
                env_steps,
                reward: ep_reward,
                episode_len: ep_len,
                actor_loss: last.actor_loss,
                critic_loss: last.critic_loss,
            };
            on_episode(&point);
            curve.push(point);
        }
        Ok(curve)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Runtime::new(dir.to_str().unwrap()).unwrap())
    }

    fn cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::preset_8node(0.1);
        cfg.algorithm = Algorithm::Ppo;
        cfg
    }

    #[test]
    fn gae_matches_hand_computation() {
        let Some(rt) = runtime() else { return };
        let mut drv = PpoDriver::new(&rt, &cfg()).unwrap();
        drv.gamma = 0.5;
        drv.lambda = 1.0;
        // Two steps: r=1, v=0 each, terminal at the end, last_value=0.
        let s = vec![0.0f32; drv.spec.state_dim];
        let a = vec![0.0f32; drv.spec.action_dim];
        drv.record(&s, &a, 0.0, 0.0, 1.0, false);
        drv.record(&s, &a, 0.0, 0.0, 1.0, true);
        let (adv, ret) = drv.gae(0.0);
        // delta_1 = 1; adv_1 = 1. delta_0 = 1 + 0.5*0 - 0 = 1; adv_0 = 1 + 0.5*1 = 1.5.
        assert!((adv[1] - 1.0).abs() < 1e-6);
        assert!((adv[0] - 1.5).abs() < 1e-6);
        assert!((ret[0] - 1.5).abs() < 1e-6);
    }

    #[test]
    fn act_and_update_run() {
        let Some(rt) = runtime() else { return };
        let c = cfg();
        let mut drv = PpoDriver::new(&rt, &c).unwrap();
        let s_dim = c.env.state_len();
        let state = vec![0.2f32; s_dim];
        let (a, logp, v) = drv.act(&state, false).unwrap();
        assert_eq!(a.len(), c.env.action_len());
        assert!(logp.is_finite() && v.is_finite());
        for i in 0..8 {
            drv.record(&state, &a, logp, v, 0.5, i == 7);
        }
        let before = drv.actor.clone();
        let m = drv.update(1, 0.0).unwrap();
        assert!(m.actor_loss.is_finite());
        assert_ne!(before, drv.actor);
        assert_eq!(drv.rollout_len(), 0);
    }
}
