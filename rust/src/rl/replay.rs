//! Experience replay buffer D (Table VIII: capacity 1e6, uniform
//! sampling). Transitions are stored in flat, pre-sized ring arrays so
//! sampling a batch is a gather with no per-transition allocation — this
//! sits on the training hot path (§Perf).

use crate::obs::schema;
use crate::util::rng::Pcg64;

/// Ring buffer of (s, a, r, s', done) transitions with fixed dims.
pub struct ReplayBuffer {
    state_dim: usize,
    action_dim: usize,
    capacity: usize,
    len: usize,
    head: usize,
    states: Vec<f32>,
    actions: Vec<f32>,
    rewards: Vec<f32>,
    next_states: Vec<f32>,
    dones: Vec<f32>,
}

/// A sampled batch, flattened row-major for the PJRT boundary.
pub struct Batch {
    pub s: Vec<f32>,
    pub a: Vec<f32>,
    pub r: Vec<f32>,
    pub s2: Vec<f32>,
    pub done: Vec<f32>,
    pub size: usize,
}

impl ReplayBuffer {
    pub fn new(state_dim: usize, action_dim: usize, capacity: usize) -> Self {
        assert!(capacity > 0);
        ReplayBuffer {
            state_dim,
            action_dim,
            capacity,
            len: 0,
            head: 0,
            states: vec![0.0; capacity * state_dim],
            actions: vec![0.0; capacity * action_dim],
            rewards: vec![0.0; capacity],
            next_states: vec![0.0; capacity * state_dim],
            dones: vec![0.0; capacity],
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Append one transition, overwriting the oldest when full.
    pub fn push(&mut self, s: &[f32], a: &[f32], r: f32, s2: &[f32], done: bool) {
        assert_eq!(s.len(), self.state_dim);
        assert_eq!(a.len(), self.action_dim);
        assert_eq!(s2.len(), self.state_dim);
        let i = self.head;
        self.states[i * self.state_dim..(i + 1) * self.state_dim].copy_from_slice(s);
        self.actions[i * self.action_dim..(i + 1) * self.action_dim].copy_from_slice(a);
        self.rewards[i] = r;
        self.next_states[i * self.state_dim..(i + 1) * self.state_dim].copy_from_slice(s2);
        self.dones[i] = if done { 1.0 } else { 0.0 };
        self.head = (self.head + 1) % self.capacity;
        self.len = (self.len + 1).min(self.capacity);
    }

    /// Load an `eat-experience-v1` JSONL document (as written by
    /// `obs::decisions::export_experience`): the meta line fixes the
    /// state/action dims, then one `(s, a, r, s2, done)` tuple per line.
    /// A recorded `eat qos`/`eat faults` sweep becomes offline training
    /// data through this path.
    pub fn from_experience_jsonl(text: &str, capacity: usize) -> anyhow::Result<ReplayBuffer> {
        use crate::util::json::{self, Value};
        let mut buf: Option<ReplayBuffer> = None;
        let floats = |v: &Value, key: &str| -> anyhow::Result<Vec<f32>> {
            v.req(key)?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("bad experience array '{key}'"))?
                .iter()
                .map(|x| {
                    x.as_f64()
                        .map(|f| f as f32)
                        .ok_or_else(|| anyhow::anyhow!("bad float in '{key}'"))
                })
                .collect()
        };
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let v = json::parse(line)
                .map_err(|e| anyhow::anyhow!("experience line {}: {e}", lineno + 1))?;
            if let Some(schema) = v.get("schema").and_then(Value::as_str) {
                anyhow::ensure!(
                    schema == self::schema::EXPERIENCE,
                    "experience line {}: unsupported schema '{schema}'",
                    lineno + 1
                );
                let sd = v.req("state_dim")?.as_usize().unwrap_or(0);
                let ad = v.req("action_dim")?.as_usize().unwrap_or(0);
                anyhow::ensure!(sd > 0 && ad > 0, "experience meta has zero dims");
                buf = Some(ReplayBuffer::new(sd, ad, capacity));
                continue;
            }
            let rb = buf
                .as_mut()
                .ok_or_else(|| anyhow::anyhow!("experience tuple before the meta line"))?;
            let s = floats(&v, "s")?;
            let a = floats(&v, "a")?;
            let s2 = floats(&v, "s2")?;
            anyhow::ensure!(
                s.len() == rb.state_dim && s2.len() == rb.state_dim && a.len() == rb.action_dim,
                "experience line {}: tuple dims do not match the meta line",
                lineno + 1
            );
            let r = v
                .req("r")?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("experience line {}: bad reward", lineno + 1))?
                as f32;
            let done = v.get("done").and_then(Value::as_bool).unwrap_or(false);
            rb.push(&s, &a, r, &s2, done);
        }
        buf.ok_or_else(|| anyhow::anyhow!("experience document has no meta line"))
    }

    /// Uniformly sample `batch` transitions (with replacement).
    pub fn sample(&self, batch: usize, rng: &mut Pcg64) -> Batch {
        assert!(self.len > 0, "sampling from empty replay buffer");
        let mut out = Batch {
            s: Vec::with_capacity(batch * self.state_dim),
            a: Vec::with_capacity(batch * self.action_dim),
            r: Vec::with_capacity(batch),
            s2: Vec::with_capacity(batch * self.state_dim),
            done: Vec::with_capacity(batch),
            size: batch,
        };
        for _ in 0..batch {
            let i = rng.next_below(self.len as u64) as usize;
            out.s
                .extend_from_slice(&self.states[i * self.state_dim..(i + 1) * self.state_dim]);
            out.a
                .extend_from_slice(&self.actions[i * self.action_dim..(i + 1) * self.action_dim]);
            out.r.push(self.rewards[i]);
            out.s2
                .extend_from_slice(&self.next_states[i * self.state_dim..(i + 1) * self.state_dim]);
            out.done.push(self.dones[i]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    #[test]
    fn push_and_sample_shapes() {
        let mut rb = ReplayBuffer::new(4, 2, 8);
        for i in 0..5 {
            let s = [i as f32; 4];
            let a = [i as f32; 2];
            rb.push(&s, &a, i as f32, &s, false);
        }
        assert_eq!(rb.len(), 5);
        let b = rb.sample(16, &mut Pcg64::seeded(1));
        assert_eq!(b.s.len(), 64);
        assert_eq!(b.a.len(), 32);
        assert_eq!(b.r.len(), 16);
        assert_eq!(b.done.len(), 16);
    }

    #[test]
    fn ring_overwrites_oldest() {
        let mut rb = ReplayBuffer::new(1, 1, 3);
        for i in 0..5 {
            rb.push(&[i as f32], &[0.0], i as f32, &[0.0], false);
        }
        assert_eq!(rb.len(), 3);
        // Contents should be exactly {2, 3, 4}: sample widely and check.
        let b = rb.sample(64, &mut Pcg64::seeded(2));
        for &s in &b.s {
            assert!(s >= 2.0 && s <= 4.0, "stale element {s}");
        }
    }

    #[test]
    fn sampled_rows_are_consistent() {
        // Property: every sampled row (s, a, r) matches one inserted
        // transition exactly (rows are never mixed).
        prop::check("replay row consistency", 50, |g| {
            let dim_s = g.usize_in(1, 6);
            let dim_a = g.usize_in(1, 4);
            let cap = g.usize_in(2, 32);
            let n = g.usize_in(1, 64);
            let mut rb = ReplayBuffer::new(dim_s, dim_a, cap);
            for i in 0..n {
                let tag = i as f32;
                rb.push(
                    &vec![tag; dim_s],
                    &vec![tag + 0.5; dim_a],
                    tag,
                    &vec![tag + 0.25; dim_s],
                    i % 3 == 0,
                );
            }
            let b = rb.sample(8, g.rng());
            for row in 0..8 {
                let tag = b.r[row];
                for j in 0..dim_s {
                    assert_eq!(b.s[row * dim_s + j], tag);
                    assert_eq!(b.s2[row * dim_s + j], tag + 0.25);
                }
                for j in 0..dim_a {
                    assert_eq!(b.a[row * dim_a + j], tag + 0.5);
                }
            }
        });
    }

    #[test]
    fn experience_jsonl_loads_and_rejects_mismatches() {
        let doc = concat!(
            "{\"schema\":\"eat-experience-v1\",\"state_dim\":2,\"action_dim\":1,\"tuples\":2}\n",
            "{\"s\":[0.25,0.5],\"a\":[-1],\"r\":0.75,\"s2\":[0.5,1],\"done\":false}\n",
            "{\"s\":[0.5,1],\"a\":[1],\"r\":-0.1,\"s2\":[0.5,1],\"done\":true}\n",
        );
        let rb = ReplayBuffer::from_experience_jsonl(doc, 8).unwrap();
        assert_eq!(rb.len(), 2);
        let b = rb.sample(4, &mut Pcg64::seeded(7));
        assert_eq!(b.s.len(), 8);
        assert_eq!(b.a.len(), 4);
        // A tuple whose dims disagree with the meta line is an error, not
        // a silent truncation; so is a missing meta line.
        let bad = concat!(
            "{\"schema\":\"eat-experience-v1\",\"state_dim\":2,\"action_dim\":1,\"tuples\":1}\n",
            "{\"s\":[0.25],\"a\":[-1],\"r\":0.75,\"s2\":[0.5,1],\"done\":false}\n",
        );
        assert!(ReplayBuffer::from_experience_jsonl(bad, 8).is_err());
        assert!(ReplayBuffer::from_experience_jsonl(
            "{\"s\":[0.25],\"a\":[-1],\"r\":0.75,\"s2\":[0.5],\"done\":false}\n",
            8
        )
        .is_err());
    }

    #[test]
    #[should_panic]
    fn sample_empty_panics() {
        let rb = ReplayBuffer::new(1, 1, 2);
        rb.sample(1, &mut Pcg64::seeded(3));
    }
}
