//! A minimal Rust lexer for the lint pass: just enough token structure to
//! tell code from comments and string contents, with a line number on
//! every token.
//!
//! The rules only ever need identifiers, string literal *values*, and
//! single-character punctuation — so that is all the lexer models. What it
//! must get exactly right is what a regex grep cannot: `println!` inside a
//! string or comment is not a call; `"eat-trace-v1"` inside a doc comment
//! is not a schema literal; a `//` inside a string does not open a
//! comment; `'a` is a lifetime while `'a'` is a char literal; raw strings
//! `r#"…"#` have no escapes; and `\` at end of line continues a string
//! across a newline (the line counter must still advance there, or every
//! finding after a multi-line format string drifts).
//!
//! Suppression pragmas live in line comments, which token streams erase —
//! so the lexer collects them as a side channel while scanning.

/// Token kind. `Str` carries the literal's raw contents (escapes kept
/// verbatim); `Ident` the identifier text; `Punct` one character.
#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    Ident(String),
    Str(String),
    Punct(char),
    Lifetime,
    CharLit,
    Num,
}

/// One token with the 1-based source line it starts on (for `Str`, the
/// line it *ends* on — findings point at the close of multi-line
/// literals, where the suppressing pragma can also live).
#[derive(Clone, Debug)]
pub struct Token {
    pub tok: Tok,
    pub line: usize,
}

/// An `// eat-lint: allow(<rule>, "<justification>")` comment.
/// `justified` is true only when the justification string is present and
/// non-empty — `allow(rule)` and `allow(rule, "")` both count as bare.
#[derive(Clone, Debug, PartialEq)]
pub struct Pragma {
    pub line: usize,
    pub rule: String,
    pub justified: bool,
}

/// Lexer output: the token stream plus the pragma side channel.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub pragmas: Vec<Pragma>,
}

/// Parse the first pragma in a line comment's text, if any. Mirrors the
/// shape `eat-lint:\s*allow\(\s*rule\s*(,\s*"justification")?\s*\)`; a
/// malformed tail (unclosed paren, unquoted justification) is no pragma
/// at all rather than a guess.
fn parse_pragma(comment: &[char], line: usize) -> Option<Pragma> {
    let marker: Vec<char> = "eat-lint:".chars().collect();
    let at = comment
        .windows(marker.len())
        .position(|w| w == marker.as_slice())?;
    let mut i = at + marker.len();
    let n = comment.len();
    let skip_ws = |i: &mut usize| {
        while *i < n && comment[*i].is_whitespace() {
            *i += 1;
        }
    };
    skip_ws(&mut i);
    let allow: Vec<char> = "allow(".chars().collect();
    if n - i < allow.len() || comment[i..i + allow.len()] != allow[..] {
        return None;
    }
    i += allow.len();
    skip_ws(&mut i);
    let start = i;
    while i < n && (comment[i].is_ascii_lowercase() || comment[i] == '-') {
        i += 1;
    }
    if i == start {
        return None;
    }
    let rule: String = comment[start..i].iter().collect();
    skip_ws(&mut i);
    if i < n && comment[i] == ')' {
        return Some(Pragma { line, rule, justified: false });
    }
    if i >= n || comment[i] != ',' {
        return None;
    }
    i += 1;
    skip_ws(&mut i);
    if i >= n || comment[i] != '"' {
        return None;
    }
    i += 1;
    let jstart = i;
    while i < n && comment[i] != '"' {
        i += 1;
    }
    if i >= n {
        return None;
    }
    let justified = i > jstart;
    i += 1;
    skip_ws(&mut i);
    if i < n && comment[i] == ')' {
        Some(Pragma { line, rule, justified })
    } else {
        None
    }
}

/// Lex one source file. Never fails: unterminated constructs simply end
/// at EOF (the lint pass runs on code that may not compile yet).
pub fn lex(src: &str) -> Lexed {
    let s: Vec<char> = src.chars().collect();
    let n = s.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = s[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        // Line comment (also covers `///` and `//!` docs): scan to EOL,
        // harvesting a pragma if one is present.
        if c == '/' && i + 1 < n && s[i + 1] == '/' {
            let mut j = i;
            while j < n && s[j] != '\n' {
                j += 1;
            }
            if let Some(p) = parse_pragma(&s[i..j], line) {
                out.pragmas.push(p);
            }
            i = j;
            continue;
        }
        // Block comment, nested per Rust rules.
        if c == '/' && i + 1 < n && s[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if s[i] == '/' && i + 1 < n && s[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if s[i] == '*' && i + 1 < n && s[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if s[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            continue;
        }
        // Raw (byte) string: b?r#*" … "#* — no escapes inside.
        if c == 'r' || (c == 'b' && i + 1 < n && s[i + 1] == 'r') {
            let mut j = i + if c == 'b' { 2 } else { 1 };
            let mut hashes = 0usize;
            while j < n && s[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && s[j] == '"' {
                j += 1;
                let start = j;
                // Find the closing `"` followed by the same hash count.
                let end = loop {
                    if j >= n {
                        break n;
                    }
                    if s[j] == '"' && (0..hashes).all(|k| j + 1 + k < n && s[j + 1 + k] == '#') {
                        break j;
                    }
                    j += 1;
                };
                let val: String = s[start..end].iter().collect();
                line += val.matches('\n').count();
                out.tokens.push(Token { tok: Tok::Str(val), line });
                i = (end + 1 + hashes).min(n);
                continue;
            }
            // Not a raw string ("r" / "br" was an identifier prefix);
            // fall through to identifier lexing below.
        }
        // Normal or byte string with escapes.
        if c == '"' || (c == 'b' && i + 1 < n && s[i + 1] == '"') {
            if c == 'b' {
                i += 1;
            }
            let mut j = i + 1;
            let mut buf = String::new();
            while j < n && s[j] != '"' {
                if s[j] == '\\' {
                    // A backslash-newline continuation still crosses a
                    // physical line: count it or every later finding in
                    // the file points one line short.
                    if j + 1 < n && s[j + 1] == '\n' {
                        line += 1;
                    }
                    buf.push(s[j]);
                    if j + 1 < n {
                        buf.push(s[j + 1]);
                    }
                    j += 2;
                } else {
                    if s[j] == '\n' {
                        line += 1;
                    }
                    buf.push(s[j]);
                    j += 1;
                }
            }
            out.tokens.push(Token { tok: Tok::Str(buf), line });
            i = j + 1;
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if i + 1 < n && s[i + 1] == '\\' {
                let mut j = i + 2;
                while j < n && s[j] != '\'' {
                    j += 1;
                }
                out.tokens.push(Token { tok: Tok::CharLit, line });
                i = j + 1;
                continue;
            }
            if i + 2 < n && s[i + 2] == '\'' {
                out.tokens.push(Token { tok: Tok::CharLit, line });
                i += 3;
                continue;
            }
            let mut j = i + 1;
            while j < n && (s[j].is_alphanumeric() || s[j] == '_') {
                j += 1;
            }
            out.tokens.push(Token { tok: Tok::Lifetime, line });
            i = j;
            continue;
        }
        if c.is_alphabetic() || c == '_' {
            let mut j = i;
            while j < n && (s[j].is_alphanumeric() || s[j] == '_') {
                j += 1;
            }
            let name: String = s[i..j].iter().collect();
            out.tokens.push(Token { tok: Tok::Ident(name), line });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n && (s[j].is_alphanumeric() || s[j] == '.' || s[j] == '_') {
                j += 1;
            }
            out.tokens.push(Token { tok: Tok::Num, line });
            i = j;
            continue;
        }
        if !c.is_whitespace() {
            out.tokens.push(Token { tok: Tok::Punct(c), line });
        }
        i += 1;
    }
    out
}

impl Lexed {
    /// Identifier text at `idx`, if that token is an identifier.
    pub fn ident(&self, idx: usize) -> Option<&str> {
        match &self.tokens.get(idx)?.tok {
            Tok::Ident(s) => Some(s),
            _ => None,
        }
    }

    /// True when token `idx` is the punctuation character `ch`.
    pub fn punct(&self, idx: usize) -> Option<char> {
        match self.tokens.get(idx)?.tok {
            Tok::Punct(c) => Some(c),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .into_iter()
            .filter_map(|t| match t.tok {
                Tok::Ident(s) => Some(s),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn tokens_inside_strings_and_comments_are_not_code() {
        let src = r##"
            // println!("HashMap") and Instant::now() in a comment
            /* eprintln! in /* a nested */ block comment */
            let a = "println! HashMap Instant";
            let b = r#"thread_rng() in a raw string"#;
            let c = b"HashSet in a byte string";
            call(a);
        "##;
        let ids = idents(src);
        for banned in ["println", "eprintln", "HashMap", "HashSet", "Instant", "thread_rng"] {
            assert!(!ids.iter().any(|s| s == banned), "{banned} leaked out of a literal");
        }
        assert!(ids.iter().any(|s| s == "call"));
    }

    #[test]
    fn string_values_are_captured_verbatim() {
        let lexed = lex("let s = \"eat-trace-v1\";");
        let strs: Vec<_> = lexed
            .tokens
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Str(s) => Some(s.as_str()),
                _ => None,
            })
            .collect();
        assert_eq!(strs, ["eat-trace-v1"]);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let lexed = lex("fn f<'a>(x: &'a str) -> char { 'x' }");
        let lifetimes = lexed.tokens.iter().filter(|t| t.tok == Tok::Lifetime).count();
        let chars = lexed.tokens.iter().filter(|t| t.tok == Tok::CharLit).count();
        assert_eq!((lifetimes, chars), (2, 1));
    }

    #[test]
    fn backslash_newline_continuation_still_counts_the_line() {
        // The continuation inside the string spans two physical lines;
        // `after` must land on line 3, not 2.
        let src = "let s = \"a\\\nb\";\nlet after = 1;\n";
        let lexed = lex(src);
        let after = lexed
            .tokens
            .iter()
            .find(|t| matches!(&t.tok, Tok::Ident(s) if s == "after"))
            .expect("ident after");
        assert_eq!(after.line, 3);
    }

    #[test]
    fn pragma_parsing_requires_wellformed_tail() {
        let ok = lex("// eat-lint: allow(logging, \"table output\")\n");
        assert_eq!(
            ok.pragmas,
            vec![Pragma { line: 1, rule: "logging".into(), justified: true }]
        );
        let bare = lex("// eat-lint: allow(logging)\n");
        assert!(!bare.pragmas[0].justified);
        let empty = lex("// eat-lint: allow(logging, \"\")\n");
        assert!(!empty.pragmas[0].justified);
        let malformed = lex("// eat-lint: allow(logging, unquoted)\n");
        assert!(malformed.pragmas.is_empty());
    }

    #[test]
    fn multiline_raw_string_advances_lines() {
        let src = "let s = r#\"line1\nline2\"#;\nlet tail = 0;\n";
        let lexed = lex(src);
        let tail = lexed
            .tokens
            .iter()
            .find(|t| matches!(&t.tok, Tok::Ident(s) if s == "tail"))
            .expect("ident tail");
        assert_eq!(tail.line, 3);
    }
}
