//! The rule engine: path-based tier classification, `#[test]` masking,
//! and the per-token checks behind each lint rule.
//!
//! Rules fire on the token stream from [`super::lexer`], never on raw
//! text, so string and comment contents cannot trip them. Test-only code
//! (items behind `#[test]` / `#[cfg(test, …)]`) is masked out first: test
//! modules legitimately use wall clocks, `unwrap()`, and pinned schema
//! literals (pinning the wire format *independently* of `obs/schema.rs`
//! is exactly what the round-trip tests are for).

use super::lexer::{Lexed, Tok};
use super::{Finding, Rule};

/// Directories whose modules must stay deterministic: no wall clocks, no
/// randomized iteration order. Matched against any ancestor directory
/// component of the scanned path.
const DET_TIER: &[&str] = &["sim", "faults", "qos", "workload", "obs", "experiments", "coordinator"];

/// Directories where `unwrap()`/`expect()` sit on hot paths and need a
/// written invariant.
const UNWRAP_TIER: &[&str] = &["sim", "serving"];

/// Identifiers banned in the deterministic tier. `Instant`/`SystemTime`
/// read the wall clock; `thread_rng` is OS-seeded; `HashMap`/`HashSet`
/// iterate in randomized order (all three break replay and CRN pairing).
const DET_BANNED: &[&str] = &["Instant", "SystemTime", "thread_rng", "HashMap", "HashSet"];

/// Print-to-stdio macros the logging rule owns.
const LOG_MACROS: &[&str] = &["println", "eprintln", "print", "eprint"];

/// How one file is classified by the rule engine, derived from its path.
#[derive(Clone, Copy, Debug, Default)]
pub struct FileClass {
    /// Under a deterministic-tier directory (`DET_TIER`).
    pub det_tier: bool,
    /// Under a hot-path directory (`UNWRAP_TIER`).
    pub unwrap_tier: bool,
    /// Is `obs/log.rs`, the one sanctioned stdio site.
    pub log_exempt: bool,
    /// Is `obs/schema.rs`, the one sanctioned schema-literal site.
    pub schema_exempt: bool,
}

/// Classify a path label (e.g. `sim/env.rs`, relative to the scan root).
pub fn classify(label: &str) -> FileClass {
    let comps: Vec<&str> = label.split(['/', '\\']).collect();
    let (dirs, file) = comps.split_at(comps.len().saturating_sub(1));
    let file = file.first().copied().unwrap_or("");
    let in_obs = dirs.contains(&"obs");
    FileClass {
        det_tier: dirs.iter().any(|d| DET_TIER.contains(d)),
        unwrap_tier: dirs.iter().any(|d| UNWRAP_TIER.contains(d)),
        log_exempt: in_obs && file == "log.rs",
        schema_exempt: in_obs && file == "schema.rs",
    }
}

/// True when `s` is shaped like a registered schema name:
/// `eat-<seg>(-<seg>)*-vN` with lowercase alphanumeric segments.
pub fn is_schema_name(s: &str) -> bool {
    let parts: Vec<&str> = s.split('-').collect();
    if parts.len() < 3 || parts[0] != "eat" {
        return false;
    }
    let ver = parts[parts.len() - 1];
    if ver.len() < 2 || !ver.starts_with('v') || !ver[1..].bytes().all(|b| b.is_ascii_digit()) {
        return false;
    }
    parts[1..parts.len() - 1].iter().all(|seg| {
        !seg.is_empty() && seg.bytes().all(|b| b.is_ascii_lowercase() || b.is_ascii_digit())
    })
}

/// Mark every token that belongs to test-only code: an item introduced by
/// a `#[test]` or `#[cfg(test…)]` attribute, through its closing `}` (or
/// terminating `;`). Inner attributes `#![…]` never start a skip.
pub fn test_mask(lexed: &Lexed) -> Vec<bool> {
    let toks = &lexed.tokens;
    let mut mask = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        if lexed.punct(i) == Some('#') {
            if lexed.punct(i + 1) == Some('!') {
                // Inner attribute: consume the bracket group, no skip.
                if lexed.punct(i + 2) == Some('[') {
                    i = consume_brackets(lexed, i + 2) + 1;
                    continue;
                }
            } else if lexed.punct(i + 1) == Some('[') {
                let close = consume_brackets(lexed, i + 1);
                if attr_is_test(lexed, i + 2, close) {
                    for m in mask.iter_mut().take(close.min(toks.len() - 1) + 1).skip(i) {
                        *m = true;
                    }
                    // Any further attributes on the same item are part
                    // of it too.
                    let mut p = close + 1;
                    while lexed.punct(p) == Some('#') && lexed.punct(p + 1) == Some('[') {
                        let c2 = consume_brackets(lexed, p + 1);
                        for m in mask.iter_mut().take(c2.min(toks.len() - 1) + 1).skip(p) {
                            *m = true;
                        }
                        p = c2 + 1;
                    }
                    // Consume the item: to a `;` at depth 0 before any
                    // `{`, or to the matching `}` of the first `{`.
                    let mut depth = 0usize;
                    let mut started = false;
                    while p < toks.len() {
                        mask[p] = true;
                        match lexed.punct(p) {
                            Some(';') if depth == 0 && !started => break,
                            Some('{') => {
                                depth += 1;
                                started = true;
                            }
                            Some('}') => {
                                depth = depth.saturating_sub(1);
                                if started && depth == 0 {
                                    break;
                                }
                            }
                            _ => {}
                        }
                        p += 1;
                    }
                    i = p + 1;
                    continue;
                }
                i = close + 1;
                continue;
            }
        }
        i += 1;
    }
    mask
}

/// Index of the `]` closing the bracket group opened at `open` (which
/// must point at a `[`); saturates at the last token on malformed input.
fn consume_brackets(lexed: &Lexed, open: usize) -> usize {
    let mut depth = 0usize;
    let mut j = open;
    while j < lexed.tokens.len() {
        match lexed.punct(j) {
            Some('[') => depth += 1,
            Some(']') => {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
            _ => {}
        }
        j += 1;
    }
    lexed.tokens.len().saturating_sub(1)
}

/// Does the attribute body spanning tokens `(start..close)` mark a test?
/// Matches `test` exactly, or anything starting `cfg(test…`. Deliberately
/// conservative: `cfg(all(test, …))` does not mask — only a leading
/// `test` predicate does.
fn attr_is_test(lexed: &Lexed, start: usize, close: usize) -> bool {
    let mut body = String::new();
    for idx in start..close.min(lexed.tokens.len()) {
        match &lexed.tokens[idx].tok {
            Tok::Ident(s) => body.push_str(s),
            Tok::Punct(c) => body.push(*c),
            Tok::Num => body.push('0'),
            _ => body.push('_'),
        }
    }
    body == "test" || body.starts_with("cfg(test")
}

/// Run every rule over one lexed file. `label` is the path relative to
/// the scan root (used for tier classification and reporting).
pub fn check(label: &str, lexed: &Lexed) -> Vec<Finding> {
    let class = classify(label);
    let mask = test_mask(lexed);
    let mut findings = Vec::new();

    // Suppression table: (line, rule) -> justified. A bare pragma is
    // itself a finding and suppresses nothing.
    let mut sup: Vec<(usize, Rule, bool)> = Vec::new();
    for p in &lexed.pragmas {
        if let Some(rule) = Rule::parse(&p.rule) {
            sup.push((p.line, rule, p.justified));
            if !p.justified {
                findings.push(Finding {
                    file: label.to_string(),
                    line: p.line,
                    rule: Rule::Pragma,
                    message: "suppression pragma without a justification string".to_string(),
                });
            }
        } else {
            findings.push(Finding {
                file: label.to_string(),
                line: p.line,
                rule: Rule::Pragma,
                message: format!("pragma names unknown rule '{}'", p.rule),
            });
        }
    }
    let suppressed = |line: usize, rule: Rule| -> bool {
        // A justified pragma on the finding's own line wins; otherwise
        // one on the line directly above. An unjustified pragma matches
        // first and suppresses nothing (mirrors its own finding).
        for probe in [line, line.wrapping_sub(1)] {
            if let Some(&(_, _, j)) = sup.iter().find(|(l, r, _)| *l == probe && *r == rule) {
                return j;
            }
        }
        false
    };
    let mut emit = |findings: &mut Vec<Finding>, line: usize, rule: Rule, message: String| {
        if !suppressed(line, rule) {
            findings.push(Finding { file: label.to_string(), line, rule, message });
        }
    };

    for (idx, tok) in lexed.tokens.iter().enumerate() {
        if mask[idx] {
            continue;
        }
        let line = tok.line;
        match &tok.tok {
            Tok::Ident(name) => {
                if class.det_tier && DET_BANNED.contains(&name.as_str()) {
                    emit(
                        &mut findings,
                        line,
                        Rule::Determinism,
                        format!("`{name}` in a deterministic-tier module"),
                    );
                }
                if !class.log_exempt
                    && LOG_MACROS.contains(&name.as_str())
                    && lexed.punct(idx + 1) == Some('!')
                {
                    emit(
                        &mut findings,
                        line,
                        Rule::Logging,
                        format!("`{name}!` outside obs/log.rs"),
                    );
                }
                if class.unwrap_tier
                    && (name == "unwrap" || name == "expect")
                    && lexed.punct(idx + 1) == Some('(')
                    && idx > 0
                    && lexed.punct(idx - 1) == Some('.')
                {
                    // `.lock().unwrap()` is the sanctioned mutex-poisoning
                    // idiom (propagate a poisoned lock as a panic).
                    let is_lock = name == "unwrap"
                        && idx >= 4
                        && lexed.ident(idx - 4) == Some("lock")
                        && lexed.punct(idx - 3) == Some('(')
                        && lexed.punct(idx - 2) == Some(')');
                    if !is_lock {
                        emit(
                            &mut findings,
                            line,
                            Rule::Unwrap,
                            format!("`.{name}()` on a sim/serving hot path"),
                        );
                    }
                }
                if class.det_tier
                    && name == "seeded"
                    && idx >= 3
                    && lexed.punct(idx - 1) == Some(':')
                    && lexed.punct(idx - 2) == Some(':')
                    && lexed.ident(idx - 3) == Some("Pcg64")
                {
                    emit(
                        &mut findings,
                        line,
                        Rule::Rng,
                        "`Pcg64::seeded` (ad-hoc stream 0) in a deterministic-tier module"
                            .to_string(),
                    );
                }
            }
            Tok::Str(val) => {
                if !class.schema_exempt && is_schema_name(val) {
                    emit(
                        &mut findings,
                        line,
                        Rule::Schema,
                        format!("schema literal \"{val}\" outside obs/schema.rs"),
                    );
                }
            }
            _ => {}
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lint_source;

    #[test]
    fn determinism_rule_fires_only_in_tier() {
        let src = "use std::collections::HashMap;\nfn f() { let t = Instant::now(); }\n";
        let in_tier = lint_source("sim/bad.rs", src);
        assert_eq!(in_tier.len(), 2, "{in_tier:?}");
        assert!(in_tier.iter().all(|f| f.rule == Rule::Determinism));
        let out_of_tier = lint_source("util/ok.rs", src);
        assert!(out_of_tier.is_empty(), "{out_of_tier:?}");
    }

    #[test]
    fn logging_rule_exempts_obs_log() {
        let src = "fn f() { println!(\"x\"); }\n";
        assert_eq!(lint_source("serving/w.rs", src).len(), 1);
        assert!(lint_source("obs/log.rs", src).is_empty());
    }

    #[test]
    fn schema_rule_exempts_registry_and_non_schema_strings() {
        let src = "fn f() -> &'static str { \"eat-trace-v1\" }\n";
        let hit = lint_source("obs/trace.rs", src);
        assert_eq!(hit.len(), 1);
        assert_eq!(hit[0].rule, Rule::Schema);
        assert!(lint_source("obs/schema.rs", src).is_empty());
        for not_schema in ["eat-v1", "eat-trace", "Eat-Trace-v1", "meat-trace-v1", "eat-trace-vx"] {
            assert!(!is_schema_name(not_schema), "{not_schema}");
        }
        assert!(is_schema_name("eat-bench-compare-v12"));
    }

    #[test]
    fn unwrap_rule_requires_method_call_and_exempts_lock() {
        let src = "fn f(o: Option<u32>) -> u32 { o.unwrap() }\n";
        assert_eq!(lint_source("sim/x.rs", src).len(), 1);
        // experiments/ is deterministic-tier but not a hot path.
        assert!(lint_source("experiments/x.rs", src).is_empty());
        let lock = "fn f(m: &std::sync::Mutex<u32>) -> u32 { *m.lock().unwrap() }\n";
        assert!(lint_source("serving/x.rs", lock).is_empty(), "lock().unwrap() is sanctioned");
        let lock_expect = "fn f(m: &M) -> u32 { *m.lock().expect(\"poisoned\") }\n";
        assert_eq!(lint_source("serving/x.rs", lock_expect).len(), 1, "expect is not exempt");
    }

    #[test]
    fn rng_rule_flags_adhoc_seeding_only() {
        let bad = "fn f() { let r = Pcg64::seeded(42); }\n";
        let hits = lint_source("sim/x.rs", bad);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, Rule::Rng);
        let good = "fn f() { let r = Pcg64::new(42, 7); let s = r.fork(3); }\n";
        assert!(lint_source("sim/x.rs", good).is_empty());
    }

    #[test]
    fn test_items_are_masked() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f() { let t = Instant::now(); x.unwrap(); }\n}\nfn live() { let h: HashMap<u32, u32> = HashMap::new(); }\n";
        let hits = lint_source("sim/x.rs", src);
        assert_eq!(hits.len(), 2, "only the live HashMap uses flag: {hits:?}");
        assert!(hits.iter().all(|f| f.rule == Rule::Determinism && f.line == 5));
    }

    #[test]
    fn pragma_round_trip() {
        let bad = "fn f() { println!(\"x\"); }\n";
        // Justified pragma on the previous line suppresses.
        let ok = "fn f() {\n    // eat-lint: allow(logging, \"table output\")\n    println!(\"x\");\n}\n";
        assert!(lint_source("qos/x.rs", ok).is_empty());
        // Bare pragma: the original finding stays AND the pragma itself
        // is flagged.
        let bare = "fn f() {\n    // eat-lint: allow(logging)\n    println!(\"x\");\n}\n";
        let hits = lint_source("qos/x.rs", bare);
        assert_eq!(hits.len(), 2, "{hits:?}");
        assert!(hits.iter().any(|f| f.rule == Rule::Pragma));
        assert!(hits.iter().any(|f| f.rule == Rule::Logging));
        // Wrong-rule pragma does not suppress.
        let wrong = "fn f() {\n    // eat-lint: allow(unwrap, \"justified\")\n    println!(\"x\");\n}\n";
        assert_eq!(lint_source("qos/x.rs", wrong).len(), 1);
        assert_eq!(lint_source("qos/x.rs", bad).len(), 1);
    }

    #[test]
    fn classify_matches_nested_paths() {
        assert!(classify("sim/env.rs").det_tier);
        assert!(classify("experiments/qos.rs").det_tier);
        assert!(!classify("experiments/qos.rs").unwrap_tier);
        assert!(classify("serving/worker.rs").unwrap_tier);
        assert!(classify("obs/log.rs").log_exempt);
        assert!(classify("obs/schema.rs").schema_exempt);
        assert!(!classify("analysis/rules.rs").det_tier);
        // The file name alone is not a directory component.
        assert!(!classify("qos.rs").det_tier);
    }
}
