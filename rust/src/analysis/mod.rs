//! `eat lint` — a dependency-free, repo-specific static-analysis pass.
//!
//! Every headline property of this reproduction (bit-identical event/tick
//! cores, CRN-paired fault timelines, byte-identical shard merges,
//! recording-on/off-invariant ledgers) is a *determinism* invariant that
//! property tests can only check after the fact. This pass rejects the
//! classes of code that break them, at CI time:
//!
//! | rule          | what it rejects                                              |
//! |---------------|--------------------------------------------------------------|
//! | `determinism` | `Instant`/`SystemTime`/`thread_rng`/`HashMap`/`HashSet` in deterministic-tier dirs |
//! | `logging`     | `println!`/`eprintln!` outside `obs/log.rs`                  |
//! | `schema`      | `eat-*-vN` string literals outside `obs/schema.rs`           |
//! | `unwrap`      | `.unwrap()`/`.expect()` in `sim/`/`serving/` (`.lock().unwrap()` exempt) |
//! | `rng`         | `Pcg64::seeded` (ad-hoc stream 0) in deterministic-tier dirs |
//!
//! Any site can be sanctioned with an inline pragma **that must carry a
//! justification**:
//!
//! ```text
//! // eat-lint: allow(logging, "table output is the command's stdout contract")
//! println!("{table}");
//! ```
//!
//! A bare `allow(rule)` suppresses nothing and is itself a finding
//! (`pragma`), so exemptions stay documented. The pass is a hand-rolled
//! lexer ([`lexer`]) plus a token-level rule engine ([`rules`]) — no new
//! dependencies, no proc macros, no syn.

pub mod lexer;
pub mod rules;

use crate::obs::schema;
use crate::util::json::Value;
use std::fmt;
use std::path::{Path, PathBuf};

/// The lint rules. `Pragma` is the meta-rule for malformed suppression
/// comments; it cannot itself be suppressed.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    Determinism,
    Logging,
    Schema,
    Unwrap,
    Rng,
    Pragma,
}

impl Rule {
    pub fn name(self) -> &'static str {
        match self {
            Rule::Determinism => "determinism",
            Rule::Logging => "logging",
            Rule::Schema => "schema",
            Rule::Unwrap => "unwrap",
            Rule::Rng => "rng",
            Rule::Pragma => "pragma",
        }
    }

    /// Parse a rule name as written in a pragma.
    pub fn parse(name: &str) -> Option<Rule> {
        match name {
            "determinism" => Some(Rule::Determinism),
            "logging" => Some(Rule::Logging),
            "schema" => Some(Rule::Schema),
            "unwrap" => Some(Rule::Unwrap),
            "rng" => Some(Rule::Rng),
            "pragma" => Some(Rule::Pragma),
            _ => None,
        }
    }

    /// One-line remediation hint (`--fix-suggestions`).
    pub fn suggestion(self) -> &'static str {
        match self {
            Rule::Determinism => {
                "use BTreeMap/BTreeSet and the simulated clock; wall-time telemetry needs \
                 `// eat-lint: allow(determinism, \"why\")`"
            }
            Rule::Logging => {
                "route progress output through log_info!/log_warn! (obs/log.rs); only \
                 machine-readable stdout may carry a logging pragma"
            }
            Rule::Schema => "register the name as a constant in obs/schema.rs and reference it",
            Rule::Unwrap => {
                "handle the None/Err case, or state the invariant: \
                 `// eat-lint: allow(unwrap, \"why this cannot fail\")`"
            }
            Rule::Rng => {
                "derive a dedicated stream with Pcg64::new(seed, stream) or rng.fork(stream) \
                 so substreams cannot collide"
            }
            Rule::Pragma => "add the justification: `// eat-lint: allow(<rule>, \"why\")`",
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One violation: where, which rule, and what was found.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Path as reported (scan-root-relative label joined to the root).
    pub file: String,
    /// 1-based source line.
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

/// Result of linting a path set.
#[derive(Debug, Default)]
pub struct LintReport {
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Human-readable report: one `file:line: [rule] message` per finding
    /// plus a summary line.
    pub fn render(&self, fix_suggestions: bool) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
            if fix_suggestions {
                out.push_str(&format!("    fix: {}\n", f.rule.suggestion()));
            }
        }
        out.push_str(&format!(
            "eat lint: {} finding(s) over {} file(s)",
            self.findings.len(),
            self.files_scanned
        ));
        out
    }

    /// Machine-readable document (`eat-lint-v1`).
    pub fn to_json(&self, fix_suggestions: bool) -> Value {
        let mut doc = Value::obj();
        doc.set("schema", schema::LINT)
            .set("files_scanned", self.files_scanned)
            .set("clean", self.is_clean());
        let findings: Vec<Value> = self
            .findings
            .iter()
            .map(|f| {
                let mut v = Value::obj();
                v.set("file", f.file.as_str())
                    .set("line", f.line)
                    .set("rule", f.rule.name())
                    .set("message", f.message.as_str());
                if fix_suggestions {
                    v.set("suggestion", f.rule.suggestion());
                }
                v
            })
            .collect();
        doc.set("findings", findings);
        doc
    }
}

/// Lint a single source text under a path label (relative to a notional
/// scan root — `sim/env.rs` is deterministic-tier, `bad.rs` is not).
/// This is the seam the fixture tests drive directly.
pub fn lint_source(label: &str, src: &str) -> Vec<Finding> {
    rules::check(label, &lexer::lex(src))
}

/// Lint every `.rs` file under each path (file or directory), in a
/// deterministic order. Tier classification uses the path *relative to
/// the scanned root*, so `eat lint rust/src` and
/// `cd rust/src && eat lint .` classify identically.
pub fn lint_paths<P: AsRef<Path>>(paths: &[P]) -> anyhow::Result<LintReport> {
    let mut report = LintReport::default();
    for root in paths {
        let root = root.as_ref();
        let mut files: Vec<(String, PathBuf)> = Vec::new();
        if root.is_file() {
            let label = root
                .file_name()
                .map(|f| f.to_string_lossy().into_owned())
                .unwrap_or_else(|| root.display().to_string());
            files.push((label, root.to_path_buf()));
        } else if root.is_dir() {
            walk(root, root, &mut files)?;
        } else {
            anyhow::bail!("lint path {} does not exist", root.display());
        }
        files.sort();
        for (label, path) in files {
            let src = std::fs::read_to_string(&path)
                .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
            report.files_scanned += 1;
            for mut f in rules::check(&label, &lexer::lex(&src)) {
                // Report the on-disk path, not the root-relative label.
                f.file = path.display().to_string();
                report.findings.push(f);
            }
        }
    }
    report.findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(report)
}

/// Collect `.rs` files under `dir` as (root-relative label, full path).
fn walk(root: &Path, dir: &Path, out: &mut Vec<(String, PathBuf)>) -> anyhow::Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("{}: {e}", dir.display()))?
        .map(|e| e.map(|e| e.path()))
        .collect::<Result<_, _>>()?;
    entries.sort();
    for path in entries {
        if path.is_dir() {
            walk(root, &path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            let label = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .into_owned();
            out.push((label, path));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_root() -> PathBuf {
        // CARGO_MANIFEST_DIR is the workspace root (Cargo.toml lives
        // there; sources under rust/src via explicit [lib] path).
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
    }

    #[test]
    fn repo_is_clean() {
        let report = lint_paths(&[repo_root().join("rust/src")]).expect("lint run");
        assert!(report.files_scanned > 50, "scanned {} files", report.files_scanned);
        assert!(
            report.is_clean(),
            "the tree must lint clean:\n{}",
            report.render(false)
        );
    }

    #[test]
    fn each_bad_fixture_flags_its_rule() {
        // Lint the fixture corpus under its own root so the sim/ tier
        // fixtures classify as deterministic-tier/hot-path code.
        let report = lint_paths(&[repo_root().join("rust/lint-fixtures")]).expect("lint run");
        for (rel, rule) in [
            ("sim/bad_determinism.rs", Rule::Determinism),
            ("sim/bad_rng.rs", Rule::Rng),
            ("sim/bad_unwrap.rs", Rule::Unwrap),
            ("bad_logging.rs", Rule::Logging),
            ("bad_schema.rs", Rule::Schema),
            ("bad_pragma.rs", Rule::Pragma),
        ] {
            assert!(
                report.findings.iter().any(|f| f.rule == rule && f.file.ends_with(rel)),
                "{rel}: expected a {rule} finding, got {:?}",
                report.findings
            );
        }
    }

    #[test]
    fn fixture_dir_is_entirely_bad() {
        let report = lint_paths(&[repo_root().join("rust/lint-fixtures")]).expect("lint run");
        assert!(!report.is_clean(), "the negative-smoke corpus must keep failing");
        assert_eq!(report.files_scanned, 6);
    }

    #[test]
    fn json_report_shape() {
        let report = lint_paths(&[repo_root().join("rust/lint-fixtures")]).expect("lint run");
        let doc = report.to_json(true);
        assert_eq!(doc.req("schema").unwrap().as_str(), Some("eat-lint-v1"));
        assert_eq!(doc.req("clean").unwrap().as_bool(), Some(false));
        let findings = doc.req("findings").unwrap().as_arr().unwrap();
        assert_eq!(findings.len(), report.findings.len());
        for f in findings {
            for key in ["file", "line", "rule", "message", "suggestion"] {
                assert!(f.get(key).is_some(), "finding missing {key}");
            }
        }
    }

    #[test]
    fn lint_paths_rejects_missing_path() {
        assert!(lint_paths(&[repo_root().join("no/such/dir")]).is_err());
    }
}
