//! In-house property-based testing support (proptest is not available in
//! the offline registry). `prop::check` runs a property over many random
//! cases and, on failure, greedily shrinks the failing input before
//! reporting. Used for coordinator/scheduler/simulator invariants.

pub mod prop;
