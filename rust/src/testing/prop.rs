//! Mini property-testing framework.
//!
//! ```no_run
//! use eat::testing::prop::{check, Gen};
//!
//! check("reverse twice is identity", 200, |g| {
//!     let xs = g.vec_u32(0..64, 1000);
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     assert_eq!(xs, ys);
//! });
//! ```

use crate::util::rng::Pcg64;

/// Random input generator handed to each property case.
pub struct Gen {
    rng: Pcg64,
    /// Log of choices for reporting.
    pub trace: Vec<String>,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen {
            rng: Pcg64::new(seed, 0x9e37),
            trace: Vec::new(),
        }
    }

    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        let v = lo + self.rng.next_below((hi - lo) as u64) as usize;
        self.trace.push(format!("usize_in({lo},{hi})={v}"));
        v
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.uniform(lo, hi);
        self.trace.push(format!("f64_in({lo},{hi})={v:.4}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.next_u64() & 1 == 1;
        self.trace.push(format!("bool={v}"));
        v
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.next_below(xs.len() as u64) as usize;
        self.trace.push(format!("pick[{i}]"));
        &xs[i]
    }

    pub fn vec_u32(&mut self, len_range: std::ops::Range<usize>, max: u32) -> Vec<u32> {
        let len = self.usize_in(len_range.start, len_range.end.max(len_range.start + 1));
        (0..len)
            .map(|_| self.rng.next_below(max as u64 + 1) as u32)
            .collect()
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len)
            .map(|_| self.rng.uniform(lo as f64, hi as f64) as f32)
            .collect()
    }
}

/// Run `cases` random instances of the property; panic with the seed of the
/// first failing case. Properties signal failure by panicking (assert!).
/// Re-running with `EAT_PROP_SEED=<seed>` reproduces a single failing case.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: u64, prop: F) {
    // Explicit reproduction mode.
    if let Ok(seed) = std::env::var("EAT_PROP_SEED") {
        let seed: u64 = seed.parse().expect("EAT_PROP_SEED must be an integer");
        let mut g = Gen::new(seed);
        prop(&mut g);
        return;
    }
    let base = 0xEA7_5EEDu64;
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
            g.trace
        });
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed on case {case} (seed {seed}):\n  {msg}\n  \
                 reproduce with EAT_PROP_SEED={seed}"
            );
        }
    }
}

/// Workload-subsystem properties: the invariants every arrival process,
/// mix, and trace must hold regardless of parameters.
#[cfg(test)]
mod workload_props {
    use super::check;
    use crate::config::EnvConfig;
    use crate::sim::task::Workload;
    use crate::util::rng::Pcg64;
    use crate::workload::{self, WorkloadConfig};

    #[test]
    fn interarrivals_nonnegative_and_sorted_for_every_scenario() {
        check("arrival sortedness", 30, |g| {
            let name = *g.pick(WorkloadConfig::scenario_names());
            let rate = g.f64_in(0.01, 0.5);
            let mut cfg = EnvConfig::default();
            cfg.workload = Some(WorkloadConfig::preset(name, rate).unwrap());
            let (mut ap, mix) = workload::build_for_env(&cfg);
            let w = workload::generate(ap.as_mut(), &mix, 400, g.rng());
            assert_eq!(w.len(), 400);
            let mut prev = 0.0;
            for t in &w.tasks {
                assert!(t.arrival.is_finite(), "{name}: non-finite arrival");
                assert!(
                    t.arrival >= prev,
                    "{name}: arrival {} before {prev}",
                    t.arrival
                );
                prev = t.arrival;
                assert!(cfg.patch_choices.contains(&t.patches));
                assert!((t.model.0 as usize) < cfg.num_models);
                if let Some(q) = t.q_min {
                    assert!(q.is_finite() && q > 0.0);
                }
            }
        });
    }

    #[test]
    fn empirical_rate_converges_to_mean_rate() {
        // Processes with a well-defined long-run rate must converge to it.
        // (FlashCrowd's spike is a transient, so its horizon-average keeps
        // a bias; it is covered by the sortedness property above.)
        for (name, tol) in [
            ("poisson", 0.05),
            ("constant", 0.01),
            ("bursty", 0.15),
            ("diurnal", 0.05),
        ] {
            let mut cfg = EnvConfig::default();
            cfg.workload = Some(WorkloadConfig::preset(name, 0.1).unwrap());
            let (mut ap, mix) = workload::build_for_env(&cfg);
            let expect = ap.mean_rate();
            let n = 40_000;
            let w = workload::generate(ap.as_mut(), &mix, n, &mut Pcg64::seeded(77));
            let empirical = n as f64 / w.tasks.last().unwrap().arrival;
            assert!(
                (empirical - expect).abs() / expect < tol,
                "{name}: empirical rate {empirical} vs mean_rate {expect}"
            );
        }
    }

    #[test]
    fn trace_roundtrip_is_bit_exact() {
        check("trace roundtrip", 25, |g| {
            let name = *g.pick(WorkloadConfig::scenario_names());
            let mut cfg = EnvConfig::default();
            cfg.tasks_per_episode = g.usize_in(1, 80);
            cfg.workload = Some(WorkloadConfig::preset(name, g.f64_in(0.02, 0.3)).unwrap());
            let w = Workload::generate(&cfg, g.rng());
            let back = workload::trace::from_jsonl(&workload::trace::to_jsonl(&w)).unwrap();
            assert_eq!(w.len(), back.len());
            for (a, b) in w.tasks.iter().zip(&back.tasks) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.prompt_id, b.prompt_id, "{name}: prompt id drift");
                assert_eq!(a.patches, b.patches);
                assert_eq!(a.model, b.model);
                assert_eq!(a.arrival.to_bits(), b.arrival.to_bits(), "{name}: arrival drift");
                assert_eq!(a.q_min.map(f64::to_bits), b.q_min.map(f64::to_bits));
            }
        });
    }

    #[test]
    fn histogram_percentiles_bounded_by_observations() {
        use crate::workload::LatencyHistogram;
        check("histogram bounds", 50, |g| {
            let mut h = LatencyHistogram::new(g.f64_in(0.1, 2.0), g.usize_in(4, 256));
            let n = g.usize_in(1, 400);
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for _ in 0..n {
                let x = g.f64_in(0.0, 500.0);
                lo = lo.min(x);
                hi = hi.max(x);
                h.observe(x);
            }
            for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
                let p = h.percentile(q).unwrap();
                assert!(p >= lo && p <= hi, "p{q} = {p} outside [{lo}, {hi}]");
            }
            assert!(h.p50() <= h.p90() && h.p90() <= h.p99());
        });
    }
}

/// QoS-subsystem properties: the invariants the deadline-aware queue,
/// admission controllers, and tenant configs must hold for any parameters.
#[cfg(test)]
mod qos_props {
    use super::check;
    use crate::qos::{
        AdmissionConfig, AdmissionState, EdfWfqQueue, QueueDiscipline, TenantsConfig,
    };
    use crate::sim::task::{ModelType, Task};
    use crate::workload::{ArrivalConfig, ModelMix};

    fn task(id: u64, deadline: Option<f64>) -> Task {
        Task {
            id,
            prompt_id: id,
            patches: 2,
            model: ModelType(0),
            arrival: 0.0,
            q_min: None,
            tenant: None,
            deadline,
        }
    }

    #[test]
    fn edf_order_never_inverts_within_a_tier() {
        // Under arbitrary interleavings of pushes, pops, and mid-queue
        // removals, the dequeue order restricted to any single tier is
        // always sorted by (deadline, insertion seq) — an earlier deadline
        // is never behind a later one.
        check("edf within tier", 40, |g| {
            let tiers = g.usize_in(1, 5);
            let weights: Vec<f64> = (0..tiers).map(|_| g.f64_in(0.5, 8.0)).collect();
            let mut q = EdfWfqQueue::new(weights);
            let mut next_id = 0u64;
            for _ in 0..g.usize_in(10, 120) {
                if !q.is_empty() && g.bool() && g.bool() {
                    let n = g.usize_in(0, q.len());
                    assert!(q.remove_nth(n).is_some());
                } else {
                    let deadline = if g.bool() {
                        Some(g.f64_in(0.0, 500.0))
                    } else {
                        None
                    };
                    q.push(g.usize_in(0, tiers), task(next_id, deadline));
                    next_id += 1;
                }
                let mut last = vec![(0u64, 0u64); tiers];
                for (tier, key) in q.order(q.len()) {
                    assert!(
                        key >= last[tier],
                        "tier {tier}: key {key:?} after {:?}",
                        last[tier]
                    );
                    last[tier] = key;
                }
            }
            // Drain fully: pop must yield exactly len() tasks.
            let expect = q.len();
            let mut drained = 0;
            while q.pop().is_some() {
                drained += 1;
            }
            assert_eq!(drained, expect);
        });
    }

    #[test]
    fn token_bucket_admission_rate_converges() {
        // Saturating arrivals: the admitted count over a long horizon
        // converges to burst + rate × horizon, i.e. the admitted *rate*
        // converges to the bucket rate.
        check("token bucket rate", 25, |g| {
            let rate = g.f64_in(0.2, 2.0);
            let burst = g.f64_in(1.0, 10.0);
            let mut st = AdmissionState::new(AdmissionConfig::TokenBucket { rate, burst }, None);
            let horizon = 2_000.0;
            // Arrivals 2.5x-20x faster than the refill rate.
            let gap = g.f64_in(0.05, 0.4) / rate;
            let mut now = 0.0;
            let mut admitted = 0u64;
            while now < horizon {
                if st.admit(None, now, 0) {
                    admitted += 1;
                }
                now += gap;
            }
            let expect = burst.floor() + rate * horizon;
            let err = (admitted as f64 - expect).abs() / expect;
            assert!(err < 0.05, "admitted {admitted} vs expected {expect:.0} (err {err:.3})");
        });
    }

    #[test]
    fn tenant_config_json_roundtrips_for_random_configs() {
        check("tenants json roundtrip", 30, |g| {
            let n = g.usize_in(1, 5);
            let tenants = (0..n)
                .map(|i| crate::qos::TenantConfig {
                    name: format!("tenant-{i}"),
                    tier: g.usize_in(0, 4) as u8,
                    weight: g.f64_in(0.1, 8.0),
                    latency_slo: g.f64_in(10.0, 500.0),
                    q_min: g.f64_in(0.05, 0.27),
                    arrival: ArrivalConfig::Poisson {
                        rate: g.f64_in(0.01, 0.5),
                    },
                    model_mix: if g.bool() {
                        ModelMix::Uniform
                    } else {
                        ModelMix::Zipf {
                            exponent: g.f64_in(0.5, 2.0),
                        }
                    },
                })
                .collect();
            let cfg = TenantsConfig {
                tenants,
                admission: match g.usize_in(0, 3) {
                    0 => AdmissionConfig::AdmitAll,
                    1 => AdmissionConfig::DropTail {
                        max_queue: g.usize_in(1, 128),
                    },
                    _ => AdmissionConfig::TokenBucket {
                        rate: g.f64_in(0.01, 1.0),
                        burst: g.f64_in(1.0, 16.0),
                    },
                },
                queue: if g.bool() {
                    QueueDiscipline::EdfWfq
                } else {
                    QueueDiscipline::Fifo
                },
            };
            let back = TenantsConfig::from_json(&cfg.to_json()).unwrap();
            assert_eq!(back, cfg);
        });
    }
}

/// Fault-subsystem properties (PR 4's determinism guards): a recorded
/// churn episode replays bit-exactly through the JSONL trace, and configs
/// with faults disabled are bit-identical to the pre-faults trajectories.
#[cfg(test)]
mod fault_props {
    use super::check;
    use crate::config::ExperimentConfig;
    use crate::faults::FaultsConfig;
    use crate::sim::env::{Action, EdgeEnv, EpisodeReport};
    use crate::sim::task::Workload;
    use crate::util::rng::Pcg64;
    use crate::workload::trace;

    fn drive(env: &mut EdgeEnv) -> EpisodeReport {
        let l = env.cfg.queue_window;
        let mut scores = vec![-1.0f32; l];
        scores[0] = 1.0;
        let action = Action {
            exec_gate: -1.0,
            steps_raw: 0.4,
            task_scores: scores,
        };
        for _ in 0..=env.cfg.step_limit {
            if env.step(&action).done {
                break;
            }
        }
        env.report()
    }

    fn assert_reports_bit_equal(a: &EpisodeReport, b: &EpisodeReport, what: &str) {
        assert_eq!(a.completed_tasks, b.completed_tasks, "{what}: completed");
        assert_eq!(a.decision_steps, b.decision_steps, "{what}: steps");
        assert_eq!(a.total_reward.to_bits(), b.total_reward.to_bits(), "{what}: reward");
        assert_eq!(
            a.avg_response_latency.to_bits(),
            b.avg_response_latency.to_bits(),
            "{what}: latency"
        );
        assert_eq!(a.p99_latency.to_bits(), b.p99_latency.to_bits(), "{what}: p99");
        assert_eq!(a.avg_quality.to_bits(), b.avg_quality.to_bits(), "{what}: quality");
        assert_eq!(a.reloads, b.reloads, "{what}: reloads");
        assert_eq!(a.retries, b.retries, "{what}: retries");
        assert_eq!(a.failures, b.failures, "{what}: failures");
        assert_eq!(a.failed_tasks, b.failed_tasks, "{what}: failed tasks");
        assert_eq!(
            a.wasted_patch_s.to_bits(),
            b.wasted_patch_s.to_bits(),
            "{what}: wasted work"
        );
        assert_eq!(a.spec_wins, b.spec_wins, "{what}: spec wins");
    }

    fn random_churn(g: &mut super::Gen) -> FaultsConfig {
        FaultsConfig {
            mtbf: g.f64_in(80.0, 400.0),
            mttr: g.f64_in(5.0, 60.0),
            zones: g.usize_in(1, 5),
            zone_shock_rate: g.f64_in(0.0, 0.004),
            straggler_rate: g.f64_in(0.0, 0.02),
            spec_beta: if g.bool() { 1.5 } else { 0.0 },
            max_retries: g.usize_in(1, 4) as u32,
            health_aware: g.bool(),
            ..FaultsConfig::default()
        }
    }

    #[test]
    fn recorded_fault_episode_replays_bit_exactly_through_jsonl() {
        // Record: stochastic faults over a fixed workload. Replay: the
        // same workload and env seed, with the recorded events round-
        // tripped through the JSONL trace and scripted back in. Every
        // number must match bit-for-bit.
        check("fault trace replay", 8, |g| {
            let mut cfg = ExperimentConfig::preset_8node(0.1).env;
            cfg.tasks_per_episode = g.usize_in(8, 24);
            cfg.patch_choices = vec![1, 2];
            cfg.patch_weights = vec![1.0, 1.0];
            cfg.faults = Some(random_churn(g));
            let seed = g.usize_in(0, 1_000_000) as u64;
            let workload = Workload::generate(&cfg, &mut Pcg64::new(seed, 0xC0FFEE));
            let mut live = EdgeEnv::with_workload(
                cfg.clone(),
                workload.clone(),
                Pcg64::new(seed, 0xE21),
            );
            let live_rep = drive(&mut live);
            // Round-trip workload + events through the JSONL trace.
            let text = trace::to_jsonl_with_faults(&workload, live.fault_events());
            let (replay_wl, replay_events) = trace::from_jsonl_with_faults(&text).unwrap();
            let mut replay =
                EdgeEnv::with_workload(cfg, replay_wl, Pcg64::new(seed, 0xE21));
            replay.script_faults(replay_events).unwrap();
            let replay_rep = drive(&mut replay);
            assert_reports_bit_equal(&live_rep, &replay_rep, "trace replay");
        });
    }

    #[test]
    fn disabled_faults_are_bit_identical_to_pre_faults_path() {
        // The regression guard (the analogue of PR 3's no-tenants FIFO
        // guarantee): `faults: None` and `faults: Some(off)` take the
        // seed's exact code path, for any env shape.
        check("faults-off regression", 8, |g| {
            let nodes = *g.pick(&[4usize, 8]);
            let mut cfg = ExperimentConfig::preset(nodes).env;
            cfg.tasks_per_episode = g.usize_in(6, 20);
            cfg.arrival_rate = g.f64_in(0.03, 0.15);
            let seed = g.usize_in(0, 1_000_000) as u64;
            let mut none_env = EdgeEnv::new(cfg.clone(), seed);
            let none_rep = drive(&mut none_env);
            cfg.faults = Some(FaultsConfig::off());
            let mut off_env = EdgeEnv::new(cfg, seed);
            let off_rep = drive(&mut off_env);
            assert!(off_env.fault_events().is_empty());
            assert_eq!(off_rep.failures, 0);
            assert_eq!(off_rep.dispatched_patch_s, 0.0);
            assert_reports_bit_equal(&none_rep, &off_rep, "faults off");
        });
    }

    #[test]
    fn patch_second_books_balance_under_random_churn() {
        // completed + wasted + in-flight nominal patch-seconds always
        // equals dispatched, whatever the churn or dispatch mode.
        check("work balance", 8, |g| {
            let mut cfg = ExperimentConfig::preset_8node(0.1).env;
            cfg.tasks_per_episode = g.usize_in(8, 24);
            cfg.patch_choices = vec![1, 2, 4];
            cfg.patch_weights = vec![1.0, 1.0, 1.0];
            cfg.faults = Some(random_churn(g));
            let seed = g.usize_in(0, 1_000_000) as u64;
            let mut env = EdgeEnv::new(cfg, seed);
            let rep = drive(&mut env);
            let sum = rep.completed_patch_s + rep.wasted_patch_s + rep.inflight_patch_s;
            assert!(
                (sum - rep.dispatched_patch_s).abs() <= 1e-6 * rep.dispatched_patch_s.max(1.0),
                "dispatched {} != completed {} + wasted {} + inflight {}",
                rep.dispatched_patch_s,
                rep.completed_patch_s,
                rep.wasted_patch_s,
                rep.inflight_patch_s
            );
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", 100, |g| {
            let a = g.f64_in(-1e6, 1e6);
            let b = g.f64_in(-1e6, 1e6);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            check("always fails", 5, |g| {
                let x = g.usize_in(0, 10);
                assert!(x > 100, "x={x} not > 100");
            });
        });
        let err = r.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("EAT_PROP_SEED="), "msg={msg}");
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::new(1);
        let mut b = Gen::new(1);
        for _ in 0..32 {
            assert_eq!(a.usize_in(0, 1000), b.usize_in(0, 1000));
        }
    }
}
