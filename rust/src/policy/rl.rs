//! RL policies: thin `Policy` adapters over the SAC / PPO drivers, used at
//! evaluation time (Algorithm 1's decision process with a trained or
//! training policy network).

use super::Policy;
use crate::config::ExperimentConfig;
use crate::rl::{PpoDriver, SacDriver};
use crate::runtime::Runtime;
use crate::sim::env::{Action, EdgeEnv};

/// SAC-family policy (EAT / EAT-A / EAT-D / EAT-DA).
pub struct SacPolicy {
    driver: SacDriver,
    deterministic: bool,
}

impl SacPolicy {
    /// Defaults to *stochastic* action selection: Algorithm 1 samples
    /// a ~ N(x_0, σ²) — the diffusion policy is generative by design, and
    /// deterministic (σ=0) evaluation of a briefly-trained policy can pin
    /// the execution gate shut.
    pub fn new(rt: &Runtime, cfg: &ExperimentConfig) -> anyhow::Result<Self> {
        Ok(SacPolicy {
            driver: SacDriver::new(rt, cfg)?,
            deterministic: false,
        })
    }

    pub fn from_driver(driver: SacDriver, deterministic: bool) -> Self {
        SacPolicy {
            driver,
            deterministic,
        }
    }

    pub fn driver_mut(&mut self) -> &mut SacDriver {
        &mut self.driver
    }

    pub fn set_deterministic(&mut self, deterministic: bool) {
        self.deterministic = deterministic;
    }
}

impl Policy for SacPolicy {
    fn name(&self) -> String {
        self.driver.alg.name().to_string()
    }

    fn decide(&mut self, env: &EdgeEnv) -> anyhow::Result<Action> {
        let state = env.state();
        let raw = self.driver.act(&state, self.deterministic)?;
        Ok(Action::from_vec(&raw))
    }
}

/// PPO baseline policy.
pub struct PpoPolicy {
    driver: PpoDriver,
    deterministic: bool,
}

impl PpoPolicy {
    pub fn new(rt: &Runtime, cfg: &ExperimentConfig) -> anyhow::Result<Self> {
        Ok(PpoPolicy {
            driver: PpoDriver::new(rt, cfg)?,
            deterministic: false,
        })
    }

    pub fn from_driver(driver: PpoDriver, deterministic: bool) -> Self {
        PpoPolicy {
            driver,
            deterministic,
        }
    }

    pub fn driver_mut(&mut self) -> &mut PpoDriver {
        &mut self.driver
    }
}

impl Policy for PpoPolicy {
    fn name(&self) -> String {
        "PPO".to_string()
    }

    fn decide(&mut self, env: &EdgeEnv) -> anyhow::Result<Action> {
        let state = env.state();
        let (raw, _logp, _value) = self.driver.act(&state, self.deterministic)?;
        Ok(Action::from_vec(&raw))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algorithm;

    fn runtime() -> Option<Runtime> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Runtime::new(dir.to_str().unwrap()).unwrap())
    }

    #[test]
    fn sac_policy_decides_for_all_variants() {
        let Some(rt) = runtime() else { return };
        for alg in [
            Algorithm::Eat,
            Algorithm::EatA,
            Algorithm::EatD,
            Algorithm::EatDa,
        ] {
            let mut cfg = ExperimentConfig::preset_8node(0.1);
            cfg.algorithm = alg;
            if !rt.has_entry(&format!("{}_{}_act", alg.artifact_key().unwrap(), cfg.topology_key())) {
                continue;
            }
            let env = EdgeEnv::new(cfg.env.clone(), 1);
            let mut p = SacPolicy::new(&rt, &cfg).unwrap();
            let a = p.decide(&env).unwrap();
            assert_eq!(a.task_scores.len(), cfg.env.queue_window);
        }
    }

    #[test]
    fn ppo_policy_decides() {
        let Some(rt) = runtime() else { return };
        let mut cfg = ExperimentConfig::preset_8node(0.1);
        cfg.algorithm = Algorithm::Ppo;
        let env = EdgeEnv::new(cfg.env.clone(), 2);
        let mut p = PpoPolicy::new(&rt, &cfg).unwrap();
        let a = p.decide(&env).unwrap();
        assert!(a.exec_gate.is_finite());
    }
}
