//! Shared machinery for the sequence-optimising meta-heuristics
//! (Harmony Search and the Genetic Algorithm).
//!
//! Both baselines "precompute a fixed action sequence to maximize the
//! reward" (§VI.B.3): a genome is a horizon x action_dim matrix of raw
//! action components in [-1, 1], whose fitness is the total episode reward
//! when replayed on a *planning* environment. The planning environment
//! uses the same cluster/workload configuration but a different workload
//! realisation than evaluation — the paper's point is precisely that these
//! methods lack environmental feedback, so their plan meets a workload it
//! has never seen.

use crate::config::ExperimentConfig;
use crate::sim::env::{Action, EdgeEnv};
use crate::util::rng::Pcg64;

/// Planning horizon in decision steps (paper: "optimize a 2048-steps").
pub const HORIZON: usize = 2048;

/// Flat genome: HORIZON x action_dim raw components.
pub type Genome = Vec<f32>;

pub fn genome_len(action_dim: usize) -> usize {
    HORIZON * action_dim
}

pub fn random_genome(action_dim: usize, rng: &mut Pcg64) -> Genome {
    let mut g = vec![0.0f32; genome_len(action_dim)];
    for x in g.iter_mut() {
        *x = rng.uniform(-1.0, 1.0) as f32;
    }
    g
}

/// Action at step `t` of a genome.
pub fn decode(genome: &Genome, t: usize, action_dim: usize) -> Action {
    let t = t % HORIZON; // wrap if the episode outlives the plan
    let row = &genome[t * action_dim..(t + 1) * action_dim];
    Action::from_vec(row)
}

/// Build a fresh planning environment: same config, *shifted* seed so the
/// plan never sees the evaluation workload.
pub fn planning_env(cfg: &ExperimentConfig, plan_round: u64) -> EdgeEnv {
    EdgeEnv::new(cfg.env.clone(), cfg.seed ^ 0x9E3779B9 ^ plan_round)
}

/// Fitness: total reward of replaying the genome on `env` (consumed).
pub fn fitness(mut env: EdgeEnv, genome: &Genome, action_dim: usize) -> f64 {
    let mut t = 0usize;
    loop {
        let action = decode(genome, t, action_dim);
        let out = env.step(&action);
        t += 1;
        if out.done {
            break;
        }
    }
    env.report().total_reward
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    #[test]
    fn decode_wraps_horizon() {
        let a_dim = 4;
        let mut rng = Pcg64::seeded(1);
        let g = random_genome(a_dim, &mut rng);
        let a0 = decode(&g, 0, a_dim);
        let aw = decode(&g, HORIZON, a_dim);
        assert_eq!(a0.to_vec(), aw.to_vec());
    }

    #[test]
    fn fitness_is_deterministic_for_same_genome() {
        let cfg = ExperimentConfig::preset_4node(0.05);
        let mut rng = Pcg64::seeded(2);
        let a_dim = cfg.env.action_len();
        let g = random_genome(a_dim, &mut rng);
        let f1 = fitness(planning_env(&cfg, 0), &g, a_dim);
        let f2 = fitness(planning_env(&cfg, 0), &g, a_dim);
        assert_eq!(f1, f2);
    }

    #[test]
    fn planning_env_differs_from_eval_env() {
        let cfg = ExperimentConfig::preset_4node(0.05);
        let plan = planning_env(&cfg, 0);
        let eval = EdgeEnv::new(cfg.env.clone(), cfg.seed);
        // Different workload realisations (almost surely).
        let pq: Vec<f64> = plan.workload_arrivals();
        let eq: Vec<f64> = eval.workload_arrivals();
        assert_ne!(pq, eq);
    }
}
