//! Harmony Search baseline (Geem et al. 2001; paper §VI.A.2): harmony
//! memory of 64 action sequences, 64 improvisations, memory-consideration
//! rate 0.8, pitch-adjustment rate 0.2, bandwidth 0.1 (on the [-1, 1]
//! action scale). The best harmony becomes a fixed plan replayed at
//! evaluation time.

use super::seq::{self, Genome};
use super::Policy;
use crate::config::ExperimentConfig;
use crate::sim::env::{Action, EdgeEnv};
use crate::util::rng::Pcg64;

pub struct HarmonyPolicy {
    cfg: ExperimentConfig,
    rng: Pcg64,
    plan: Option<Genome>,
    step: usize,
    plan_round: u64,
    // Hyperparameters (paper values).
    pub memory_size: usize,
    pub improvisations: usize,
    pub hmcr: f64,
    pub par: f64,
    pub bandwidth: f32,
}

impl HarmonyPolicy {
    pub fn new(cfg: ExperimentConfig) -> Self {
        let seed = cfg.seed;
        HarmonyPolicy {
            cfg,
            rng: Pcg64::new(seed, 0x4A12),
            plan: None,
            step: 0,
            plan_round: 0,
            memory_size: 64,
            improvisations: 64,
            hmcr: 0.8,
            par: 0.2,
            bandwidth: 0.1,
        }
    }

    fn optimise(&mut self) -> Genome {
        let a_dim = self.cfg.env.action_len();
        let glen = seq::genome_len(a_dim);
        // Initial memory: random harmonies, scored on planning rollouts.
        let mut memory: Vec<(Genome, f64)> = (0..self.memory_size)
            .map(|_| {
                let g = seq::random_genome(a_dim, &mut self.rng);
                let f = seq::fitness(seq::planning_env(&self.cfg, self.plan_round), &g, a_dim);
                (g, f)
            })
            .collect();
        for _ in 0..self.improvisations {
            let mut g = vec![0.0f32; glen];
            for i in 0..glen {
                if self.rng.next_f64() < self.hmcr {
                    // Memory consideration: copy this gene from a random
                    // remembered harmony...
                    let src = self.rng.next_below(memory.len() as u64) as usize;
                    let mut v = memory[src].0[i];
                    // ...with optional pitch adjustment.
                    if self.rng.next_f64() < self.par {
                        v += self.rng.uniform(-1.0, 1.0) as f32 * self.bandwidth;
                    }
                    g[i] = v.clamp(-1.0, 1.0);
                } else {
                    g[i] = self.rng.uniform(-1.0, 1.0) as f32;
                }
            }
            let f = seq::fitness(seq::planning_env(&self.cfg, self.plan_round), &g, a_dim);
            // Replace the worst harmony if improved.
            let (worst_idx, worst_f) = memory
                .iter()
                .enumerate()
                .map(|(i, (_, f))| (i, *f))
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            if f > worst_f {
                memory[worst_idx] = (g, f);
            }
        }
        memory
            .into_iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap()
            .0
    }
}

impl Policy for HarmonyPolicy {
    fn name(&self) -> String {
        "Harmony".to_string()
    }

    fn reset(&mut self, _env: &EdgeEnv) {
        // The paper's meta-heuristics precompute ONE fixed action sequence;
        // plan lazily on first use, then just rewind for later episodes.
        if self.plan.is_none() {
            self.plan = Some(self.optimise());
            self.plan_round += 1;
        }
        self.step = 0;
    }

    fn decide(&mut self, _env: &EdgeEnv) -> anyhow::Result<Action> {
        if self.plan.is_none() {
            self.plan = Some(self.optimise());
        }
        let a_dim = self.cfg.env.action_len();
        let action = seq::decode(self.plan.as_ref().unwrap(), self.step, a_dim);
        self.step += 1;
        Ok(action)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algorithm;

    fn small_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::preset_4node(0.05);
        cfg.algorithm = Algorithm::Harmony;
        cfg.env.tasks_per_episode = 6;
        cfg.env.step_limit = 200;
        cfg.env.time_limit = 200.0;
        cfg
    }

    #[test]
    fn optimised_plan_beats_random_on_planning_env() {
        let cfg = small_cfg();
        let mut p = HarmonyPolicy::new(cfg.clone());
        p.memory_size = 8;
        p.improvisations = 16;
        let plan = p.optimise();
        let a_dim = cfg.env.action_len();
        let plan_fit = seq::fitness(seq::planning_env(&cfg, 0), &plan, a_dim);
        let mut rng = Pcg64::seeded(99);
        let rand_fit: f64 = (0..4)
            .map(|_| {
                let g = seq::random_genome(a_dim, &mut rng);
                seq::fitness(seq::planning_env(&cfg, 0), &g, a_dim)
            })
            .sum::<f64>()
            / 4.0;
        assert!(
            plan_fit >= rand_fit,
            "plan {plan_fit} should be >= mean random {rand_fit}"
        );
    }

    #[test]
    fn runs_an_episode() {
        let cfg = small_cfg();
        let mut p = HarmonyPolicy::new(cfg.clone());
        p.memory_size = 4;
        p.improvisations = 4;
        let mut env = EdgeEnv::new(cfg.env.clone(), cfg.seed);
        p.reset(&env);
        loop {
            let a = p.decide(&env).unwrap();
            if env.step(&a).done {
                break;
            }
        }
        assert!(env.report().decision_steps > 0);
    }
}
