//! Random baseline: uniform action vector each tick; the env's task/server
//! selectors then interpret it (paper: "Randomly selects an action and
//! adopts the Task selector and Server selector to allocate the task").

use super::Policy;
use crate::config::EnvConfig;
use crate::sim::env::{Action, EdgeEnv};
use crate::util::rng::Pcg64;

pub struct RandomPolicy {
    cfg: EnvConfig,
    rng: Pcg64,
}

impl RandomPolicy {
    pub fn new(cfg: EnvConfig, seed: u64) -> Self {
        RandomPolicy {
            cfg,
            rng: Pcg64::new(seed, 0x2A4D),
        }
    }
}

impl Policy for RandomPolicy {
    fn name(&self) -> String {
        "Random".to_string()
    }

    fn decide(&mut self, _env: &EdgeEnv) -> anyhow::Result<Action> {
        let l = self.cfg.queue_window;
        let mut scores = vec![0.0f32; l];
        for s in scores.iter_mut() {
            *s = self.rng.uniform(-1.0, 1.0) as f32;
        }
        Ok(Action {
            exec_gate: self.rng.uniform(-1.0, 1.0) as f32,
            steps_raw: self.rng.uniform(-1.0, 1.0) as f32,
            task_scores: scores,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::sim::env::EdgeEnv;

    #[test]
    fn emits_valid_actions() {
        let cfg = ExperimentConfig::preset_8node(0.1);
        let env = EdgeEnv::new(cfg.env.clone(), 1);
        let mut p = RandomPolicy::new(cfg.env.clone(), 7);
        let mut execs = 0;
        for _ in 0..200 {
            let a = p.decide(&env).unwrap();
            assert!(a.exec_gate.abs() <= 1.0 && a.steps_raw.abs() <= 1.0);
            assert_eq!(a.task_scores.len(), cfg.env.queue_window);
            if a.wants_exec() {
                execs += 1;
            }
        }
        // Gate ~Bernoulli(0.5): both branches exercised.
        assert!(execs > 50 && execs < 150, "execs={execs}");
    }
}
