//! Genetic Algorithm baseline (Holland; paper §VI.A.2): population 64,
//! 32 generations, 10 parents, crossover probability 1, per-gene mutation
//! probability 0.1, 1 elite. Evolves a fixed 2048-step action sequence on
//! planning rollouts, then replays the champion at evaluation time.

use super::seq::{self, Genome};
use super::Policy;
use crate::config::ExperimentConfig;
use crate::sim::env::{Action, EdgeEnv};
use crate::util::rng::Pcg64;

pub struct GeneticPolicy {
    cfg: ExperimentConfig,
    rng: Pcg64,
    plan: Option<Genome>,
    step: usize,
    plan_round: u64,
    // Hyperparameters (paper values).
    pub population: usize,
    pub generations: usize,
    pub parents: usize,
    pub mutation_prob: f64,
    pub elites: usize,
}

impl GeneticPolicy {
    pub fn new(cfg: ExperimentConfig) -> Self {
        let seed = cfg.seed;
        GeneticPolicy {
            cfg,
            rng: Pcg64::new(seed, 0x6E47),
            plan: None,
            step: 0,
            plan_round: 0,
            population: 64,
            generations: 32,
            parents: 10,
            mutation_prob: 0.1,
            elites: 1,
        }
    }

    fn score(&self, g: &Genome) -> f64 {
        seq::fitness(
            seq::planning_env(&self.cfg, self.plan_round),
            g,
            self.cfg.env.action_len(),
        )
    }

    fn optimise(&mut self) -> Genome {
        let a_dim = self.cfg.env.action_len();
        let glen = seq::genome_len(a_dim);
        let mut pop: Vec<(Genome, f64)> = (0..self.population)
            .map(|_| {
                let g = seq::random_genome(a_dim, &mut self.rng);
                let f = self.score(&g);
                (g, f)
            })
            .collect();
        for _ in 0..self.generations {
            pop.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            let parents: Vec<Genome> =
                pop.iter().take(self.parents).map(|(g, _)| g.clone()).collect();
            let mut next: Vec<(Genome, f64)> = pop[..self.elites].to_vec();
            while next.len() < self.population {
                // Crossover (prob 1): uniform mix of two random parents.
                let pa = &parents[self.rng.next_below(parents.len() as u64) as usize];
                let pb = &parents[self.rng.next_below(parents.len() as u64) as usize];
                let mut child = vec![0.0f32; glen];
                for i in 0..glen {
                    child[i] = if self.rng.next_u64() & 1 == 0 { pa[i] } else { pb[i] };
                    // Per-gene mutation.
                    if self.rng.next_f64() < self.mutation_prob {
                        child[i] = self.rng.uniform(-1.0, 1.0) as f32;
                    }
                }
                let f = self.score(&child);
                next.push((child, f));
            }
            pop = next;
        }
        pop.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        pop.remove(0).0
    }
}

impl Policy for GeneticPolicy {
    fn name(&self) -> String {
        "Genetic".to_string()
    }

    fn reset(&mut self, _env: &EdgeEnv) {
        // Precompute one fixed plan (paper behaviour); rewind thereafter.
        if self.plan.is_none() {
            self.plan = Some(self.optimise());
            self.plan_round += 1;
        }
        self.step = 0;
    }

    fn decide(&mut self, _env: &EdgeEnv) -> anyhow::Result<Action> {
        if self.plan.is_none() {
            self.plan = Some(self.optimise());
        }
        let a_dim = self.cfg.env.action_len();
        let action = seq::decode(self.plan.as_ref().unwrap(), self.step, a_dim);
        self.step += 1;
        Ok(action)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algorithm;

    fn small_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::preset_4node(0.05);
        cfg.algorithm = Algorithm::Genetic;
        cfg.env.tasks_per_episode = 6;
        cfg.env.step_limit = 150;
        cfg.env.time_limit = 150.0;
        cfg
    }

    #[test]
    fn evolution_does_not_regress() {
        let cfg = small_cfg();
        let mut p = GeneticPolicy::new(cfg.clone());
        p.population = 8;
        p.generations = 3;
        p.parents = 3;
        let champion = p.optimise();
        let champ_fit = p.score(&champion);
        // The champion should at least beat a fresh random genome on the
        // same planning env (p.plan_round unchanged inside optimise()).
        let mut rng = Pcg64::seeded(5);
        let g = seq::random_genome(cfg.env.action_len(), &mut rng);
        let rand_fit = p.score(&g);
        assert!(champ_fit >= rand_fit, "{champ_fit} < {rand_fit}");
    }

    #[test]
    fn replays_plan_over_episode() {
        let cfg = small_cfg();
        let mut p = GeneticPolicy::new(cfg.clone());
        p.population = 4;
        p.generations = 2;
        p.parents = 2;
        let mut env = EdgeEnv::new(cfg.env.clone(), cfg.seed);
        p.reset(&env);
        let a1 = p.decide(&env).unwrap();
        let a2 = p.decide(&env).unwrap();
        // Plan is fixed: decisions come from consecutive genome rows.
        let plan = p.plan.as_ref().unwrap();
        let a_dim = cfg.env.action_len();
        assert_eq!(a1.to_vec(), plan[0..a_dim].to_vec());
        assert_eq!(a2.to_vec(), plan[a_dim..2 * a_dim].to_vec());
        loop {
            let a = p.decide(&env).unwrap();
            if env.step(&a).done {
                break;
            }
        }
    }
}
