//! Greedy baseline: exhaustively evaluates every (visible task, step
//! count) pair against the predicted immediate reward and picks the best
//! (paper: "selects actions to maximize immediate rewards by evaluating
//! all policies"). Because quality grows with steps much faster than the
//! reciprocal time term shrinks, this policy maxes out inference steps —
//! winning Table IX quality but losing Table X latency badly.

use super::{steps_to_raw, Policy};
use crate::config::EnvConfig;
use crate::sim::cluster::Selection;
use crate::sim::env::{Action, EdgeEnv};

pub struct GreedyPolicy {
    cfg: EnvConfig,
}

impl GreedyPolicy {
    pub fn new(cfg: EnvConfig) -> Self {
        GreedyPolicy { cfg }
    }

    /// Predicted immediate reward of scheduling queue slot `idx` with
    /// `steps` right now (mirrors EdgeEnv::reward_for but with the
    /// *predictor*, not realised samples — the policy can't see the
    /// simulator's dice).
    fn predicted_reward(&self, env: &EdgeEnv, idx: usize, steps: u32) -> Option<f64> {
        let task = env.queue().get(idx)?;
        // Health-aware under an active fault config: down servers are
        // masked, so Greedy never bids on a gang that cannot run.
        let sel = env.select_for(task.model, task.patches);
        let (reuse, feasible) = match sel {
            Selection::Reuse(_) => (true, true),
            Selection::Fresh(_) => (false, true),
            Selection::Infeasible => (false, false),
        };
        if !feasible {
            return None;
        }
        let em = env.exec_model();
        let mut duration = em.predict_exec(steps, task.patches);
        if !reuse {
            duration += em.predict_init(task.patches);
        }
        let waiting = (env.now() - task.arrival).max(0.0);
        let response = waiting + duration;
        let q = env.quality_model().mean_quality(steps);
        let r = &self.cfg.reward;
        let penalty = if q < r.q_min { r.p_quality } else { 0.0 };
        let denom = r.beta_t * response + r.mu_t * env.avg_queue_wait() + 1e-3;
        Some(r.alpha_q * q - r.lambda_q * penalty + 1.0 / denom)
    }
}

impl Policy for GreedyPolicy {
    fn name(&self) -> String {
        "Greedy".to_string()
    }

    fn decide(&mut self, env: &EdgeEnv) -> anyhow::Result<Action> {
        let l = self.cfg.queue_window;
        let visible = env.queue().len().min(l);
        let mut best: Option<(usize, u32, f64)> = None;
        for idx in 0..visible {
            for steps in self.cfg.s_min..=self.cfg.s_max {
                if let Some(r) = self.predicted_reward(env, idx, steps) {
                    if best.map(|(_, _, b)| r > b).unwrap_or(true) {
                        best = Some((idx, steps, r));
                    }
                }
            }
        }
        match best {
            None => Ok(Action::noop(l)),
            Some((idx, steps, _)) => {
                let mut scores = vec![-1.0f32; l];
                scores[idx] = 1.0;
                Ok(Action {
                    exec_gate: -1.0,
                    steps_raw: steps_to_raw(steps, self.cfg.s_min, self.cfg.s_max),
                    task_scores: scores,
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::sim::env::EdgeEnv;

    #[test]
    fn greedy_maxes_steps_on_idle_cluster() {
        let cfg = ExperimentConfig::preset_8node(0.1);
        let mut env = EdgeEnv::new(cfg.env.clone(), 3);
        let mut p = GreedyPolicy::new(cfg.env.clone());
        // Let at least one task arrive.
        while env.queue().is_empty() {
            env.step(&Action::noop(cfg.env.queue_window));
        }
        let a = p.decide(&env).unwrap();
        assert!(a.wants_exec());
        assert_eq!(a.steps(cfg.env.s_min, cfg.env.s_max), cfg.env.s_max);
    }

    #[test]
    fn greedy_noops_on_empty_queue() {
        let mut cfg = ExperimentConfig::preset_8node(0.0001);
        cfg.env.tasks_per_episode = 1;
        let env = EdgeEnv::new(cfg.env.clone(), 4);
        let mut p = GreedyPolicy::new(cfg.env.clone());
        if env.queue().is_empty() {
            let a = p.decide(&env).unwrap();
            assert!(!a.wants_exec());
        }
    }

    #[test]
    fn greedy_runs_full_episode_with_high_quality() {
        let cfg = ExperimentConfig::preset_8node(0.1);
        let mut env = EdgeEnv::new(cfg.env.clone(), 5);
        let mut p = GreedyPolicy::new(cfg.env.clone());
        loop {
            let a = p.decide(&env).unwrap();
            if env.step(&a).done {
                break;
            }
        }
        let rep = env.report();
        assert!(rep.completed_tasks > 10);
        // Greedy always takes S_max -> quality ~0.270 (Table IX).
        assert!((rep.avg_quality - 0.27).abs() < 0.01, "q={}", rep.avg_quality);
    }
}
