//! Scheduling policies: the EAT RL family plus the paper's seven baselines
//! (§VI.A.3). Every policy emits the composite action vector of Eq. 8 and
//! is driven uniformly by `coordinator::run_episode`.

pub mod genetic;
pub mod greedy;
pub mod harmony;
pub mod random;
pub mod rl;
pub mod seq;

pub use genetic::GeneticPolicy;
pub use greedy::GreedyPolicy;
pub use harmony::HarmonyPolicy;
pub use random::RandomPolicy;
pub use rl::{PpoPolicy, SacPolicy};

use crate::config::{Algorithm, ExperimentConfig};
use crate::runtime::Runtime;
use crate::sim::env::{Action, EdgeEnv};

/// A scheduling policy: maps observations to composite actions.
pub trait Policy {
    fn name(&self) -> String;

    /// Called once at episode start (meta-heuristics re-plan here).
    fn reset(&mut self, _env: &EdgeEnv) {}

    /// Produce the action for the current decision step.
    fn decide(&mut self, env: &EdgeEnv) -> anyhow::Result<Action>;
}

/// Instantiate the policy named by the config. RL policies need a runtime
/// (`Some(rt)`); heuristics ignore it.
pub fn build_policy(
    cfg: &ExperimentConfig,
    rt: Option<&Runtime>,
) -> anyhow::Result<Box<dyn Policy>> {
    Ok(match cfg.algorithm {
        Algorithm::Random => Box::new(RandomPolicy::new(cfg.env.clone(), cfg.seed)),
        Algorithm::Greedy => Box::new(GreedyPolicy::new(cfg.env.clone())),
        Algorithm::Harmony => Box::new(HarmonyPolicy::new(cfg.clone())),
        Algorithm::Genetic => Box::new(GeneticPolicy::new(cfg.clone())),
        Algorithm::Ppo => {
            let rt = rt.ok_or_else(|| anyhow::anyhow!("PPO needs a runtime"))?;
            Box::new(PpoPolicy::new(rt, cfg)?)
        }
        _ => {
            let rt = rt.ok_or_else(|| anyhow::anyhow!("{} needs a runtime", cfg.algorithm.name()))?;
            Box::new(SacPolicy::new(rt, cfg)?)
        }
    })
}

/// Map a concrete step count back to the raw a_s knob in [-1, 1]
/// (inverse of `Action::steps`).
pub fn steps_to_raw(steps: u32, s_min: u32, s_max: u32) -> f32 {
    let u = (steps.clamp(s_min, s_max) - s_min) as f32 / (s_max - s_min).max(1) as f32;
    2.0 * u - 1.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steps_raw_roundtrip() {
        let (lo, hi) = (1u32, 25u32);
        for s in lo..=hi {
            let raw = steps_to_raw(s, lo, hi);
            let back = Action {
                exec_gate: -1.0,
                steps_raw: raw,
                task_scores: vec![0.0],
            }
            .steps(lo, hi);
            assert_eq!(back, s, "roundtrip failed for {s}");
        }
    }
}
