//! `eat` — leader entrypoint for the EAT scheduling system.
//!
//! Subcommands:
//!   eat experiment <id> [--nodes N] [--episodes K] [...]   regenerate a
//!       paper table/figure (table1, table2_4, table6, table9/10/11,
//!       table12, fig4..fig8, grid, all)
//!   eat train [--alg eat] [--nodes 8] [--episodes 20]      train a policy
//!       and write a checkpoint under artifacts/checkpoints/
//!   eat eval [--alg eat] [--nodes 8] [--episodes 5]        evaluate one
//!       policy and print the summary
//!   eat serve [--workers 4] [--tasks 16] [--time-scale 2e-3]
//!            [--scenario <family>]
//!       run the socket-based serving system end to end with the
//!       reuse-aware scheduler; --scenario drives it with any workload
//!       scenario family instead of stationary Poisson
//!   eat scenarios [--nodes 8] [--episodes 2] [--algs greedy,random,...]
//!       sweep every workload scenario family (poisson, constant, bursty,
//!       diurnal, flash, zipf-hot, rotating) across policies with
//!       p50/p90/p99 latency, utilization and reload counts; supports
//!       JSONL trace --record <dir> and bit-exact --replay <file>
//!   eat qos [--nodes 8] [--tasks 120] [--overloads 1.0,3.0] [...]
//!       multi-tenant QoS sweep: overload factor × admission policy ×
//!       queue discipline, with per-tenant p50/p90/p99, SLO attainment,
//!       and drop rates
//!   eat faults [--nodes 8] [--mtbfs 0,600,200] [--modes aware,blind]
//!       fault & straggler sweep: MTBF x zone shocks x straggler rate x
//!       dispatch mode, with goodput, wasted-work fraction, retries, and
//!       per-tenant SLO attainment under churn
//!   eat trace import <csv> <out.jsonl>                      map a CSV
//!       request log onto a JSONL workload trace (replayable via
//!       `eat scenarios --replay`)
//!   eat info                                                print artifact
//!       manifest summary

use eat::config::{Algorithm, ExperimentConfig};
use eat::coordinator::evaluate;
use eat::experiments;
use eat::rl::{PpoDriver, SacDriver};
use eat::runtime::Runtime;
use eat::util::cli::Args;

fn usage() -> ! {
    eprintln!(
        "usage: eat <experiment|train|eval|serve|scenarios|qos|faults|info> [options]\n\
         \n  eat experiment <id>   ids: table1 table2_4 table6 table9 table10 table11\n\
         \x20                          table12 fig4 fig5 fig6 fig7 fig8 grid scenarios all\n\
         \x20     options: --nodes 4|8|12 --episodes K --train-episodes K --algs a,b,c\n\
         \x20              --rates 0.01,0.05 --seed S --verbose\n\
         \n  eat train   --alg eat|eat-a|eat-d|eat-da|ppo --nodes N --episodes K [--seed S]\n\
         \n  eat eval    --alg <any> --nodes N --episodes K [--train-episodes K]\n\
         \n  eat serve   --workers 4 --tasks 16 --time-scale 2e-3 [--seed S]\n\
         \x20           [--scenario poisson|constant|bursty|diurnal|flash|zipf-hot|rotating]\n\
         \n  eat scenarios [--nodes N] [--episodes K] [--rate R] [--algs a,b,c]\n\
         \x20             [--scenarios poisson,bursty,...] [--record dir]\n\
         \x20             [--replay file [--scenario name] [--ep K]]\n\
         \n  eat qos     [--nodes N] [--tasks K] [--episodes E] [--rate R] [--seed S]\n\
         \x20           [--overloads 1.0,3.0] [--admissions admit-all,drop-tail,token-bucket]\n\
         \x20           [--queues fifo,edf] [--max-queue Q] [--bucket-rate R] [--bucket-burst B]\n\
         \n  eat faults  [--nodes N] [--tasks K] [--episodes E] [--rate R] [--seed S]\n\
         \x20           [--mtbfs 0,600,200] [--zone-rates 0.002] [--straggler-rates 0.005]\n\
         \x20           [--modes aware,blind] [--mttr T] [--zones Z] [--spec-beta B]\n\
         \x20           [--max-retries R]\n\
         \n  eat trace import <csv> <out.jsonl>\n\
         \n  eat info"
    );
    std::process::exit(2)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let Some(cmd) = args.positional.first().map(String::as_str) else {
        usage()
    };
    match cmd {
        "experiment" => {
            let Some(id) = args.positional.get(1).map(String::as_str) else {
                usage()
            };
            experiments::run(id, &args)?;
        }
        "train" => {
            let alg = Algorithm::parse(&args.get_or("alg", "eat"))?;
            let nodes = args.get_usize("nodes", 8);
            let episodes = args.get_usize("episodes", 10);
            let mut cfg = ExperimentConfig::preset(nodes);
            cfg.algorithm = alg;
            cfg.seed = args.get_u64("seed", 42);
            let rt = Runtime::new(args.get("artifacts").unwrap_or("artifacts"))?;
            std::fs::create_dir_all(format!("{}/checkpoints", cfg.artifacts_dir)).ok();
            let ckpt = experiments::checkpoint_path(&cfg);
            println!("training {} on {nodes} nodes for {episodes} episodes...", alg.name());
            let t0 = std::time::Instant::now();
            if alg == Algorithm::Ppo {
                let mut d = PpoDriver::new(&rt, &cfg)?;
                d.train_loop(&cfg, episodes, |p| {
                    println!(
                        "  ep {:>3}: reward {:>8.1} len {:>4} pi_loss {:>8.3}",
                        p.episode, p.reward, p.episode_len, p.actor_loss
                    );
                })?;
                d.save_actor(&ckpt)?;
            } else {
                let mut d = SacDriver::new(&rt, &cfg)?;
                d.train_loop(&cfg, episodes, |p| {
                    println!(
                        "  ep {:>3}: reward {:>8.1} len {:>4} critic {:>8.3} actor {:>8.3}",
                        p.episode, p.reward, p.episode_len, p.critic_loss, p.actor_loss
                    );
                })?;
                d.save_actor(&ckpt)?;
            }
            println!("saved {ckpt} ({:.1}s)", t0.elapsed().as_secs_f64());
        }
        "eval" => {
            let alg = Algorithm::parse(&args.get_or("alg", "eat"))?;
            let nodes = args.get_usize("nodes", 8);
            let episodes = args.get_usize("episodes", 5);
            let mut cfg = ExperimentConfig::preset(nodes);
            cfg.algorithm = alg;
            cfg.seed = args.get_u64("seed", 42);
            if let Some(rate) = args.get("rate") {
                cfg.env.arrival_rate = rate.parse()?;
            }
            let rt = if alg.artifact_key().is_some() {
                Some(Runtime::new(args.get("artifacts").unwrap_or("artifacts"))?)
            } else {
                None
            };
            let mut policy = experiments::trained_policy(
                &cfg,
                rt.as_ref(),
                args.get_usize("train-episodes", 2),
                args.has_flag("verbose"),
            )?;
            let s = evaluate(&cfg, policy.as_mut(), episodes);
            println!(
                "{}: quality {:.3}  latency {:.1}s  reload {:.3}  efficiency {:.2e}  \
                 reward {:.1}  decision {:.2e}s",
                s.algorithm,
                s.avg_quality,
                s.avg_response_latency,
                s.reload_rate,
                s.efficiency,
                s.avg_reward,
                s.decision_latency_s
            );
        }
        "serve" => {
            serve(&args)?;
        }
        "scenarios" => {
            experiments::scenarios::run(&args)?;
        }
        "qos" => {
            experiments::qos::run(&args)?;
        }
        "faults" => {
            experiments::faults::run(&args)?;
        }
        "trace" => match args.positional.get(1).map(String::as_str) {
            Some("import") => {
                let (Some(csv), Some(out)) = (args.positional.get(2), args.positional.get(3))
                else {
                    usage()
                };
                let n = eat::workload::import::import_file(csv, out)?;
                println!("imported {n} tasks: {csv} -> {out}");
            }
            _ => usage(),
        },
        "info" => {
            let rt = Runtime::new(args.get("artifacts").unwrap_or("artifacts"))?;
            println!("platform: {}", rt.platform());
            println!("batch size: {}", rt.manifest.batch_size);
            println!("denoise steps: {}", rt.manifest.denoise_steps);
            println!("entries ({}):", rt.manifest.entries.len());
            for (k, e) in &rt.manifest.entries {
                println!("  {k}: {} inputs, {} outputs", e.inputs.len(), e.outputs.len());
            }
        }
        _ => usage(),
    }
    Ok(())
}

/// End-to-end serving: spawn socket workers, generate a task stream, and
/// schedule it with the reuse-aware gang scheduler, reporting per-task
/// latency and the throughput/reload summary.
fn serve(args: &Args) -> anyhow::Result<()> {
    use eat::serving::{ServingHost, WorkerPool};
    use eat::sim::cluster::{Cluster, Selection};
    use eat::sim::task::{ModelType, Workload};
    use eat::util::rng::Pcg64;
    use eat::workload::{MetricsCollector, WorkloadConfig};

    let workers = args.get_usize("workers", 4);
    let n_tasks = args.get_usize("tasks", 12);
    let time_scale = args.get_f64("time-scale", 2e-3);
    let seed = args.get_u64("seed", 42);
    let mut cfg = ExperimentConfig::preset(workers.max(4)).env;
    cfg.num_servers = workers;
    cfg.tasks_per_episode = n_tasks;
    cfg.patch_choices.retain(|&c| c <= workers);
    cfg.patch_weights = vec![1.0; cfg.patch_choices.len()];
    // Any scenario family can drive the serving emulation too.
    if let Some(name) = args.get("scenario") {
        cfg.workload = Some(WorkloadConfig::preset(name, cfg.arrival_rate)?);
    }

    println!("spawning {workers} socket workers (time scale {time_scale})...");
    let pool = WorkerPool::spawn(workers, cfg.exec.clone(), time_scale, seed)?;
    let host = ServingHost::new(pool.addrs().to_vec());
    let mut tracker = Cluster::new(workers); // mirrors worker model state
    let workload = Workload::generate(&cfg, &mut Pcg64::new(seed, 1));
    let mut metrics = MetricsCollector::new(workers);

    let t0 = std::time::Instant::now();
    // Dispatch is synchronous, so model a sequential simulated timeline:
    // a task starts once it has arrived AND the previous dispatch
    // finished. This makes the arrival process matter — bursty/flash
    // scenarios build genuine backlog (waiting > 0) while sparse ones
    // leave idle gaps.
    let mut sim_clock = 0.0f64;
    for task in &workload.tasks {
        // Gang selection with the reuse-aware greedy selector. The tracker
        // never marks servers busy (dispatch below is synchronous), so
        // selection is purely about model-reuse placement.
        let sel = tracker.select(ModelType(task.model.0), task.patches);
        let (gang, reuse) = match &sel {
            Selection::Reuse(v) => (v.clone(), true),
            Selection::Fresh(v) => (v.clone(), false),
            Selection::Infeasible => {
                // A task that cannot fit this cluster (e.g. more patches
                // than workers) used to vanish silently; count it so the
                // summary reflects deferred work instead of hiding it.
                metrics.observe_deferred();
                eprintln!(
                    "task {:>3}  patches {}  deferred: no feasible gang on {} workers",
                    task.id, task.patches, workers
                );
                continue;
            }
        };
        let waiting = (sim_clock - task.arrival).max(0.0);
        if task.arrival > sim_clock {
            // Idle until the task arrives.
            metrics.advance_time(task.arrival - sim_clock);
            sim_clock = task.arrival;
        }
        let steps = 20;
        let out = host.dispatch_collect(
            task.id,
            &format!("prompt-{}", task.prompt_id),
            steps,
            task.model.0,
            task.tenant.unwrap_or(0),
            &gang,
            waiting,
            &mut metrics,
        )?;
        let sim_s = out.sim_exec_seconds();
        metrics.advance_time(sim_s);
        sim_clock += sim_s;
        tracker.dispatch(&gang, 0.0, ModelType(task.model.0), reuse, sim_clock);
        println!(
            "task {:>3}  patches {}  gang {:?}  wait {:>6.1}s  sim {:>6.1}s  reload {}  wall {:>6.3}s",
            task.id,
            task.patches,
            gang,
            waiting,
            sim_s,
            out.any_reload(),
            out.wall_seconds
        );
    }
    println!(
        "\nserved {} tasks in {:.2}s wall; total simulated exec {:.1}s",
        workload.len(),
        t0.elapsed().as_secs_f64(),
        metrics.sim_time(),
    );
    println!("{}", metrics.summary_line());
    pool.shutdown();
    Ok(())
}
