//! `eat` — leader entrypoint for the EAT scheduling system.
//!
//! Subcommands:
//!   eat experiment <id> [--nodes N] [--episodes K] [...]   regenerate a
//!       paper table/figure (table1, table2_4, table6, table9/10/11,
//!       table12, fig4..fig8, grid, all)
//!   eat train [--alg eat] [--nodes 8] [--episodes 20]      train a policy
//!       and write a checkpoint under artifacts/checkpoints/
//!   eat eval [--alg eat] [--nodes 8] [--episodes 5]        evaluate one
//!       policy and print the summary
//!   eat serve [--workers 4] [--tasks 16] [--time-scale 2e-3]
//!            [--scenario <family>] [--resilient] [--kill-at K] [--wedge]
//!       run the socket-based serving system end to end with the
//!       reuse-aware scheduler; --scenario drives it with any workload
//!       scenario family instead of stationary Poisson; --resilient adds
//!       the heartbeat health registry + fault-tolerant gang dispatch,
//!       and --kill-at/--wedge/--respawn-at inject worker faults mid-run
//!   eat scenarios [--nodes 8] [--episodes 2] [--algs greedy,random,...]
//!       sweep every workload scenario family (poisson, constant, bursty,
//!       diurnal, flash, zipf-hot, rotating) across policies with
//!       p50/p90/p99 latency, utilization and reload counts; supports
//!       JSONL trace --record <dir> and bit-exact --replay <file>
//!   eat qos [--nodes 8] [--tasks 120] [--overloads 1.0,3.0] [...]
//!       multi-tenant QoS sweep: overload factor × admission policy ×
//!       queue discipline, with per-tenant p50/p90/p99, SLO attainment,
//!       and drop rates
//!   eat faults [--nodes 8] [--mtbfs 0,600,200] [--modes aware,blind]
//!       fault & straggler sweep: MTBF x zone shocks x straggler rate x
//!       dispatch mode, with goodput, wasted-work fraction, retries, and
//!       per-tenant SLO attainment under churn
//!   eat bench [--quick] [--out BENCH_sim.json] [--check BASELINE.json]
//!            [--min-speedup X]
//!       simulator-core benchmark: servers × tasks grid on the
//!       event-driven core vs the tick-scan core, emitting tasks/sec,
//!       decision-latency percentiles, and peak RSS as BENCH_sim.json;
//!       --check fails on >20% throughput regression vs a committed
//!       baseline, --min-speedup gates the ≥10k-server speedup ratio
//!   eat trace import <csv> <out.jsonl>                      map a CSV
//!       request log onto a JSONL workload trace (replayable via
//!       `eat scenarios --replay`)
//!   eat decisions analyze <ledger.jsonl> [--export-experience out.jsonl]
//!       hindsight-regret and calibration report over a per-decision
//!       scheduler ledger (`--decisions` on qos/faults/scenarios);
//!       --export-experience emits (state, action, reward) replay tuples,
//!       --compare gates one policy's median regret against another's
//!   eat slo report <file> [--target X] [--window 60]        per-tenant
//!       error budgets and multi-window burn rates over a lifecycle trace
//!       or fleet time series; exits non-zero when a budget is exhausted
//!   eat bench compare OLD.json NEW.json [--min-ratio 0.8]   per-cell
//!       throughput delta verdicts between two eat-bench-v1 documents
//!   eat lint [--json] [--fix-suggestions] [PATHS…]          repo-specific
//!       static analysis (determinism tiers, logging discipline, schema
//!       registry, unwrap audit, RNG hygiene); scans rust/src by default
//!       and exits non-zero on any finding
//!   eat info                                                print artifact
//!       manifest summary

use eat::config::{Algorithm, ExperimentConfig};
use eat::coordinator::evaluate;
use eat::experiments;
use eat::rl::{PpoDriver, SacDriver};
use eat::runtime::Runtime;
use eat::util::cli::Args;
use eat::{log_info, log_warn};

fn usage() -> ! {
    // eat-lint: allow(logging, "usage text must reach the terminal even with --quiet")
    eprintln!(
        "usage: eat <experiment|train|eval|serve|scenarios|qos|faults|bench|decisions|slo|lint|info> [options]\n\
         \n  eat experiment <id>   ids: table1 table2_4 table6 table9 table10 table11\n\
         \x20                          table12 fig4 fig5 fig6 fig7 fig8 grid scenarios all\n\
         \x20     options: --nodes 4|8|12 --episodes K --train-episodes K --algs a,b,c\n\
         \x20              --rates 0.01,0.05 --seed S --verbose\n\
         \n  eat train   --alg eat|eat-a|eat-d|eat-da|ppo --nodes N --episodes K [--seed S]\n\
         \n  eat eval    --alg <any> --nodes N --episodes K [--train-episodes K]\n\
         \n  eat serve   --workers 4 --tasks 16 --time-scale 2e-3 [--seed S]\n\
         \x20           [--scenario poisson|constant|bursty|diurnal|flash|zipf-hot|rotating]\n\
         \x20           [--resilient] [--hb-interval S] [--hb-timeout S] [--down-after N]\n\
         \x20           [--dispatch-timeout S] [--max-rounds R] [--defer-timeout S]\n\
         \x20           [--config file.json (reads its \"serving\" section)]\n\
         \x20           [--max-patches P] [--kill-at K [--kill-worker W] [--wedge]]\n\
         \x20           [--respawn-at K] [--metrics-addr 127.0.0.1:9184] [--trace out.jsonl]\n\
         \n  eat scenarios [--nodes N] [--episodes K] [--rate R] [--algs a,b,c]\n\
         \x20             [--scenarios poisson,bursty,...] [--record dir]\n\
         \x20             [--replay file [--scenario name] [--ep K]] [--trace out.jsonl]\n\
         \x20             [--decisions out.jsonl]\n\
         \n  eat qos     [--nodes N] [--tasks K] [--episodes E] [--rate R] [--seed S]\n\
         \x20           [--overloads 1.0,3.0] [--admissions admit-all,drop-tail,token-bucket]\n\
         \x20           [--queues fifo,edf] [--max-queue Q] [--bucket-rate R] [--bucket-burst B]\n\
         \x20           [--threads T] [--trace out.jsonl]\n\
         \x20           [--timeseries out.jsonl [--cadence 25]] [--decisions out.jsonl]\n\
         \n  eat faults  [--nodes N] [--tasks K] [--episodes E] [--rate R] [--seed S]\n\
         \x20           [--mtbfs 0,600,200] [--zone-rates 0.002] [--straggler-rates 0.005]\n\
         \x20           [--modes aware,blind] [--mttr T] [--zones Z] [--spec-beta B]\n\
         \x20           [--max-retries R] [--threads T] [--trace out.jsonl]\n\
         \x20           [--decisions out.jsonl]\n\
         \n  eat bench   [--quick] [--seed S] [--out BENCH_sim.json]\n\
         \x20           [--check BASELINE.json] [--min-speedup X]\n\
         \n  eat bench compare OLD.json NEW.json [--min-ratio 0.8] [--out verdict.json]\n\
         \x20     per-cell throughput deltas between two eat-bench-v1 docs; non-zero\n\
         \x20     exit when any cell's new/old ratio falls below the floor\n\
         \n  eat trace import <csv> <out.jsonl>\n\
         \n  eat trace analyze <trace.jsonl> [--json] [--top N]   decompose per-task latency\n\
         \x20     into queue/retry/cold/exec/straggler components (non-zero exit on\n\
         \x20     imbalance); --top lists the N slowest tasks with their decomposition\n\
         \n  eat decisions analyze <ledger.jsonl> [--json]\n\
         \x20     [--export-experience out.jsonl] [--compare other.jsonl]\n\
         \x20     hindsight-regret + calibration report over an eat-decisions-v1 ledger\n\
         \x20     (non-zero exit on join/books imbalance); --export-experience emits\n\
         \x20     (state, action, reward) replay tuples; --compare exits non-zero when\n\
         \x20     this ledger's median regret exceeds the other's\n\
         \n  eat slo report <trace.jsonl|series.jsonl> [--config file.json] [--target X]\n\
         \x20     [--latency-slo S] [--window 60] [--slow-window 300] [--json]\n\
         \x20     per-tenant error budgets + burn rates; non-zero exit on exhaustion\n\
         \n  eat lint [--json] [--fix-suggestions] [PATHS...]   static analysis; scans\n\
         \x20     rust/src by default and exits non-zero on any finding; suppress a site\n\
         \x20     with `// eat-lint: allow(<rule>, \"<justification>\")`\n\
         \n  eat info\n\
         \nglobal: --quiet caps progress logging at warnings; EAT_LOG=error|warn|info|debug"
    );
    std::process::exit(2)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    eat::obs::log::init(args.has_flag("quiet"), args.has_flag("verbose"));
    let Some(cmd) = args.positional.first().map(String::as_str) else {
        usage()
    };
    match cmd {
        "experiment" => {
            let Some(id) = args.positional.get(1).map(String::as_str) else {
                usage()
            };
            experiments::run(id, &args)?;
        }
        "train" => {
            let alg = Algorithm::parse(&args.get_or("alg", "eat"))?;
            let nodes = args.get_usize("nodes", 8);
            let episodes = args.get_usize("episodes", 10);
            let mut cfg = ExperimentConfig::preset(nodes);
            cfg.algorithm = alg;
            cfg.seed = args.get_u64("seed", 42);
            let rt = Runtime::new(args.get("artifacts").unwrap_or("artifacts"))?;
            std::fs::create_dir_all(format!("{}/checkpoints", cfg.artifacts_dir)).ok();
            let ckpt = experiments::checkpoint_path(&cfg);
            log_info!("training {} on {nodes} nodes for {episodes} episodes...", alg.name());
            let t0 = std::time::Instant::now();
            if alg == Algorithm::Ppo {
                let mut d = PpoDriver::new(&rt, &cfg)?;
                d.train_loop(&cfg, episodes, |p| {
                    log_info!(
                        "  ep {:>3}: reward {:>8.1} len {:>4} pi_loss {:>8.3}",
                        p.episode, p.reward, p.episode_len, p.actor_loss
                    );
                })?;
                d.save_actor(&ckpt)?;
            } else {
                let mut d = SacDriver::new(&rt, &cfg)?;
                d.train_loop(&cfg, episodes, |p| {
                    log_info!(
                        "  ep {:>3}: reward {:>8.1} len {:>4} critic {:>8.3} actor {:>8.3}",
                        p.episode, p.reward, p.episode_len, p.critic_loss, p.actor_loss
                    );
                })?;
                d.save_actor(&ckpt)?;
            }
            log_info!("saved {ckpt} ({:.1}s)", t0.elapsed().as_secs_f64());
        }
        "eval" => {
            let alg = Algorithm::parse(&args.get_or("alg", "eat"))?;
            let nodes = args.get_usize("nodes", 8);
            let episodes = args.get_usize("episodes", 5);
            let mut cfg = ExperimentConfig::preset(nodes);
            cfg.algorithm = alg;
            cfg.seed = args.get_u64("seed", 42);
            if let Some(rate) = args.get("rate") {
                cfg.env.arrival_rate = rate.parse()?;
            }
            let rt = if alg.artifact_key().is_some() {
                Some(Runtime::new(args.get("artifacts").unwrap_or("artifacts"))?)
            } else {
                None
            };
            let mut policy = experiments::trained_policy(
                &cfg,
                rt.as_ref(),
                args.get_usize("train-episodes", 2),
                args.has_flag("verbose"),
            )?;
            let s = evaluate(&cfg, policy.as_mut(), episodes);
            // eat-lint: allow(logging, "the eval summary is the command's stdout contract")
            println!(
                "{}: quality {:.3}  latency {:.1}s  reload {:.3}  efficiency {:.2e}  \
                 reward {:.1}  decision {:.2e}s",
                s.algorithm,
                s.avg_quality,
                s.avg_response_latency,
                s.reload_rate,
                s.efficiency,
                s.avg_reward,
                s.decision_latency_s
            );
        }
        "serve" => {
            serve(&args)?;
        }
        "scenarios" => {
            experiments::scenarios::run(&args)?;
        }
        "qos" => {
            experiments::qos::run(&args)?;
        }
        "faults" => {
            experiments::faults::run(&args)?;
        }
        "bench" => {
            experiments::bench::run(&args)?;
        }
        "trace" => match args.positional.get(1).map(String::as_str) {
            Some("import") => {
                let (Some(csv), Some(out)) = (args.positional.get(2), args.positional.get(3))
                else {
                    usage()
                };
                let n = eat::workload::import::import_file(csv, out)?;
                log_info!("imported {n} tasks: {csv} -> {out}");
            }
            Some("analyze") => {
                let Some(path) = args.positional.get(2) else { usage() };
                let text = std::fs::read_to_string(path)
                    .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
                let analysis = eat::obs::analyze_jsonl(&text)?;
                if args.has_flag("json") {
                    // eat-lint: allow(logging, "machine-readable report goes to stdout")
                    println!("{}", analysis.to_json(path).to_json_pretty());
                } else {
                    // eat-lint: allow(logging, "analysis report is the command's stdout contract")
                    println!("{}", analysis.render(path));
                }
                if let Some(n) = args.get_usize_opt("top") {
                    // eat-lint: allow(logging, "analysis report is the command's stdout contract")
                    println!("\n{}", analysis.render_top(n));
                }
                // Books invariant: every decomposition must sum to its
                // measured latency bit-exactly; imbalance exits non-zero.
                analysis.check_books()?;
            }
            _ => usage(),
        },
        "decisions" => match args.positional.get(1).map(String::as_str) {
            Some("analyze") => {
                let Some(path) = args.positional.get(2) else { usage() };
                let text = std::fs::read_to_string(path)
                    .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
                let ledger = eat::obs::DecisionLedger::parse_jsonl(&text)?;
                let analysis = eat::obs::decisions::analyze(&ledger);
                if args.has_flag("json") {
                    // eat-lint: allow(logging, "machine-readable report goes to stdout")
                    println!("{}", analysis.to_json(path).to_json_pretty());
                } else {
                    // eat-lint: allow(logging, "regret report is the command's stdout contract")
                    println!("{}", analysis.render(path));
                }
                if let Some(out) = args.get("export-experience") {
                    let tuples = eat::obs::decisions::export_experience(&ledger)?;
                    if let Some(dir) = std::path::Path::new(out).parent() {
                        if !dir.as_os_str().is_empty() {
                            std::fs::create_dir_all(dir)?;
                        }
                    }
                    std::fs::write(out, &tuples)?;
                    let n_tuples = tuples.lines().count().saturating_sub(1);
                    log_info!("wrote experience export {out} ({n_tuples} tuples)");
                }
                if let Some(other_path) = args.get("compare") {
                    let other_text = std::fs::read_to_string(other_path)
                        .map_err(|e| anyhow::anyhow!("{other_path}: {e}"))?;
                    let other_ledger = eat::obs::DecisionLedger::parse_jsonl(&other_text)?;
                    let other = eat::obs::decisions::analyze(&other_ledger);
                    let (ours, theirs) = (analysis.median_regret(), other.median_regret());
                    // eat-lint: allow(logging, "comparison verdict is the command's stdout contract")
                    println!("median regret: {path} {ours:.3} vs {other_path} {theirs:.3}");
                    anyhow::ensure!(
                        ours <= theirs + 1e-9,
                        "median regret regression: {path} ({ours:.3}) exceeds {other_path} ({theirs:.3})"
                    );
                }
                // Books invariant: every resolved decision must join to
                // exactly one outcome; imbalance exits non-zero.
                analysis.check_books()?;
            }
            _ => usage(),
        },
        "slo" => match args.positional.get(1).map(String::as_str) {
            Some("report") => slo_report(&args)?,
            _ => usage(),
        },
        "lint" => {
            let paths: Vec<&str> = if args.positional.len() > 1 {
                args.positional[1..].iter().map(String::as_str).collect()
            } else {
                vec!["rust/src"]
            };
            let suggest = args.has_flag("fix-suggestions");
            let report = eat::analysis::lint_paths(&paths)?;
            if args.has_flag("json") {
                // eat-lint: allow(logging, "machine-readable report goes to stdout")
                println!("{}", report.to_json(suggest).to_json_pretty());
            } else {
                // eat-lint: allow(logging, "findings report is the command's stdout contract")
                println!("{}", report.render(suggest));
            }
            anyhow::ensure!(
                report.is_clean(),
                "eat lint: {} finding(s) — see report above",
                report.findings.len()
            );
        }
        "info" => {
            let rt = Runtime::new(args.get("artifacts").unwrap_or("artifacts"))?;
            // eat-lint: allow(logging, "manifest report is the command's stdout contract")
            println!("platform: {}", rt.platform());
            // eat-lint: allow(logging, "manifest report is the command's stdout contract")
            println!("batch size: {}", rt.manifest.batch_size);
            // eat-lint: allow(logging, "manifest report is the command's stdout contract")
            println!("denoise steps: {}", rt.manifest.denoise_steps);
            // eat-lint: allow(logging, "manifest report is the command's stdout contract")
            println!("entries ({}):", rt.manifest.entries.len());
            for (k, e) in &rt.manifest.entries {
                // eat-lint: allow(logging, "manifest report is the command's stdout contract")
                println!("  {k}: {} inputs, {} outputs", e.inputs.len(), e.outputs.len());
            }
        }
        _ => usage(),
    }
    Ok(())
}

/// `eat slo report <file>` — per-tenant error budgets and burn rates over
/// a lifecycle trace (`eat-trace-v1`) or a fleet time series
/// (`eat-timeseries-v1`), detected by the meta line's schema. Tenant SLO
/// classes default to the three-tier config; `--config file.json` reads a
/// `tenants` section instead, and `--target` / `--latency-slo` override
/// every class (so CI can gate the same trace at different strictness).
/// Exits non-zero when any tenant exhausts its budget.
fn slo_report(args: &Args) -> anyhow::Result<()> {
    use eat::obs::slo::{report_from_series, report_from_trace, SloClass, SloOptions};
    use eat::obs::FleetSeries;
    use eat::qos::TenantsConfig;

    let Some(path) = args.positional.get(2) else { usage() };
    let text =
        std::fs::read_to_string(path).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let tenants = match args.get("config") {
        Some(p) => {
            let cfg_text =
                std::fs::read_to_string(p).map_err(|e| anyhow::anyhow!("{p}: {e}"))?;
            let v = eat::util::json::parse(&cfg_text)?;
            match v.get("tenants") {
                Some(_) => TenantsConfig::from_json(&v)?,
                None => anyhow::bail!("{p}: no \"tenants\" section"),
            }
        }
        None => TenantsConfig::three_tier(0.1),
    };
    let mut classes = SloClass::from_config(&tenants);
    if let Some(t) = args.get("target") {
        let target: f64 = t.parse().map_err(|e| anyhow::anyhow!("--target {t}: {e}"))?;
        anyhow::ensure!(target > 0.0 && target < 1.0, "--target must be in (0, 1)");
        for c in &mut classes {
            c.target = target;
        }
    }
    if let Some(s) = args.get("latency-slo") {
        let slo: f64 = s.parse().map_err(|e| anyhow::anyhow!("--latency-slo {s}: {e}"))?;
        anyhow::ensure!(slo > 0.0, "--latency-slo must be positive");
        for c in &mut classes {
            c.latency_slo = slo;
        }
    }
    let opt = SloOptions {
        fast_window: args.get_f64("window", SloOptions::default().fast_window),
        slow_window: args.get_f64("slow-window", SloOptions::default().slow_window),
    };
    anyhow::ensure!(
        opt.fast_window > 0.0 && opt.slow_window > 0.0,
        "burn windows must be positive"
    );
    // The meta line's schema decides how to replay the file: a fleet time
    // series carries pre-classified hits/misses per window, a trace (or a
    // legacy meta-less trace) replays terminal events against the
    // latency SLO.
    let schema = text
        .lines()
        .next()
        .and_then(|l| eat::util::json::parse(l).ok())
        .and_then(|v| v.get("schema").and_then(|s| s.as_str().map(String::from)));
    let report = match schema.as_deref() {
        Some(eat::obs::schema::TIMESERIES) => {
            let series = FleetSeries::parse_jsonl(&text)?;
            report_from_series(&series, &classes, opt)
        }
        _ => {
            let doc = eat::obs::trace::parse_jsonl_doc(&text)?;
            if doc.evicted > 0 {
                log_warn!(
                    "{path}: {} events evicted from the trace ring; budgets are a lower bound",
                    doc.evicted
                );
            }
            report_from_trace(&doc.events, &classes, opt)
        }
    };
    if args.has_flag("json") {
        // eat-lint: allow(logging, "machine-readable report goes to stdout")
        println!("{}", report.to_json(path).to_json_pretty());
    } else {
        // eat-lint: allow(logging, "burn-rate report is the command's stdout contract")
        println!("{}", report.render(path));
    }
    report.check()
}

/// End-to-end serving: spawn socket workers, generate a task stream, and
/// schedule it with the reuse-aware gang scheduler, reporting per-task
/// latency and the throughput/reload summary.
///
/// With `--resilient`, a background heartbeat thread maintains a live
/// health registry that both masks down workers out of gang selection
/// (`Cluster::select_healthy`) and supplies spares to the fault-tolerant
/// dispatch path; `--kill-at` / `--wedge` / `--respawn-at` inject worker
/// faults mid-run so the recovery is demonstrable end-to-end.
fn serve(args: &Args) -> anyhow::Result<()> {
    use eat::config::ServingConfig;
    use eat::serving::{HealthMonitor, HealthRegistry, ServingHost, WorkerPool};
    use eat::sim::cluster::Cluster;
    use eat::sim::task::Workload;
    use eat::util::rng::Pcg64;
    use eat::workload::{MetricsCollector, WorkloadConfig};
    use std::sync::Arc;
    use std::time::Duration;

    let workers = args.get_usize("workers", 4);
    let n_tasks = args.get_usize("tasks", 12);
    let time_scale = args.get_f64("time-scale", 2e-3);
    let seed = args.get_u64("seed", 42);
    let resilient = args.has_flag("resilient");
    let mut cfg = ExperimentConfig::preset(workers.max(4)).env;
    cfg.num_servers = workers;
    cfg.tasks_per_episode = n_tasks;
    let max_patches = args.get_usize("max-patches", workers);
    cfg.patch_choices.retain(|&c| c <= workers.min(max_patches));
    anyhow::ensure!(
        !cfg.patch_choices.is_empty(),
        "--max-patches {max_patches} leaves no feasible gang size on {workers} workers"
    );
    cfg.patch_weights = vec![1.0; cfg.patch_choices.len()];
    // Any scenario family can drive the serving emulation too.
    if let Some(name) = args.get("scenario") {
        cfg.workload = Some(WorkloadConfig::preset(name, cfg.arrival_rate)?);
    }

    // Serving-loop settings: a `serving` section in --config seeds the
    // defaults, individual CLI flags override it, and — when neither
    // pins a dispatch timeout — it auto-scales with --time-scale so a
    // legitimately sleeping cold gang is never excluded as dead.
    let file_serving = match args.get("config") {
        Some(path) => ExperimentConfig::load(path)?.serving,
        None => None,
    };
    let cli_timeout = args.get("dispatch-timeout").is_some();
    let file_section = file_serving.is_some();
    let defaults = file_serving.unwrap_or_default();
    let mut serving = ServingConfig {
        hb_interval: args.get_f64("hb-interval", defaults.hb_interval),
        hb_timeout: args.get_f64("hb-timeout", defaults.hb_timeout),
        down_after: args.get_usize("down-after", defaults.down_after as usize) as u32,
        dispatch_timeout: args.get_f64("dispatch-timeout", defaults.dispatch_timeout),
        max_rounds: args.get_usize("max-rounds", defaults.max_rounds),
        defer_timeout: args.get_f64("defer-timeout", defaults.defer_timeout),
    };
    if !cli_timeout {
        // Floor the dispatch timeout at the worst legitimate scaled sleep
        // (a cold load plus SERVE_STEPS of execution, slept at
        // time_scale; 2x + 1 s of margin covers the sampling jitter).
        // This also lifts a config file's too-small value — only an
        // explicit --dispatch-timeout pins it exactly.
        let exec = eat::sim::exec_model::ExecModel::new(cfg.exec.clone());
        let worst_sim = cfg
            .patch_choices
            .iter()
            .map(|&p| exec.predict_init(p) + exec.predict_exec(SERVE_STEPS, p))
            .fold(0.0, f64::max);
        serving.dispatch_timeout = serving
            .dispatch_timeout
            .max(worst_sim * time_scale * 2.0 + 1.0);
    }
    serving.validate()?;
    // The non-resilient path has no retries, so its per-worker timeout
    // stays generous unless the flag or a config-file section chose one.
    let plain_timeout = if cli_timeout || file_section {
        Duration::from_secs_f64(serving.dispatch_timeout)
    } else {
        eat::serving::DEFAULT_DISPATCH_TIMEOUT
    };
    let inject = FaultInjection {
        kill_at: args.get_usize_opt("kill-at"),
        worker: args.get_usize_opt("kill-worker"),
        wedge: args.has_flag("wedge"),
        respawn_at: args.get_usize_opt("respawn-at"),
    };
    if let Some(k) = inject.kill_at {
        anyhow::ensure!(
            k < n_tasks,
            "--kill-at ({k}) is past the last task (tasks: {n_tasks}); the fault would never fire"
        );
    }
    if let Some(w) = inject.worker {
        anyhow::ensure!(
            w < workers,
            "--kill-worker ({w}) does not exist (workers: {workers})"
        );
    }
    anyhow::ensure!(
        inject.kill_at.is_some() || (inject.worker.is_none() && !inject.wedge),
        "--kill-worker/--wedge need --kill-at to say when the fault fires"
    );
    if let Some(r) = inject.respawn_at {
        let Some(k) = inject.kill_at else {
            anyhow::bail!("--respawn-at needs --kill-at (nothing to revive)");
        };
        anyhow::ensure!(
            r > k,
            "--respawn-at ({r}) must come after --kill-at ({k}); the fault \
             is injected first"
        );
        anyhow::ensure!(
            r < n_tasks,
            "--respawn-at ({r}) is past the last task (tasks: {n_tasks}); \
             the revival would never run"
        );
    }
    log_info!(
        "spawning {workers} socket workers (time scale {time_scale}{})...",
        if resilient { ", resilient" } else { "" }
    );
    let mut pool = WorkerPool::spawn(workers, cfg.exec.clone(), time_scale, seed)?;
    let host = ServingHost::new(pool.addrs().to_vec());
    let registry = resilient.then(|| Arc::new(HealthRegistry::new(workers, serving.down_after)));
    let monitor = registry.as_ref().map(|reg| {
        HealthMonitor::start(
            host.clone(),
            reg.clone(),
            Duration::from_secs_f64(serving.hb_interval),
            Duration::from_secs_f64(serving.hb_timeout),
        )
    });
    let mut tracker = Cluster::new(workers); // mirrors worker model state
    let workload = Workload::generate(&cfg, &mut Pcg64::new(seed, 1));
    let mut metrics = MetricsCollector::new(workers);
    // --metrics-addr: a live Prometheus text-exposition endpoint sharing
    // one registry with the serving loop, scrapeable mid-run.
    let metrics_srv = args
        .get("metrics-addr")
        .map(|addr| -> anyhow::Result<_> {
            let reg = Arc::new(eat::obs::MetricRegistry::new());
            // Which binary produced these series: crate version always,
            // git hash when the build environment exported one.
            reg.set_build_info(
                env!("CARGO_PKG_VERSION"),
                option_env!("EAT_GIT_HASH").unwrap_or("unknown"),
            );
            let server = eat::obs::MetricsServer::bind(addr, reg.clone())?;
            log_info!("metrics: exposition live on http://{}/metrics", server.local_addr());
            Ok((reg, server))
        })
        .transpose()?;
    // --trace: record every task's lifecycle spans for `eat trace analyze`.
    let mut tracer = args
        .get("trace")
        .map(|_| eat::obs::TraceRecorder::new(eat::obs::TraceRecorder::default_capacity()));

    let t0 = std::time::Instant::now();
    let mut result = serve_loop(
        &host,
        &mut pool,
        &mut tracker,
        &workload,
        &mut metrics,
        registry.as_deref(),
        &serving,
        plain_timeout,
        time_scale,
        &inject,
        metrics_srv.as_ref().map(|(reg, _)| reg.as_ref()),
        tracer.as_mut(),
    );
    // Teardown runs on EVERY exit path: a dispatch error used to return
    // early and strand the worker listeners and their threads.
    if let Some(m) = monitor {
        m.stop();
    }
    if let Some(reg) = &registry {
        let st = reg.stats();
        metrics.observe_recoveries(st.recoveries);
        if let Some((mreg, _)) = &metrics_srv {
            // Final mirror: a recovery landing after the last dispatch
            // still shows up on the endpoint before teardown.
            export_health(mreg, st, reg.counts());
        }
        // eat-lint: allow(logging, "serve summary is a stdout contract (CI greps serve.log)")
        println!(
            "health: {} probes  {} downs  {} recoveries  ({}/{} workers up)",
            st.probes,
            st.downs,
            st.recoveries,
            reg.up_count(),
            workers
        );
    }
    // eat-lint: allow(logging, "serve summary is a stdout contract (CI greps serve.log)")
    println!(
        "\nserved {}/{} tasks in {:.2}s wall; total simulated exec {:.1}s",
        metrics.completed(),
        workload.len(),
        t0.elapsed().as_secs_f64(),
        metrics.sim_time(),
    );
    // eat-lint: allow(logging, "serve summary is a stdout contract (CI greps serve.log)")
    println!("{}", metrics.summary_line());
    if resilient {
        // The serving books mirror the simulator's invariant:
        // dispatched = completed + wasted (+ in-flight, always 0 here).
        // eat-lint: allow(logging, "serve summary is a stdout contract (CI greps serve.log)")
        println!(
            "books: dispatched {:.1} patch-s = completed {:.1} + wasted {:.1}",
            metrics.dispatched_ps(),
            metrics.completed_ps(),
            metrics.wasted_ps()
        );
    }
    if let (Some(path), Some(tr)) = (args.get("trace"), tracer.as_ref()) {
        let wrote = tr.write_jsonl(path).map(|()| {
            log_info!(
                "wrote trace {path} ({} events, {} evicted)",
                tr.len(),
                tr.evicted()
            );
        });
        result = result.and(wrote);
    }
    pool.shutdown();
    result
}

/// Mirror the health registry's monotone totals and up/down gauges into
/// the Prometheus registry (used per task iteration and once at teardown).
fn export_health(
    mreg: &eat::obs::MetricRegistry,
    st: eat::serving::HealthStats,
    (up, total): (usize, usize),
) {
    mreg.counter_set("eat_health_probes_total", "heartbeat probes sent", st.probes);
    mreg.counter_set(
        "eat_health_downs_total",
        "up->down worker transitions",
        st.downs,
    );
    mreg.counter_set(
        "eat_recoveries_total",
        "down->up worker transitions (a probe revived the worker)",
        st.recoveries,
    );
    mreg.gauge_set(
        "eat_workers_up",
        "workers currently believed up",
        up as f64,
    );
    mreg.gauge_set("eat_workers", "worker pool size", total as f64);
}

/// Inference steps the serving loop requests for every task. The
/// dispatch-timeout auto-floor in `serve` is computed from this same
/// constant, so the two cannot drift apart.
const SERVE_STEPS: u32 = 20;

/// Mid-run worker fault injection for `eat serve`: before dispatching task
/// ordinal `kill_at`, kill (or, with `wedge`, hang) a worker — `worker` if
/// given, else the first member of that task's selected gang, which
/// guarantees the fault lands on the dispatch path. `respawn_at` restarts
/// the faulted worker (or unwedges it) before that task ordinal.
struct FaultInjection {
    kill_at: Option<usize>,
    worker: Option<usize>,
    wedge: bool,
    respawn_at: Option<usize>,
}

#[allow(clippy::too_many_arguments)]
fn serve_loop(
    host: &eat::serving::ServingHost,
    pool: &mut eat::serving::WorkerPool,
    tracker: &mut eat::sim::cluster::Cluster,
    workload: &eat::sim::task::Workload,
    metrics: &mut eat::workload::MetricsCollector,
    registry: Option<&eat::serving::HealthRegistry>,
    serving: &eat::config::ServingConfig,
    plain_timeout: std::time::Duration,
    time_scale: f64,
    inject: &FaultInjection,
    mreg: Option<&eat::obs::MetricRegistry>,
    mut tracer: Option<&mut eat::obs::TraceRecorder>,
) -> anyhow::Result<()> {
    use eat::obs::trace::{GangRef, SpanKind};
    use eat::sim::cluster::Selection;
    use eat::sim::task::ModelType;
    use std::time::{Duration, Instant};

    let timeout = Duration::from_secs_f64(serving.dispatch_timeout);
    let mut faulted: Option<usize> = None;
    let mut fault_injected = false;
    // Per-tenant deadline outcomes for the labelled endpoint series
    // (tenant id as the label value, "-" for untenanted tasks).
    let mut tenant_slo: std::collections::BTreeMap<String, (u64, u64)> =
        std::collections::BTreeMap::new();
    // Dispatch is synchronous, so model a sequential simulated timeline:
    // a task starts once it has arrived AND the previous dispatch
    // finished. This makes the arrival process matter — bursty/flash
    // scenarios build genuine backlog (waiting > 0) while sparse ones
    // leave idle gaps.
    let mut sim_clock = 0.0f64;
    for (ordinal, task) in workload.tasks.iter().enumerate() {
        if inject.respawn_at == Some(ordinal) {
            if let Some(w) = faulted.take() {
                if inject.wedge {
                    pool.unwedge(w);
                } else {
                    pool.respawn(w)?;
                }
                log_warn!(">>> revived worker {w} before task {}", task.id);
                if let Some(reg) = registry {
                    // Block until a probe confirms the revival, so the
                    // demonstration is deterministic.
                    let deadline = Instant::now() + Duration::from_secs_f64(serving.defer_timeout);
                    while !reg.up(w) && Instant::now() < deadline {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                }
            }
        }
        if let Some(reg) = registry {
            tracker.set_health(&reg.snapshot(), sim_clock);
        }
        // Gang selection with the reuse-aware greedy selector — restricted
        // to up workers when a health registry is live. The tracker never
        // marks servers busy (dispatch below is synchronous), so selection
        // is purely about model-reuse placement and health. Under
        // resilience an infeasible task *waits* for workers to recover
        // (mirroring the simulator, where infeasible tasks queue rather
        // than vanish) up to `defer_timeout` wall seconds.
        let model = ModelType(task.model.0);
        let mut sel = match registry {
            Some(_) => tracker.select_healthy(model, task.patches),
            None => tracker.select(model, task.patches),
        };
        if let Some(reg) = registry {
            let deadline = Instant::now() + Duration::from_secs_f64(serving.defer_timeout);
            while sel == Selection::Infeasible && Instant::now() < deadline {
                std::thread::sleep(Duration::from_secs_f64(serving.hb_interval));
                tracker.set_health(&reg.snapshot(), sim_clock);
                sel = tracker.select_healthy(model, task.patches);
            }
        }
        let (gang, reuse) = match &sel {
            Selection::Reuse(v) => (v.clone(), true),
            Selection::Fresh(v) => (v.clone(), false),
            Selection::Infeasible => {
                // A task that cannot fit this cluster (e.g. more patches
                // than workers) used to vanish silently; count it so the
                // summary reflects deferred work instead of hiding it.
                metrics.observe_deferred();
                if let Some(mr) = mreg {
                    mr.counter_add("eat_deferred_total", "tasks deferred (no feasible gang)", 1);
                }
                log_warn!(
                    "task {:>3}  patches {}  deferred: no feasible gang on {} workers",
                    task.id,
                    task.patches,
                    tracker.len()
                );
                continue;
            }
        };
        // `>=` rather than `==`: if the task at the kill-at ordinal was
        // deferred (its iteration `continue`s before reaching here), the
        // fault still fires on the next dispatched task — but only once
        // (`fault_injected`), never again after a respawn.
        if inject.kill_at.is_some_and(|k| ordinal >= k) && !fault_injected {
            fault_injected = true;
            // Default to a gang member so the fault provably lands on the
            // dispatch path, not on an idle bystander.
            let w = inject.worker.unwrap_or(gang[0]);
            if inject.wedge {
                pool.wedge(w);
                log_warn!(">>> wedged worker {w} before task {} (accepts, never replies)", task.id);
            } else {
                pool.kill(w);
                log_warn!(">>> killed worker {w} before task {}", task.id);
            }
            faulted = Some(w);
        }
        let waiting = (sim_clock - task.arrival).max(0.0);
        if task.arrival > sim_clock {
            // Idle until the task arrives.
            metrics.advance_time(task.arrival - sim_clock);
            sim_clock = task.arrival;
        }
        // The dispatch instant on the simulated timeline. The analyzer's
        // queue component is `dispatch.t - admitted.t`, which equals
        // `waiting` bit-exactly: backlogged tasks dispatch at the old
        // sim_clock (the same subtraction), fresh ones at their arrival
        // (a zero subtraction).
        let dispatched_at = sim_clock;
        if let Some(tr) = tracer.as_deref_mut() {
            tr.record(task.arrival, task.id, task.tenant, SpanKind::Admitted);
        }
        let steps = SERVE_STEPS;
        let prompt = format!("prompt-{}", task.prompt_id);
        let (out, excluded) = match registry {
            Some(reg) => {
                let spares: Vec<usize> = reg
                    .healthy()
                    .into_iter()
                    .filter(|w| !gang.contains(w))
                    .collect();
                let (out, excluded) = match tracer.as_deref_mut() {
                    Some(tr) => host.dispatch_resilient_traced(
                        task.id,
                        &prompt,
                        steps,
                        task.model.0,
                        task.tenant,
                        &gang,
                        &spares,
                        timeout,
                        serving.max_rounds,
                        time_scale,
                        waiting,
                        metrics,
                        dispatched_at,
                        tr,
                    ),
                    None => host.dispatch_resilient_collect(
                        task.id,
                        &prompt,
                        steps,
                        task.model.0,
                        task.tenant,
                        &gang,
                        &spares,
                        timeout,
                        serving.max_rounds,
                        time_scale,
                        waiting,
                        metrics,
                    ),
                }
                .map_err(|e| anyhow::anyhow!("{e} (task ordinal {ordinal})"))?;
                // Down until a heartbeat probe revives them; their mirror
                // loses the loaded weights immediately.
                for &w in &excluded {
                    reg.mark_down(w);
                }
                tracker.abort_gang(&excluded, sim_clock);
                (out, excluded)
            }
            None => {
                // Tracing propagates the task id as a wire trace id, so
                // workers measure and report their spans in the replies.
                let trace_id = tracer.as_ref().map(|_| task.id);
                let out = host
                    .dispatch_collect(
                        task.id,
                        &prompt,
                        steps,
                        task.model.0,
                        task.tenant,
                        trace_id,
                        &gang,
                        waiting,
                        plain_timeout,
                        metrics,
                    )
                    .map_err(|e| anyhow::anyhow!("{e} (task ordinal {ordinal})"))?;
                if let Some(tr) = tracer.as_deref_mut() {
                    // The plain path has no rounds: one dispatch, one
                    // completion, response booked as waiting + exec (the
                    // same expression `dispatch_collect` observed).
                    let (cold, exec) = out
                        .results
                        .iter()
                        .map(|r| (r.load_time, r.exec_time))
                        .max_by(|a, b| (a.0 + a.1).total_cmp(&(b.0 + b.1)))
                        .unwrap_or((0.0, 0.0));
                    let members: Vec<usize> = out.results.iter().map(|r| r.worker_id).collect();
                    let gref = GangRef::capture(&members, |i| {
                        out.results.get(i).is_some_and(|r| r.reused)
                    });
                    let tid = task.id;
                    tr.record(
                        dispatched_at,
                        tid,
                        task.tenant,
                        SpanKind::Dispatched {
                            gang: gref,
                            cold,
                            exec,
                            attempt: 0,
                            speculative: false,
                        },
                    );
                    tr.record(dispatched_at, tid, task.tenant, SpanKind::ExecStart);
                    // Worker span of the gang's critical member (largest
                    // host-observed round trip): `eat trace analyze`
                    // decomposes it into network/lock-wait/load/exec.
                    if let Some((i, &rtt)) = out
                        .rtts
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.total_cmp(b.1))
                    {
                        let t = out.results[i].timings.unwrap_or_default();
                        tr.record(
                            dispatched_at + out.sim_exec_seconds(),
                            tid,
                            task.tenant,
                            SpanKind::WorkerSpan {
                                rtt,
                                recv: t.recv,
                                lock_wait: t.lock_wait,
                                load: t.load,
                                exec: t.exec,
                                reply: t.reply,
                            },
                        );
                    }
                    tr.record(
                        dispatched_at + out.sim_exec_seconds(),
                        tid,
                        task.tenant,
                        SpanKind::Completed {
                            response: waiting + out.sim_exec_seconds(),
                            start: dispatched_at,
                            speculative: false,
                        },
                    );
                }
                (out, Vec::new())
            }
        };
        // Failed retry rounds burnt simulated time too: the task's slot
        // on the timeline covers them, exactly as a simulator retry runs
        // later than the original dispatch.
        let sim_s = out.retry_seconds + out.sim_exec_seconds();
        metrics.advance_time(sim_s);
        sim_clock += sim_s;
        // Track the gang that actually completed — spares may have
        // replaced excluded members, and a rebuilt gang is a fresh load.
        let final_gang: Vec<usize> = out.results.iter().map(|r| r.worker_id).collect();
        tracker.dispatch(&final_gang, 0.0, model, reuse && excluded.is_empty(), sim_clock);
        if let Some(mr) = mreg {
            mr.counter_add("eat_dispatches_total", "gang dispatches issued", 1);
            mr.counter_set("eat_tasks_completed_total", "tasks completed", metrics.completed());
            mr.counter_set("eat_retries_total", "gang retry rounds", metrics.retries());
            mr.counter_set(
                "eat_failures_total",
                "worker failures observed by dispatch",
                metrics.failures(),
            );
            mr.observe(
                "eat_task_latency_seconds",
                "per-task response latency (simulated seconds)",
                waiting + sim_s,
            );
            let backlog = workload.tasks[ordinal + 1..]
                .iter()
                .filter(|t| t.arrival <= sim_clock)
                .count();
            mr.gauge_set(
                "eat_queue_depth",
                "arrived tasks awaiting dispatch",
                backlog as f64,
            );
            // Per-tenant deadline hit/miss totals and attainment, labelled
            // by tenant id. `sim_clock` is this task's completion instant
            // on the simulated timeline; deadline-less tasks count as hits
            // (same convention as the simulator's SLO accounting).
            let label = task.tenant.map_or_else(|| "-".to_string(), |t| t.to_string());
            let hit = task.deadline.map_or(true, |d| sim_clock <= d);
            let e = tenant_slo.entry(label.clone()).or_insert((0, 0));
            if hit {
                e.0 += 1;
            } else {
                e.1 += 1;
            }
            mr.tenant_counter_set(
                "eat_tenant_deadline_hits_total",
                "completed tasks that met their deadline",
                &label,
                e.0,
            );
            mr.tenant_counter_set(
                "eat_tenant_deadline_misses_total",
                "completed tasks that missed their deadline",
                &label,
                e.1,
            );
            mr.tenant_gauge_set(
                "eat_tenant_slo_attainment",
                "deadline hits / completed tasks",
                &label,
                e.0 as f64 / (e.0 + e.1) as f64,
            );
            if let Some(reg) = registry {
                export_health(mr, reg.stats(), reg.counts());
            }
        }
        log_info!(
            "task {:>3}  patches {}  gang {:?}  wait {:>6.1}s  sim {:>6.1}s  reload {}{}  wall {:>6.3}s",
            task.id,
            task.patches,
            final_gang,
            waiting,
            sim_s,
            out.any_reload(),
            if excluded.is_empty() {
                String::new()
            } else {
                format!("  excluded {excluded:?}")
            },
            out.wall_seconds
        );
    }
    Ok(())
}
