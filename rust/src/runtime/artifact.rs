//! Artifact manifest: the contract between `aot.py` and the rust runtime.
//!
//! `artifacts/manifest.json` describes every lowered HLO module (input /
//! output tensor names and shapes, all f32) plus, per algorithm x topology,
//! the flat parameter-vector lengths and the files holding the freshly
//! initialised parameters.

use crate::util::json::{self, Value};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Shape + name of one tensor crossing the AOT boundary (dtype is f32 by
/// construction; scalars have an empty shape).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered HLO module.
#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub key: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl EntrySpec {
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|t| t.name == name)
    }

    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|t| t.name == name)
    }
}

/// Parameter metadata for one algorithm x topology.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub key: String,
    pub actor_len: usize,
    pub critic_len: usize,
    pub action_dim: usize,
    pub state_dim: usize,
    /// T+1 for diffusion algorithms, 0 for PPO.
    pub chain_steps: usize,
    pub batch_size: usize,
    /// net name -> init file (relative to the artifacts dir).
    pub init_files: BTreeMap<String, String>,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub batch_size: usize,
    pub denoise_steps: usize,
    pub entries: BTreeMap<String, EntrySpec>,
    pub params: BTreeMap<String, ParamSpec>,
}

fn tensor_specs(v: &Value) -> anyhow::Result<Vec<TensorSpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow::anyhow!("tensor spec list not an array"))?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t
                    .req("name")?
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("tensor name not a string"))?
                    .to_string(),
                shape: t
                    .req("shape")?
                    .as_usize_vec()
                    .ok_or_else(|| anyhow::anyhow!("tensor shape not usize array"))?,
            })
        })
        .collect()
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<ArtifactManifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {}: {e} (run `make artifacts`)", path.display()))?;
        let v = json::parse(&text)?;
        let mut entries = BTreeMap::new();
        if let Some(Value::Obj(map)) = v.get("entries") {
            for (key, ev) in map {
                entries.insert(
                    key.clone(),
                    EntrySpec {
                        key: key.clone(),
                        file: ev
                            .req("file")?
                            .as_str()
                            .ok_or_else(|| anyhow::anyhow!("entry file not a string"))?
                            .to_string(),
                        inputs: tensor_specs(ev.req("inputs")?)?,
                        outputs: tensor_specs(ev.req("outputs")?)?,
                    },
                );
            }
        }
        let mut params = BTreeMap::new();
        if let Some(Value::Obj(map)) = v.get("params") {
            for (key, pv) in map {
                let mut init_files = BTreeMap::new();
                if let Some(Value::Obj(files)) = pv.get("init_files") {
                    for (net, f) in files {
                        init_files.insert(
                            net.clone(),
                            f.as_str()
                                .ok_or_else(|| anyhow::anyhow!("init file not a string"))?
                                .to_string(),
                        );
                    }
                }
                let get = |k: &str| -> anyhow::Result<usize> {
                    pv.req(k)?
                        .as_usize()
                        .ok_or_else(|| anyhow::anyhow!("param field {k} not a number"))
                };
                params.insert(
                    key.clone(),
                    ParamSpec {
                        key: key.clone(),
                        actor_len: get("actor_len")?,
                        critic_len: get("critic_len")?,
                        action_dim: get("action_dim")?,
                        state_dim: get("state_dim")?,
                        chain_steps: get("chain_steps")?,
                        batch_size: get("batch_size")?,
                        init_files,
                    },
                );
            }
        }
        Ok(ArtifactManifest {
            dir,
            batch_size: v
                .get("batch_size")
                .and_then(Value::as_usize)
                .unwrap_or(128),
            denoise_steps: v
                .get("denoise_steps")
                .and_then(Value::as_usize)
                .unwrap_or(10),
            entries,
            params,
        })
    }

    pub fn entry(&self, key: &str) -> anyhow::Result<&EntrySpec> {
        self.entries
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("artifact entry '{key}' not in manifest (regenerate with `make artifacts`)"))
    }

    pub fn param(&self, key: &str) -> anyhow::Result<&ParamSpec> {
        self.params
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("param spec '{key}' not in manifest"))
    }

    pub fn hlo_path(&self, entry: &EntrySpec) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// Read an initial parameter vector (raw little-endian f32 file).
    pub fn load_init(&self, param_key: &str, net: &str) -> anyhow::Result<Vec<f32>> {
        let spec = self.param(param_key)?;
        let file = spec
            .init_files
            .get(net)
            .ok_or_else(|| anyhow::anyhow!("no init file for net '{net}' of '{param_key}'"))?;
        let bytes = std::fs::read(self.dir.join(file))?;
        anyhow::ensure!(bytes.len() % 4 == 0, "init file size not a multiple of 4");
        let mut out = Vec::with_capacity(bytes.len() / 4);
        for chunk in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        let expected = if net == "actor" { spec.actor_len } else { spec.critic_len };
        anyhow::ensure!(
            out.len() == expected,
            "init vector '{net}' length {} != manifest {}",
            out.len(),
            expected
        );
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        let manifest = r#"{
          "version": 1, "batch_size": 8, "denoise_steps": 10,
          "entries": {
            "demo_act": {
              "file": "demo_act.hlo.txt",
              "inputs": [{"name": "actor", "shape": [12]}, {"name": "state", "shape": [3, 4]}],
              "outputs": [{"name": "action", "shape": [5]}]
            }
          },
          "params": {
            "demo": {
              "actor_len": 3, "critic_len": 2, "action_dim": 5, "state_dim": 12,
              "chain_steps": 11, "batch_size": 8,
              "init_files": {"actor": "demo_init_actor.f32"}
            }
          }
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let floats: Vec<u8> = [1.0f32, -2.5, 3.25]
            .iter()
            .flat_map(|x| x.to_le_bytes())
            .collect();
        std::fs::write(dir.join("demo_init_actor.f32"), floats).unwrap();
    }

    #[test]
    fn loads_manifest_and_init() {
        let dir = std::env::temp_dir().join(format!("eat_manifest_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_fixture(&dir);
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.batch_size, 8);
        let e = m.entry("demo_act").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[1].shape, vec![3, 4]);
        assert_eq!(e.inputs[1].element_count(), 12);
        assert_eq!(e.input_index("state"), Some(1));
        let init = m.load_init("demo", "actor").unwrap();
        assert_eq!(init, vec![1.0, -2.5, 3.25]);
        assert!(m.load_init("demo", "critic").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = ArtifactManifest::load("/nonexistent_dir_xyz").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
