//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them from the rust hot path. Python is never involved at
//! runtime — the HLO text is parsed, compiled once per executable, and
//! cached for the life of the process.

pub mod artifact;
pub mod exec;

pub use artifact::{ArtifactManifest, EntrySpec, ParamSpec, TensorSpec};
pub use exec::{Executable, Runtime};
