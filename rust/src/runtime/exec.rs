//! PJRT executable cache + typed f32 execution helpers.
//!
//! `Runtime` owns one CPU PJRT client and a lazily populated cache of
//! compiled executables keyed by manifest entry. `Executable::run` takes
//! flat f32 slices in manifest input order, shapes them into literals, and
//! returns flat f32 vectors in manifest output order (everything crossing
//! the boundary is f32 by construction; aot.py lowers with
//! return_tuple=True so outputs always arrive as one tuple literal).

use super::artifact::{ArtifactManifest, EntrySpec};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// A compiled HLO module plus its I/O spec.
pub struct Executable {
    pub spec: EntrySpec,
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
}

impl Executable {
    /// Upload a tensor to the device once; the returned buffer can be
    /// passed to `run_b` across many calls. This is the §Perf hot-path
    /// optimisation: the actor's ~80k-float parameter vector is uploaded
    /// once per *gradient update* instead of once per *decision*.
    pub fn to_device(&self, data: &[f32], input_index: usize) -> anyhow::Result<xla::PjRtBuffer> {
        let ts = self
            .spec
            .inputs
            .get(input_index)
            .ok_or_else(|| anyhow::anyhow!("input index {input_index} out of range"))?;
        anyhow::ensure!(
            data.len() == ts.element_count(),
            "to_device '{}': expected {} elements, got {}",
            ts.name,
            ts.element_count(),
            data.len()
        );
        let dims: Vec<usize> = if ts.shape.is_empty() { vec![1] } else { ts.shape.clone() };
        self.client
            .buffer_from_host_buffer::<f32>(data, &dims, None)
            .map_err(|e| anyhow::anyhow!("to_device '{}': {e:?}", ts.name))
    }

    /// Execute with device-resident inputs (see `to_device`). Outputs are
    /// returned as flat host vectors like `run`.
    pub fn run_b(&self, inputs: &[&xla::PjRtBuffer]) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(
            inputs.len() == self.spec.inputs.len(),
            "'{}' expects {} inputs, got {}",
            self.spec.key,
            self.spec.inputs.len(),
            inputs.len()
        );
        let result = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .map_err(|e| anyhow::anyhow!("execute_b '{}': {e:?}", self.spec.key))?;
        self.collect_outputs(result)
    }
    /// Execute with flat f32 inputs in manifest order. Each slice's length
    /// must match the spec'd element count. Returns one flat Vec per
    /// declared output.
    pub fn run(&self, inputs: &[&[f32]]) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(
            inputs.len() == self.spec.inputs.len(),
            "'{}' expects {} inputs, got {}",
            self.spec.key,
            self.spec.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (ts, data) in self.spec.inputs.iter().zip(inputs) {
            anyhow::ensure!(
                data.len() == ts.element_count(),
                "input '{}' of '{}': expected {} elements, got {}",
                ts.name,
                self.spec.key,
                ts.element_count(),
                data.len()
            );
            let lit = xla::Literal::vec1(data);
            let lit = if ts.shape.len() == 1 {
                lit
            } else {
                let dims: Vec<i64> = ts.shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims)
                    .map_err(|e| anyhow::anyhow!("reshape {}: {e:?}", ts.name))?
            };
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute '{}': {e:?}", self.spec.key))?;
        self.collect_outputs(result)
    }

    fn collect_outputs(
        &self,
        result: Vec<Vec<xla::PjRtBuffer>>,
    ) -> anyhow::Result<Vec<Vec<f32>>> {
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal '{}': {e:?}", self.spec.key))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("to_tuple '{}': {e:?}", self.spec.key))?;
        anyhow::ensure!(
            parts.len() == self.spec.outputs.len(),
            "'{}' returned {} outputs, manifest says {}",
            self.spec.key,
            parts.len(),
            self.spec.outputs.len()
        );
        let mut outs = Vec::with_capacity(parts.len());
        for (ts, lit) in self.spec.outputs.iter().zip(parts) {
            let v = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("output '{}' of '{}': {e:?}", ts.name, self.spec.key))?;
            anyhow::ensure!(
                v.len() == ts.element_count(),
                "output '{}' of '{}': expected {} elements, got {}",
                ts.name,
                self.spec.key,
                ts.element_count(),
                v.len()
            );
            outs.push(v);
        }
        Ok(outs)
    }
}

/// CPU PJRT client + compiled-executable cache.
pub struct Runtime {
    pub manifest: ArtifactManifest,
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Runtime {
    /// Create a runtime over an artifacts directory (reads manifest.json).
    pub fn new(artifacts_dir: &str) -> anyhow::Result<Runtime> {
        let manifest = ArtifactManifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e:?}"))?;
        Ok(Runtime {
            manifest,
            client,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile (or fetch from cache) the executable for a manifest
    /// entry key such as `eat_n8l8_train`.
    pub fn load(&self, key: &str) -> anyhow::Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(key) {
            return Ok(e.clone());
        }
        let spec = self.manifest.entry(key)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse HLO '{}': {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile '{key}': {e:?}"))?;
        let executable = Rc::new(Executable {
            spec,
            exe,
            client: self.client.clone(),
        });
        self.cache.borrow_mut().insert(key.to_string(), executable.clone());
        Ok(executable)
    }

    /// True if the manifest has an entry for `key`.
    pub fn has_entry(&self, key: &str) -> bool {
        self.manifest.entries.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    //! Integration tests (need `make artifacts` first; skipped otherwise).
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping runtime test: no artifacts (run `make artifacts`)");
            return None;
        }
        Some(Runtime::new(dir.to_str().unwrap()).unwrap())
    }

    #[test]
    fn act_executes_and_is_deterministic() {
        let Some(rt) = runtime() else { return };
        if !rt.has_entry("eat_n8l8_act") {
            return;
        }
        let exe = rt.load("eat_n8l8_act").unwrap();
        let p = rt.manifest.param("eat_n8l8").unwrap().clone();
        let actor = rt.manifest.load_init("eat_n8l8", "actor").unwrap();
        let state = vec![0.25f32; p.state_dim];
        let chain = vec![0.1f32; p.chain_steps * p.action_dim];
        let expl = vec![0.0f32; p.action_dim];
        let out1 = exe.run(&[&actor, &state, &chain, &expl]).unwrap();
        let out2 = exe.run(&[&actor, &state, &chain, &expl]).unwrap();
        assert_eq!(out1.len(), 3);
        assert_eq!(out1[0].len(), p.action_dim);
        assert_eq!(out1[0], out2[0], "same inputs must give same action");
        assert!(out1[0].iter().all(|x| x.is_finite() && x.abs() <= 1.0));
    }

    #[test]
    fn run_rejects_wrong_arity_and_shape() {
        let Some(rt) = runtime() else { return };
        if !rt.has_entry("eat_n8l8_act") {
            return;
        }
        let exe = rt.load("eat_n8l8_act").unwrap();
        assert!(exe.run(&[&[0.0f32]]).is_err());
        let p = rt.manifest.param("eat_n8l8").unwrap().clone();
        let actor = rt.manifest.load_init("eat_n8l8", "actor").unwrap();
        let bad_state = vec![0.0f32; p.state_dim + 1];
        let chain = vec![0.0f32; p.chain_steps * p.action_dim];
        let expl = vec![0.0f32; p.action_dim];
        assert!(exe.run(&[&actor, &bad_state, &chain, &expl]).is_err());
    }

    #[test]
    fn executable_cache_hits() {
        let Some(rt) = runtime() else { return };
        if !rt.has_entry("eat_n8l8_act") {
            return;
        }
        let a = rt.load("eat_n8l8_act").unwrap();
        let b = rt.load("eat_n8l8_act").unwrap();
        assert!(Rc::ptr_eq(&a, &b));
    }
}
