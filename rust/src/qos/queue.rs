//! Deadline-aware pending queue: EDF within a tier, smooth weighted round
//! robin (SWRR) across tiers.
//!
//! [`EdfWfqQueue`] is the raw structure — one ordered set per priority
//! tier, keyed by (deadline, insertion seq), with SWRR credits deciding
//! which tier serves next. Push/pop are O(log n) plus O(#tiers), so a
//! million-task backlog stays cheap (see `benches/bench_qos.rs`).
//!
//! [`PendingQueue`] adapts it to `EdgeEnv`, which exposes the queue to
//! policies as an indexable `VecDeque<Task>` (the top-l slots of the state
//! matrix). In FIFO mode it *is* the seed's `VecDeque` — bit-identical
//! behaviour when no tenants are configured. In QoS mode it keeps a
//! materialised view in dequeue order, rebuilt after each mutation (queue
//! depths at the env's decision cadence are small; the raw structure is
//! what the overload benchmarks exercise).

use super::TenantRegistry;
use crate::sim::task::Task;
use std::collections::{BTreeMap, VecDeque};

/// Sort key inside a tier: (deadline bits, insertion sequence). Deadlines
/// are finite and non-negative, so `f64::to_bits` is order-preserving;
/// deadline-less tasks sort last (FIFO among themselves via the seq).
fn deadline_key(task: &Task) -> u64 {
    task.deadline.map_or(u64::MAX, |d| d.max(0.0).to_bits())
}

/// Per-tier EDF sets with smooth-weighted-round-robin service order across
/// tiers. Service share of a continuously backlogged tier converges to its
/// weight fraction; within a tier, earlier deadlines always serve first.
#[derive(Clone, Debug)]
pub struct EdfWfqQueue {
    tiers: Vec<BTreeMap<(u64, u64), Task>>,
    weights: Vec<f64>,
    credits: Vec<f64>,
    seq: u64,
    len: usize,
}

impl EdfWfqQueue {
    /// One entry per tier; `weights[i]` is tier i's service weight.
    pub fn new(weights: Vec<f64>) -> EdfWfqQueue {
        assert!(!weights.is_empty(), "need at least one tier");
        assert!(
            weights.iter().all(|&w| w > 0.0 && w.is_finite()),
            "tier weights must be positive and finite"
        );
        EdfWfqQueue {
            tiers: weights.iter().map(|_| BTreeMap::new()).collect(),
            credits: vec![0.0; weights.len()],
            weights,
            seq: 0,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn num_tiers(&self) -> usize {
        self.tiers.len()
    }

    /// Insert a task into `tier` (clamped to the last tier).
    pub fn push(&mut self, tier: usize, task: Task) {
        let tier = tier.min(self.tiers.len() - 1);
        self.seq += 1;
        self.tiers[tier].insert((deadline_key(&task), self.seq), task);
        self.len += 1;
    }

    /// One SWRR step over the currently non-empty tiers: add each tier's
    /// weight to its credit, serve the highest credit (ties to the lower,
    /// i.e. higher-priority, tier), and charge it the round's total.
    fn swrr_step(credits: &mut [f64], weights: &[f64], remaining: &[usize]) -> Option<usize> {
        let mut total = 0.0;
        let mut best: Option<usize> = None;
        for i in 0..weights.len() {
            if remaining[i] == 0 {
                continue;
            }
            total += weights[i];
            credits[i] += weights[i];
            if best.map_or(true, |b| credits[i] > credits[b]) {
                best = Some(i);
            }
        }
        let b = best?;
        credits[b] -= total;
        Some(b)
    }

    /// Replay the SWRR step the `order` walk would have taken, but forced
    /// onto `chosen` (the policy may schedule any visible slot, not just
    /// the head; the chosen tier still pays for the service it received).
    fn swrr_charge(&mut self, chosen: usize) {
        let mut total = 0.0;
        for i in 0..self.weights.len() {
            if self.tiers[i].is_empty() && i != chosen {
                continue;
            }
            total += self.weights[i];
            self.credits[i] += self.weights[i];
        }
        self.credits[chosen] -= total;
    }

    /// The first `k` (tier, key) pairs in dequeue order, without mutating
    /// the queue. Within each tier the keys come out EDF-sorted.
    pub fn order(&self, k: usize) -> Vec<(usize, (u64, u64))> {
        let k = k.min(self.len);
        let mut out = Vec::with_capacity(k);
        if k == 0 {
            return out;
        }
        let mut credits = self.credits.clone();
        // Only the first k keys of a tier can appear in a k-step walk, so
        // the collection cost is O(min(n, k) · tiers), not O(n).
        let keys: Vec<Vec<(u64, u64)>> = self
            .tiers
            .iter()
            .map(|m| m.keys().take(k).copied().collect())
            .collect();
        let mut cursor = vec![0usize; self.tiers.len()];
        let mut remaining: Vec<usize> = self.tiers.iter().map(BTreeMap::len).collect();
        while out.len() < k {
            let Some(t) = Self::swrr_step(&mut credits, &self.weights, &remaining) else {
                break;
            };
            out.push((t, keys[t][cursor[t]]));
            cursor[t] += 1;
            remaining[t] -= 1;
        }
        out
    }

    pub fn get(&self, tier: usize, key: &(u64, u64)) -> Option<&Task> {
        self.tiers.get(tier)?.get(key)
    }

    /// Remove the `n`-th task in dequeue order, charging its tier one SWRR
    /// service round.
    pub fn remove_nth(&mut self, n: usize) -> Option<Task> {
        if n >= self.len {
            return None;
        }
        let (tier, key) = *self.order(n + 1).last()?;
        self.swrr_charge(tier);
        let task = self.tiers[tier].remove(&key)?;
        self.len -= 1;
        Some(task)
    }

    /// Dequeue the head task (the next one SWRR + EDF would serve).
    /// O(#tiers + log n) — the hot path under a large backlog; credit
    /// accounting is identical to `remove_nth(0)`.
    pub fn pop(&mut self) -> Option<Task> {
        let remaining: Vec<usize> = self.tiers.iter().map(BTreeMap::len).collect();
        let t = Self::swrr_step(&mut self.credits, &self.weights, &remaining)?;
        let key = *self.tiers[t].keys().next()?;
        let task = self.tiers[t].remove(&key)?;
        self.len -= 1;
        Some(task)
    }

    /// Iterate every queued task (arbitrary order; aggregate statistics).
    pub fn iter_all(&self) -> impl Iterator<Item = &Task> {
        self.tiers.iter().flat_map(|m| m.values())
    }
}

/// The env-facing pending queue: the seed's FIFO `VecDeque` when no
/// tenants are configured (bit-identical behaviour), or an [`EdfWfqQueue`]
/// with a materialised dequeue-order view under a QoS discipline.
#[derive(Clone, Debug)]
pub struct PendingQueue {
    mode: Mode,
}

#[derive(Clone, Debug)]
enum Mode {
    Fifo(VecDeque<Task>),
    Qos {
        inner: EdfWfqQueue,
        registry: TenantRegistry,
        view: VecDeque<Task>,
    },
}

impl PendingQueue {
    pub fn fifo() -> PendingQueue {
        PendingQueue {
            mode: Mode::Fifo(VecDeque::new()),
        }
    }

    pub fn qos(registry: TenantRegistry) -> PendingQueue {
        let inner = EdfWfqQueue::new(registry.queue_weights().to_vec());
        PendingQueue {
            mode: Mode::Qos {
                inner,
                registry,
                view: VecDeque::new(),
            },
        }
    }

    fn rebuild(inner: &EdfWfqQueue, view: &mut VecDeque<Task>) {
        view.clear();
        for (tier, key) in inner.order(inner.len()) {
            if let Some(t) = inner.get(tier, &key) {
                view.push_back(t.clone());
            }
        }
    }

    pub fn push(&mut self, task: Task) {
        self.push_lazy(task);
        self.commit();
    }

    /// Insert without refreshing the materialised view — for absorbing
    /// arrival batches without an O(n) rebuild per task. `len()` and
    /// `is_empty()` stay exact; call [`commit`](Self::commit) before the
    /// view is next read.
    pub fn push_lazy(&mut self, task: Task) {
        match &mut self.mode {
            Mode::Fifo(q) => q.push_back(task),
            Mode::Qos {
                inner, registry, ..
            } => {
                let tier = registry.tier_slot(task.tenant);
                inner.push(tier, task);
            }
        }
    }

    /// Refresh the materialised view after a `push_lazy` batch (no-op in
    /// FIFO mode, where the deque is always current).
    pub fn commit(&mut self) {
        if let Mode::Qos { inner, view, .. } = &mut self.mode {
            Self::rebuild(inner, view);
        }
    }

    /// Re-admit a task whose gang was killed mid-flight, deadline-aware:
    /// under EDF/WFQ it re-enters its tier keyed by its (unchanged)
    /// deadline, so an urgent retry overtakes laxer work automatically; in
    /// FIFO mode it goes to the *front* — it arrived before everything
    /// queued behind it, and a retry that re-waits the whole queue would
    /// starve under churn.
    pub fn push_retry(&mut self, task: Task) {
        match &mut self.mode {
            Mode::Fifo(q) => q.push_front(task),
            Mode::Qos {
                inner,
                registry,
                view,
            } => {
                let tier = registry.tier_slot(task.tenant);
                inner.push(tier, task);
                Self::rebuild(inner, view);
            }
        }
    }

    /// Remove the task at visible position `index` (dequeue order).
    pub fn remove(&mut self, index: usize) -> Option<Task> {
        match &mut self.mode {
            Mode::Fifo(q) => q.remove(index),
            Mode::Qos { inner, view, .. } => {
                let task = inner.remove_nth(index)?;
                Self::rebuild(inner, view);
                Some(task)
            }
        }
    }

    pub fn len(&self) -> usize {
        match &self.mode {
            Mode::Fifo(q) => q.len(),
            Mode::Qos { inner, .. } => inner.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The queue in dequeue order, as the env exposes it to policies.
    pub fn items(&self) -> &VecDeque<Task> {
        match &self.mode {
            Mode::Fifo(q) => q,
            Mode::Qos { view, .. } => view,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qos::TenantsConfig;
    use crate::sim::task::ModelType;

    fn task(id: u64, tenant: Option<u32>, deadline: Option<f64>) -> Task {
        Task {
            id,
            prompt_id: id,
            patches: 2,
            model: ModelType(0),
            arrival: 0.0,
            q_min: None,
            tenant,
            deadline,
        }
    }

    #[test]
    fn single_tier_is_pure_edf() {
        let mut q = EdfWfqQueue::new(vec![1.0]);
        q.push(0, task(0, None, Some(30.0)));
        q.push(0, task(1, None, Some(10.0)));
        q.push(0, task(2, None, Some(20.0)));
        q.push(0, task(3, None, None)); // deadline-less tasks go last
        let ids: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|t| t.id).collect();
        assert_eq!(ids, vec![1, 2, 0, 3]);
        assert!(q.is_empty());
    }

    #[test]
    fn equal_deadlines_fall_back_to_fifo() {
        let mut q = EdfWfqQueue::new(vec![1.0]);
        for id in 0..5 {
            q.push(0, task(id, None, Some(50.0)));
        }
        let ids: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|t| t.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn swrr_serves_tiers_proportionally() {
        // Weights 3:1 with both tiers continuously backlogged: the serve
        // pattern repeats with exactly 3 tier-0 serves per tier-1 serve.
        let mut q = EdfWfqQueue::new(vec![3.0, 1.0]);
        for id in 0..400u64 {
            q.push((id % 2) as usize, task(id, None, Some(id as f64)));
        }
        let (mut t0, mut t1) = (0usize, 0usize);
        for _ in 0..200 {
            let t = q.pop().unwrap();
            if t.id % 2 == 0 {
                t0 += 1;
            } else {
                t1 += 1;
            }
        }
        assert!((148..=152).contains(&t0), "tier0 served {t0}/200");
        assert!((48..=52).contains(&t1), "tier1 served {t1}/200");
    }

    #[test]
    fn empty_tiers_cede_their_share() {
        let mut q = EdfWfqQueue::new(vec![3.0, 1.0]);
        for id in 0..10u64 {
            q.push(1, task(id, None, Some(id as f64)));
        }
        // Tier 0 is empty: tier 1 gets every slot, in EDF order.
        let ids: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|t| t.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn remove_nth_matches_order() {
        let mut q = EdfWfqQueue::new(vec![2.0, 1.0]);
        for id in 0..12u64 {
            q.push((id % 2) as usize, task(id, None, Some((100 - id) as f64)));
        }
        let ord = q.order(q.len());
        assert_eq!(ord.len(), 12);
        // Removing position 3 yields exactly the task order() promised.
        let expect_id = q.get(ord[3].0, &ord[3].1).unwrap().id;
        let got = q.remove_nth(3).unwrap();
        assert_eq!(got.id, expect_id);
        assert_eq!(q.len(), 11);
    }

    #[test]
    fn order_is_edf_within_each_tier() {
        let mut q = EdfWfqQueue::new(vec![5.0, 2.0, 1.0]);
        let deadlines = [40.0, 10.0, 90.0, 20.0, 70.0, 30.0, 60.0, 50.0, 80.0];
        for (i, &d) in deadlines.iter().enumerate() {
            q.push(i % 3, task(i as u64, None, Some(d)));
        }
        let mut last = vec![(0u64, 0u64); 3];
        for (tier, key) in q.order(q.len()) {
            assert!(key >= last[tier], "tier {tier} order inverted");
            last[tier] = key;
        }
    }

    fn three_tier_registry() -> TenantRegistry {
        let cfg = TenantsConfig::three_tier(0.3);
        TenantRegistry::new(&cfg)
    }

    #[test]
    fn pending_queue_fifo_matches_vecdeque() {
        let mut q = PendingQueue::fifo();
        for id in 0..4 {
            q.push(task(id, None, None));
        }
        assert_eq!(q.len(), 4);
        assert_eq!(q.items()[0].id, 0);
        let removed = q.remove(2).unwrap();
        assert_eq!(removed.id, 2);
        assert_eq!(q.items().iter().map(|t| t.id).collect::<Vec<_>>(), vec![0, 1, 3]);
    }

    #[test]
    fn pending_queue_qos_orders_view_by_discipline() {
        let reg = three_tier_registry();
        let mut q = PendingQueue::qos(reg);
        // Batch (tenant 2) arrives first, premium (tenant 0) second with a
        // later wall-clock deadline — premium's tier still serves first.
        q.push(task(0, Some(2), Some(50.0)));
        q.push(task(1, Some(0), Some(120.0)));
        assert_eq!(q.items()[0].id, 1, "premium tier must head the queue");
        let got = q.remove(0).unwrap();
        assert_eq!(got.id, 1);
        assert_eq!(q.items()[0].id, 0);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn push_lazy_defers_view_until_commit() {
        let reg = three_tier_registry();
        let mut q = PendingQueue::qos(reg);
        q.push_lazy(task(0, Some(1), Some(40.0)));
        q.push_lazy(task(1, Some(0), Some(90.0)));
        // Length is exact immediately; the view refreshes on commit.
        assert_eq!(q.len(), 2);
        assert!(q.items().is_empty());
        q.commit();
        assert_eq!(q.items().len(), 2);
        assert_eq!(q.items()[0].id, 1, "premium heads the committed view");
        // FIFO mode needs no commit.
        let mut f = PendingQueue::fifo();
        f.push_lazy(task(7, None, None));
        assert_eq!(f.items().len(), 1);
        f.commit();
        assert_eq!(f.items().len(), 1);
    }

    #[test]
    fn push_retry_is_deadline_aware() {
        // FIFO: the retried task jumps the queue (it arrived first).
        let mut q = PendingQueue::fifo();
        q.push(task(0, None, None));
        q.push(task(1, None, None));
        q.push_retry(task(9, None, None));
        assert_eq!(q.items()[0].id, 9);
        // EDF: the retried task slots in by its unchanged deadline, ahead
        // of laxer work and behind more urgent work in the same tier.
        let reg = three_tier_registry();
        let mut q = PendingQueue::qos(reg);
        q.push(task(0, Some(0), Some(10.0)));
        q.push(task(1, Some(0), Some(90.0)));
        q.push_retry(task(9, Some(0), Some(50.0)));
        let ids: Vec<u64> = q.items().iter().map(|t| t.id).collect();
        assert_eq!(ids, vec![0, 9, 1]);
    }

    #[test]
    fn pending_queue_untenanted_tasks_use_fallback_tier() {
        let reg = three_tier_registry();
        let mut q = PendingQueue::qos(reg);
        q.push(task(0, None, None));
        q.push(task(1, Some(0), Some(60.0)));
        assert_eq!(q.len(), 2);
        // Premium outranks the untenanted fallback tier.
        assert_eq!(q.items()[0].id, 1);
    }
}
